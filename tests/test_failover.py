"""Replication, crash-consistency and failover (the robustness PR).

Covers the replicated-shard overhaul end to end:

  * ring successors: K distinct live replicas per primary, deterministic;
  * primary-backup over the host wire: a write ack releases only after
    every replica holds the bytes (ack-hold), so an acked write survives
    the primary's crash;
  * crash-consistent apply: the redo journal turns a coalesced run into
    journal-writev -> single-slot commit flip -> in-place writev, so a
    power-fail at ANY device op leaves each file fully pre- or fully
    post-run — never torn (torn-writev injection + recovery mount);
  * the supervisor: tick-clock heartbeats, deterministic detection,
    replica promotion, ring repair and epoch bump;
  * client transparency: the epoch fence refuses stale-epoch packets with
    retryable redirects; all three clients (DDSClient, ClusterClient,
    KVClient) replay against the repaired ring with the same request ids;
  * a property-style crash sweep: kill each shard at a range of ticks
    across a deterministic run — zero lost acknowledged writes;
  * KV promotion: the adopted log copy rebuilds the index, stale DPU
    cache-table entries are replaced, adopted invalidation views work;
  * shed retry with bounded exponential backoff honoring ``retry_after``.
"""

import pytest

from repro.core import wire
from repro.core.client import ClusterClient
from repro.core.dds_server import DDSClient, DDSStorageServer, ServerConfig
from repro.core.file_service import FileServiceRunner, SegmentFS
from repro.core.host_lib import DDSFrontEnd
from repro.core.qos import QoSProfile
from repro.core.ring import DMAEngine
from repro.distributed.cluster import DDSCluster
from repro.distributed.fault_tolerance import HeartbeatMonitor
from repro.apps.kv_store import (KVClient, KVLocation, ShardedKVStore,
                                 decode_record)
from repro.storage.blockdev import BlockDevice

RCFG = dict(replication=1, heartbeat_timeout_ticks=6)


def make_cluster(num_shards=3, **over):
    kw = dict(RCFG)
    kw.update(over)
    return DDSCluster(num_shards, ServerConfig(**kw))


# ---------------------------------------------------------------------------
# Ring successors + replica placement
# ---------------------------------------------------------------------------


def test_ring_successors_distinct_and_deterministic():
    cl = make_cluster(4, replication=2)
    for i in range(4):
        succ = cl.ring.successors(i, 2)
        assert len(succ) == 2
        assert i not in succ
        assert len(set(succ)) == 2
        assert succ == cl.ring.successors(i, 2)  # stable
    # K is clamped to num_shards - 1
    assert len(cl.ring.successors(0, 99)) == 3


def test_replication_clamped_and_disabled_paths():
    # A single shard cannot replicate; an unreplicated cluster arms nothing.
    solo = DDSCluster(1, ServerConfig(replication=2))
    assert solo.replication == 0 and solo.supervisor is None
    plain = DDSCluster(2, ServerConfig())
    assert plain.supervisor is None
    assert all(s.replicator is None for s in plain.servers)


def test_create_file_places_replicas_on_successors():
    cl = make_cluster(3)
    g = cl.create_file("data")
    loc = cl.locate(g)
    assert set(loc.replicas) == {cl.ring.successors(loc.shard, 1)[0]}
    # control-plane bulk load mirrors onto the replica directly
    cl.write_sync(g, 0, b"seed" * 64)
    (t, rlfid), = loc.replicas.items()
    assert cl.servers[t].frontend.read_sync(rlfid, 0, 256) == b"seed" * 64


# ---------------------------------------------------------------------------
# Primary-backup forwarding + ack-hold
# ---------------------------------------------------------------------------


def test_wire_write_forwarded_before_ack_releases():
    cl = make_cluster(3)
    g = cl.create_file("x")
    c = ClusterClient(cl)
    rid = c.write(g, 0, b"A" * 512)
    assert c.harvest([rid])[rid] == (wire.E_OK, b"")
    loc = cl.locate(g)
    (t, rlfid), = loc.replicas.items()
    cl.run_until_idle()
    # the ack implies the replica holds the bytes
    assert cl.servers[t].frontend.read_sync(rlfid, 0, 512) == b"A" * 512
    repl = cl.servers[loc.shard].replicator
    assert repl.forwarded == 1 and repl.forwarded_bytes == 512
    assert repl.lag.n == 1
    stats = cl.latency_stats()
    assert stats["replication"]["forwarded"] == 1


def test_reads_are_not_forwarded():
    cl = make_cluster(3)
    g = cl.create_file("x")
    cl.write_sync(g, 0, b"r" * 128)
    c = ClusterClient(cl)
    rid = c.read(g, 0, 128)
    assert c.harvest([rid])[rid] == (wire.E_OK, b"r" * 128)
    assert cl.locate(g) is not None
    repl = cl.servers[cl.locate(g).shard].replicator
    assert repl.forwarded == 0  # the bulk-load mirror bypassed the wire


# ---------------------------------------------------------------------------
# Crash-consistent apply: redo journal + torn-writev injection
# ---------------------------------------------------------------------------


def _journal_stack(segment_size=1 << 16):
    dev = BlockDevice(1 << 22, block_size=512)
    fs = SegmentFS(dev, segment_size, journal_segments=2)
    svc = FileServiceRunner(fs, DMAEngine())
    fe = DDSFrontEnd(svc, ring_capacity=1 << 14)
    return dev, fs, svc, fe


def _drive_until_crash(svc, dev, budget=500):
    for _ in range(budget):
        if dev.crashed:
            return
        svc.step()
        if not dev.crashed:
            dev.poll(64)
    assert dev.crashed, "injected tear never fired"


@pytest.mark.parametrize("tear_op,expect_new", [
    (1, False),   # journal record itself torn: commit never lands -> OLD
    (2, True),    # in-place writev torn after commit: replay -> NEW
])
def test_torn_writev_leaves_file_pre_or_post_never_torn(tear_op, expect_new):
    dev, fs, svc, fe = _journal_stack()
    fid = fe.create_file("t")
    old_a, old_b = b"\xAA" * 2048, b"\xAB" * 2048
    fe.write_sync(fid, 0, old_a + old_b)
    new_a, new_b = b"\xBA" * 2048, b"\xBB" * 2048
    # Two adjacent writes coalesce into ONE run = one journal record = one
    # in-place writev with two gathered chunks (the satellite-3 hazard:
    # a coalesced run must flip atomically, not per source buffer).
    dev.inject_torn_writev(nth=tear_op, chunks=1)
    fe.submit_many([("w", fid, 0, new_a), ("w", fid, 2048, new_b)])
    _drive_until_crash(svc, dev)

    # Recovery mount on the survived media.
    fs2 = SegmentFS.mount(dev, 1 << 16, journal_segments=2)
    rec = fs2.recover_journal()
    phys = fs2.files[fid].segments[0] * (1 << 16)
    got = dev.raw_read(phys, 4096)
    want = (new_a + new_b) if expect_new else (old_a + old_b)
    assert got == want
    assert got in (old_a + old_b, new_a + new_b)   # never torn
    # The initial write_sync journaled one committed record; the torn run
    # adds a second only when its commit flip landed before the tear.
    assert rec["records"] == (2 if expect_new else 1)
    assert fs2.journal_replayed_records == rec["records"]


def test_torn_inplace_write_is_visibly_torn_without_recovery():
    """Sanity of the fault model itself: the tear DOES corrupt media (half
    the coalesced run landed) — recovery is what un-tears it."""
    dev, fs, svc, fe = _journal_stack()
    fid = fe.create_file("t")
    fe.write_sync(fid, 0, b"\x00" * 4096)
    dev.inject_torn_writev(nth=2, chunks=1)
    fe.submit_many([("w", fid, 0, b"\x11" * 2048),
                    ("w", fid, 2048, b"\x22" * 2048)])
    _drive_until_crash(svc, dev)
    phys = fs.files[fid].segments[0] * (1 << 16)
    raw = dev.raw_read(phys, 4096)
    assert raw[:2048] == b"\x11" * 2048      # first chunk landed
    assert raw[2048:] == b"\x00" * 2048      # second did not: torn
    fs2 = SegmentFS.mount(dev, 1 << 16, journal_segments=2)
    assert fs2.recover_journal()["records"] == 2   # seed write + torn run
    assert dev.raw_read(phys, 4096) == b"\x11" * 2048 + b"\x22" * 2048


# ---------------------------------------------------------------------------
# Detection + promotion
# ---------------------------------------------------------------------------


def test_heartbeat_on_ticks_is_deterministic():
    class Clock:
        now = 0
    clock = Clock()
    mon = HeartbeatMonitor.on_ticks(["a", "b"], clock, timeout_ticks=5)
    mon.beat("a", 0)
    mon.beat("b", 0)
    clock.now = 5
    assert mon.dead_hosts() == []       # exactly at timeout: still alive
    clock.now = 6
    assert mon.dead_hosts() == ["a", "b"]


def test_supervisor_detects_crash_and_promotes_deterministically():
    cl = make_cluster(3)
    g = cl.create_file("x")
    cl.write_sync(g, 0, b"D" * 128)
    victim = cl.locate(g).shard
    cl.crash(victim)
    crash_tick = cl.clock.now
    for _ in range(20):
        cl.pump()
    assert len(cl.failover_events) == 1
    ev = cl.failover_events[0]
    assert ev["dead"] == victim and ev["epoch"] == 1
    # detection latency == miss_windows * (heartbeat_timeout_ticks + 1)
    # pumps, exactly — the first silent window only notes a miss, the
    # second consecutive one promotes.
    assert ev["tick"] == crash_tick + 2 * (RCFG["heartbeat_timeout_ticks"] + 1)
    loc = cl.locate(g)
    assert loc.shard == ev["promoted"] and loc.shard != victim
    assert cl.servers[loc.shard].frontend.read_sync(
        loc.local_fid, 0, 128) == b"D" * 128
    assert cl.route_of(victim) == ev["promoted"]
    stats = cl.latency_stats()
    assert stats["failover"]["epoch"] == 1
    assert stats["failover"]["events"] == cl.failover_events


def test_crash_at_schedules_deterministic_kill():
    cl = make_cluster(3)
    cl.crash_at(1, 10)
    while cl.clock.now < 9:
        cl.pump()
    assert 1 not in cl._dead
    cl.pump()
    assert 1 in cl._dead


# ---------------------------------------------------------------------------
# Client transparency: epoch fence + redirect replay (all three clients)
# ---------------------------------------------------------------------------


def test_epoch_fence_redirect_roundtrip_ddsclient():
    srv = DDSStorageServer(ServerConfig())
    fid = srv.frontend.create_file("e")
    srv.frontend.write_sync(fid, 0, b"E" * 64)
    srv.run_until_idle()
    srv.director.epoch_of = lambda: 3
    srv.director.on_stale_epoch = srv._on_stale_epoch
    c = DDSClient(srv)
    c.epoch = 1                      # stale: fence must refuse + redirect
    rid = c.read(fid, 0, 64)
    status, body = c.wait(rid)
    assert (status, body) == (wire.E_OK, b"E" * 64)   # transparent replay
    assert c.epoch == 3              # adopted the advertised epoch
    assert srv.lifecycle.redirects >= 1


def test_cluster_client_replays_through_failover():
    cl = make_cluster(3)
    files = [cl.create_file(f"f{i}") for i in range(12)]
    c = ClusterClient(cl)
    rids = c.submit([("w", g, 0, bytes([i + 1]) * 128)
                     for i, g in enumerate(files)])
    res = c.harvest(rids)
    assert all(v == (wire.E_OK, b"") for v in res.values())
    victim = cl.locate(files[0]).shard
    reads = c.submit([("r", g, 0, 128) for g in files])
    cl.crash(victim)                 # mid-flight
    res = c.harvest(reads)
    for i, rid in enumerate(reads):
        assert res[rid] == (wire.E_OK, bytes([i + 1]) * 128)
    assert cl.epoch == 1 and c._epoch_seen == 1
    assert all(conn.epoch == 1 for conn in c.conns)


def test_kv_client_failover_with_cache_invalidation_and_adoption():
    store = ShardedKVStore(3, ServerConfig(**RCFG))
    c = KVClient(store)
    keys = [f"k{i:03d}".encode() for i in range(30)]
    res = c.harvest(c.submit([("put", k, b"v-" + k) for k in keys]))
    assert all(s == wire.E_OK for s, _ in res.values())
    res = c.harvest(c.submit([("get", k) for k in keys]))  # warm DPU cache
    assert all(s == wire.E_OK for s, _ in res.values())

    victims = {store.shard_for_key(k) for k in keys}
    victim = sorted(victims)[0]
    vkeys = [k for k in keys if store.shard_for_key(k) == victim]
    assert vkeys
    promoted = store.cluster.ring.successors(victim, 1)[0]
    # Plant a STALE DPU cache entry for an adopted key on the promotion
    # target: promotion must replace it, or the DPU would serve garbage.
    table = store.cluster.servers[promoted].cache_table
    table.insert(vkeys[0], KVLocation(999, 0, 8))

    reads = c.submit([("get", k) for k in keys])
    store.cluster.crash(victim)
    res = c.harvest(reads)
    for k, rid in zip(keys, reads):
        status, body = res[rid]
        assert status == wire.E_OK
        assert decode_record(body)[1] == b"v-" + k
    assert store.cluster.failover_events[0]["promoted"] == promoted
    # the stale entry was replaced with the adopted-log location
    loc = table.lookup(vkeys[0])
    assert loc is not None and loc.file_id != 999
    st = store._states[promoted]
    assert st.adopted_records == len(vkeys)
    assert loc.file_id in st.adopted
    # key->shard cache re-routes to the promoted shard post-epoch-bump
    assert c._shard(vkeys[0]) == promoted

    # overwrite an adopted key (appends to the promoted shard's OWN log),
    # then delete it: both exercise the cross-fid invalidation view.
    r = c.put(vkeys[0], b"NEW")
    assert c.harvest([r])[r][0] == wire.E_OK
    r = c.get(vkeys[0])
    assert decode_record(c.harvest([r])[r][1])[1] == b"NEW"
    r = c.delete(vkeys[0])
    assert c.harvest([r])[r][0] == wire.E_OK
    r = c.get(vkeys[0])
    assert c.harvest([r])[r][0] == wire.E_NOENT


# ---------------------------------------------------------------------------
# Property-style crash sweep: zero lost acknowledged writes
# ---------------------------------------------------------------------------


def _crash_run(victim: int, crash_delay: int):
    """One deterministic run: write, kill ``victim`` ``crash_delay`` ticks
    into the read+write wave, verify every acked write is readable."""
    cl = make_cluster(3)
    files = [cl.create_file(f"f{i}") for i in range(9)]
    c = ClusterClient(cl)
    res = c.harvest(c.submit([("w", g, 0, bytes([i + 1]) * 64)
                              for i, g in enumerate(files)]))
    assert all(v[0] == wire.E_OK for v in res.values())
    crash_tick = cl.clock.now + crash_delay
    cl.crash_at(victim, crash_tick)
    wave = c.submit([("w", g, 64, bytes([i + 33]) * 64)
                     for i, g in enumerate(files)]
                    + [("r", g, 0, 64) for g in files])
    res = c.harvest(wave)
    # K=1, one crash: the repaired ring serves everything — no lost acks,
    # no spurious errors, reads see phase-1 bytes.
    for i, rid in enumerate(wave[:9]):
        assert res[rid] == (wire.E_OK, b""), (victim, crash_delay, i)
    for i, rid in enumerate(wave[9:]):
        assert res[rid] == (wire.E_OK, bytes([i + 1]) * 64), \
            (victim, crash_delay, i)
    # Let the kill + detection complete even when the wave outran the
    # scheduled crash tick (a victim without in-flight traffic blocks no
    # harvest, so the wave can finish pre-crash).
    deadline = crash_tick + 2 * (RCFG["heartbeat_timeout_ticks"] + 1) + 5
    while cl.clock.now < deadline:
        cl.pump()
    # every phase-2 ack readable post-failover
    res = c.harvest(c.submit([("r", g, 64, 64) for g in files]))
    for i, rid in enumerate(sorted(res)):
        assert res[rid] == (wire.E_OK, bytes([i + 33]) * 64)
    return cl.failover_events


@pytest.mark.parametrize("victim", [0, 1, 2])
@pytest.mark.parametrize("crash_delay", [0, 3, 8, 17])
def test_crash_sweep_zero_lost_acked_writes(victim, crash_delay):
    events = _crash_run(victim, crash_delay)
    assert len(events) == 1 and events[0]["dead"] == victim


def test_crash_run_is_deterministic():
    assert _crash_run(1, 3) == _crash_run(1, 3)


# ---------------------------------------------------------------------------
# Satellite 2: shed retry with bounded exponential backoff
# ---------------------------------------------------------------------------


def test_shed_retry_backoff_recovers_within_cap():
    cl = DDSCluster(1, ServerConfig(
        device_capacity=1 << 24,
        qos=QoSProfile(tenant_rates={7: 1.0}, tenant_bursts={7: 2.0})))
    g = cl.create_file("s")
    cl.write_sync(g, 0, b"\x01" * 4096)
    c = ClusterClient(cl, tenant=7, retry_attempts=5)
    rids = c.submit([("r", g, 0, 64)] * 6)    # burst 2.0: 4 shed initially
    res = c.harvest(rids)
    # ... but the bucket refills at 1/tick and the bounded-backoff retry
    # resubmits with the server's retry_after honored: all succeed.
    assert all(v == (wire.E_OK, b"\x01" * 64) for v in res.values())
    assert cl.servers[0].admission.summary()["shed"] >= 4   # retries happened


def test_shed_retry_cap_surfaces_terminal_error():
    cl = DDSCluster(1, ServerConfig(
        device_capacity=1 << 24,
        qos=QoSProfile(tenant_rates={7: 0.05}, tenant_bursts={7: 1.0})))
    g = cl.create_file("s")
    cl.write_sync(g, 0, b"\x01" * 4096)
    c = ClusterClient(cl, tenant=7, retry_attempts=1)
    rids = c.submit([("r", g, 0, 64)] * 4)
    res = c.harvest(rids)
    statuses = sorted(s for s, _ in res.values())
    assert wire.E_SHED in statuses            # cap exhausted: terminal shed
    assert wire.E_OK in statuses              # the granted ones served
    for s, body in res.values():
        if s == wire.E_SHED:
            tenant, ra = wire.decode_shed_hint(body)
            assert tenant == 7 and ra >= 1
    assert c.outstanding() == 0               # nothing leaked


def test_retry_disabled_surfaces_shed_immediately():
    cl = DDSCluster(1, ServerConfig(
        device_capacity=1 << 24,
        qos=QoSProfile(tenant_rates={7: 1.0}, tenant_bursts={7: 2.0})))
    g = cl.create_file("s")
    cl.write_sync(g, 0, b"\x01" * 4096)
    c = ClusterClient(cl, tenant=7)           # retry_attempts=0
    res = c.harvest(c.submit([("r", g, 0, 64)] * 6))
    assert sum(1 for s, _ in res.values() if s == wire.E_SHED) == 4


# ---------------------------------------------------------------------------
# Batch-checksum integrity gate: corrupted writev bytes are DETECTED —
# neither served to a reader nor replayed out of the journal.
# ---------------------------------------------------------------------------


def test_corrupted_writev_media_fails_reads_with_eio():
    from repro.storage.blockdev import STATUS_EIO, STATUS_OK
    dev, fs, svc, fe = _journal_stack()
    dev.enable_checksums()
    fid = fe.create_file("t")
    fe.write_sync(fid, 0, b"\xC3" * 4096)
    phys = fs.files[fid].segments[0] * (1 << 16)
    assert dev.verify_blocks() == 0        # journaled run committed its CRCs

    dev._mem[phys + 123] ^= 0x01           # single-bit rot inside the run
    assert dev.verify_blocks() == 1        # exactly one block flagged

    sts, dst = [], memoryview(bytearray(4096))
    dev.submit_read(phys, 4096, dst, on_complete=sts.append)
    dev.poll()
    assert sts == [STATUS_EIO]
    assert bytes(dst) == bytes(4096)       # corrupt bytes never delivered
    assert dev.stats.crc_read_failures == 1

    # Rewriting the span re-commits: the same read succeeds again.
    fe.write_sync(fid, 0, b"\xC4" * 4096)
    sts2 = []
    dev.submit_read(phys, 4096, dst, on_complete=sts2.append)
    dev.poll()
    assert sts2 == [STATUS_OK] and bytes(dst) == b"\xC4" * 4096


def test_corrupted_journal_record_is_refused_at_recovery():
    from repro.core.file_service import _JREC
    dev, fs, svc, fe = _journal_stack()
    fid = fe.create_file("t")
    old = b"\xAA" * 2048
    fe.write_sync(fid, 0, old)
    # Commit flip lands, in-place writev applies ZERO chunks: media stays
    # fully old, and recovery alone decides whether the record applies.
    dev.inject_torn_writev(nth=2, chunks=0)
    fe.submit_many([("w", fid, 0, b"\xBB" * 2048)])
    _drive_until_crash(svc, dev)

    # Rot one payload byte of the committed-but-unapplied record on the
    # survived media (its region is the only one still journal-pending).
    pos, _end = next(iter(fs._journal_pending.values()))
    dev._mem[fs._journal_start + pos + _JREC.size + 4 + 10] ^= 0x80

    fs2 = SegmentFS.mount(dev, 1 << 16, journal_segments=2)
    rec = fs2.recover_journal()
    assert rec["records"] == 1             # the seed write_sync only
    assert fs2.journal_crc_failures == 1   # the rotted record was refused
    phys = fs2.files[fid].segments[0] * (1 << 16)
    assert dev.raw_read(phys, 2048) == old  # corrupt bytes never applied
