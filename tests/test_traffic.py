"""Traffic director (§5): signatures, PEP transport transparency, RSS."""

from repro.core.traffic import (ApplicationSignature, FiveTuple, NaiveSplitter,
                                Packet, TCPReceiver, TrafficDirector,
                                rss_core, FLAG_SYN)
from repro.core.dds_server import (decode_batch, default_off_pred,
                                   encode_app_read, encode_app_write,
                                   encode_batch)


def flow(port=5000):
    return FiveTuple("10.0.0.2", 31337, "10.0.0.1", port)


def test_signature_wildcards():
    sig = ApplicationSignature(dst_port=5000)  # any client -> local:5000/tcp
    assert sig.matches(flow())
    assert not sig.matches(flow(port=80))
    assert not sig.matches(FiveTuple("a", 1, "b", 5000, proto="udp"))


def test_non_matching_packets_hardware_forwarded():
    td = TrafficDirector(ApplicationSignature(dst_port=5000),
                         default_off_pred)
    other = FiveTuple("x", 1, "y", 9999)
    td.ingress.push(Packet(other, 0, b"payload"))
    before = td.stats.modeled_time_s
    td.step()
    assert td.stats.hw_forwarded == 1
    assert td.stats.modeled_time_s == before  # line-rate: no Arm latency
    assert len(td.to_host) == 1


def test_fig11_naive_splitting_triggers_dup_acks():
    """Without the PEP, offloaded bytes create host-side sequence gaps."""
    host = TCPReceiver()
    splitter = NaiveSplitter(default_off_pred)
    host.receive(Packet(flow(), 0, b"", flags=FLAG_SYN))
    seq = 1
    dup_before = host.dup_acks
    for i in range(6):
        if i % 2 == 0:  # reads -> consumed by the DPU
            payload = encode_batch([encode_app_read(i, 1, 0, 64)])
        else:           # writes -> to the host, with ORIGINAL seq numbers
            payload = encode_batch([encode_app_write(i, 1, 0, b"z" * 16)])
        splitter.process(Packet(flow(), seq, payload), host)
        seq += len(payload)
    assert host.dup_acks > dup_before          # Fig 11 reproduced
    assert len(splitter.offloaded) == 3


def test_pep_maintains_contiguous_host_sequences():
    """With TCP splitting, the host-side connection never sees gaps."""
    td = TrafficDirector(ApplicationSignature(dst_port=5000),
                         default_off_pred)
    f = flow()
    td.ingress.push(Packet(f, 0, b"", flags=FLAG_SYN))
    td.step()
    seq = 1
    for i in range(6):
        if i % 2 == 0:
            payload = encode_batch([encode_app_read(i, 1, 0, 64)])
        else:
            payload = encode_batch([encode_app_write(i, 1, 0, b"z" * 16)])
        td.ingress.push(Packet(f, seq, payload))
        td.step()
        seq += len(payload)
    host = TCPReceiver()
    host.expected_seq = 0
    while True:
        pkt = td.to_host.pop()
        if pkt is None:
            break
        ok, _ = host.receive(pkt)
        assert ok
    assert host.dup_acks == 0                   # transport transparency
    assert td.stats.to_dpu == 3
    assert td.stats.to_host == 3


def test_rss_symmetric():
    f = flow()
    for cores in (1, 2, 4, 8):
        assert rss_core(f, cores) == rss_core(f.reversed(), cores)


def test_rss_distributes():
    cores = 4
    hits = set()
    for p in range(100):
        hits.add(rss_core(FiveTuple("c", 10000 + p, "s", 5000), cores))
    assert len(hits) == cores
