"""Checkpoint manager, data pipeline, optimizer, compression, simulate."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.dds_server import DDSStorageServer, ServerConfig
from repro.data.pipeline import BatchSpec, RingPrefetcher, TokenPipeline
from repro.optim import (adamw_init, adamw_update, compress_tree,
                         decompress_tree, init_compression, warmup_cosine)
from repro.storage.checkpoint import CheckpointManager


@pytest.fixture()
def cm():
    return CheckpointManager(DDSStorageServer(ServerConfig()), keep=2)


def tree_of(seed=0):
    rng = np.random.default_rng(seed)
    return {"layer": {"w": rng.normal(size=(16, 8)).astype(np.float32),
                      "b": rng.normal(size=(8,)).astype(np.float32)},
            "emb": rng.normal(size=(32, 4)).astype(np.float32)}


def assert_tree_close(a, b):
    jax.tree_util.tree_map(
        lambda x, y: np.testing.assert_allclose(x, y, atol=1e-7), a, b)


def test_save_restore_roundtrip(cm):
    t = tree_of()
    cm.save(5, t)
    assert cm.latest_step() == 5
    assert_tree_close(cm.restore(5, t), t)


def test_atomic_commit_no_manifest_no_checkpoint(cm):
    """A crash before the manifest write leaves no visible checkpoint."""
    t = tree_of()
    fe = cm.server.frontend
    fid = fe.create_file("ckpt-99/leaf")     # partial write, NO manifest
    fe.write_sync(fid, 0, b"partial")
    assert cm.latest_step() is None
    with pytest.raises(FileNotFoundError):
        cm.restore(99)


def test_elastic_restore_reshards(cm):
    t = tree_of()
    cm.save(7, t)
    for shards in (1, 2, 4):
        parts = [cm.restore_elastic(7, t, i, shards) for i in range(shards)]
        w = np.concatenate([p["layer"]["w"] for p in parts], axis=0)
        np.testing.assert_allclose(w, t["layer"]["w"])


def test_gc_keeps_latest(cm):
    for s in (1, 2, 3, 4):
        cm.save(s, tree_of(s))
    steps = sorted(cm._manifests())
    assert steps == [3, 4]                    # keep=2
    assert_tree_close(cm.restore(4, tree_of())["emb"], tree_of(4)["emb"])


def test_async_save(cm):
    t = tree_of()
    cm.save_async(11, t)
    cm.wait_async()
    assert cm.latest_step() == 11


def test_pipeline_determinism_and_sharding():
    spec = BatchSpec(8, 16, 1000)
    a = TokenPipeline(spec, seed=3, rank=0, world=2)
    b = TokenPipeline(spec, seed=3, rank=1, world=2)
    assert a.local_batch == 4
    a0, a0b = a.batch_at(5), a.batch_at(5)
    assert np.array_equal(a0["tokens"], a0b["tokens"])       # deterministic
    assert not np.array_equal(a0["tokens"], b.batch_at(5)["tokens"])  # sharded
    # labels are next-token targets
    full = TokenPipeline(spec, seed=3).batch_at(0)
    assert np.array_equal(full["tokens"][:, 1:], full["labels"][:, :-1])


def test_ring_prefetcher_threaded():
    pipe = TokenPipeline(BatchSpec(4, 8, 100), seed=1)
    pf = RingPrefetcher(pipe, depth=2)
    pf.start()
    try:
        steps = [pf.next_batch()[0] for _ in range(5)]
        assert steps == [0, 1, 2, 3, 4]
        s, b = pipe.batch_at(2), None
    finally:
        pf.stop()


def test_adamw_converges_quadratic():
    params = {"x": jnp.asarray([5.0, -3.0])}
    state = adamw_init(params)
    target = jnp.asarray([1.0, 2.0])
    for step in range(300):
        grads = {"x": 2 * (params["x"] - target)}
        params, state, _ = adamw_update(grads, state, params, lr=5e-2,
                                        weight_decay=0.0)
    np.testing.assert_allclose(params["x"], target, atol=1e-2)


def test_grad_clip():
    params = {"x": jnp.zeros(4)}
    state = adamw_init(params)
    grads = {"x": jnp.full(4, 100.0)}
    _, _, norm = adamw_update(grads, state, params, lr=0.0, max_grad_norm=1.0)
    assert float(norm) == pytest.approx(200.0)


def test_schedule_shape():
    lr = [float(warmup_cosine(s, peak_lr=1.0, warmup_steps=10,
                              total_steps=100)) for s in range(100)]
    assert lr[0] == 0.0 and max(lr) == pytest.approx(1.0, abs=1e-3)
    assert lr[5] < lr[9]                       # warming up
    assert lr[99] < 0.2                        # decayed


def test_compression_error_feedback_unbiased():
    """With error feedback, the ACCUMULATED dequantized sum tracks the true
    gradient sum (residuals never vanish silently)."""
    rng = np.random.default_rng(0)
    grads_seq = [{"w": jnp.asarray(rng.normal(size=(64,)), jnp.float32)}
                 for _ in range(20)]
    state = init_compression(grads_seq[0])
    true_sum = np.zeros(64)
    deq_sum = np.zeros(64)
    for g in grads_seq:
        q, s, state = compress_tree(g, state)
        deq = decompress_tree(q, s)
        true_sum += np.asarray(g["w"])
        deq_sum += np.asarray(deq["w"])
    resid = np.asarray(state.error["w"])
    np.testing.assert_allclose(deq_sum + resid, true_sum, atol=1e-3)


def test_simulate_anchors_match_paper():
    from repro.core import simulate as sim
    base = sim.baseline_tcp_ntfs_read().evaluate(390)
    assert base.kiops == pytest.approx(390, rel=0.01)
    assert base.host_cores == pytest.approx(10.7, rel=0.05)
    dds = sim.dds_offload_read().evaluate(730)
    assert dds.host_cores == 0.0
    assert dds.kiops == pytest.approx(730, rel=0.01)
    assert sim.dds_offload_read(zero_copy=False).peak_kiops() == pytest.approx(
        521, rel=0.01)
    faster = sim.faster_kv(dds=False).evaluate(340)
    assert faster.host_cores == pytest.approx(20, rel=0.15)
    fdds = sim.faster_kv(dds=True).evaluate(970)
    assert fdds.host_cores == 0.0
