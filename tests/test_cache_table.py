"""Cuckoo cache table (DDS §6.1): correctness + properties + concurrency."""

import threading

import pytest
from hypothesis import given, settings, strategies as st

from repro.core.cache_table import CacheTable


def test_basic_ops():
    t = CacheTable(max_items=128)
    assert t.insert("a", 1) and t.insert("b", 2)
    assert t.lookup("a") == 1 and t.lookup("b") == 2
    assert t.lookup("c") is None
    assert t.insert("a", 10)          # update in place
    assert t.lookup("a") == 10
    assert len(t) == 2
    assert t.delete("a") and not t.delete("a")
    assert t.lookup("a") is None
    assert len(t) == 1


def test_capacity_pre_reserved():
    t = CacheTable(max_items=16)
    for i in range(16):
        assert t.insert(i, i)
    assert not t.insert(999, 999)     # at capacity: reject, never resize
    assert t.stats.full_rejections == 1
    assert t.delete(0)
    assert t.insert(999, 999)


def test_collision_chaining():
    t = CacheTable(max_items=64, slots_per_bucket=1)
    for i in range(64):
        assert t.insert(f"key-{i}", i)
    for i in range(64):
        assert t.lookup(f"key-{i}") == i


@settings(max_examples=60, deadline=None)
@given(st.lists(st.tuples(st.sampled_from(["ins", "del"]),
                          st.integers(0, 40), st.integers(0, 1000)),
                max_size=200))
def test_property_matches_dict(ops):
    t = CacheTable(max_items=64)
    model: dict = {}
    for op, k, v in ops:
        if op == "ins":
            if len(model) < 64 or k in model:
                assert t.insert(k, v)
                model[k] = v
        else:
            assert t.delete(k) == (k in model)
            model.pop(k, None)
    for k, v in model.items():
        assert t.lookup(k) == v
    assert len(t) == len(model)


def test_concurrent_readers_during_writes():
    t = CacheTable(max_items=4096)
    for i in range(512):
        t.insert(i, i * 7)
    errors = []
    stop = threading.Event()

    def reader():
        while not stop.is_set():
            for i in range(0, 512, 17):
                v = t.lookup(i)
                if v is not None and v != i * 7 and v != i * 7 + 1:
                    errors.append((i, v))

    def writer():
        for rounds in range(50):
            for i in range(0, 512, 5):
                t.insert(i, i * 7)  # rewrite same values

    rs = [threading.Thread(target=reader) for _ in range(2)]
    w = threading.Thread(target=writer)
    for r in rs:
        r.start()
    w.start()
    w.join()
    stop.set()
    for r in rs:
        r.join()
    assert not errors


def test_lookup_stats():
    t = CacheTable(max_items=32)
    t.insert("x", 1)
    t.lookup("x")
    t.lookup("nope")
    assert t.stats.lookups == 2 and t.stats.hits == 1


def test_lookup_many_matches_per_key_lookup():
    t = CacheTable(max_items=256)
    for i in range(100):
        t.insert(f"k{i}", i * 7)
    keys = [f"k{i}" for i in range(0, 150, 3)]   # mix of hits and misses
    expect = [t.lookup(k) for k in keys]
    assert t.lookup_many(keys) == expect


def test_lookup_many_single_stats_round():
    t = CacheTable(max_items=64)
    t.insert("hot", 42)
    t.lookup_many(["hot", "cold", "hot"])
    assert t.stats.batched_lookups == 1
    assert t.stats.lookups == 3       # still counted per key...
    assert t.stats.hits == 2          # ...with exact hit accounting
    t.lookup_many([])
    assert t.stats.lookups == 3


@given(st.lists(st.tuples(st.integers(0, 40), st.booleans()), max_size=60))
@settings(max_examples=50, deadline=None)
def test_lookup_many_property_vs_dict(ops):
    t = CacheTable(max_items=128)
    model = {}
    for key, insert in ops:
        if insert:
            t.insert(key, key + 1000)
            model[key] = key + 1000
        elif key in model:
            t.delete(key)
            del model[key]
    keys = list(range(41))
    assert t.lookup_many(keys) == [model.get(k) for k in keys]


def test_lookup_many_exact_under_contended_writer():
    """Stable keys must resolve exactly — right value, never a false miss —
    while a writer thread churns disjoint keys through the same buckets
    (the seqlock-over-arrays discipline of the vectorized probe)."""
    t = CacheTable(max_items=4096)
    stable = {b"s%03d" % i: i for i in range(256)}
    for k, v in stable.items():
        t.insert(k, v)
    stop = threading.Event()

    def writer():
        j = 0
        while not stop.is_set():
            k = b"w%03d" % (j % 512)
            if j % 3 == 2:
                t.delete(k)
            else:
                t.insert(k, j)
            j += 1

    th = threading.Thread(target=writer)
    th.start()
    try:
        keys = list(stable)
        for _ in range(300):
            for k, v in zip(keys, t.lookup_many(keys)):
                assert v == stable[k]
    finally:
        stop.set()
        th.join()


def test_seqlock_exhaustion_falls_back_to_locked_probe():
    """A writer parked mid-window (version held odd) must not turn present
    keys into false misses: the retry budget exhausts and the probe takes
    the writer lock for one authoritative read instead."""
    t = CacheTable(max_items=256)
    t.insert(b"present", 42)
    b1, b2 = t._buckets_for(t._hash_key(b"present"))
    for b in {b1, b2}:
        t._versions[b] += 1       # odd: simulated writer stuck in-window
        t._versions_np[b] += 1
    before = t.stats.locked_probes
    assert t.lookup(b"present") == 42          # no false miss, no hang
    assert t.stats.locked_probes > before
    # The burst path funnels its unstable elements through the same
    # fallback: every element of a vectorized probe stays exact.
    assert t.lookup_many([b"present"] * 16) == [42] * 16
    for b in {b1, b2}:                         # release the fake writer
        t._versions[b] += 1
        t._versions_np[b] += 1
    assert t.lookup(b"present") == 42
    assert t.stats.locked_probes > before
