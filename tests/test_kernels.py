"""Per-kernel shape/dtype sweeps against the pure-jnp oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ops import flash_attention_xla
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.paged_attention.kernel import paged_attention_pallas
from repro.kernels.paged_attention.ref import paged_attention_ref
from repro.kernels.ssm_scan.kernel import gla_scan_pallas
from repro.kernels.ssm_scan.ops import gla_scan_xla
from repro.kernels.ssm_scan.ref import gla_decode_step, gla_scan_ref

TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


def _rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


# ---------------------------------------------------------------------------
# Flash attention.
# ---------------------------------------------------------------------------

FA_CASES = [
    # B, Sq, Sk, Hq, Hkv, D, causal, window
    (2, 128, 128, 4, 2, 64, True, None),
    (1, 256, 256, 8, 8, 64, True, 64),
    (2, 64, 192, 4, 1, 32, False, None),
    (1, 128, 128, 6, 2, 128, True, None),
    (1, 64, 64, 2, 2, 64, True, 16),
]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("case", FA_CASES)
def test_flash_attention_pallas_interpret(case, dtype):
    B, Sq, Sk, Hq, Hkv, D, causal, window = case
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = _rand(ks[0], (B, Sq, Hq, D), dtype)
    k = _rand(ks[1], (B, Sk, Hkv, D), dtype)
    v = _rand(ks[2], (B, Sk, Hkv, D), dtype)
    ref = attention_ref(q, k, v, causal=causal, window=window)
    out = flash_attention_pallas(q, k, v, causal=causal, window=window,
                                 block_q=64, block_k=64, interpret=True)
    np.testing.assert_allclose(out.astype(jnp.float32),
                               ref.astype(jnp.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("case", FA_CASES + [(1, 100, 100, 2, 2, 64, True, None)])
def test_flash_attention_xla_chunked(case, dtype):
    B, Sq, Sk, Hq, Hkv, D, causal, window = case
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = _rand(ks[0], (B, Sq, Hq, D), dtype)
    k = _rand(ks[1], (B, Sk, Hkv, D), dtype)
    v = _rand(ks[2], (B, Sk, Hkv, D), dtype)
    ref = attention_ref(q, k, v, causal=causal, window=window)
    out = flash_attention_xla(q, k, v, causal=causal, window=window,
                              block_q=64, block_k=64)
    np.testing.assert_allclose(out.astype(jnp.float32),
                               ref.astype(jnp.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


# ---------------------------------------------------------------------------
# Paged attention.
# ---------------------------------------------------------------------------

PA_CASES = [
    # B, Hq, Hkv, D, pool_pages, page, max_pages
    (2, 8, 2, 64, 16, 16, 4),
    (1, 4, 4, 32, 8, 8, 8),
    (3, 16, 8, 128, 32, 32, 3),
    (2, 4, 1, 64, 8, 64, 2),
]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("case", PA_CASES)
def test_paged_attention_pallas_interpret(case, dtype):
    B, Hq, Hkv, D, P, page, maxp = case
    ks = jax.random.split(jax.random.PRNGKey(2), 4)
    q = _rand(ks[0], (B, Hq, D), dtype)
    kp = _rand(ks[1], (P, page, Hkv, D), dtype)
    vp = _rand(ks[2], (P, page, Hkv, D), dtype)
    bt = jax.random.randint(ks[3], (B, maxp), 0, P, jnp.int32)
    sl = jnp.asarray([(maxp * page) - 3] + [(maxp - 1) * page - 1] * (B - 1),
                     jnp.int32)[:B]
    ref = paged_attention_ref(q, kp, vp, bt, sl)
    out = paged_attention_pallas(q, kp, vp, bt, sl, interpret=True)
    np.testing.assert_allclose(out.astype(jnp.float32),
                               ref.astype(jnp.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


def test_paged_attention_respects_block_table():
    """Permuting physical pages + table together must not change results."""
    B, Hq, Hkv, D, P, page, maxp = 1, 4, 2, 32, 8, 16, 4
    ks = jax.random.split(jax.random.PRNGKey(3), 4)
    q = _rand(ks[0], (B, Hq, D), jnp.float32)
    kp = _rand(ks[1], (P, page, Hkv, D), jnp.float32)
    vp = _rand(ks[2], (P, page, Hkv, D), jnp.float32)
    bt = jnp.asarray([[0, 1, 2, 3]], jnp.int32)
    sl = jnp.asarray([maxp * page], jnp.int32)
    base = paged_attention_ref(q, kp, vp, bt, sl)
    perm = jnp.asarray([3, 0, 1, 2, 4, 5, 6, 7])
    inv = jnp.argsort(perm)
    out = paged_attention_ref(q, kp[perm], vp[perm], inv[bt], sl)
    np.testing.assert_allclose(base, out, atol=1e-6)


# ---------------------------------------------------------------------------
# GLA / SSM scan.
# ---------------------------------------------------------------------------

GLA_CASES = [
    # B, H, S, K, V, chunk
    (2, 4, 128, 64, 64, 32),
    (1, 2, 256, 32, 64, 64),
    (2, 1, 96, 16, 16, 32),
    (1, 3, 64, 128, 32, 16),
]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("case", GLA_CASES)
def test_gla_xla_chunked(case, dtype):
    B, H, S, K, V, chunk = case
    ks = jax.random.split(jax.random.PRNGKey(4), 4)
    q = _rand(ks[0], (B, H, S, K), dtype) * 0.5
    k = _rand(ks[1], (B, H, S, K), dtype) * 0.5
    v = _rand(ks[2], (B, H, S, V), dtype)
    w = -jnp.exp(_rand(ks[3], (B, H, S, K), jnp.float32)) * 0.05
    ref_o, ref_s = gla_scan_ref(q, k, v, w)
    out_o, out_s = gla_scan_xla(q, k, v, w, chunk=chunk)
    np.testing.assert_allclose(out_o.astype(jnp.float32),
                               ref_o.astype(jnp.float32),
                               atol=TOL[dtype] * 4, rtol=TOL[dtype] * 4)
    np.testing.assert_allclose(out_s, ref_s, atol=1e-3, rtol=1e-3)


@pytest.mark.parametrize("case", GLA_CASES[:3])
def test_gla_pallas_interpret(case):
    B, H, S, K, V, chunk = case
    if S % chunk:
        pytest.skip("pallas path needs chunk-aligned S")
    ks = jax.random.split(jax.random.PRNGKey(5), 4)
    q = _rand(ks[0], (B, H, S, K), jnp.float32) * 0.5
    k = _rand(ks[1], (B, H, S, K), jnp.float32) * 0.5
    v = _rand(ks[2], (B, H, S, V), jnp.float32)
    w = -jnp.exp(_rand(ks[3], (B, H, S, K), jnp.float32)) * 0.05
    ref_o, ref_s = gla_scan_ref(q, k, v, w)
    out_o, out_s = gla_scan_pallas(q, k, v, w, chunk=chunk, interpret=True)
    np.testing.assert_allclose(out_o, ref_o, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(out_s, ref_s, atol=1e-3, rtol=1e-3)


def test_gla_decode_continuation():
    """prefill(S-1) + decode_step == full scan at position S-1."""
    B, H, S, K, V = 2, 2, 64, 32, 32
    ks = jax.random.split(jax.random.PRNGKey(6), 4)
    q = _rand(ks[0], (B, H, S, K), jnp.float32) * 0.5
    k = _rand(ks[1], (B, H, S, K), jnp.float32) * 0.5
    v = _rand(ks[2], (B, H, S, V), jnp.float32)
    w = -jnp.exp(_rand(ks[3], (B, H, S, K), jnp.float32)) * 0.05
    o_all, s_all = gla_scan_ref(q, k, v, w)
    _, s_pre = gla_scan_xla(q[:, :, :-1], k[:, :, :-1], v[:, :, :-1],
                            w[:, :, :-1], chunk=16)
    o_dec, s_dec = gla_decode_step(q[:, :, -1], k[:, :, -1], v[:, :, -1],
                                   w[:, :, -1], s_pre)
    np.testing.assert_allclose(o_dec, o_all[:, :, -1], atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(s_dec, s_all, atol=1e-4, rtol=1e-4)


def test_gla_strong_decay_stays_finite():
    """The exponent guard keeps extreme decays finite (regression)."""
    B, H, S, K, V = 1, 1, 256, 32, 32
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q = _rand(ks[0], (B, H, S, K), jnp.float32)
    k = _rand(ks[1], (B, H, S, K), jnp.float32)
    v = _rand(ks[2], (B, H, S, V), jnp.float32)
    w = jnp.full((B, H, S, K), -2.5)          # very strong decay
    o, s = gla_scan_xla(q, k, v, w, chunk=128)
    assert bool(jnp.all(jnp.isfinite(o)))
    assert bool(jnp.all(jnp.isfinite(s)))


# ---------------------------------------------------------------------------
# Backward passes (training differentiates through the portable paths).
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("case", FA_CASES[:3])
def test_flash_attention_xla_gradients_match_naive(case):
    B, Sq, Sk, Hq, Hkv, D, causal, window = case
    ks = jax.random.split(jax.random.PRNGKey(8), 3)
    q = _rand(ks[0], (B, Sq, Hq, D), jnp.float32)
    k = _rand(ks[1], (B, Sk, Hkv, D), jnp.float32)
    v = _rand(ks[2], (B, Sk, Hkv, D), jnp.float32)

    def loss_ref(q, k, v):
        return jnp.sum(jnp.square(attention_ref(
            q, k, v, causal=causal, window=window)))

    def loss_xla(q, k, v):
        return jnp.sum(jnp.square(flash_attention_xla(
            q, k, v, causal=causal, window=window, block_q=64, block_k=64)))

    g_ref = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    g_xla = jax.grad(loss_xla, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g_ref, g_xla):
        np.testing.assert_allclose(a, b, atol=2e-4, rtol=2e-4)


@pytest.mark.parametrize("case", GLA_CASES[:2])
def test_gla_xla_gradients_match_naive(case):
    B, H, S, K, V, chunk = case
    ks = jax.random.split(jax.random.PRNGKey(9), 4)
    q = _rand(ks[0], (B, H, S, K), jnp.float32) * 0.5
    k = _rand(ks[1], (B, H, S, K), jnp.float32) * 0.5
    v = _rand(ks[2], (B, H, S, V), jnp.float32)
    w = -jnp.exp(_rand(ks[3], (B, H, S, K), jnp.float32)) * 0.05

    def loss_ref(q, k, v, w):
        return jnp.sum(jnp.square(gla_scan_ref(q, k, v, w)[0]))

    def loss_xla(q, k, v, w):
        return jnp.sum(jnp.square(gla_scan_xla(q, k, v, w, chunk=chunk)[0]))

    g_ref = jax.grad(loss_ref, argnums=(0, 1, 2, 3))(q, k, v, w)
    g_xla = jax.grad(loss_xla, argnums=(0, 1, 2, 3))(q, k, v, w)
    for a, b in zip(g_ref, g_xla):
        np.testing.assert_allclose(a, b, atol=5e-3, rtol=5e-3)
