"""Dry-run machinery on a small 8-device mesh (subprocess: jax device count
is locked at first init, so the 8-device world must be a fresh process).

Validates the full lower->compile->analyze path for one train, one decode,
and one MoE cell on a (2, 4) mesh — the same code path the 512-device
production dry-run uses.
"""

import json
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

import dataclasses
from repro.configs import get_config, reduced_config, ShapeConfig
from repro.distributed import sharding as sh
from repro.launch.dryrun import collective_bytes
from repro.models.registry import build_model
from repro.optim import AdamWState
from repro.train.loop import TrainConfig, abstract_init, make_train_fn
from repro.serve.engine import make_serve_fns

mesh = jax.make_mesh((2, 4), ("data", "model"))
out = {}

for arch in ("tinyllama_1p1b", "granite_moe_3b_a800m"):
    cfg = reduced_config(get_config(arch))
    api = build_model(cfg)
    shape = ShapeConfig("t", "train", 64, 8)
    specs = api.input_specs(shape)
    pshapes, axes = abstract_init(api)
    tcfg = TrainConfig()
    step = make_train_fn(api, tcfg)
    pspecs = sh.sanitize_tree(sh.param_specs(axes, mesh, cfg), pshapes, mesh)
    opt_specs = AdamWState(P(), pspecs, pspecs)
    bspecs = {k: P(("data",), None) for k in specs}
    ns = lambda t: jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), t,
        is_leaf=lambda x: isinstance(x, P))
    f32 = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
    opt_shapes = AdamWState(jax.ShapeDtypeStruct((), jnp.int32),
                            jax.tree_util.tree_map(f32, pshapes),
                            jax.tree_util.tree_map(f32, pshapes))
    with mesh, sh.activation_sharding_scope(mesh):
        fn = jax.jit(step, in_shardings=(ns(pspecs), ns(opt_specs), None,
                                         ns(bspecs), NamedSharding(mesh, P())),
                     out_shardings=(ns(pspecs), ns(opt_specs), None,
                                    ns({"loss": P(), "grad_norm": P(),
                                        "lr": P()})))
        lowered = fn.lower(pshapes, opt_shapes, None, specs,
                           jax.ShapeDtypeStruct((), jnp.int32))
        compiled = lowered.compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, list):   # jax < 0.5 returns a one-element list
        cost = cost[0] if cost else {}
    coll = collective_bytes(compiled.as_text())
    out[arch] = {
        "flops": float(cost.get("flops", 0)),
        "collective_bytes": sum(v for k, v in coll.items()
                                if not k.startswith("n_")),
    }

# decode path
cfg = reduced_config(get_config("tinyllama_1p1b"))
api = build_model(cfg)
shape = ShapeConfig("d", "decode", 64, 8)
specs = api.input_specs(shape)
pshapes, axes = abstract_init(api)
with mesh, sh.activation_sharding_scope(mesh, "decode"):
    _, decode_jit = make_serve_fns(api, mesh, axes, shape, pshapes)
    fn = decode_jit(specs["cache"])
    compiled = fn.lower(pshapes, specs["cache"], specs["kv_len"],
                        specs["token"]).compile()
out["decode_ok"] = True
print(json.dumps(out))
"""


@pytest.mark.slow
def test_dryrun_on_8_device_mesh():
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = os.path.abspath(src)
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=540)
    assert proc.returncode == 0, proc.stderr[-3000:]
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    assert out["decode_ok"]
    for arch in ("tinyllama_1p1b", "granite_moe_3b_a800m"):
        assert out[arch]["flops"] > 0
        assert out[arch]["collective_bytes"] > 0  # sharded: collectives exist


COMPRESS_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, re
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

import dataclasses
from repro.configs import get_config, reduced_config
from repro.distributed import sharding as sh
from repro.models.registry import build_model
from repro.optim import AdamWState, adamw_init
from repro.optim.compression import CompressionState
from repro.train.loop import (TrainConfig, abstract_init,
                              make_compressed_pod_train_fn,
                              init_pod_compression)

mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
cfg = dataclasses.replace(reduced_config(get_config("tinyllama_1p1b")),
                          num_layers=2, vocab_size=256)
api = build_model(cfg)
params, axes = api.init(jax.random.PRNGKey(0))
opt = adamw_init(params)
comp = init_pod_compression(params, 2)
step = make_compressed_pod_train_fn(api, TrainConfig(peak_lr=1e-3,
                                                     warmup_steps=1,
                                                     total_steps=10), mesh)
rng = np.random.default_rng(0)
batch = {"tokens": jnp.asarray(rng.integers(0, 256, (8, 32)), jnp.int32),
         "labels": jnp.asarray(rng.integers(0, 256, (8, 32)), jnp.int32)}
with mesh, sh.activation_sharding_scope(mesh):
    fn = jax.jit(step)
    losses = []
    for i in range(6):
        params, opt, comp, metrics = fn(params, opt, comp, batch,
                                        jnp.asarray(i, jnp.int32))
        losses.append(float(metrics["loss"]))
# int8 wire check on the lowered HLO
with mesh, sh.activation_sharding_scope(mesh):
    hlo = fn.lower(params, opt, comp, batch,
                   jnp.asarray(0, jnp.int32)).compile().as_text()
n_s8 = len(re.findall(r"s8\[[\d,]+\][^=]*all-gather", hlo))
print(json.dumps({"losses": losses, "s8_allgathers": n_s8}))
"""


@pytest.mark.slow
def test_compressed_pod_grads_trains_and_uses_int8_wire():
    import jax
    if not hasattr(jax, "shard_map"):
        pytest.skip("partial-manual shard_map needs jax>=0.5 "
                    "(experimental auto mode crashes XLA here)")
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = os.path.abspath(src)
    proc = subprocess.run([sys.executable, "-c", COMPRESS_SCRIPT], env=env,
                          capture_output=True, text=True, timeout=540)
    assert proc.returncode == 0, proc.stderr[-3000:]
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    losses = out["losses"]
    assert all(l == l for l in losses)          # finite
    assert losses[-1] < losses[0]               # memorizing the fixed batch
    assert out["s8_allgathers"] > 0             # int8 actually on the wire
