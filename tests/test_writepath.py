"""Host-path batch overhaul: coalesced writes, burst rings, backpressure.

Covers the PR-3 write pipeline end to end:

  * ring burst APIs (``consume_batch`` single doorbell, ``insert_burst``
    single reservation, ``publish_batch`` gathered delivery);
  * ``SegmentFS.submit_writev`` scatter-gather coalescing (segment-aligned
    runs, cross-segment integrity, read-your-writes barriers);
  * the file service's E_NOSPC backpressure and TailA wrap-pad slots;
  * the zero-copy write invariant (``request_copies == 0`` under a burst);
  * ``write_many`` burst issue on both clients;
  * cache-table ``items()`` stability under cuckoo kicks and the stats
    surfaced through the KV app.
"""

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import wire
from repro.core.cache_table import CacheTable
from repro.core.client import ClusterClient
from repro.core.dds_server import (DDSClient, DDSStorageServer, ServerConfig,
                                   encode_app_write)
from repro.core.file_service import FileServiceRunner, SegmentFS
from repro.core.host_lib import DDSFrontEnd
from repro.core.ring import (FRAME_HDR, DMAEngine, ProgressiveRing,
                             ResponseRing, frame, unframe_batch)
from repro.distributed.cluster import DDSCluster
from repro.storage.blockdev import BlockDevice


def make_stack(zero_copy=True, segment_size=1 << 16, capacity=1 << 22,
               resp_buf_size=1 << 22):
    dev = BlockDevice(capacity, block_size=512)
    fs = SegmentFS(dev, segment_size)
    svc = FileServiceRunner(fs, DMAEngine(), zero_copy=zero_copy,
                            resp_buf_size=resp_buf_size)
    fe = DDSFrontEnd(svc, ring_capacity=1 << 14)
    return dev, fs, svc, fe


# ---------------------------------------------------------------------------
# Ring burst APIs
# ---------------------------------------------------------------------------


def test_consume_batch_one_doorbell_per_burst():
    ring = ProgressiveRing(1 << 12)
    dma = DMAEngine()
    msgs = [frame(bytes([i]) * 16) for i in range(8)]
    for m in msgs:
        ring.insert(m)
    before = dma.stats.snapshot()
    batches = ring.consume_batch(dma)
    delta = dma.stats.delta(before)
    assert unframe_batch(b"".join(batches)) == [m[4:] for m in msgs]
    # ONE IncHead doorbell for the whole burst (the only DMA write).
    assert delta.writes == 1
    assert ring.head == ring.tail


def test_consume_batch_empty_ring_no_doorbell():
    ring = ProgressiveRing(1 << 12)
    dma = DMAEngine()
    before = dma.stats.snapshot()
    assert ring.consume_batch(dma) == []
    assert dma.stats.delta(before).writes == 0


def test_insert_burst_single_reservation_fifo():
    ring = ProgressiveRing(1 << 12)
    dma = DMAEngine()
    payloads = [bytes([i]) * (8 + i) for i in range(10)]
    msgs = [(FRAME_HDR.pack(len(p)), p) for p in payloads]
    atomic_before = ring._atom.ops
    ring.insert_burst(msgs)
    # one CAS + one fetch-add for the WHOLE burst
    assert ring._atom.ops - atomic_before == 2
    got = unframe_batch(ring.consume(dma))
    assert got == payloads


def test_insert_burst_chunks_when_exceeding_max_progress():
    ring = ProgressiveRing(1 << 10, max_progress=128)
    dma = DMAEngine()
    payloads = [bytes([i]) * 40 for i in range(12)]  # 44B framed; 2/chunk
    collected = []

    msgs = [(FRAME_HDR.pack(len(p)), p) for p in payloads]
    # Interleave consumption so chunked reservations find space.
    import threading
    t = threading.Thread(target=lambda: ring.insert_burst(msgs))
    t.start()
    while True:
        batch = ring.consume(dma)
        if batch:
            collected += unframe_batch(batch)
        if not t.is_alive() and len(collected) == len(payloads):
            break
    t.join()
    assert collected == payloads


def test_publish_batch_gathers_views_and_wraps():
    ring = ResponseRing(1 << 8)
    dma = DMAEngine()
    # Fill past the wrap point in two bursts, claiming in between.
    first = [frame(b"a" * 100), frame(b"b" * 80)]
    assert ring.publish_batch(dma, [p for m in first
                                    for p in (m[:4], memoryview(m)[4:])])
    _, data = ring.try_claim()
    assert unframe_batch(data) == [b"a" * 100, b"b" * 80]
    second = [frame(b"c" * 120)]  # crosses the ring wrap boundary now
    assert ring.publish_batch(dma, second)
    _, data = ring.try_claim()
    assert unframe_batch(data) == [b"c" * 120]


def test_publish_batch_all_or_nothing_on_overflow():
    ring = ResponseRing(1 << 8)
    dma = DMAEngine()
    tail_before = ring.tail
    assert not ring.publish_batch(dma, [b"x" * 300])  # > capacity
    assert ring.tail == tail_before
    assert ring.try_claim() is None


# ---------------------------------------------------------------------------
# SegmentFS scatter-gather writes
# ---------------------------------------------------------------------------


def test_submit_writev_cross_segment_runs():
    dev = BlockDevice(1 << 20, block_size=512)
    fs = SegmentFS(dev, segment_size=1 << 12)
    fid = fs.create_file("v")
    # 3 buffers, 6000 bytes total -> crosses one segment boundary.
    bufs = [b"A" * 2500, b"B" * 2500, b"C" * 1000]
    writes_before = dev.stats.writes
    assert fs.submit_writev(fid, 0, bufs, cookie=7) == wire.E_OK
    dev.drain()
    assert dev.reap() == [(7, 0)]
    # one gathered device op per physical segment run, not per buffer
    assert dev.stats.writes - writes_before == len(fs.translate(fid, 0, 6000))
    out = bytearray(6000)
    done = []
    fs.submit_read(fid, 0, 6000, memoryview(out), done.append)
    dev.drain()
    assert done == [wire.E_OK]
    assert bytes(out) == b"".join(bufs)


def test_submit_writev_rejects_unknown_file_synchronously():
    dev = BlockDevice(1 << 20, block_size=512)
    fs = SegmentFS(dev, segment_size=1 << 12)
    assert fs.submit_writev(999, 0, [b"x"], cookie=1) == wire.E_NOENT
    dev.drain()
    assert dev.reap() == []  # no completion follows a synchronous reject


@settings(max_examples=20, deadline=None)
@given(st.data())
def test_property_coalesced_writes_cross_segments_intact(data):
    """Bursts of adjacent writes spanning segment boundaries read back
    intact (oracle: a shadow buffer)."""
    _, fs, svc, fe = make_stack(segment_size=1 << 12)
    fid = fe.create_file("prop")
    size = 1 << 14
    shadow = bytearray(size)
    fe.write_sync(fid, 0, bytes(size))
    for _ in range(data.draw(st.integers(1, 4))):
        start = data.draw(st.integers(0, size - 4096))
        ops = []
        off = start
        for _ in range(data.draw(st.integers(1, 6))):
            n = data.draw(st.integers(1, 900))
            if off + n > size:
                break
            payload = bytes([data.draw(st.integers(0, 255))]) * n
            ops.append(("w", fid, off, payload))
            shadow[off : off + n] = payload
            off += n
        if not ops:
            continue
        rids = fe.submit_many(ops)
        comps = {}
        for _ in range(200_000):
            svc.step()
            for c in fe.poll_wait(fe._control_group):
                comps[c.request_id] = c
            if len(comps) == len(rids):
                break
        assert sorted(comps) == rids
        assert all(c.error == wire.E_OK for c in comps.values())
    assert fe.read_sync(fid, 0, size) == bytes(shadow)


def test_write_burst_coalesces_and_acks_per_request():
    dev, _, svc, fe = make_stack(segment_size=1 << 16)
    fid = fe.create_file("log")
    chunk = b"r" * 100
    ops = [("w", fid, i * 100, chunk) for i in range(32)]
    writes_before = dev.stats.writes
    rids = fe.submit_many(ops)
    svc.run_until_idle()
    comps = {c.request_id: c for c in fe.poll_wait(fe._control_group)}
    assert sorted(comps) == rids               # every request acked...
    assert all(c.error == wire.E_OK for c in comps.values())
    assert svc.stats.writes == 32
    assert svc.stats.write_submits < 32        # ...but not one submit each
    assert svc.stats.coalesced_writes > 0
    assert dev.stats.writes - writes_before < 32
    assert fe.read_sync(fid, 0, 3200) == chunk * 32


def test_coalescing_flushes_before_interleaved_read():
    """A read between adjacent writes sees the writes (device-order barrier)."""
    _, _, svc, fe = make_stack()
    fid = fe.create_file("rw")
    fe.write_sync(fid, 0, b"\x00" * 256)
    ops = [("w", fid, 0, b"x" * 64), ("w", fid, 64, b"y" * 64),
           ("r", fid, 0, 128), ("w", fid, 128, b"z" * 64)]
    rids = fe.submit_many(ops)
    svc.run_until_idle()
    comps = {c.request_id: c for c in fe.poll_wait(fe._control_group)}
    assert [comps[r].error for r in rids] == [wire.E_OK] * 4
    assert comps[rids[2]].data == b"x" * 64 + b"y" * 64  # read-your-writes


# ---------------------------------------------------------------------------
# Backpressure (E_NOSPC) and TailA wrap padding
# ---------------------------------------------------------------------------


def test_nospc_response_larger_than_buffer():
    _, _, svc, fe = make_stack(resp_buf_size=1 << 10)
    fid = fe.create_file("big")
    fe.write_sync(fid, 0, bytes(8192))
    rid = fe.read_file(fid, 0, 4096)   # response can never fit: 4096 > 1024
    c = None
    for _ in range(100_000):
        svc.step()
        got = fe.poll_wait(fe._control_group)
        if got:
            c = got[0]
            break
    assert c is not None and c.request_id == rid
    assert c.error == wire.E_NOSPC


def test_nospc_backpressure_sheds_then_recovers():
    """Overflowing the response buffer E_NOSPCs the overflow inline, keeps
    earlier slots intact, and the service recovers once drained."""
    _, _, svc, fe = make_stack(resp_buf_size=1 << 10)
    fid = fe.create_file("bp")
    fe.write_sync(fid, 0, bytes(4096))
    # Each response slot is 16 + 200 bytes; ~4 fit in the 1 KiB buffer.
    rids = [fe.read_file(fid, i * 200, 200) for i in range(12)]
    results = {}
    for _ in range(200_000):
        svc.step()
        for c in fe.poll_wait(fe._control_group):
            results[c.request_id] = c
        if len(results) == len(rids):
            break
    assert len(results) == len(rids)
    errs = [results[r].error for r in rids]
    assert all(e in (wire.E_OK, wire.E_NOSPC) for e in errs)
    assert wire.E_OK in errs                 # forward progress
    # service fully drained: later requests still work
    ok = fe.read_sync(fid, 0, 100)
    assert ok == bytes(100)
    assert not svc._any_pending()


def test_taila_wrap_pad_keeps_responses_contiguous():
    """Responses stream correctly across many response-buffer wraps; pad
    slots occupy space but are never delivered."""
    _, _, svc, fe = make_stack(resp_buf_size=1 << 10)
    fid = fe.create_file("wrap")
    fe.write_sync(fid, 0, bytes(4096))
    # 316-byte slots against a 1024-byte buffer: every third-ish allocation
    # pads to the wrap boundary.
    for i in range(24):
        rid = fe.read_file(fid, (i * 300) % 3700, 300)
        c = fe._wait_one(fid, rid)
        assert c.error == wire.E_OK
        assert c.data == bytes(300)
    assert svc.stats.responses_delivered >= 24
    g = svc.groups[fe._control_group]
    assert not g.pending and not g.ready     # pads consumed, nothing stuck


# ---------------------------------------------------------------------------
# Zero-copy write invariant
# ---------------------------------------------------------------------------


def test_request_copies_zero_under_zero_copy_write_burst():
    _, _, svc, fe = make_stack(zero_copy=True)
    fid = fe.create_file("zc")
    blob = bytes(range(256)) * 4
    ops = [("w", fid, i * 128, memoryview(blob)[:128]) for i in range(64)]
    fe.submit_many(ops)
    svc.run_until_idle()
    fe.poll_wait(fe._control_group)
    assert svc.stats.writes == 64
    assert svc.stats.request_copies == 0     # end-to-end zero-copy writes
    assert svc.stats.response_copies == 0


def test_request_copies_counted_in_straw_man_mode():
    _, _, svc, fe = make_stack(zero_copy=False)
    fid = fe.create_file("cp")
    fe.submit_many([("w", fid, i * 64, b"d" * 64) for i in range(8)])
    svc.run_until_idle()
    assert svc.stats.request_copies == 8


def test_encode_app_write_accepts_memoryview_without_materializing():
    data = bytes(range(64))
    assert (encode_app_write(7, 3, 128, memoryview(data))
            == encode_app_write(7, 3, 128, data))


# ---------------------------------------------------------------------------
# write_many burst issue
# ---------------------------------------------------------------------------


def test_dds_client_write_many_single_batch():
    srv = DDSStorageServer(ServerConfig())
    fid = srv.frontend.create_file("wm")
    srv.run_until_idle()
    cli = DDSClient(srv)
    rids = cli.write_many([(fid, i * 32, bytes([i]) * 32) for i in range(16)])
    for rid in rids:
        status, _ = cli.wait(rid)
        assert status == wire.E_OK
    status, body = cli.wait(cli.read(fid, 0, 16 * 32))
    assert status == wire.E_OK
    assert body == b"".join(bytes([i]) * 32 for i in range(16))


def test_cluster_client_write_many_routes_and_coalesces():
    cluster = DDSCluster(num_shards=2,
                         config=ServerConfig(device_capacity=1 << 26))
    files = [cluster.create_file(f"f{i}") for i in range(4)]
    cli = ClusterClient(cluster)
    writes = [(files[i % 4], (i // 4) * 64, bytes([i & 0xFF]) * 64)
              for i in range(32)]
    rids = cli.write_many(writes)
    got = cli.wait_many(rids)
    assert all(status == wire.E_OK for status, _ in got.values())
    coalesced = sum(s.file_service.stats.coalesced_writes
                    for s in cluster.servers)
    assert coalesced > 0                     # adjacent same-file runs merged
    for i, (gfid, off, data) in enumerate(writes):
        rid = cli.read(gfid, off, 64)
        status, body = cli.wait(rid)
        assert status == wire.E_OK and body == data


# ---------------------------------------------------------------------------
# Cache table: kick-stable items() + surfaced stats
# ---------------------------------------------------------------------------


def test_items_snapshot_stable_under_kicks():
    t = CacheTable(max_items=512, slots_per_bucket=1, load_factor=1.0)
    expect = {}
    for i in range(400):
        key = f"k{i}"
        assert t.insert(key, i)
        expect[key] = i
    assert t.stats.kicks > 0                 # the layout really was kicked
    assert dict(t.items()) == expect
    # items() is a snapshot: mutating mid-iteration neither deadlocks nor
    # perturbs what the snapshot yields.
    it = t.items()
    first = next(it)
    t.insert("fresh", 999)
    t.delete(first[0])
    rest = dict(it)
    assert first[0] not in rest
    assert set(rest) | {first[0]} == set(expect)


@settings(max_examples=15, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 120), st.integers(0, 1_000_000)),
                min_size=1, max_size=300))
def test_property_items_match_dict_after_kick_heavy_churn(ops):
    """items() agrees with a shadow dict through kick-heavy insert/delete
    churn (1-slot buckets at load factor 1.0 maximize relocations)."""
    t = CacheTable(max_items=256, slots_per_bucket=1, load_factor=1.0)
    shadow = {}
    for key_i, val in ops:
        key = f"key-{key_i}"
        if val % 5 == 0 and key in shadow:
            assert t.delete(key)
            del shadow[key]
        elif t.insert(key, val):
            shadow[key] = val
    assert dict(t.items()) == shadow
    assert len(t) == len(shadow)


def test_kv_shard_stats_surface_cache_counters():
    from repro.apps.kv_store import KVClient, ShardedKVStore
    store = ShardedKVStore(num_shards=2,
                           config=ServerConfig(device_capacity=1 << 26))
    cli = KVClient(store)
    loc = cli.wait_put(cli.put(b"alpha", b"1" * 64))
    assert loc.size > 0
    assert cli.wait_value(cli.get(b"alpha")) == b"1" * 64
    stats = store.shard_stats()
    assert len(stats) == 2
    cache = stats[store.shard_for_key(b"alpha")]["cache"]
    for field in ("lookups", "hits", "inserts", "deletes", "kicks",
                  "chain_inserts", "full_rejections"):
        assert field in cache
    assert cache["inserts"] >= 1             # cache-on-write fired
    assert cache["hits"] >= 1                # the GET's predicate hit
    assert stats[store.shard_for_key(b"alpha")]["cache_items"] >= 1
