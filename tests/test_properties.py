"""System-level property tests (hypothesis): invariants of the DDS stack.

Invariants under random workloads:
  * end-to-end linearizability vs a shadow file (reads see the latest
    acknowledged write, regardless of DPU/host routing);
  * offload-engine responses arrive in request order per client (the
    context-ring ordering discipline, Fig 13);
  * every request is answered exactly once (no loss, no duplication)
    whether served by the DPU or bounced to the host;
  * the cache table never serves a stale page after invalidate-on-read
    (partial-offload correctness, §9.1).
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import wire
from repro.core.dds_server import DDSClient, DDSStorageServer, ServerConfig, \
    encode_batch
from repro.storage.pagestore import PageStore


@settings(max_examples=20, deadline=None)
@given(st.data())
def test_random_workload_matches_shadow(data):
    srv = DDSStorageServer(ServerConfig())
    fid = srv.frontend.create_file("prop.dat")
    size = 8192
    shadow = bytearray(size)
    srv.frontend.write_sync(fid, 0, bytes(size))
    srv.run_until_idle()
    cli = DDSClient(srv)
    n_ops = data.draw(st.integers(3, 12))
    for _ in range(n_ops):
        if data.draw(st.booleans()):
            off = data.draw(st.integers(0, size - 64))
            n = data.draw(st.integers(1, 64))
            val = bytes([data.draw(st.integers(0, 255))]) * n
            status, _ = cli.wait(cli.write(fid, off, val))
            assert status == wire.E_OK
            shadow[off : off + n] = val
        else:
            off = data.draw(st.integers(0, size - 64))
            n = data.draw(st.integers(1, 64))
            status, body = cli.wait(cli.read(fid, off, n))
            assert status == wire.E_OK
            assert body == bytes(shadow[off : off + n])


@settings(max_examples=15, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 63), st.integers(1, 128)),
                min_size=2, max_size=16))
def test_offloaded_responses_in_request_order(reqs):
    """All-read batches: responses must come back in submission order."""
    srv = DDSStorageServer(ServerConfig(offload_ring=4))  # small ring: bounces
    fid = srv.frontend.create_file("ord.dat")
    srv.frontend.write_sync(fid, 0, bytes(range(256)) * 64)
    srv.run_until_idle()
    cli = DDSClient(srv)
    rids = cli.send_batch([("r", fid, off * 64, n) for off, n in reqs])
    seen = []
    for _ in range(400_000):
        cli.collect()
        for r in rids:
            if r in cli.responses and r not in seen:
                seen.append(r)
        if len(seen) == len(rids):
            break
        srv.pump()
    assert sorted(seen) == sorted(rids)            # exactly once, no loss
    st_off = srv.offload.stats
    assert st_off.completed + st_off.bounced_to_host >= len(
        [r for r in reqs])                          # all accounted


@settings(max_examples=10, deadline=None)
@given(st.data())
def test_page_store_never_serves_stale(data):
    """After invalidate-on-read, GETs fall back to the host until the next
    replay re-caches — a DPU-served page always carries the freshest LSN."""
    ps = PageStore(page_size=512, num_pages=64)
    lsns = {}
    cli = DDSClient(ps.server)
    rid = 0
    for step in range(data.draw(st.integers(4, 12))):
        page = data.draw(st.integers(0, 7))
        action = data.draw(st.sampled_from(["replay", "host_read", "get"]))
        if action == "replay":
            lsn = lsns.get(page, 0) + 10
            lsns[page] = lsn
            ps.replay(page, lsn, f"p{page}v{lsn}".encode())
        elif action == "host_read" and page in lsns:
            ps.host_read_for_update(page)           # invalidates DPU cache
        elif page in lsns:
            rid += 1
            cli._send(encode_batch([PageStore.encode_get(
                rid, page, lsns[page])]))
            status, body = cli.wait(rid)
            assert status == wire.E_OK
            lsn, payload = PageStore.decode_page(body)
            assert lsn == lsns[page]                # never stale
            assert payload.rstrip(b"\x00") == f"p{page}v{lsn}".encode()
