"""Serving engine: continuous batching, DDS KV paging, sharding specs."""

import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import SHAPES, get_config, reduced_config
from repro.models.registry import build_model
from repro.serve.engine import BatchScheduler, PagedKVEngine, Request
from repro.storage.pagestore import PageStore


@pytest.fixture(scope="module")
def small_lm():
    cfg = dataclasses.replace(reduced_config(get_config("tinyllama_1p1b")),
                              num_layers=2, vocab_size=512)
    api = build_model(cfg)
    params, _ = api.init(jax.random.PRNGKey(0))
    return api, params


def test_continuous_batching_completes(small_lm):
    api, params = small_lm
    sched = BatchScheduler(api, params, slots=4, cache_len=64)
    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(0, 512, size=4), max_new=5)
            for i in range(10)]
    for r in reqs:
        sched.submit(r)
    done = steps = 0
    while done < 10 and steps < 500:
        done += sched.step()
        steps += 1
    assert done == 10
    assert all(len(r.generated) == 5 for r in reqs)
    # 10 requests over 4 slots need at least ceil(10/4)*5 steps
    assert steps >= 15


def test_greedy_decode_is_deterministic(small_lm):
    api, params = small_lm
    outs = []
    for _ in range(2):
        sched = BatchScheduler(api, params, slots=2, cache_len=32)
        req = Request(0, np.asarray([5, 7, 9]), max_new=4)
        sched.submit(req)
        while not req.done:
            sched.step()
        outs.append(tuple(req.generated))
    assert outs[0] == outs[1]


def test_paged_kv_spill_and_fetch():
    store = PageStore(page_size=4096, num_pages=256)
    eng = PagedKVEngine(store, block_bytes=1024, hbm_blocks=4)
    blobs = {}
    for blk in range(12):
        data = bytes([blk]) * 1024
        blobs[blk] = data
        eng.put_block(0, 0, blk, data)
    assert eng.spills == 8                       # 12 blocks, 4 slots
    # cold fetch goes through the DPU offload path and returns page bytes
    before = store.server.offload.stats.completed
    got = eng.get_block(0, 0, 0)
    assert got[:1024] == blobs[0]
    assert store.server.offload.stats.completed == before + 1
    # hot block: HBM hit, no store traffic
    assert eng.get_block(0, 0, 11) is None
    assert eng.hits == 1


def test_kv_block_versions_respected():
    store = PageStore(page_size=4096, num_pages=256)
    eng = PagedKVEngine(store, block_bytes=1024, hbm_blocks=2)
    eng.put_block(1, 0, 0, b"v1" * 512)
    eng.put_block(1, 0, 0, b"v2" * 512)          # rewrite bumps version
    eng.put_block(1, 0, 1, b"xx" * 512)
    eng.put_block(1, 0, 2, b"yy" * 512)          # evicts block 0
    got = eng.get_block(1, 0, 0)
    assert got[:1024] == b"v2" * 512             # freshest version came back


@pytest.mark.slow
def test_paged_decode_matches_dense():
    """lm_decode_step_paged == lm_decode_step over the same prefix."""
    import dataclasses
    import jax.numpy as jnp
    from repro.models import transformer as TF

    cfg = dataclasses.replace(reduced_config(get_config("tinyllama_1p1b")),
                              num_layers=2, vocab_size=256)
    api = build_small = build_model(cfg)
    params, _ = api.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    tokens = jnp.asarray(rng.integers(0, 256, (2, 12)), jnp.int32)

    # dense path: prefill 8, decode 9..11
    _, dense_cache = api.prefill(params, {"tokens": tokens[:, :8]},
                                 cache_len=16)
    # paged path: replay the same prefix token-by-token into the pool
    paged = TF.lm_init_paged_cache(cfg, batch=2, max_len=16, page=4)
    for t in range(8):
        logits_p, paged = TF.lm_decode_step_paged(
            params, cfg, paged, jnp.asarray(t, jnp.int32),
            tokens[:, t : t + 1])
    for t in range(8, 12):
        d_logits, dense_cache = api.decode_step(
            params, dense_cache, jnp.asarray(t, jnp.int32),
            tokens[:, t : t + 1])
        p_logits, paged = TF.lm_decode_step_paged(
            params, cfg, paged, jnp.asarray(t, jnp.int32),
            tokens[:, t : t + 1])
        np.testing.assert_allclose(np.asarray(p_logits, np.float32),
                                   np.asarray(d_logits, np.float32),
                                   atol=3e-2, rtol=3e-2)
