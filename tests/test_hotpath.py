"""Hot-path overhaul: slab pool, flow demux, batch decode, drain sharing.

Covers the PR-2 invariants:

  * the size-classed slab allocator (O(1) allocate/release) never corrupts
    neighboring allocations, reuses freed blocks, bounces to the host on
    exhaustion, and keeps 64-byte alignment;
  * ``reassemble_responses`` consumes many small responses in one pass
    (regression for the old O(n^2) ``del rx[:total]`` loop);
  * the demuxed ``to_client`` wire isolates flows and preserves per-flow
    FIFO order; ``pop_flow``/``drain_flow`` never see foreign packets;
  * ``decode_batch``/``unframe_batch`` return zero-copy views that decode
    identically to the old bytes-slicing implementations;
  * deferred pool release: an undrained response is never overwritten by
    later reads (TX-completion ownership), and draining returns every block.
"""

import struct

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import wire
from repro.core.dds_server import (APP_RESP_HDR, DDSClient, DDSStorageServer,
                                   ServerConfig, decode_batch, encode_batch,
                                   reassemble_responses)
from repro.core.offload import PKT_HEADROOM, SlabPool
from repro.core.ring import frame, unframe_batch
from repro.core.traffic import FiveTuple, FlowDemuxWire, Packet


# -- slab allocator ---------------------------------------------------------------------

def test_slab_allocate_release_reuse():
    pool = SlabPool(1 << 16)
    a = pool.allocate(100)          # -> 128 B class
    assert a is not None
    off_a, view_a = a
    assert off_a % 64 == 0 and len(view_a) == 100
    pool.release(off_a, 100)
    b = pool.allocate(120)          # same class: freed block comes right back
    assert b is not None and b[0] == off_a
    assert pool.in_use() == 128
    assert pool.allocs == 2 and pool.failed == 0


def test_slab_alignment_and_distinct_blocks():
    pool = SlabPool(1 << 16)
    seen = set()
    for n in (1, 63, 64, 65, 200, 1000, 4096):
        off, view = pool.allocate(n)
        assert off % 64 == 0
        assert len(view) == n
        for o, ln in seen:
            assert off + len(view) <= o or off >= o + ln, "overlap!"
        seen.add((off, n))


def test_slab_exhaustion_and_borrowed_class_release():
    pool = SlabPool(1 << 10)        # 1 KiB: 8 blocks of the 128 B class
    offs = []
    while True:
        a = pool.allocate(128)
        if a is None:
            break
        offs.append(a[0])
    assert len(offs) == 8 and pool.failed == 1
    # free one big-class... release one and allocate a SMALLER request: the
    # bump region is gone, so the 64 B request borrows the freed 128 B block
    pool.release(offs[0], 128)
    b = pool.allocate(32)
    assert b is not None and b[0] == offs[0]
    # releasing the borrowed block returns it to its TRUE (128 B) class
    pool.release(b[0], 32)
    c = pool.allocate(128)
    assert c is not None and c[0] == offs[0]


def test_slab_double_release_raises():
    pool = SlabPool(1 << 12)
    off, _ = pool.allocate(64)
    pool.release(off, 64)
    with pytest.raises(ValueError):
        pool.release(off, 64)


def test_slab_occupancy_accounting():
    pool = SlabPool(1 << 16)
    pool.allocate(100)              # 128 class
    pool.allocate(200)              # 256 class
    occ = pool.occupancy()
    assert occ["live_bytes"] == 300
    assert occ["committed_bytes"] == 128 + 256
    assert occ["internal_frag_bytes"] == (128 - 100) + (256 - 200)
    assert occ["classes"][128]["live"] == 1
    assert occ["classes"][256]["live"] == 1


@settings(max_examples=40, deadline=None)
@given(st.lists(st.tuples(st.integers(1, 2048), st.booleans()),
                min_size=1, max_size=64))
def test_slab_sequences_never_corrupt_neighbors(ops):
    """Random allocate/release sequences: every live block keeps its bytes.

    Each allocation is filled with its own tag; at every step every live
    allocation must still hold its tag — a slab handing out overlapping
    blocks (or resurrecting a released offset) would scribble on a neighbor.
    """
    pool = SlabPool(1 << 14)
    live: dict[int, tuple[memoryview, int, int]] = {}  # off -> (view, n, tag)
    tag = 0
    for n, do_release in ops:
        if do_release and live:
            off = next(iter(live))
            view, sz, t = live.pop(off)
            assert bytes(view) == bytes([t]) * sz, "corrupted before release"
            pool.release(off, sz)
        else:
            a = pool.allocate(n)
            if a is None:
                continue            # exhausted: allocator said so honestly
            off, view = a
            tag = (tag + 1) % 251
            view[:] = bytes([tag]) * n
            assert off not in live
            live[off] = (view, n, tag)
        for off, (view, sz, t) in live.items():
            assert bytes(view) == bytes([t]) * sz, f"block {off} corrupted"


def test_slab_reset_when_fully_free_serves_larger_class():
    """A pool carved into small classes, once fully drained, must still be
    able to serve a larger class (no permanent starvation)."""
    pool = SlabPool(1 << 12)        # 4 KiB
    offs = []
    while (a := pool.allocate(64)) is not None:
        offs.append(a[0])
    assert len(offs) == 64          # bump fully carved into the 64 B class
    for off in offs:
        pool.release(off, 64)
    big = pool.allocate(2048)       # larger than any carved class
    assert big is not None and len(big[1]) == 2048
    assert pool.in_use() == 2048


def test_failed_offloaded_read_reports_real_request_id():
    """An offloaded read that fails at the device still answers ITS rid."""
    srv = DDSStorageServer(ServerConfig())
    fid = srv.frontend.create_file("short")
    srv.frontend.write_sync(fid, 0, bytes(512))
    srv.run_until_idle()
    cli = DDSClient(srv)
    rid = cli.read(fid, 0, 4096)    # beyond EOF: submit fails (E_INVAL)
    status, body = cli.wait(rid)    # must NOT time out on req_id 0
    assert status != wire.E_OK and body == b""
    assert srv.offload.stats.failed == 1


def test_pool_exhaustion_bounces_to_host():
    """A pool too small for the read forces the host path — no data loss."""
    srv = DDSStorageServer(ServerConfig(offload_pool=1 << 12))
    fid = srv.frontend.create_file("big")
    srv.frontend.write_sync(fid, 0, bytes(range(256)) * 64)
    srv.run_until_idle()
    cli = DDSClient(srv)
    status, body = cli.wait(cli.read(fid, 0, 8192))  # > pool
    assert status == wire.E_OK and len(body) == 8192
    assert srv.offload.stats.bounced_to_host == 1
    assert srv.offload.pool.failed >= 1


# -- reassembly (O(n) regression test) -------------------------------------------------

def test_reassemble_many_small_responses_single_pass():
    rx = bytearray()
    for rid in range(1, 501):
        body = bytes([rid & 0xFF]) * 3
        rx += APP_RESP_HDR.pack(rid, 0, len(body)) + body
    rx += APP_RESP_HDR.pack(999, 0, 100)[:8]     # trailing partial header
    responses: dict = {}
    order: list = []
    n = reassemble_responses(rx, responses, order)
    assert n == 500 and len(responses) == 500
    assert order == list(range(1, 501))
    assert responses[7] == (0, b"\x07\x07\x07")
    assert bytes(rx) == APP_RESP_HDR.pack(999, 0, 100)[:8]  # partial kept


def test_reassemble_partial_body_left_for_next_call():
    rx = bytearray(APP_RESP_HDR.pack(1, 0, 10) + b"12345")
    responses: dict = {}
    assert reassemble_responses(rx, responses) == 0
    rx += b"67890"
    assert reassemble_responses(rx, responses) == 1
    assert responses[1] == (0, b"1234567890") and len(rx) == 0


# -- flow demux -------------------------------------------------------------------------

def _flow(port):
    return FiveTuple("10.0.0.2", port, "10.0.0.1", 5000)


def test_flow_demux_isolates_flows_and_keeps_fifo():
    w = FlowDemuxWire("t")
    a, b = _flow(1), _flow(2)
    for i in range(3):
        w.push(Packet(a, i, b"a%d" % i))
        w.push(Packet(b, i, b"b%d" % i))
    assert len(w) == 6
    assert [bytes(p.payload) for p in w.drain_flow(a)] == [b"a0", b"a1", b"a2"]
    assert w.pop_flow(a) is None                 # a is empty; b untouched
    assert bytes(w.pop_flow(b).payload) == b"b0"
    assert [bytes(p.payload) for p in w.drain_flow(b)] == [b"b1", b"b2"]
    assert len(w) == 0 and w.pop() is None


def test_flow_demux_push_many_and_generic_pop():
    w = FlowDemuxWire("t")
    a = _flow(7)
    w.push_many(a, [Packet(a, 0, b"x"), Packet(a, 1, b"y")])
    assert len(w) == 2
    assert bytes(w.pop().payload) == b"x"        # per-flow FIFO via pop()
    assert bytes(w.pop_flow(a).payload) == b"y"


def test_packet_consumed_releases_pool_block_once():
    """Single-packet consumers (pop_flow) release ownership via consumed()."""
    pool = SlabPool(1 << 12)
    off, view = pool.allocate(100)
    pkt = Packet(_flow(1), 0, view, pool_ref=(pool, off, 100))
    pkt.consumed()
    assert pool.in_use() == 0
    pkt.consumed()                  # idempotent: ref cleared on first call
    assert pool.allocate(100)[0] == off


# -- zero-copy batch decode -------------------------------------------------------------

def test_decode_batch_views_match_bytes_and_are_zero_copy():
    msgs = [b"alpha", b"", b"x" * 2000, struct.pack("<I", 7)]
    payload = encode_batch(msgs)
    out = decode_batch(payload)
    assert [bytes(m) for m in out] == msgs
    assert all(isinstance(m, memoryview) for m in out)
    assert out[2].obj is payload                 # a view INTO the buffer


def test_unframe_batch_views_match_bytes():
    msgs = [b"r1", b"longer-message" * 10, b""]
    batch = b"".join(frame(m) for m in msgs)
    out = unframe_batch(batch)
    assert [bytes(m) for m in out] == msgs
    assert all(isinstance(m, memoryview) for m in out)


# -- wait_many: no head-of-line blocking ------------------------------------------------

def test_wait_many_harvests_out_of_order_completions():
    """rids are collected as they arrive, regardless of the order given."""
    from repro.core.client import ClusterClient
    from repro.distributed.cluster import DDSCluster

    cl = DDSCluster(num_shards=2)
    fids = [cl.create_file(f"w{i}") for i in range(4)]
    for i, f in enumerate(fids):
        cl.write_sync(f, 0, bytes([i + 1]) * 4096)
    cc = ClusterClient(cl)
    rids = [cc.read(f, 0, 64) for f in fids for _ in range(3)]
    cc.flush()
    # ask for the rids in REVERSE order: a serial per-rid wait would block
    # on the last-issued rid while all the others sit ready
    res = cc.wait_many(list(reversed(rids)))
    assert set(res) == set(rids)
    for k, rid in enumerate(rids):
        status, body = res[rid]
        assert status == 0 and body == bytes([k // 3 + 1]) * 64
    assert cc.outstanding() == 0


# -- deferred pool release (TX-completion ownership) -----------------------------------

def test_undrained_responses_survive_later_reads():
    """Responses left on the wire keep their bytes while new reads execute."""
    srv = DDSStorageServer(ServerConfig())
    fid = srv.frontend.create_file("f")
    srv.frontend.write_sync(fid, 0, bytes([i & 0xFF for i in range(16384)]))
    srv.run_until_idle()
    cli = DDSClient(srv)
    # issue many reads but do NOT collect between pumps: every response
    # sits on the demuxed wire referencing pool memory
    rids = cli.send_batch([("r", fid, i * 64, 64) for i in range(64)])
    for _ in range(200):
        if len(srv.director.to_client) >= 64:
            break
        srv.pump()
        srv.device.drain()
    assert srv.offload.pool.in_use() > 0         # blocks still owned by wire
    for _ in range(2000):
        cli.collect()
        if len(cli.responses) == len(rids):
            break
        srv.pump()
    expect = bytes([i & 0xFF for i in range(16384)])
    for k, rid in enumerate(rids):
        status, body = cli.responses[rid]
        assert status == wire.E_OK
        assert body == expect[k * 64 : k * 64 + 64], f"read {k} corrupted"
    assert srv.offload.pool.in_use() == 0        # every block came back
    assert srv.offload.stats.data_copies == 0    # still zero-copy
