"""Work-signaled cluster scheduler: ready-set invariants (PR 4).

The contract under test (see ``distributed.cluster``):

  * **no lost wakeups** — a shard stays runnable (armed in the ready set)
    while ``server.busy()`` holds: pending device completions, undrained
    rings/wires, in-flight host requests;
  * **idle shards cost nothing** — with traffic directed at one shard,
    the other shards take ZERO pump steps;
  * **equivalence** — ``run_until_idle`` leaves the cluster in a state
    byte-identical to the pre-overhaul poll-every-shard loop.
"""

import pytest

from repro.core import wire
from repro.core.client import ClusterClient
from repro.distributed.cluster import DDSCluster, ReadySet


def _mixed_workload(cli: ClusterClient, fids: list, rounds: int = 3) -> list:
    """A deterministic read+write mix touching every file."""
    rids = []
    for r in range(rounds):
        for i, f in enumerate(fids):
            rids.append(cli.write(f, 128 * r, bytes([r + 1]) * (64 + i)))
            rids.append(cli.read(f, 64 * r, 96))
        cli.flush()
    return rids


def _loaded_cluster(num_shards: int = 4):
    cl = DDSCluster(num_shards=num_shards)
    fids = [cl.create_file(f"s{i}") for i in range(2 * num_shards)]
    for i, f in enumerate(fids):
        cl.write_sync(f, 0, bytes([i + 1]) * 4096)
    return cl, fids


# -- ready-set primitive ---------------------------------------------------------------

def test_ready_set_mark_take_rearm_semantics():
    rs = ReadySet(4)
    assert not rs and rs.take() == []
    rs.mark(2)
    rs.mark(0)
    rs.mark(2)                      # double-mark is idempotent
    assert len(rs) == 2
    assert rs.take() == [0, 2]      # shard-index order (determinism)
    assert rs.take() == []          # take clears
    rs.mark(1)                      # re-arm after take works
    assert rs.take() == [1]


def test_ready_set_quiet_latch_cleared_by_mark():
    rs = ReadySet(2)
    rs.quiet = True
    rs.mark(0)
    assert not rs.quiet             # any doorbell invalidates verified-idle
    assert rs.take() == [0]


# -- no lost wakeups -------------------------------------------------------------------

def test_client_send_arms_the_target_shard():
    cl, fids = _loaded_cluster(4)
    cl.run_until_idle()
    cli = ClusterClient(cl)
    cl.run_until_idle()             # settle the SYN handshakes
    loc = cl.locate(fids[0])
    cli.read(fids[0], 0, 64)
    cli.flush()                     # the send IS the doorbell
    assert loc.shard in cl.runnable()


def test_busy_server_stays_runnable_until_drained():
    """THE no-lost-wakeup invariant: busy => armed, at every pump step."""
    cl, fids = _loaded_cluster(4)
    cli = ClusterClient(cl)
    rids = _mixed_workload(cli, fids)
    for _ in range(200_000):
        for i, srv in enumerate(cl.servers):
            if srv.busy():
                assert i in cl.runnable(), \
                    f"shard {i} is busy but not runnable (lost wakeup)"
        if cl.pump() + cli.poll() == 0 and cli.outstanding() == 0:
            break
    res = cli.wait_many(rids)
    assert all(s == wire.E_OK for s, _ in res.values())


def test_device_backlog_keeps_shard_runnable():
    """A shard whose device holds pending completions must stay armed even
    when its own pump produced no work this step."""
    cl, _ = _loaded_cluster(2)
    cl.run_until_idle()
    srv = cl.servers[0]
    buf = bytearray(64)
    # A raw tagged submission (no file-service consumer): the device is
    # busy until polled, then its completion queue holds the cookie.
    srv.device.submit_read(0, 64, memoryview(buf), cookie=7)
    assert srv.device.busy()
    assert 0 in cl.runnable()       # the submission doorbell armed shard 0
    cl.pump()
    assert 0 in cl.runnable()       # still busy => still armed (re-arm rule)
    srv.device.drain()
    assert srv.device.busy()        # completion awaits reap: still busy
    assert 0 in cl.runnable()
    srv.device.reap()
    cl.run_until_idle()             # idle-sweep escape: terminates anyway


def test_wakeup_after_verified_idle():
    """The quiet latch must not swallow doorbells: work issued AFTER the
    cluster verified itself idle is still served."""
    cl, fids = _loaded_cluster(4)
    cli = ClusterClient(cl)
    cl.run_until_idle()
    assert cl.pump() == 0           # verified idle (quiet latch set)
    assert cl.pump() == 0           # stays idle for free
    st, body = cli.wait(cli.read(fids[0], 0, 32))
    assert st == wire.E_OK and len(body) == 32


# -- idle shards cost nothing ----------------------------------------------------------

def test_idle_shards_take_zero_pump_steps():
    cl, fids = _loaded_cluster(16)
    cli = ClusterClient(cl)
    cli.run_until_idle()
    target = cl.locate(fids[0]).shard
    mine = [f for f in fids if cl.locate(f).shard == target]
    before = list(cl.pump_steps)
    rids = []
    for r in range(4):
        rids += [cli.read(f, 32 * r, 64) for f in mine]
        cli.flush()
    res = cli.wait_many(rids)
    assert all(s == wire.E_OK for s, _ in res.values())
    deltas = [after - b for after, b in zip(cl.pump_steps, before)]
    assert deltas[target] > 0
    for shard, d in enumerate(deltas):
        if shard != target:
            assert d == 0, f"idle shard {shard} was pumped {d} times"


# -- equivalence with the pre-overhaul loop --------------------------------------------

def _legacy_run_until_idle(cluster: DDSCluster, max_iters: int = 200_000):
    """The pre-PR poll-everything loop, verbatim."""
    idle = 0
    for _ in range(max_iters):
        work = 0
        for srv in cluster.servers:
            work += srv.pump()
        if work == 0:
            for srv in cluster.servers:
                srv.device.drain()
            idle += 1
            if idle >= 3:
                return
        else:
            idle = 0
    raise TimeoutError("legacy loop did not go idle")


def test_run_until_idle_matches_legacy_loop_byte_for_byte():
    results = []
    for legacy in (True, False):
        cl, fids = _loaded_cluster(4)
        cli = ClusterClient(cl)
        rids = _mixed_workload(cli, fids, rounds=4)
        if legacy:
            _legacy_run_until_idle(cl)
        else:
            cl.run_until_idle()
        while cli.poll():
            pass
        st = cl.stats()
        results.append((dict(cli.responses),
                        st.offloaded_completed, st.host_responses,
                        [bytes(s.fs.device.raw_read(0, 4096))
                         for s in cl.servers]))
        assert set(cli.responses) == set(rids)
    (resp_a, off_a, host_a, mem_a), (resp_b, off_b, host_b, mem_b) = results
    assert resp_a == resp_b          # same statuses, same payload bytes
    assert (off_a, host_a) == (off_b, host_b)
    assert mem_a == mem_b            # on-"disk" state identical


def test_cluster_run_until_idle_converges_without_idle_sweeps():
    """Once verifiably idle, run_until_idle costs O(1) pumps, not sweeps."""
    cl, _ = _loaded_cluster(8)
    cl.run_until_idle()
    before = list(cl.pump_steps)
    for _ in range(50):
        cl.run_until_idle()          # idle convergence: no server stepped
    assert cl.pump_steps == before


if __name__ == "__main__":
    import sys
    sys.exit(pytest.main([__file__, "-v"]))
