"""DPU file service (§4.3) + host front-end library (§4.2)."""

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import wire
from repro.core.file_service import FileServiceRunner, SegmentFS
from repro.core.host_lib import DDSFrontEnd
from repro.core.ring import DMAEngine
from repro.storage.blockdev import BlockDevice


def make_stack(zero_copy=True, segment_size=1 << 16, capacity=1 << 22):
    dev = BlockDevice(capacity, block_size=512)
    fs = SegmentFS(dev, segment_size)
    svc = FileServiceRunner(fs, DMAEngine(), zero_copy=zero_copy)
    fe = DDSFrontEnd(svc, ring_capacity=1 << 14)
    return dev, fs, svc, fe


def test_write_read_roundtrip():
    _, fs, svc, fe = make_stack()
    fid = fe.create_file("a.dat")
    data = bytes(range(256)) * 8
    fe.write_sync(fid, 0, data)
    assert fe.read_sync(fid, 0, len(data)) == data
    assert fe.read_sync(fid, 100, 50) == data[100:150]


def test_cross_segment_io():
    _, fs, svc, fe = make_stack(segment_size=1 << 12)
    fid = fe.create_file("big.dat")
    data = bytes([i % 251 for i in range(3 * (1 << 12) + 77)])
    fe.write_sync(fid, 0, data)
    assert fs.file_size(fid) == len(data)
    assert len(fs.files[fid].segments) == 4  # file mapping spans segments
    assert fe.read_sync(fid, 0, len(data)) == data
    # a read crossing a segment boundary
    off = (1 << 12) - 13
    assert fe.read_sync(fid, off, 40) == data[off : off + 40]


def test_scatter_gather():
    _, _, svc, fe = make_stack()
    fid = fe.create_file("sg.dat")
    fe.write_file_gather(fid, 0, [b"aaaa", b"bbbb", b"cc"])
    svc.run_until_idle()
    bufs = [bytearray(4), bytearray(4), bytearray(2)]
    rid = fe.read_file_scatter(fid, 0, bufs)
    fe._wait_one(fid, rid)
    assert bytes(bufs[0]) == b"aaaa"
    assert bytes(bufs[1]) == b"bbbb"
    assert bytes(bufs[2]) == b"cc"


def test_directories_and_listing():
    _, fs, svc, fe = make_stack()
    d = fe.create_directory("logs")
    f1 = fe.create_file("one", d)
    f2 = fe.create_file("two", d)
    assert sorted(fs.list_dir(d)) == ["one", "two"]
    fe.delete_file(f1)
    assert fs.list_dir(d) == ["two"]


def test_metadata_persistence_mount():
    dev, fs, svc, fe = make_stack()
    fid = fe.create_file("persist.me")
    fe.write_sync(fid, 0, b"hello-metadata")
    fe.fsync()
    fs2 = SegmentFS.mount(dev, fs.segment_size)  # remount same device
    assert fs2.files[fid].name == "persist.me"
    assert fs2.files[fid].segments == fs.files[fid].segments
    out = bytearray(14)
    done = []
    fs2.submit_read(fid, 0, 14, memoryview(out), lambda e: done.append(e))
    dev.drain()
    assert done == [wire.E_OK] and bytes(out) == b"hello-metadata"


def test_zero_copy_eliminates_copies():
    _, _, svc_zc, fe_zc = make_stack(zero_copy=True)
    _, _, svc_cp, fe_cp = make_stack(zero_copy=False)
    for fe, svc in ((fe_zc, svc_zc), (fe_cp, svc_cp)):
        fid = fe.create_file("x")
        fe.write_sync(fid, 0, b"q" * 4096)
        fe.read_sync(fid, 0, 4096)
    assert svc_zc.stats.response_copies == 0
    assert svc_zc.stats.request_copies == 0
    assert svc_cp.stats.response_copies > 0   # the straw-man pays copies
    assert svc_cp.stats.request_copies > 0


def test_ordered_responses():
    """Responses are delivered in request order (TailA/B/C discipline)."""
    _, _, svc, fe = make_stack()
    fid = fe.create_file("ord")
    fe.write_sync(fid, 0, bytes(1024))
    rids = [fe.read_file(fid, i * 64, 64) for i in range(8)]
    got = []
    for _ in range(100_000):
        svc.step()
        got += [c.request_id for c in fe.poll_wait(fe._file_group.get(fid, 1))]
        if len(got) >= 8:
            break
    assert got == sorted(got) == rids


def test_error_paths():
    _, _, svc, fe = make_stack()
    fid = fe.create_file("err")
    fe.write_sync(fid, 0, b"abc")
    with pytest.raises(OSError):
        fe.read_sync(fid, 0, 999)       # beyond EOF
    with pytest.raises(OSError):
        fe.read_sync(12345, 0, 4)       # no such file


def test_translate_coalesces_contiguous_segments():
    dev = BlockDevice(1 << 22, block_size=512)
    fs = SegmentFS(dev, 1 << 12)
    fid = fs.create_file("t")
    fs.ensure_capacity(fid, 3 << 12)
    segs = fs.files[fid].segments
    if segs == sorted(segs) and all(b - a == 1 for a, b in zip(segs, segs[1:])):
        runs = fs.translate(fid, 0, 3 << 12)
        assert len(runs) == 1           # adjacent segments coalesce


@settings(max_examples=25, deadline=None)
@given(st.data())
def test_property_random_io(data):
    """Random writes then reads match a shadow buffer (oracle)."""
    _, _, svc, fe = make_stack(segment_size=1 << 12)
    fid = fe.create_file("prop")
    size = 1 << 14
    shadow = bytearray(size)
    fe.write_sync(fid, 0, bytes(size))
    for _ in range(data.draw(st.integers(1, 8))):
        off = data.draw(st.integers(0, size - 1))
        n = data.draw(st.integers(1, min(512, size - off)))
        payload = bytes([data.draw(st.integers(0, 255))]) * n
        fe.write_sync(fid, off, payload)
        shadow[off : off + n] = payload
    for _ in range(4):
        off = data.draw(st.integers(0, size - 1))
        n = data.draw(st.integers(1, min(1024, size - off)))
        assert fe.read_sync(fid, off, n) == bytes(shadow[off : off + n])
