"""Deterministic stand-in for the slice of `hypothesis` this suite uses.

CI installs the real thing (``pip install -e .[test]``); hermetic containers
without network access fall back to this shim so the five property-test
modules still collect and run.  It implements only the API surface the tests
exercise — ``given``/``settings`` plus the ``integers``/``booleans``/
``binary``/``lists``/``tuples``/``sampled_from``/``data`` strategies — with a
seeded PRNG per example and **no shrinking**: a failing example reports its
example index so it can be replayed.

conftest.py registers this module as ``hypothesis`` in ``sys.modules`` only
when the real package is absent.
"""

from __future__ import annotations

import inspect
import random
import types
import zlib

DEFAULT_MAX_EXAMPLES = 20


class SearchStrategy:
    """A strategy is just a seeded-draw function."""

    def __init__(self, draw, name="strategy"):
        self._draw = draw
        self._name = name

    def do_draw(self, rnd: random.Random):
        return self._draw(rnd)

    def __repr__(self):
        return f"<fallback {self._name}>"


def integers(min_value=0, max_value=1 << 30) -> SearchStrategy:
    return SearchStrategy(lambda r: r.randint(min_value, max_value), "integers")


def booleans() -> SearchStrategy:
    return SearchStrategy(lambda r: bool(r.getrandbits(1)), "booleans")


def binary(min_size=0, max_size=64) -> SearchStrategy:
    return SearchStrategy(
        lambda r: bytes(r.getrandbits(8) for _ in range(r.randint(min_size, max_size))),
        "binary")


def sampled_from(elements) -> SearchStrategy:
    elements = list(elements)
    return SearchStrategy(lambda r: elements[r.randrange(len(elements))],
                          "sampled_from")


def lists(elements: SearchStrategy, min_size=0, max_size=16) -> SearchStrategy:
    return SearchStrategy(
        lambda r: [elements.do_draw(r) for _ in range(r.randint(min_size, max_size))],
        "lists")


def tuples(*elems: SearchStrategy) -> SearchStrategy:
    return SearchStrategy(lambda r: tuple(e.do_draw(r) for e in elems), "tuples")


class DataObject:
    """Interactive-draw handle (the argument ``st.data()`` tests receive)."""

    def __init__(self, rnd: random.Random):
        self._rnd = rnd

    def draw(self, strategy: SearchStrategy, label=None):
        return strategy.do_draw(self._rnd)


class _DataStrategy(SearchStrategy):
    def __init__(self):
        super().__init__(lambda r: DataObject(r), "data")


def data() -> _DataStrategy:
    return _DataStrategy()


def _seed_for(func_name: str, example: int) -> int:
    return zlib.crc32(f"dds:{func_name}:{example}".encode())


def given(*strategies: SearchStrategy, **kw_strategies: SearchStrategy):
    """Right-align positional strategies onto the test's parameters, run
    ``max_examples`` deterministic examples, re-raise on first failure."""

    def decorate(func):
        params = list(inspect.signature(func).parameters)
        npos = len(strategies)
        pos_names = params[len(params) - npos:] if npos else []

        def wrapper(*args, **kwargs):
            # settings() may have decorated either the wrapper (settings
            # above given) or the raw function (settings below given).
            max_examples = getattr(wrapper, "_max_examples",
                                   getattr(func, "_max_examples",
                                           DEFAULT_MAX_EXAMPLES))
            for i in range(max_examples):
                rnd = random.Random(_seed_for(func.__qualname__, i))
                drawn = dict(zip(pos_names,
                                 (s.do_draw(rnd) for s in strategies)))
                for name, s in kw_strategies.items():
                    drawn[name] = s.do_draw(rnd)
                try:
                    func(*args, **drawn, **kwargs)
                except _UnsatisfiedAssumption:
                    continue  # assume() failed: discard this example
                except Exception as e:
                    raise AssertionError(
                        f"falsifying example #{i} of {func.__qualname__} "
                        f"(deterministic seed {_seed_for(func.__qualname__, i)}): "
                        f"{e!r}") from e

        wrapper.__name__ = func.__name__
        wrapper.__qualname__ = func.__qualname__
        wrapper.__doc__ = func.__doc__
        wrapper.__module__ = func.__module__
        covered = set(pos_names) | set(kw_strategies)
        wrapper.__signature__ = inspect.Signature(
            [p for n, p in inspect.signature(func).parameters.items()
             if n not in covered])
        wrapper.is_hypothesis_test = True
        return wrapper

    return decorate


def settings(max_examples: int = DEFAULT_MAX_EXAMPLES, deadline=None, **_ignored):
    """Works above or below ``@given`` (attribute is read lazily)."""

    def decorate(func):
        func._max_examples = max_examples
        return func

    return decorate


def assume(condition) -> bool:
    """Weak `assume`: abandon the example silently when unsatisfied."""
    if not condition:
        raise _UnsatisfiedAssumption()
    return True


class _UnsatisfiedAssumption(Exception):
    pass


def build_modules() -> tuple[types.ModuleType, types.ModuleType]:
    """Create importable ``hypothesis`` + ``hypothesis.strategies`` modules."""
    strategies = types.ModuleType("hypothesis.strategies")
    for fn in (integers, booleans, binary, sampled_from, lists, tuples, data):
        setattr(strategies, fn.__name__, fn)
    strategies.SearchStrategy = SearchStrategy

    hyp = types.ModuleType("hypothesis")
    hyp.given = given
    hyp.settings = settings
    hyp.assume = assume
    hyp.strategies = strategies
    hyp.__version__ = "0.0-dds-fallback"
    hyp.__is_dds_fallback__ = True
    return hyp, strategies
