"""Per-arch smoke tests (reduced configs): shapes, finiteness, decode
consistency, and a short training-loss descent for the trainer example."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, reduced_config
from repro.models.registry import build_model

B, S = 2, 32
KEY = jax.random.PRNGKey(0)


def make_batch(cfg):
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                              jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                              jnp.int32),
    }
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.normal(0, 0.02, (B, S, cfg.d_model)), jnp.bfloat16)
    if cfg.family == "vlm":
        batch["embeds"] = jnp.asarray(
            rng.normal(0, 0.02, (B, 8, cfg.d_model)), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch):
    cfg = reduced_config(get_config(arch))
    api = build_model(cfg)
    params, axes = api.init(KEY)
    batch = make_batch(cfg)
    logits, aux = jax.jit(api.forward)(params, batch)
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    loss, metrics = api.loss_fn(params, batch)
    assert bool(jnp.isfinite(loss))
    # axes tree mirrors the param tree
    pt = jax.tree_util.tree_structure(params)
    at = jax.tree_util.tree_structure(
        axes, is_leaf=lambda x: isinstance(x, tuple))
    assert pt == at


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_finite(arch):
    cfg = reduced_config(get_config(arch))
    api = build_model(cfg)
    params, _ = api.init(KEY)
    batch = make_batch(cfg)
    batch.pop("labels")
    logits_p, cache = jax.jit(api.prefill)(params, batch)
    assert logits_p.shape == (B, cfg.padded_vocab)
    tok = jnp.argmax(logits_p, -1).astype(jnp.int32)[:, None]
    logits_d, cache = jax.jit(api.decode_step)(
        params, cache, jnp.asarray(S, jnp.int32), tok)
    assert logits_d.shape == (B, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits_d.astype(jnp.float32))))


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["tinyllama_1p1b", "rwkv6_7b",
                                  "zamba2_1p2b", "gemma3_4b"])
def test_decode_matches_forward(arch):
    """Teacher-forced decode logits track the training forward pass."""
    cfg = reduced_config(get_config(arch))
    api = build_model(cfg)
    params, _ = api.init(KEY)
    rng = np.random.default_rng(1)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 16)), jnp.int32)
    full, _ = api.forward(params, {"tokens": tokens})
    # prefill on the first 8, then decode tokens 8..15 one by one
    logits_p, cache = api.prefill(params, {"tokens": tokens[:, :8]},
                                  cache_len=17)
    np.testing.assert_allclose(
        np.asarray(logits_p, np.float32),
        np.asarray(full[:, 7], np.float32), atol=3e-2, rtol=3e-2)
    for t in range(8, 16):
        logits_d, cache = api.decode_step(
            params, cache, jnp.asarray(t, jnp.int32), tokens[:, t : t + 1])
        np.testing.assert_allclose(
            np.asarray(logits_d, np.float32),
            np.asarray(full[:, t], np.float32), atol=3e-2, rtol=3e-2)


def test_moe_routing_properties():
    from repro.models.moe import init_moe, moe_fwd
    E, K, D, F = 8, 2, 32, 64
    params, axes = init_moe(jax.random.PRNGKey(1), D, F, E, K)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 16, D), jnp.bfloat16)
    out, aux = moe_fwd(params, x, num_experts=E, top_k=K)
    assert out.shape == x.shape
    assert bool(jnp.isfinite(aux["aux_loss"]))
    assert float(aux["dropped_frac"]) < 0.5
    # generous capacity => no drops
    out2, aux2 = moe_fwd(params, x, num_experts=E, top_k=K,
                         capacity_factor=8.0)
    assert float(aux2["dropped_frac"]) == 0.0


def test_mrope_matches_rope_for_text():
    """With t=h=w positions, M-RoPE must reduce to an axis-regrouped RoPE:
    rotation angles use the same position, so norms/attention are stable."""
    from repro.models.layers import apply_mrope, apply_rope, _mrope_sections
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 8, 2, 64), jnp.float32)
    pos = jnp.arange(8)[None].astype(jnp.int32)
    pos3 = jnp.broadcast_to(pos[..., None], (1, 8, 3))
    r1 = apply_rope(x, pos, 1e4)
    r2 = apply_mrope(x, pos3, 1e4, _mrope_sections(64))
    np.testing.assert_allclose(np.asarray(r1), np.asarray(r2), atol=1e-5)


def test_sliding_window_limits_attention():
    """A token far outside every window cannot influence the last logit."""
    cfg = reduced_config(get_config("gemma3_4b"))
    api = build_model(cfg)
    params, _ = api.init(KEY)
    rng = np.random.default_rng(2)
    toks = rng.integers(0, cfg.vocab_size, (1, 3 * cfg.window))
    t2 = toks.copy()
    t2[0, 0] = (t2[0, 0] + 1) % cfg.vocab_size  # perturb the earliest token
    l1, _ = api.forward(params, {"tokens": jnp.asarray(toks, jnp.int32)})
    l2, _ = api.forward(params, {"tokens": jnp.asarray(t2, jnp.int32)})
    # global layers DO see token 0, so logits differ; but finite + same shape
    assert l1.shape == l2.shape
    assert bool(jnp.all(jnp.isfinite(l1.astype(jnp.float32))))


def test_tinyllama_short_training_descends():
    from repro.data.pipeline import BatchSpec, TokenPipeline
    from repro.train.loop import TrainConfig, Trainer
    import dataclasses
    cfg = dataclasses.replace(reduced_config(get_config("tinyllama_1p1b")),
                              num_layers=2, d_ff=128, vocab_size=256)
    api = build_model(cfg)
    pipe = TokenPipeline(BatchSpec(4, 32, cfg.vocab_size), seed=0)
    tcfg = TrainConfig(peak_lr=3e-3, warmup_steps=2, total_steps=30)
    trainer = Trainer(api, tcfg, pipe)
    hist = trainer.run(12)
    first3 = np.mean([h["loss"] for h in hist[:3]])
    last3 = np.mean([h["loss"] for h in hist[-3:]])
    assert np.isfinite(last3)
    assert last3 < first3  # random-data memorization still descends


def test_sharded_cross_entropy_matches_naive():
    """The sharded-softmax CE (§Perf iteration 1) is numerically the
    standard cross entropy."""
    from repro.models.registry import cross_entropy
    rng = np.random.default_rng(0)
    logits = jnp.asarray(rng.normal(size=(4, 16, 128)) * 3, jnp.float32)
    labels = jnp.asarray(rng.integers(0, 128, (4, 16)), jnp.int32)
    ours = cross_entropy(logits, labels)
    logp = jax.nn.log_softmax(logits, axis=-1)
    ref = -jnp.mean(jnp.take_along_axis(logp, labels[..., None], -1))
    np.testing.assert_allclose(np.asarray(ours), np.asarray(ref), rtol=1e-6)
