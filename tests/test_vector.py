"""Vectorized data plane: kernel ≡ scalar properties + checksummed writev.

Three layers of coverage for the array-at-a-time kernels (README
"Vectorized data plane"):

  * property tests proving each vector kernel bit-identical to its scalar
    reference (splitmix64, key hashing, frame detect/pack, checksums) —
    the equivalence arguments the burst fast paths rest on;
  * the integrity checksum pipeline end to end: position-salted checksums
    detect bit flips / transpositions / truncation, the block device's
    opt-in per-block checksums fail corrupted reads with EIO on every
    read path (callback, burst, cookie), the torn-writev prefix commits
    its checksums, and a corrupted journal record refuses to replay;
  * the predicate->engine single-probe memo: consumed when the table is
    untouched between the routing probe and the engine step, invalidated
    (and re-probed) by ANY table mutation in between.
"""

import numpy as np
from hypothesis import given, settings, strategies as st

from repro.apps.kv_store import (KVClient, KVLocation, ShardedKVStore,
                                 encode_get)
from repro.core import vector, wire
from repro.core.cache_table import CacheTable
from repro.core.dds_server import ServerConfig
from repro.core.file_service import _JREC, SegmentFS
from repro.storage.blockdev import (CRC_BLOCK, STATUS_EINVAL, STATUS_EIO,
                                    STATUS_OK, BlockDevice)

# ---------------------------------------------------------------------------
# Kernel ≡ scalar reference (the equivalence the fast paths rest on)
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(st.lists(st.integers(0, vector.MASK64), min_size=1, max_size=64),
       st.sampled_from([0, vector.LEN_SEED, vector.GOLD]))
def test_mix64_matches_scalar_mix(xs, seed):
    arr = np.array(xs, dtype=np.uint64)
    got = vector.mix64(arr, seed)
    want = [vector.scalar_mix(x, seed) for x in xs]
    assert got.tolist() == want


@settings(max_examples=20, deadline=None)
@given(st.lists(st.binary(min_size=0, max_size=24), min_size=1, max_size=32),
       st.lists(st.integers(0, (1 << 62)), min_size=1, max_size=32))
def test_hash_keys_matches_cache_table_hash(bkeys, ikeys):
    t = CacheTable(64)
    keys = list(bkeys) + list(ikeys)
    got = vector.hash_keys(keys)
    want = [t._hash_key(k) for k in keys]
    assert got.tolist() == want


def test_hash_keys_big_int_fallback():
    # > int64: np.fromiter overflows -> the per-item masked path
    t = CacheTable(64)
    keys = [2**64 - 1, 2**63 + 17, 5]
    assert vector.hash_keys(keys).tolist() == [t._hash_key(k) for k in keys]


def _frames(lens):
    out = bytearray()
    for i, ln in enumerate(lens):
        out += ln.to_bytes(4, "little") + bytes([i & 0xFF]) * ln
    return bytes(out)


@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(0, 40), min_size=1, max_size=24), st.booleans())
def test_uniform_stride_claims_match_greedy_decode(lens, uniform):
    if uniform:
        lens = [max(lens[0], 1)] * len(lens)
    buf = _frames(lens)
    got = vector.uniform_stride(buf, 4)
    if got is None:
        return  # no claim: callers run the scalar walk
    n, stride, ln = got
    # The claim must agree with the greedy sequential decoder: the first
    # n frames all have payload length ln at stride multiples.
    pos = 0
    for _ in range(n):
        assert int.from_bytes(buf[pos:pos + 4], "little") == ln
        pos += 4 + ln
    assert pos == n * stride <= len(buf)


@settings(max_examples=30, deadline=None)
@given(st.lists(st.binary(min_size=0, max_size=32), min_size=1, max_size=24),
       st.booleans())
def test_pack_frames_matches_scalar_join(msgs, uniform):
    if uniform:  # force the n>=8 fixed-stride fast path
        m = msgs[0] or b"x"
        msgs = [m] * max(len(msgs), 8)
    want = b"".join(len(m).to_bytes(4, "little") + m for m in msgs)
    assert bytes(vector.pack_frames(msgs)) == want


@settings(max_examples=40, deadline=None)
@given(st.binary(min_size=0, max_size=200))
def test_checksum64_matches_scalar(blob):
    assert vector.checksum64(blob) == vector.checksum64_scalar(blob)


@settings(max_examples=30, deadline=None)
@given(st.data())
def test_block_checksums_match_per_block(data):
    block = data.draw(st.sampled_from([64, 512, 4096]))
    nblocks = data.draw(st.integers(1, 8))
    mem = np.frombuffer(
        bytes(data.draw(st.integers(0, 255)) for _ in range(64)) * (
            block * nblocks // 64),
        dtype=np.uint8).copy()
    got = vector.block_checksums(mem, 0, nblocks, block)
    want = [vector.checksum64(mem[i * block:(i + 1) * block].tobytes())
            for i in range(nblocks)]
    assert got.tolist() == want


# ---------------------------------------------------------------------------
# Checksum detection properties (the CRC32C role on the writev path)
# ---------------------------------------------------------------------------


@settings(max_examples=40, deadline=None)
@given(st.binary(min_size=1, max_size=128), st.data())
def test_checksum_detects_bit_flip(blob, data):
    blob = bytearray(blob)
    c0 = vector.checksum64(blob)
    blob[data.draw(st.integers(0, len(blob) - 1))] ^= \
        1 << data.draw(st.integers(0, 7))
    assert vector.checksum64(blob) != c0


@settings(max_examples=20, deadline=None)
@given(st.lists(st.integers(0, vector.MASK64), min_size=2, max_size=16),
       st.data())
def test_checksum_detects_word_transposition(words, data):
    i = data.draw(st.integers(0, len(words) - 2))
    j = data.draw(st.integers(i + 1, len(words) - 1))
    if words[i] == words[j]:
        words[j] ^= 1
    blob = b"".join(w.to_bytes(8, "little") for w in words)
    swapped = list(words)
    swapped[i], swapped[j] = swapped[j], swapped[i]
    blob2 = b"".join(w.to_bytes(8, "little") for w in swapped)
    assert vector.checksum64(blob) != vector.checksum64(blob2)


@settings(max_examples=20, deadline=None)
@given(st.binary(min_size=0, max_size=64))
def test_checksum_detects_truncation_and_zero_extension(blob):
    c = vector.checksum64(blob)
    assert vector.checksum64(blob + b"\x00") != c
    if blob:
        assert vector.checksum64(blob[:-1]) != c


# ---------------------------------------------------------------------------
# Device-level block checksums: every read path detects corrupt media
# ---------------------------------------------------------------------------


def test_checksummed_device_fails_corrupted_reads_on_every_path():
    dev = BlockDevice(1 << 20, block_size=512)
    dev.enable_checksums()
    blob = bytes(range(256)) * 16          # one CRC_BLOCK
    acks = []
    dev.submit_write(2 * CRC_BLOCK, blob, on_complete=acks.append)
    dev.poll()
    assert acks == [STATUS_OK]
    assert dev.verify_blocks() == 0        # commit refreshed the stored CRC

    dev._mem[2 * CRC_BLOCK + 17] ^= 0x5A   # out-of-band media corruption
    assert dev.verify_blocks(2 * CRC_BLOCK, CRC_BLOCK) == 1

    # Callback path: EIO, and NO bytes delivered into the caller's view.
    sts = []
    dst = memoryview(bytearray(64))
    dev.submit_read(2 * CRC_BLOCK, 64, dst, on_complete=sts.append)
    dev.poll()
    assert sts == [STATUS_EIO] and bytes(dst) == bytes(64)

    # Burst path: the corrupt op fails alone, its clean neighbor succeeds.
    sts2 = []
    d_ok, d_bad = memoryview(bytearray(64)), memoryview(bytearray(64))
    dev.submit_read_many(
        [(0, 64, d_ok, lambda s: sts2.append(("ok", s))),
         (2 * CRC_BLOCK, 64, d_bad, lambda s: sts2.append(("bad", s)))],
        priority=True)
    dev.poll()
    assert sts2 == [("ok", STATUS_OK), ("bad", STATUS_EIO)]

    # Cookie path: the completion queue carries the EIO.
    dev.submit_read(2 * CRC_BLOCK, 64, memoryview(bytearray(64)), cookie=7)
    dev.poll()
    assert dev.reap() == [(7, STATUS_EIO)]
    assert dev.stats.crc_read_failures == 3

    # A fresh write over the corrupt block re-commits: reads are clean again.
    dev.submit_write(2 * CRC_BLOCK, blob, on_complete=acks.append)
    dev.poll()
    sts3 = []
    out = memoryview(bytearray(len(blob)))
    dev.submit_read(2 * CRC_BLOCK, len(blob), out, on_complete=sts3.append)
    dev.poll()
    assert sts3 == [STATUS_OK] and bytes(out) == blob


def test_torn_writev_prefix_commits_its_checksums():
    dev = BlockDevice(1 << 20, block_size=512)
    dev.enable_checksums()
    dev.inject_torn_writev(nth=1, chunks=1)
    dev.submit_writev(CRC_BLOCK, [b"\x11" * CRC_BLOCK, b"\x22" * CRC_BLOCK],
                      cookie=1)
    dev.poll()
    assert dev.crashed
    assert dev.raw_read(CRC_BLOCK, CRC_BLOCK) == b"\x11" * CRC_BLOCK
    # The prefix that DID reach media carries matching checksums: recovery
    # reads of survived bytes must not false-positive as corruption.
    assert dev.verify_blocks() == 0


def test_raw_write_commits_checksums():
    dev = BlockDevice(1 << 20, block_size=512)
    dev.enable_checksums()
    dev.raw_write(0, b"\x77" * 100)        # metadata-style raw commit
    assert dev.verify_blocks() == 0


def test_server_config_knob_enables_device_checksums():
    from repro.core.dds_server import DDSStorageServer
    srv = DDSStorageServer(ServerConfig(device_capacity=1 << 22,
                                        segment_size=1 << 16,
                                        verify_checksums=True))
    assert srv.device._crc is not None
    assert srv.device.verify_blocks() == 0
    srv2 = DDSStorageServer(ServerConfig(device_capacity=1 << 22,
                                         segment_size=1 << 16))
    assert srv2.device._crc is None        # default: off


# ---------------------------------------------------------------------------
# Journal body checksum: a corrupted committed record refuses to replay
# ---------------------------------------------------------------------------


def _crashed_journaled_write(payload):
    dev = BlockDevice(1 << 22, block_size=512)
    fs = SegmentFS(dev, 1 << 16, journal_segments=2)
    fid = fs.create_file("f")
    assert fs.submit_writev(fid, 0, [payload], cookie=1) == wire.E_OK
    # The device queue holds [journal writev, commit flip, in-place writev]:
    # complete the first two, then crash — committed record, no in-place.
    dev.poll(2)
    dev.crash()
    return dev, fs, fid


def test_committed_journal_record_replays_after_crash():
    payload = b"\x33" * 1024
    dev, fs, fid = _crashed_journaled_write(payload)
    fs2 = SegmentFS.mount(dev, 1 << 16, journal_segments=2)
    rec = fs2.recover_journal()
    assert rec == {"records": 1, "bytes": len(payload)}
    assert fs2.journal_crc_failures == 0
    phys = fs2.files[fid].segments[0] * (1 << 16)
    assert dev.raw_read(phys, len(payload)) == payload


def test_corrupted_journal_record_is_detected_not_replayed():
    payload = b"\x33" * 1024
    dev, fs, fid = _crashed_journaled_write(payload)
    # Flip one payload byte of the committed record on the survived media
    # (header: _JREC fields, then nsegs * u32 segment map, then payload).
    corrupt_at = fs._journal_start + _JREC.size + 4 + 100
    dev._mem[corrupt_at] ^= 0xFF
    fs2 = SegmentFS.mount(dev, 1 << 16, journal_segments=2)
    rec = fs2.recover_journal()
    assert rec == {"records": 0, "bytes": 0}   # refused, scan stopped
    assert fs2.journal_crc_failures == 1


# ---------------------------------------------------------------------------
# Burst read submission: scalar semantics preserved entry for entry
# ---------------------------------------------------------------------------


def test_submit_read_many_order_einval_and_contents():
    dev = BlockDevice(1 << 20, block_size=512)
    media = bytes(range(256)) * 32
    dev.raw_write(0, media)
    sts = []
    outs = [memoryview(bytearray(32)) for _ in range(5)]
    reads = [(i * 32, 32, outs[i], lambda s, i=i: sts.append((i, s)))
             for i in range(5)]
    # An out-of-bounds op in the middle: EINVAL fires AT SUBMIT (scalar
    # semantics), the rest land on the queue in list order.
    reads.insert(2, (1 << 20, 32, memoryview(bytearray(32)),
                     lambda s: sts.append(("inv", s))))
    dev.submit_read_many(reads, priority=True)
    assert sts == [("inv", STATUS_EINVAL)]
    dev.poll()
    assert sts[1:] == [(i, STATUS_OK) for i in range(5)]
    for i, out in enumerate(outs):
        assert bytes(out) == media[i * 32:(i + 1) * 32]
    assert dev.stats.reads == 5


# ---------------------------------------------------------------------------
# Predicate -> engine single-probe memo (epoch-guarded handoff)
# ---------------------------------------------------------------------------


def _memo_stack():
    store = ShardedKVStore(num_shards=1,
                           config=ServerConfig(device_capacity=1 << 24,
                                               segment_size=1 << 18))
    cli = KVClient(store)
    keys = [b"memo-key-%04d" % i for i in range(32)]
    handles = cli.put_many([(k, b"v" * 32) for k in keys])
    cli.harvest(handles)
    srv = store.cluster.servers[0]
    msgs = [encode_get(1000 + i, k) for i, k in enumerate(keys)]
    payload = b"".join(len(m).to_bytes(4, "little") + m for m in msgs)
    assert len(payload) >= 512  # big enough for the columnar route
    return store, srv, keys, payload


def test_probe_memo_consumed_without_table_mutation():
    store, srv, keys, payload = _memo_stack()
    api, table = srv.api, srv.cache_table
    host, dpu = api.off_pred(payload, table)
    assert not host and len(dpu) == len(keys)
    before = table.stats.lookups
    res = api.prepare_read_many(dpu, table)
    # The memo carried the predicate's probe: the engine did NOT re-probe.
    assert table.stats.lookups == before
    idx = store._states[0].index
    for r, k in zip(res, keys):
        assert r is not None and r[0] == idx[k]


def test_probe_memo_invalidated_by_mutation_between_probe_and_engine():
    store, srv, keys, payload = _memo_stack()
    api, table = srv.api, srv.cache_table
    host, dpu = api.off_pred(payload, table)
    assert not host and len(dpu) == len(keys)
    # ANY table mutation between the routing probe and the engine step
    # bumps the epoch: the memo must be ignored and the burst re-probed.
    table.insert(b"__interloper__", KVLocation(0, 0, 0))
    before = table.stats.lookups
    res = api.prepare_read_many(dpu, table)
    assert table.stats.lookups == before + len(keys)   # full re-probe
    idx = store._states[0].index
    for r, k in zip(res, keys):
        assert r is not None and r[0] == idx[k]


def test_probe_memo_invalidated_by_delete():
    store, srv, keys, payload = _memo_stack()
    api, table = srv.api, srv.cache_table
    host, dpu = api.off_pred(payload, table)
    assert len(dpu) == len(keys)
    table.delete(keys[3])   # the memoized location is now gone
    res = api.prepare_read_many(dpu, table)
    assert res[3] is None   # re-probe sees the delete — never a stale loc
    idx = store._states[0].index
    for i, (r, k) in enumerate(zip(res, keys)):
        if i != 3:
            assert r is not None and r[0] == idx[k]
