import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Property tests use hypothesis; hermetic containers without network access
# fall back to the deterministic shim in _hypothesis_fallback (CI installs
# the real package via `pip install -e .[test]`).
try:
    import hypothesis  # noqa: F401
except ImportError:
    sys.path.insert(0, os.path.dirname(__file__))
    import _hypothesis_fallback

    _hyp, _strategies = _hypothesis_fallback.build_modules()
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _strategies
