"""Heartbeats, stragglers, checkpoint/restart, elastic shrink."""

import dataclasses

import numpy as np
import pytest

from repro.configs import get_config, reduced_config
from repro.core.dds_server import DDSStorageServer, ServerConfig
from repro.data.pipeline import BatchSpec, TokenPipeline
from repro.distributed.fault_tolerance import (HeartbeatMonitor,
                                               StragglerDetector,
                                               TrainSupervisor)
from repro.models.registry import build_model
from repro.storage.checkpoint import CheckpointManager
from repro.train.loop import TrainConfig, Trainer


def test_heartbeat_monitor_detects_dead():
    clock = {"t": 0.0}
    mon = HeartbeatMonitor(["h0", "h1"], timeout_s=10,
                           now=lambda: clock["t"])
    mon.beat("h0", 1)
    mon.beat("h1", 1)
    clock["t"] = 5.0
    mon.beat("h0", 2)
    clock["t"] = 12.0
    assert mon.dead_hosts() == ["h1"]
    assert mon.hosts["h0"].alive


def test_straggler_detector():
    det = StragglerDetector(threshold=1.5, window=8, min_samples=4)
    for step in range(8):
        for h in ("a", "b", "c", "d"):
            det.record(h, 1.0 if h != "d" else 2.2)
    bad = det.stragglers()
    assert len(bad) == 1 and bad[0][0] == "d"
    assert bad[0][1] == pytest.approx(2.2, rel=0.1)


def _tiny_trainer(ckpt=True, ckpt_every=4):
    cfg = dataclasses.replace(reduced_config(get_config("tinyllama_1p1b")),
                              num_layers=2, d_ff=64, vocab_size=256,
                              d_model=64, num_heads=2, num_kv_heads=2,
                              head_dim=32)
    api = build_model(cfg)
    pipe = TokenPipeline(BatchSpec(2, 16, cfg.vocab_size), seed=0)
    cm = (CheckpointManager(DDSStorageServer(ServerConfig()), keep=2)
          if ckpt else None)
    tcfg = TrainConfig(peak_lr=1e-3, warmup_steps=2, total_steps=50)
    return Trainer(api, tcfg, pipe, checkpoint_mgr=cm, ckpt_every=ckpt_every)


@pytest.mark.slow
def test_crash_restart_resumes_from_checkpoint():
    trainer = _tiny_trainer()
    failures = {6: "host3"}  # crash at step 6 (after the step-4 checkpoint)
    sup = TrainSupervisor(
        trainer, [f"host{i}" for i in range(4)],
        inject_failure=lambda s: failures.pop(s, None))
    hist = sup.run(10)
    assert sup.restarts == 1
    assert sup.events[0].kind == "crash"
    assert "host3" not in sup.hosts           # elastic shrink
    # we replayed steps 4..6 after restoring the step-4 checkpoint
    steps = [h["step"] for h in trainer.history]
    assert trainer.step >= 10
    assert trainer.ckpt.latest_step() is not None


def test_restart_without_checkpoint_restarts_clean():
    trainer = _tiny_trainer(ckpt=True, ckpt_every=100)  # never checkpoints
    failures = {2: "host1"}
    sup = TrainSupervisor(trainer, ["host0", "host1"],
                          inject_failure=lambda s: failures.pop(s, None))
    sup.run(5)
    assert sup.restarts == 1
    assert sup.events[0].action == "restart_shrunk"
    assert trainer.step >= 5


def test_elastic_world_resharding_data_pipeline():
    """After shrinking the world, ranks repartition the same global batch."""
    spec = BatchSpec(8, 16, 100)
    before = [TokenPipeline(spec, seed=7, rank=r, world=4).batch_at(3)
              for r in range(4)]
    after = [TokenPipeline(spec, seed=7, rank=r, world=2).batch_at(3)
             for r in range(2)]
    tot_b = np.concatenate([b["tokens"] for b in before])
    tot_a = np.concatenate([a["tokens"] for a in after])
    assert tot_b.shape[0] == tot_a.shape[0] == 8  # same global batch size
