"""End-to-end DDS storage server behaviour (§8.1 app + §9 integrations)."""

import pytest

from repro.core import wire
from repro.core.dds_server import (DDSClient, DDSStorageServer, ServerConfig,
                                   encode_batch)
from repro.storage.pagestore import KVStoreServer, PageStore


@pytest.fixture()
def server():
    srv = DDSStorageServer(ServerConfig())
    fid = srv.frontend.create_file("bench.dat")
    srv.frontend.write_sync(fid, 0, bytes(range(256)) * 64)  # 16 KiB
    srv.run_until_idle()
    return srv, fid


def test_offloaded_read(server):
    srv, fid = server
    cli = DDSClient(srv)
    rid = cli.read(fid, 512, 256)
    status, body = cli.wait(rid)
    assert status == wire.E_OK
    assert body == (bytes(range(256)) * 64)[512:768]
    assert srv.offload.stats.completed == 1
    assert srv.director.stats.to_dpu == 1
    assert srv.host_cpu_busy_s == 0.0       # zero host CPU on the read path


def test_write_takes_host_path(server):
    srv, fid = server
    cli = DDSClient(srv)
    rid = cli.write(fid, 0, b"W" * 128)
    status, _ = cli.wait(rid)
    assert status == wire.E_OK
    assert srv.director.stats.to_host == 1
    assert srv.host_cpu_busy_s > 0.0        # writes burn host CPU (Fig 14b)
    rid = cli.read(fid, 0, 128)
    status, body = cli.wait(rid)
    assert body == b"W" * 128               # read-your-writes through the DPU


def test_mixed_batch_splits(server):
    """One network message with reads+writes splits between DPU and host."""
    srv, fid = server
    cli = DDSClient(srv)
    rids = cli.send_batch([("r", fid, 0, 64), ("w", fid, 4096, b"x" * 64),
                           ("r", fid, 64, 64)])
    results = {r: cli.wait(r) for r in rids}
    assert all(status == wire.E_OK for status, _ in results.values())
    assert srv.director.stats.to_dpu == 2
    assert srv.director.stats.to_host == 1


def test_large_read_segmented_and_reassembled(server):
    srv, fid = server
    cli = DDSClient(srv)
    rid = cli.read(fid, 0, 8192)            # > MTU: multiple packets
    status, body = cli.wait(rid)
    assert status == wire.E_OK and len(body) == 8192
    assert srv.offload.stats.packets > 5


def test_zero_copy_accounting(server):
    srv, fid = server
    cli = DDSClient(srv)
    status, _ = cli.wait(cli.read(fid, 0, 2048))
    assert status == wire.E_OK
    assert srv.offload.stats.data_copies == 0


def test_context_ring_full_bounces_to_host():
    cfg = ServerConfig(offload_ring=2)
    srv = DDSStorageServer(cfg)
    fid = srv.frontend.create_file("f")
    srv.frontend.write_sync(fid, 0, bytes(4096))
    srv.run_until_idle()
    cli = DDSClient(srv)
    rids = [cli.read(fid, i * 64, 64) for i in range(8)]
    for r in rids:
        status, body = cli.wait(r)
        assert status == wire.E_OK and len(body) == 64
    # with a 2-slot ring under 8 outstanding reads, some must have bounced
    assert srv.offload.stats.bounced_to_host + srv.offload.stats.completed == 8


def test_page_store_lsn_semantics():
    ps = PageStore()
    ps.replay(3, lsn=50, payload=b"v50")
    cli = DDSClient(ps.server)
    cli._send(encode_batch([PageStore.encode_get(1, 3, 50)]))
    status, body = cli.wait(1)
    lsn, payload = PageStore.decode_page(body)
    assert (status, lsn) == (wire.E_OK, 50) and payload[:3] == b"v50"
    assert ps.server.offload.stats.completed == 1   # served by the DPU
    # requested LSN newer than cached -> host serves (partial offload)
    cli._send(encode_batch([PageStore.encode_get(2, 3, 99)]))
    status, body = cli.wait(2)
    assert status == wire.E_OK and ps.host_served == 1
    # invalidate-on-read: host pulls the page back -> next GET -> host
    ps.host_read_for_update(3)
    cli._send(encode_batch([PageStore.encode_get(3, 3, 10)]))
    cli.wait(3)
    assert ps.host_served == 2


def test_kv_store_tail_vs_disk():
    kv = KVStoreServer()
    kv.upsert(b"cold", b"on-disk-value")
    kv.flush()                                # -> cache-on-write fires
    kv.upsert(b"hot", b"tail-value")          # stays in the mutable tail
    cli = DDSClient(kv.server)
    cli._send(encode_batch([KVStoreServer.encode_get(1, b"cold")]))
    status, body = cli.wait(1)
    k, v = KVStoreServer.decode_record(body)
    assert (k, v) == (b"cold", b"on-disk-value")
    assert kv.server.offload.stats.completed == 1   # DPU-served
    cli._send(encode_batch([KVStoreServer.encode_get(2, b"hot")]))
    status, body = cli.wait(2)
    k, v = KVStoreServer.decode_record(body)
    assert (k, v) == (b"hot", b"tail-value")        # host-served (RMW data)
    cli._send(encode_batch([KVStoreServer.encode_get(3, b"missing")]))
    status, body = cli.wait(3)
    assert status == wire.E_NOENT


def test_kv_rmw_on_host():
    kv = KVStoreServer()
    kv.upsert(b"ctr", (0).to_bytes(8, "little"))
    kv.flush()
    for _ in range(5):
        kv.rmw(b"ctr", lambda cur: (
            int.from_bytes(cur or bytes(8), "little") + 1).to_bytes(8, "little"))
    assert int.from_bytes(kv.get_local(b"ctr"), "little") == 5


def test_host_only_baseline_mode():
    """offload_enabled=False: everything is hardware-forwarded to the host."""
    srv = DDSStorageServer(ServerConfig(offload_enabled=False))
    fid = srv.frontend.create_file("base")
    srv.frontend.write_sync(fid, 0, bytes(1024))
    srv.run_until_idle()
    cli = DDSClient(srv)
    status, body = cli.wait(cli.read(fid, 0, 128))
    assert status == wire.E_OK and len(body) == 128
    assert srv.offload.stats.completed == 0
    assert srv.director.stats.hw_forwarded >= 1
