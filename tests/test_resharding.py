"""Elastic resharding: crash-safe live shard add/remove (the scale PR).

Covers the online-membership overhaul end to end:

  * ring membership: incremental ``add_node``/``remove_node`` agree with
    from-scratch construction; ``remap_fraction`` bounds the migration
    volume (hypothesis property: one joiner remaps ~1/(n+1); a leaver
    remaps EXACTLY its own ranges);
  * live growth: ``add_shard`` streams owned keys source -> destination
    over the host wire while serving traffic, dual-routes writes during
    the handoff (held acks), flips ownership atomically with an epoch
    bump, and sheds the source copies after a grace window;
  * live shrink: ``remove_shard`` drains a member out of the ring and
    retires it;
  * the crash matrix: killing or partitioning either endpoint at every
    phase (setup, stream, dual, flip, cleanup) resolves to an unambiguous
    ring with zero lost acknowledged writes — pre-flip faults abort
    cleanly, a source lost AT the flip proceeds (the gate already proved
    the destination holds every acked byte), post-flip faults only end
    the cleanup drain early;
  * migration under a lossy wire: drop/dup/reorder on the migration flows
    still yields a byte-identical destination with exactly-once sync
    application (per-key single-flight + the server dedup cache);
  * tombstones: a deleted key stays dead across replica promotion AND
    across partition-heal re-silvering (the PR7 resurrection fix);
  * observability: per-shard heat, hot-shard detection, migration
    counters in ``shard_stats``/``latency_stats``;
  * client elasticity: connections grow on the epoch bump so old clients
    reach shards born after them.
"""

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import wire
from repro.core.dds_server import ServerConfig
from repro.core.faultnet import FaultSchedule, wrap_director
from repro.apps.kv_store import (KVClient, ShardedKVStore, decode_record)
from repro.distributed.cluster import DDSCluster, HashRing
from repro.distributed.resharding import PHASES

ECFG = dict(device_capacity=1 << 24, dedup_cache=256)
RCFG = dict(replication=1, heartbeat_timeout_ticks=6, **ECFG)


def _preload(store, n, prefix=b"rk"):
    c = KVClient(store, timeout_ticks=16)
    keys = [b"%s%04d" % (prefix, i) for i in range(n)]
    res = c.harvest(c.submit([("put", k, b"val:" + k) for k in keys]))
    assert all(s == wire.E_OK for s, _ in res.values())
    store.cluster.run_until_idle()
    return c, keys


def _assert_all_readable(store, expect: dict):
    """Every acked write is visible with its exact bytes (the zero-lost-
    acked-writes oracle); deleted keys answer E_NOENT."""
    v = KVClient(store, timeout_ticks=16)
    rids = v.submit([("get", k) for k in expect])
    res = v.harvest(rids)
    for k, rid in zip(expect, rids):
        status, body = res[rid]
        if expect[k] is None:
            assert status == wire.E_NOENT, (k, status)
        else:
            assert status == wire.E_OK, (k, status)
            assert decode_record(body)[1] == expect[k], k


def _pump_to_phase(cl, target, max_pumps=6000):
    """Drive the cluster until the active migration reaches ``target``.
    Phase transitions are at most one per step, so per-pump polling
    cannot skip a phase."""
    for _ in range(max_pumps):
        rs = cl.resharder
        if rs is not None and rs.phase == target:
            return rs
        if rs is None and cl.reshard_history:
            raise AssertionError(
                f"migration finished before reaching {target!r}: "
                f"{cl.reshard_history[-1]['phase']}")
        cl.pump()
    raise AssertionError(f"never reached phase {target!r}")


# ---------------------------------------------------------------------------
# Ring membership + remap_fraction (satellite: hypothesis property)
# ---------------------------------------------------------------------------


def test_incremental_add_matches_fresh_build():
    ring = HashRing(3)
    ring.add_node(3)
    fresh = HashRing(4)
    assert ring._points == fresh._points
    assert ring._owners == fresh._owners
    assert ring.nodes() == [0, 1, 2, 3]


def test_remove_node_leaves_other_ranges_untouched():
    ring = HashRing(4)
    survivor_ranges = {s: ring.claimed_ranges(s) for s in (0, 1, 3)}
    ring.remove_node(2)
    assert ring.nodes() == [0, 1, 3]
    for s, old in survivor_ranges.items():
        # every range s owned before is still owned by s (it may have
        # GAINED the leaver's ranges, never lost its own)
        new = ring.claimed_ranges(s)
        for lo, hi in old:
            assert any(nlo <= lo and hi <= nhi for nlo, nhi in new), (s, lo)


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 12))
def test_remap_fraction_add_one_node_bounded(n):
    old = HashRing(n)
    new = old.copy()
    new.add_node(n)
    frac = HashRing.remap_fraction(old, new)
    # the joiner should claim about 1/(n+1) of the space; vnode variance
    # gives slack but never lets another node's keys move between two
    # SURVIVING owners (only old-owner -> joiner moves happen)
    assert 0.0 < frac < min(1.0, 3.0 / (n + 1))
    span = sum(hi - lo for lo, hi in new.claimed_ranges(n)) / (1 << 64)
    assert frac == pytest.approx(span, rel=1e-12)


@settings(max_examples=25, deadline=None)
@given(st.integers(3, 12), st.integers(0, 11))
def test_remap_fraction_remove_node_exactly_its_share(n, leaver):
    leaver %= n
    old = HashRing(n)
    new = old.copy()
    new.remove_node(leaver)
    frac = HashRing.remap_fraction(old, new)
    owned = sum(hi - lo for lo, hi in old.claimed_ranges(leaver)) / (1 << 64)
    # removal remaps EXACTLY the leaver's ranges: nothing else moves
    assert frac == pytest.approx(owned, rel=1e-12)
    assert HashRing.remap_fraction(old, old) == 0.0


# ---------------------------------------------------------------------------
# Live growth and shrink (the tentpole happy paths)
# ---------------------------------------------------------------------------


def test_add_shard_migrates_keys_and_flips_epoch():
    store = ShardedKVStore(2, ServerConfig(**ECFG), elastic=True)
    cl = store.cluster
    c, keys = _preload(store, 60)
    epoch0 = cl.epoch
    new = store.add_shard()
    assert new == 2 and cl.resharder is not None
    cl.run_until_idle()
    assert cl.resharder is None
    assert cl.reshard_history[-1]["phase"] == "done"
    assert cl.epoch == epoch0 + 1
    assert cl.ring.nodes() == [0, 1, 2]
    owned = [k for k in keys if cl.ring.shard_for(k) == new]
    assert owned, "the joiner claimed no keys — vnode layout broke"
    assert cl.reshard_totals["keys_migrated"] >= len(owned)
    # sources shed their copies of migrated keys after the grace drain
    for k in owned:
        assert k in store._states[new].index
        assert k not in store._states[0].index
        assert k not in store._states[1].index
    _assert_all_readable(store, {k: b"val:" + k for k in keys})
    # the migration journal tells the whole story on both endpoints
    ev = cl.reshard_events[-1]
    assert ev["kind"] == "add:2" and ev["keys_moved"] >= len(owned)


def test_writes_during_migration_are_dual_routed():
    store = ShardedKVStore(2, ServerConfig(**ECFG), elastic=True)
    cl = store.cluster
    c, keys = _preload(store, 80)
    new = store.add_shard()
    rs = cl.resharder
    moving = [k for k in keys if rs.new_ring.shard_for(k) == new]
    assert len(moving) >= 2
    _pump_to_phase(cl, "dual")
    # overwrite a migrating key + insert a fresh joiner-owned key while
    # ownership is still with the source: both must dual-route (the ack
    # holds until the destination holds the bytes)
    fresh = next(b"fresh%03d" % i for i in range(1000)
                 if rs.new_ring.shard_for(b"fresh%03d" % i) == new)
    rids = c.submit([("put", moving[0], b"NEWER"), ("put", fresh, b"BORN")])
    res = c.harvest(rids)
    assert all(s == wire.E_OK for s, _ in res.values())
    cl.run_until_idle()
    assert cl.reshard_history[-1]["phase"] == "done"
    assert cl.reshard_totals["dual_routed"] >= 1
    expect = {k: b"val:" + k for k in keys}
    expect[moving[0]] = b"NEWER"
    expect[fresh] = b"BORN"
    _assert_all_readable(store, expect)
    # the new owner serves them from its own index
    assert moving[0] in store._states[new].index
    assert fresh in store._states[new].index


def test_remove_shard_drains_and_retires():
    store = ShardedKVStore(3, ServerConfig(**ECFG), elastic=True)
    cl = store.cluster
    c, keys = _preload(store, 60)
    victim = 0
    owned = [k for k in keys if cl.ring.shard_for(k) == victim]
    assert owned
    store.remove_shard(victim)
    cl.run_until_idle()
    assert cl.reshard_history[-1]["phase"] == "done"
    assert victim in cl.retired
    assert cl.ring.nodes() == [1, 2]
    assert not store._states[victim].index
    _assert_all_readable(store, {k: b"val:" + k for k in keys})
    with pytest.raises(ValueError):
        store.remove_shard(victim)          # not a member any more


def test_concurrent_membership_changes_refused():
    store = ShardedKVStore(2, ServerConfig(**ECFG), elastic=True)
    _preload(store, 16)
    store.add_shard()
    assert store.cluster.resharder is not None
    with pytest.raises(RuntimeError):
        store.add_shard()
    with pytest.raises(RuntimeError):
        store.remove_shard(0)
    store.cluster.run_until_idle()
    assert store.cluster.resharder is None


def test_migration_journal_records_every_phase():
    store = ShardedKVStore(2, ServerConfig(**ECFG), elastic=True)
    cl = store.cluster
    _preload(store, 40)
    new = store.add_shard()
    rs = cl.resharder
    cl.run_until_idle()
    recs = rs.journal.read(new)
    phases = [r["phase"] for r in recs]
    for expected in ("setup", "dual", "flip", "cleanup", "done"):
        assert expected in phases, phases
    # phase order follows the protocol order
    order = {p: i for i, p in enumerate(PHASES)}
    assert phases == sorted(phases, key=order.__getitem__)
    setup = recs[0]
    assert setup["phase"] == "setup" and setup["aux"] >= 1   # snapshot size


# ---------------------------------------------------------------------------
# The crash matrix: kill either endpoint at every phase
# ---------------------------------------------------------------------------

CRASH_MATRIX = [
    # (phase, victim_role, expected_final)
    ("setup", "dest", "aborted"),
    ("stream", "source", "aborted"),
    ("stream", "dest", "aborted"),
    ("dual", "source", "aborted"),
    ("dual", "dest", "aborted"),
    ("flip", "source", "done"),      # gate already proved the copy
    ("flip", "dest", "aborted"),     # copy lost before the swap
    ("cleanup", "source", "done"),   # ownership already moved
    ("cleanup", "dest", "done"),
]


@pytest.mark.parametrize("phase,role,expected", CRASH_MATRIX,
                         ids=[f"{p}-{r}" for p, r, _ in CRASH_MATRIX])
def test_crash_matrix_resolves_unambiguously(phase, role, expected):
    """Crash one endpoint at ``phase``; the migration must resolve to the
    expected terminal state with every acked write still readable (the
    replica holds the crashed shard's bytes — PR7's ack-hold)."""
    store = ShardedKVStore(2, ServerConfig(**RCFG), elastic=True)
    cl = store.cluster
    c, keys = _preload(store, 240)
    epoch0 = cl.epoch
    new = store.add_shard()
    rs = cl.resharder
    victim = new if role == "dest" else rs._pair_specs[0][0]
    if phase == "setup":
        # setup runs inside the first step: a dead endpoint at that
        # instant must abort before any byte moves
        cl.crash(victim)
        cl.pump()
    else:
        _pump_to_phase(cl, phase)
        cl.crash(victim)
    cl.run_until_idle()
    assert cl.resharder is None
    hist = cl.reshard_history[-1]
    assert hist["phase"] == expected, (phase, role, hist)
    if expected == "aborted":
        # ownership never moved: the joiner is not a ring member and the
        # only epoch bumps come from the failover itself
        assert new not in cl.ring.nodes()
        assert "reason" in hist
    else:
        assert new in cl.ring.nodes()
        assert cl.epoch > epoch0
    _assert_all_readable(store, {k: b"val:" + k for k in keys})


@pytest.mark.parametrize("role", ["source", "dest"])
def test_partition_stalls_then_completes(role):
    """A partitioned-but-not-failed-over endpoint stalls the migration;
    it resumes after heal and completes with nothing lost."""
    store = ShardedKVStore(2, ServerConfig(**ECFG), elastic=True)
    cl = store.cluster
    c, keys = _preload(store, 120)
    new = store.add_shard()
    rs = cl.resharder
    victim = new if role == "dest" else rs._pair_specs[0][0]
    _pump_to_phase(cl, "stream")
    acked_before = sum(p.acked for p in rs.pairs)
    cl.partition(victim, until_tick=cl.clock.now + 40)
    for _ in range(20):
        cl.pump()
    # stalled: no new sync acks land while the wire is down
    assert rs.phase in ("stream", "dual")
    assert sum(p.acked for p in rs.pairs) == acked_before
    cl.run_until_idle()
    assert cl.reshard_history[-1]["phase"] == "done"
    assert new in cl.ring.nodes()
    _assert_all_readable(store, {k: b"val:" + k for k in keys})


# ---------------------------------------------------------------------------
# Migration under a lossy wire (satellite: FaultWire on the stream)
# ---------------------------------------------------------------------------


def test_migration_survives_lossy_stream_exactly_once():
    """Drop/dup/reorder armed on the migration flows only: the stream
    must still deliver a byte-identical destination, each sync applied
    exactly once (resends answered from the dedup cache, stale syncs
    blocked by the write shield)."""
    store = ShardedKVStore(2, ServerConfig(**ECFG), elastic=True)
    cl = store.cluster
    c, keys = _preload(store, 120)
    new = store.add_shard()
    rs = cl.resharder
    _pump_to_phase(cl, "stream")   # conns exist: the SYN is already in
    mig_flow = lambda f: f.src_port >= 47000 or f.dst_port >= 47000
    stop = cl.clock.now + 300      # bounded storm: backoffed resends land
    fin, fout = wrap_director(
        cl.servers[new].director, cl.clock,
        ingress=FaultSchedule(seed=13, drop=0.2, dup=0.15, reorder=0.1,
                              stop_tick=stop),
        responses=FaultSchedule(seed=13 ^ 0x9E3779B9, drop=0.2, dup=0.15,
                                reorder=0.1, stop_tick=stop),
        flow_filter=mig_flow)
    cl.run_until_idle()
    assert cl.reshard_history[-1]["phase"] == "done"
    stats = fin.injection_stats()
    assert sum(stats["totals"].values()) > 0, "the storm never fired"
    # the filter kept the blast radius on the migration flows only
    assert all(":47" in f.split("->")[0] or ":47" in f.split("->")[1]
               for f in stats["flows"])
    hist = cl.reshard_history[-1]
    assert hist["resent"] >= 1      # drops really forced resends
    # exactly-once: every migrated key applied at the destination once
    mig = store.shard_stats()[new]["migration"]
    moved = [k for k in keys if cl.ring.shard_for(k) == new]
    assert mig["applied_puts"] == hist["keys_migrated"] == len(moved)
    assert mig["stale_skipped"] == 0
    _assert_all_readable(store, {k: b"val:" + k for k in keys})
    for k in moved:
        assert k in store._states[new].index
        assert k not in store._states[0].index


# ---------------------------------------------------------------------------
# Tombstones: deletes survive promotion and rejoin re-silver (satellite)
# ---------------------------------------------------------------------------


def test_deleted_key_not_resurrected_by_promotion():
    store = ShardedKVStore(2, ServerConfig(**RCFG))
    cl = store.cluster
    c = KVClient(store, timeout_ticks=16)
    keys = [b"t%02d" % i for i in range(8)]
    res = c.harvest(c.submit([("put", k, b"v" + k) for k in keys]))
    assert all(s == wire.E_OK for s, _ in res.values())
    victim = store.shard_for_key(keys[0])
    vkeys = [k for k in keys if store.shard_for_key(k) == victim]
    dead, live = vkeys[0], vkeys[1] if len(vkeys) > 1 else None
    rid = c.delete(dead)
    assert c.harvest([rid])[rid][0] == wire.E_OK
    cl.run_until_idle()
    cl.crash(victim)
    # promotion rebuilds the index from the adopted log: the tombstone
    # must win over the earlier PUT record
    rid = c.get(dead)
    assert c.harvest([rid])[rid][0] == wire.E_NOENT
    if live is not None:
        rid = c.get(live)
        status, body = c.harvest([rid])[rid]
        assert status == wire.E_OK and decode_record(body)[1] == b"v" + live


def test_deleted_key_stays_dead_across_resilver_and_repromote():
    store = ShardedKVStore(2, ServerConfig(replication=1,
                                           heartbeat_timeout_ticks=4,
                                           **ECFG))
    cl = store.cluster
    c = KVClient(store, timeout_ticks=16, retry_attempts=4)
    keys = [b"z%02d" % i for i in range(10)]
    res = c.harvest(c.submit([("put", k, b"v" + k) for k in keys]))
    assert all(s == wire.E_OK for s, _ in res.values())
    victim = store.shard_for_key(keys[0])
    vkeys = [k for k in keys if store.shard_for_key(k) == victim]
    assert len(vkeys) >= 2
    rid = c.delete(vkeys[0])
    assert c.harvest([rid])[rid][0] == wire.E_OK
    cl.run_until_idle()
    # partition past the grace window: promotion, then heal + re-silver
    cl.partition(victim, until_tick=cl.clock.now + 60)
    for _ in range(120):
        cl.pump()
        if cl.rejoin_events:
            break
    assert cl.rejoin_events and cl.rejoin_events[0]["healed"] == victim
    primary = cl.rejoin_events[0]["primary"]
    # the adopted view already honors the tombstone...
    rid = c.get(vkeys[0])
    assert c.harvest([rid])[rid][0] == wire.E_NOENT
    # ...delete ANOTHER adopted key post-heal (mirrors to the healed
    # replica), then kill the promoted primary: the re-silvered node
    # promotes and must not resurrect either key
    rid = c.delete(vkeys[1])
    assert c.harvest([rid])[rid][0] == wire.E_OK
    cl.run_until_idle()
    cl.crash(primary)
    for k in (vkeys[0], vkeys[1]):
        rid = c.get(k)
        assert c.harvest([rid])[rid][0] == wire.E_NOENT, k
    # untouched keys are still served
    other = [k for k in keys if k not in (vkeys[0], vkeys[1])]
    res = c.harvest(c.submit([("get", k) for k in other]))
    assert all(s == wire.E_OK for s, _ in res.values())


# ---------------------------------------------------------------------------
# Observability: heat, hot shards, migration counters (satellite)
# ---------------------------------------------------------------------------


def test_shard_heat_and_hot_shard_detection():
    store = ShardedKVStore(2, ServerConfig(**ECFG))
    c = KVClient(store)
    keys = [b"h%02d" % i for i in range(12)]
    res = c.harvest(c.submit([("put", k, b"x" + k) for k in keys]))
    assert all(s == wire.E_OK for s, _ in res.values())
    hot = store.shard_for_key(keys[0])
    hkeys = [k for k in keys if store.shard_for_key(k) == hot]
    store.shard_heat()                       # reset the baseline
    for _ in range(20):
        res = c.harvest(c.submit([("get", hkeys[0]) for _ in range(5)]))
        assert all(s == wire.E_OK for s, _ in res.values())
    assert store.hot_shards(min_ops=64) == [hot]
    # the skewed key surfaces in the per-shard hot-key estimate
    stats = store.shard_stats()
    assert hkeys[0].decode("latin1") in [k for k, _ in stats[hot]["hot_keys"]]
    # a balanced reload shows no outlier
    store.shard_heat()
    assert store.hot_shards(min_ops=64) == []


def test_migration_counters_in_stats():
    store = ShardedKVStore(2, ServerConfig(**ECFG), elastic=True)
    cl = store.cluster
    c, keys = _preload(store, 60)
    new = store.add_shard()
    _pump_to_phase(cl, "dual")
    # mid-flight: the active migration is visible with live counters
    mid = store.latency_stats()["resharding"]
    assert mid["active"]["tag"] == "add:2"
    assert mid["active"]["phase"] in ("stream", "dual")
    assert store.shard_stats()[new]["migration_shielded"] == 0
    cl.run_until_idle()
    out = store.latency_stats()["resharding"]
    assert "active" not in out
    assert out["completed"][-1]["phase"] == "done"
    assert out["totals"]["keys_migrated"] >= 1
    assert out["totals"]["bytes_streamed"] >= 1
    assert out["events"][-1]["kind"] == "add:2"
    mig = store.shard_stats()[new]["migration"]
    assert mig["applied_puts"] == out["totals"]["keys_migrated"]
    # the shield is disarmed once the migration retires
    assert store._states[new].shield is None


def test_client_connections_grow_with_the_ring():
    store = ShardedKVStore(2, ServerConfig(**ECFG), elastic=True)
    cl = store.cluster
    c, keys = _preload(store, 40)
    assert len(c.net.conns) == 2
    store.add_shard()
    cl.run_until_idle()
    # the next op syncs the epoch and grows the connection set
    res = c.harvest(c.submit([("get", k) for k in keys]))
    assert all(s == wire.E_OK for s, _ in res.values())
    assert len(c.net.conns) == 3
    # a brand-new key owned by the joiner round-trips through it
    k = next(b"nk%03d" % i for i in range(1000)
             if cl.ring.shard_for(b"nk%03d" % i) == 2)
    rid = c.put(k, b"routed")
    assert c.harvest([rid])[rid][0] == wire.E_OK
    rid = c.get(k)
    assert decode_record(c.harvest([rid])[rid][1])[1] == b"routed"
