"""Sharding-rule invariants (pure functions; no multi-device mesh needed
beyond a 1x1, since the rules operate on axis-name/shape arithmetic)."""

import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.distributed import sharding as sh


class FakeMesh:
    """Mesh stand-in: sharding rules only read .axis_names and .shape."""

    def __init__(self, shape: dict):
        self.shape = shape
        self.axis_names = tuple(shape)


MESH = FakeMesh({"data": 16, "model": 16})
MESH3 = FakeMesh({"pod": 2, "data": 16, "model": 16})


def _axis_size(entry):
    if entry is None:
        return 1
    if isinstance(entry, (tuple, list)):
        n = 1
        for a in entry:
            n *= MESH.shape.get(a, MESH3.shape.get(a, 1))
        return n
    return MESH.shape.get(entry, MESH3.shape.get(entry, 1))


@settings(max_examples=100, deadline=None)
@given(st.lists(st.sampled_from(["vocab", "embed", "heads", "kv", "ff",
                                 "experts", "layers", None]),
                min_size=1, max_size=4))
def test_spec_no_duplicate_mesh_axes(axes):
    rules = sh.param_rules(MESH, get_config("tinyllama_1p1b"))
    spec = sh.spec_from_axes(tuple(axes), rules)
    used = []
    for entry in spec:
        names = (entry if isinstance(entry, (tuple, list))
                 else [entry] if entry else [])
        for n in names:
            assert n not in used, f"axis {n} used twice in {spec}"
            used.append(n)


@settings(max_examples=100, deadline=None)
@given(st.lists(st.integers(1, 4096), min_size=1, max_size=4),
       st.lists(st.sampled_from(["data", "model", None]),
                min_size=1, max_size=4))
def test_sanitize_always_divides(shape, entries):
    entries = entries[: len(shape)]
    spec = P(*entries)
    out = sh.sanitize_spec(spec, tuple(shape), MESH)
    for dim, entry in zip(shape, list(out) + [None] * (len(shape) - len(out))):
        assert dim % _axis_size(entry) == 0


def test_embedding_keeps_vocab_only():
    """Embedding tables must never be FSDP-sharded on d_model (§Perf it. 2)."""
    rules = sh.param_rules(MESH, get_config("gemma3_4b"))
    spec = sh.spec_from_axes(("vocab", "embed"), rules)
    assert spec[0] == "model" and spec[1] is None
    spec = sh.spec_from_axes(("embed", "vocab"), rules)
    assert spec[1] == "model" and spec[0] is None


def test_moe_experts_replicated_ff_tp():
    """MoE layout: experts replicated, d_ff TP, d_model FSDP (§Perf it. 8)."""
    rules = sh.param_rules(MESH, get_config("dbrx_132b"))
    spec = sh.spec_from_axes(("experts", "embed", "ff"), rules)
    assert spec[0] is None          # experts NOT sharded over model
    assert spec[1] == "data"        # FSDP
    assert spec[2] == "model"       # TP


def test_cache_specs_pick_divisible_kv_or_hd():
    cfg = get_config("dbrx_132b")   # kv=8 (not /16), hd=128 (/16)
    from repro.configs import SHAPES
    cache = {
        "k": jax.ShapeDtypeStruct((40, 128, 32769, 8, 128), np.dtype("bfloat16")),
        "v": jax.ShapeDtypeStruct((40, 128, 32769, 8, 128), np.dtype("bfloat16")),
    }
    import jax.sharding as js
    mesh = jax.make_mesh((1, 1), ("data", "model"))

    class M:
        shape = {"data": 16, "model": 16}
        axis_names = ("data", "model")

    specs = sh.cache_specs(cache, M(), cfg, SHAPES["decode_32k"])
    for s in jax.tree_util.tree_leaves(
            specs, is_leaf=lambda x: isinstance(x, P)):
        assert s[3] is None          # kv heads 8 can't take model=16
        assert s[4] == "model"       # head_dim 128 can


def test_dp_axes_respects_skip():
    assert sh.dp_axes(MESH3) == ("pod", "data")
    with sh.activation_sharding_scope(
            jax.make_mesh((1, 1), ("data", "model")),
            skip_axes=frozenset({"pod"})):
        assert "pod" not in sh.dp_axes(MESH3)
