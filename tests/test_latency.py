"""Tail-latency overhaul: deterministic tick clock, priority demux, sheds.

Covers the PR-5 latency machinery end to end:

  * the BlockDevice priority queue: offloaded reads served first, per-queue
    FIFO, completion-latency histograms in ``stats``, and the PROPERTY that
    the write-interleave budget bounds starvation (every write completes
    within a computable number of polls under sustained priority-read load);
  * tick-clock determinism: two identical cluster runs produce byte-
    identical latency histograms (server lifecycle, client end-to-end, and
    device histograms);
  * per-flow FIFO is preserved under priority demux;
  * latency-adaptive write coalescing: adjacent writes from SEPARATE ring
    batches merge into one scatter-gather submission, bounded by the tick
    budget / ring-idle flush;
  * cache-on-write fires at device COMPLETION, never at submission;
  * the read/write fence (``ServerConfig.read_write_fence``) bounces reads
    of files whose writes are still in the file-service pipeline to the
    host FIFO (read-your-writes for anything the file service accepted);
  * terminal SHED status: a request the file service shed under overload is
    surfaced by ``DDSClient.wait`` / ``ClusterClient.wait_many`` as
    ``wire.E_SHED`` instead of spinning into a timeout.
"""

import json
import math

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import wire
from repro.core.client import ClusterClient
from repro.core.dds_server import DDSClient, DDSStorageServer, ServerConfig
from repro.core.file_service import FileServiceRunner, SegmentFS
from repro.core.host_lib import DDSFrontEnd
from repro.core.lifecycle import TickClock, TickHistogram
from repro.core.qos import QoSProfile
from repro.distributed.cluster import DDSCluster
from repro.storage.blockdev import BlockDevice


# ---------------------------------------------------------------------------
# TickHistogram
# ---------------------------------------------------------------------------


def test_tick_histogram_exact_percentiles():
    h = TickHistogram()
    for d, k in [(1, 90), (5, 9), (40, 1)]:
        for _ in range(k):
            h.add(d)
    assert h.n == 100
    assert h.percentile(50) == 1
    assert h.percentile(95) == 5
    assert h.percentile(99) == 5
    assert h.percentile(100) == 40
    assert h.summary()["p99"] == 5
    merged = TickHistogram()
    merged.merge(h)
    merged.merge(h)
    assert merged.n == 200 and merged.as_dict() == {"1": 180, "5": 18,
                                                    "40": 2}


# ---------------------------------------------------------------------------
# BlockDevice priority queue + completion histograms
# ---------------------------------------------------------------------------


def _dev(**kw):
    return BlockDevice(1 << 20, **kw)


def test_blockdev_completion_histogram_in_stats():
    dev = _dev(queue_depth=4)
    buf = bytearray(64)
    dev.submit_write(0, b"x" * 64)
    dev.clock.tick()
    dev.clock.tick()
    dev.poll()                       # completes 2 ticks after submission
    dev.submit_read(0, 64, memoryview(buf))
    dev.poll()                       # completes the tick it was submitted
    h = dev.stats.completion_ticks
    assert h.n == 2
    assert h.as_dict() == {"0": 1, "2": 1}
    assert h.summary()["max"] == 2
    assert dev.stats.prio_completion_ticks.n == 0


def test_priority_reads_served_before_write_backlog():
    dev = _dev(queue_depth=8, prio_interleave=4)
    done: list[str] = []
    for i in range(12):
        dev.submit_write(i * 4096, b"w" * 64,
                         on_complete=lambda s, i=i: done.append(f"w{i}"))
    bufs = [bytearray(64) for _ in range(3)]
    for i, b in enumerate(bufs):
        dev.submit_read(0, 64, memoryview(b), priority=True,
                        on_complete=lambda s, i=i: done.append(f"r{i}"))
    dev.poll()
    # One poll, budget 8: the 3 priority reads first (in order), then the
    # reserved-normal share fills the rest of the budget (in order).
    assert done[:3] == ["r0", "r1", "r2"]
    assert done[3:] == ["w0", "w1", "w2", "w3", "w4"]
    assert dev.stats.prio_completion_ticks.n == 3
    dev.drain()
    assert [d for d in done if d[0] == "w"] == [f"w{i}" for i in range(12)]


def test_normal_share_reserved_under_priority_pressure():
    dev = _dev(queue_depth=8, prio_interleave=4)
    done: list[str] = []
    for i in range(4):
        dev.submit_write(i * 4096, b"w" * 64,
                         on_complete=lambda s, i=i: done.append(f"w{i}"))
    for i in range(20):
        dev.submit_read(0, 64, memoryview(bytearray(64)), priority=True,
                        on_complete=lambda s, i=i: done.append(f"r{i}"))
    dev.poll()
    # budget 8, interleave 4 => >= 2 normal completions despite 20 reads.
    assert done.count("w0") + done.count("w1") == 2
    assert sum(1 for d in done if d[0] == "r") == 6


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 48), st.integers(4, 32), st.integers(2, 8))
def test_write_interleave_budget_prevents_starvation(n_writes, budget,
                                                     interleave):
    """Every write completes within the computable starvation bound even
    under SUSTAINED priority-read load that saturates the poll budget."""
    dev = _dev(queue_depth=budget, prio_interleave=interleave)
    for i in range(n_writes):
        dev.submit_write(i * 4096, b"w" * 64)
    share = max(1, budget // interleave)
    bound = math.ceil(n_writes / share)
    for _ in range(bound + 2):
        # saturate the priority queue every tick
        for _ in range(budget):
            dev.submit_read(0, 64, memoryview(bytearray(64)), priority=True)
        dev.clock.tick()
        dev.poll()
    h = dev.stats.completion_ticks      # normal-queue (write) completions
    assert h.n == n_writes
    assert max(h.counts) <= bound + 1, (
        f"write starved: completed {max(h.counts)} ticks after submit, "
        f"bound {bound} (W={n_writes} budget={budget} share={share})")


# ---------------------------------------------------------------------------
# Tick-clock determinism
# ---------------------------------------------------------------------------


def _mixed_run(seed: int) -> str:
    import random
    cluster = DDSCluster(num_shards=2,
                         config=ServerConfig(device_capacity=1 << 24,
                                             cache_items=1 << 10))
    for srv in cluster.servers:
        srv.device.queue_depth = 8
    fids = [cluster.create_file(f"det{i}") for i in range(6)]
    for f in fids:
        cluster.write_sync(f, 0, b"\x01" * 8192)
    cli = ClusterClient(cluster, port=45500)
    rng = random.Random(seed)
    for _ in range(30):
        cli.read_many([(fids[rng.randrange(6)], rng.randrange(0, 7936), 128)
                       for _ in range(8)])
        cli.write_many([(fids[rng.randrange(6)], rng.randrange(0, 15) * 512,
                         b"z" * 128) for _ in range(4)])
        cli.flush()
        cluster.pump()
        cli.poll()
    cli.run_until_idle()
    while cli.poll():
        pass
    doc = {
        "server": cluster.latency_histograms(),
        "client": cli.latency.histograms(),
        "device": [srv.device.stats.completion_ticks.as_dict()
                   for srv in cluster.servers],
        "device_prio": [srv.device.stats.prio_completion_ticks.as_dict()
                        for srv in cluster.servers],
    }
    return json.dumps(doc, sort_keys=True)


def test_two_identical_runs_identical_histograms():
    a = _mixed_run(123)
    b = _mixed_run(123)
    assert a == b
    # and the histograms are non-trivial (something was measured)
    doc = json.loads(a)
    assert doc["server"].get("dpu_read")
    assert doc["server"].get("write")
    assert doc["client"].get("read") and doc["client"].get("write")


def test_cluster_latency_stats_classes():
    import random
    cluster = DDSCluster(num_shards=2,
                         config=ServerConfig(device_capacity=1 << 24))
    fid = cluster.create_file("stats")
    cluster.write_sync(fid, 0, b"\x05" * 4096)
    cli = ClusterClient(cluster, port=45600)
    rng = random.Random(1)
    rids = cli.read_many([(fid, rng.randrange(0, 3968), 64)
                          for _ in range(16)])
    rids += cli.write_many([(fid, 4096, b"y" * 64)])
    cli.flush()
    cli.wait_many(rids)
    stats = cluster.latency_stats()
    assert stats["classes"]["dpu_read"]["count"] == 16
    assert stats["classes"]["write"]["count"] == 1
    assert stats["device_prio"]["count"] == 16
    # client-side end-to-end view
    lat = cli.latency.summary()
    assert lat["read"]["count"] == 16 and lat["write"]["count"] == 1
    # per-server view includes ring residency once host traffic flowed
    srv_stats = cluster.servers[0].latency_stats()
    assert "classes" in srv_stats


# ---------------------------------------------------------------------------
# Per-flow FIFO under priority demux
# ---------------------------------------------------------------------------


def test_per_flow_fifo_preserved_under_priority_demux():
    cluster = DDSCluster(num_shards=1,
                         config=ServerConfig(device_capacity=1 << 24))
    fid = cluster.create_file("fifo")
    cluster.write_sync(fid, 0, b"\x02" * 65536)
    reader = ClusterClient(cluster, port=45700)
    writer = ClusterClient(cluster, port=45800)
    read_rids, write_rids = [], []
    for r in range(6):
        read_rids += reader.read_many([(fid, 128 * i, 64)
                                       for i in range(10)])
        write_rids += writer.write_many([(fid, 65536 + 1024 * i, b"q" * 64)
                                         for i in range(5)])
        reader.flush()
        writer.flush()
        cluster.pump()
        reader.poll()
        writer.poll()
    reader.run_until_idle()
    writer.run_until_idle()
    while reader.poll() or writer.poll():
        pass
    # Responses on each flow arrive EXACTLY in issue order: priority demux
    # reorders across queues/flows, never within a flow.
    assert reader.conns[0].arrival_order == read_rids
    assert writer.conns[0].arrival_order == write_rids


# ---------------------------------------------------------------------------
# Latency-adaptive write coalescing (cross-batch holds, bounded age)
# ---------------------------------------------------------------------------


def _stack(**kw):
    dev = BlockDevice(1 << 22)
    fs = SegmentFS(dev, 1 << 16)
    svc = FileServiceRunner(fs, **kw)
    fe = DDSFrontEnd(svc, ring_capacity=1 << 14)
    return dev, fs, svc, fe


def test_adjacent_writes_across_batches_coalesce_once():
    dev, _, svc, fe = _stack(coalesce_ticks=4)
    fid = fe.create_file("xbatch")
    submits_before = svc.stats.write_submits
    # Two separate ring publishes => two consume batches; adjacent offsets.
    fe.write_file(fid, 0, b"a" * 100)
    svc.step()                 # batch 1 fetched; run HELD (age 0 < 4)
    fe.write_file(fid, 100, b"b" * 100)
    svc.step()                 # batch 2 extends the held run
    svc.run_until_idle()       # ring idle => flush; completes
    assert svc.stats.writes == 2
    assert svc.stats.write_submits - submits_before == 1   # ONE writev
    assert svc.stats.coalesced_writes >= 1
    assert fe.read_sync(fid, 0, 200) == b"a" * 100 + b"b" * 100


def test_held_run_flushes_at_tick_budget_under_continuous_load():
    dev, _, svc, fe = _stack(coalesce_ticks=2)
    fid = fe.create_file("aged")
    off = 0
    first_submit_step = None
    for step in range(6):      # continuous adjacent write traffic
        fe.write_file(fid, off, b"c" * 64)
        off += 64
        svc.step()
        if first_submit_step is None and svc.stats.write_submits:
            first_submit_step = step
    # The run must NOT wait for the traffic to stop: the age budget flushed
    # it within coalesce_ticks steps of the run opening.
    assert first_submit_step is not None and first_submit_step <= 2
    svc.run_until_idle()
    assert fe.read_sync(fid, 0, off) == b"c" * off


def test_read_flushes_held_run_first():
    dev, _, svc, fe = _stack(coalesce_ticks=50)   # age alone would hold long
    fid = fe.create_file("barrier")
    fe.write_sync(fid, 0, b"\x00" * 256)
    fe.write_file(fid, 0, b"x" * 64)
    svc.step()                                     # held
    rid = fe.read_file(fid, 0, 64)
    for _ in range(50):
        svc.step()
        comps = {c.request_id: c for c in fe.poll_wait(fe._control_group)}
        if rid in comps:
            assert comps[rid].data == b"x" * 64    # read-your-writes
            return
    raise AssertionError("read did not complete")


def test_cache_hook_fires_at_completion_not_submission():
    calls = []
    dev = BlockDevice(1 << 22)
    fs = SegmentFS(dev, 1 << 16)
    svc = FileServiceRunner(
        fs, coalesce_ticks=0,
        cache_hook=lambda fid, off, data: calls.append(
            (fid, off, bytes(data), dev.stats.writes)))
    fe = DDSFrontEnd(svc, ring_capacity=1 << 14)
    fid = fe.create_file("cachet")
    writes_before = dev.stats.writes
    fe.write_file(fid, 0, b"h" * 64)
    svc.run_until_idle()
    assert len(calls) == 1
    cfid, coff, cdata, writes_at_call = calls[0]
    assert (cfid, coff, cdata) == (fid, 0, b"h" * 64)
    # the device had ALREADY executed the write when the hook fired
    assert writes_at_call > writes_before


# ---------------------------------------------------------------------------
# Read/write fence: pipelined read-your-writes with priority demux
# ---------------------------------------------------------------------------


def test_read_write_fence_bounces_fenced_reads_to_host():
    """A read of a file whose writes are still in the file-service pipeline
    (held / ring-queued / at the device) is bounced to the host, where the
    submission FIFO orders it after them — fresh bytes despite the device
    priority queue."""
    srv = DDSStorageServer(ServerConfig(
        device_capacity=1 << 24, qos=QoSProfile(read_write_fence=True)))
    srv.device.queue_depth = 1           # keep the write backlog alive
    cli = DDSClient(srv)
    fid = srv.frontend.create_file("fence")
    srv.frontend.write_sync(fid, 0, b"\x00" * 65536)
    srv.run_until_idle()
    # Strided (non-coalescing) writes: a real multi-op device backlog.
    wrids = cli.write_many([(fid, 1024 * i, bytes([i]) * 128)
                            for i in range(24)])
    srv.pump()                           # writes reach the file service
    assert srv.file_service.write_inflight.get(fid, 0) > 0
    rrid = cli.read(fid, 1024 * 23, 128)   # read bytes of the LAST write
    got = cli.wait(rrid)
    assert got == (wire.E_OK, bytes([23]) * 128)   # fresh, not stale
    assert srv.offload.stats.bounced_to_host >= 1  # the fence rerouted it
    # lifecycle classified the bounced read as host-served
    assert srv.lifecycle.hist["host_read"].n >= 1
    for rid in wrids:
        assert cli.wait(rid)[0] == wire.E_OK


# ---------------------------------------------------------------------------
# Bounded host-wire drain slices
# ---------------------------------------------------------------------------


def test_drain_host_wire_bounded_slice_keeps_server_busy():
    srv = DDSStorageServer(ServerConfig(device_capacity=1 << 24))
    cli = DDSClient(srv)
    fid = srv.frontend.create_file("slice")
    srv.run_until_idle()
    # 12 single-write messages => 12 packets on the host wire.
    for i in range(12):
        cli.write(fid, 64 * i, b"s" * 64)
    srv.director.step_n(64)
    n = srv.host_app.step(max_pkts=5)      # one bounded drain slice
    assert n == 5
    assert bool(srv.director.to_host)      # remainder still queued
    assert srv.director.busy()             # server stays runnable
    srv.run_until_idle()
    for rid in range(1, 13):
        assert cli.wait(rid)[0] == wire.E_OK


# ---------------------------------------------------------------------------
# Terminal SHED status
# ---------------------------------------------------------------------------


def test_file_service_shed_hook_fires_with_request_id():
    sheds: list[int] = []
    dev = BlockDevice(1 << 22)
    fs = SegmentFS(dev, 1 << 16)
    svc = FileServiceRunner(fs, resp_buf_size=1 << 10,
                            shed_hook=sheds.append)
    fe = DDSFrontEnd(svc, ring_capacity=1 << 8)   # tiny response ring
    fid = fe.create_file("shed")
    # Flood reads; NEVER drain the response ring: slots exhaust the small
    # response buffer, inline E_NOSPC completions fill the tiny ring, and
    # the bounded emergency path gives up — SHED.
    rids = []
    for i in range(16):
        rids.append(fe.read_file(fid, 0, 200))
        svc.step()
    assert svc.stats.shed_requests > 0
    assert sheds and set(sheds) <= set(rids)


def test_client_wait_surfaces_shed_as_terminal_status():
    srv = DDSStorageServer(ServerConfig(device_capacity=1 << 24))
    cli = DDSClient(srv)
    fid = srv.frontend.create_file("shedcli")
    srv.run_until_idle()
    rid = cli.write(fid, 0, b"gone" * 16)
    # Deliver the request to the host app but stop before the file service
    # runs, then simulate the file service shedding it.
    srv.director.step_n(64)
    srv.host_app.step()
    frontend_rids = list(srv.host_app._inflight)
    assert len(frontend_rids) == 1
    srv.file_service.shed_hook(frontend_rids[0])   # the wired _on_shed
    status, body = cli.wait(rid, max_iters=2_000)  # no timeout spin
    assert status == wire.E_SHED
    # Overload sheds carry a retry-after hint (tenant 0, retry next tick).
    assert wire.decode_shed_hint(body) == (0, 1)
    assert not srv.host_app.busy()                 # in-flight entry dropped
    assert not srv.frontend.any_outstanding()      # booking cancelled
    assert srv.lifecycle.sheds == 1
    srv.run_until_idle()                           # server fully quiesces


def test_shed_during_submit_many_reentry_is_not_lost():
    """A shed that fires INSIDE frontend.submit_many (the ring-full
    on_retry re-entrantly steps the file service) lands before the host
    app records its in-flight meta; the orphan-shed reconcile must still
    mark it terminally instead of leaking a forever-pending request."""
    srv = DDSStorageServer(ServerConfig(device_capacity=1 << 24))
    cli = DDSClient(srv)
    fid = srv.frontend.create_file("reentry")
    srv.run_until_idle()
    rid = cli.write(fid, 0, b"lost?" * 8)
    srv.director.step_n(64)
    # Simulate the re-entrant window: the file service sheds the frontend
    # rid BEFORE _execute_burst has booked it (submit_many not yet run).
    g = srv.frontend._groups[srv.frontend._control_group]
    next_rid = g._next_rid              # the rid submit_many will assign
    srv.file_service.shed_hook(next_rid)
    assert next_rid in srv.host_app._orphan_sheds   # parked, not dropped
    srv.host_app.step()                 # books the meta + reconciles
    assert not srv.host_app._orphan_sheds
    assert next_rid not in srv.host_app._inflight   # meta did not leak
    status, body = cli.wait(rid, max_iters=2_000)
    assert status == wire.E_SHED and wire.decode_shed_hint(body) == (0, 1)
    srv.run_until_idle()                # server quiesces; nothing pinned


def test_cluster_wait_many_surfaces_shed():
    cluster = DDSCluster(num_shards=1,
                         config=ServerConfig(device_capacity=1 << 24))
    fid = cluster.create_file("shedmany")
    cluster.write_sync(fid, 0, b"\x00" * 4096)
    cli = ClusterClient(cluster, port=45900)
    ok_rid = cli.read(fid, 0, 64)
    shed_rid = cli.write(fid, 1024, b"x" * 64)
    cli.flush()
    srv = cluster.servers[0]
    srv.director.step_n(64)
    srv.host_app.step()
    # Shed the write while it is in flight on the host path.
    frontend_rids = list(srv.host_app._inflight)
    assert frontend_rids
    srv.file_service.shed_hook(frontend_rids[0])
    got = cli.wait_many([ok_rid, shed_rid], max_iters=20_000)
    assert got[ok_rid][0] == wire.E_OK
    assert got[shed_rid][0] == wire.E_SHED
    assert wire.decode_shed_hint(got[shed_rid][1]) == (0, 1)
    assert cli.outstanding() == 0
