"""Sharded cluster layer: routing, pipelining, and the §9.2 KV offload path."""

import pytest

from repro.apps.kv_store import KVClient, KVLocation, ShardedKVStore
from repro.core import wire
from repro.core.client import ClusterClient
from repro.core.dds_server import ServerConfig
from repro.distributed.cluster import DDSCluster, HashRing


# -- consistent-hash routing -----------------------------------------------------------

def test_ring_routing_is_deterministic_across_instances():
    a, b = HashRing(4), HashRing(4)
    keys = [f"key-{i}".encode() for i in range(500)] + list(range(500))
    assert [a.shard_for(k) for k in keys] == [b.shard_for(k) for k in keys]


def test_ring_spreads_load_and_rescales_minimally():
    ring4, ring5 = HashRing(4), HashRing(5)
    keys = list(range(2000))
    dist = ring4.distribution(keys)
    assert all(dist[s] > 0 for s in range(4))          # no empty shard
    moved = sum(ring4.shard_for(k) != ring5.shard_for(k) for k in keys)
    # consistent hashing: adding shard 5 of 5 should move ~1/5, far from all
    assert moved / len(keys) < 0.45


def test_cluster_file_placement_follows_ring():
    cl = DDSCluster(num_shards=4)
    fids = [cl.create_file(f"f{i}") for i in range(16)]
    for f in fids:
        assert cl.locate(f).shard == cl.ring.shard_for(f)
    cl2 = DDSCluster(num_shards=4)
    fids2 = [cl2.create_file(f"g{i}") for i in range(16)]
    # placement is a pure function of the (global) file id sequence
    assert [cl.locate(f).shard for f in fids] == \
           [cl2.locate(f).shard for f in fids2]


# -- pipelined batched client ----------------------------------------------------------

@pytest.fixture()
def loaded_cluster():
    cl = DDSCluster(num_shards=2)
    fids = [cl.create_file(f"d{i}") for i in range(6)]
    for i, f in enumerate(fids):
        cl.write_sync(f, 0, bytes([i + 1]) * 8192)
    return cl, fids


def test_client_batches_per_shard_messages(loaded_cluster):
    cl, fids = loaded_cluster
    cc = ClusterClient(cl)
    rids = [cc.read(f, 0, 64) for f in fids for _ in range(4)]
    cc.flush()
    # one network message per shard holding every request for that shard
    shards_used = {cl.locate(f).shard for f in fids}
    assert cc.stats.batches_sent == len(shards_used)
    assert cc.stats.messages_sent == len(rids)
    res = cc.wait_many(rids)
    assert all(s == wire.E_OK for s, _ in res.values())


def test_pipelined_responses_preserve_per_shard_issue_order(loaded_cluster):
    cl, fids = loaded_cluster
    cc = ClusterClient(cl)
    # several pipelined batches in flight before any collection
    rids = []
    for round_ in range(5):
        rids += [cc.read(f, 256 * round_, 128) for f in fids]
        cc.flush()                      # new batch; do NOT wait
    res = cc.wait_many(rids)
    assert all(s == wire.E_OK for s, _ in res.values())
    for conn in cc.conns:
        issued = [r for r in rids if r in set(conn.arrival_order)]
        assert conn.arrival_order == sorted(conn.arrival_order), \
            "offloaded responses must stream back in issue order per shard"
        assert issued == conn.arrival_order


def test_reads_offload_and_writes_take_host_path(loaded_cluster):
    cl, fids = loaded_cluster
    cc = ClusterClient(cl)
    st, _ = cc.wait(cc.write(fids[0], 0, b"Z" * 512))
    assert st == wire.E_OK
    st, body = cc.wait(cc.read(fids[0], 0, 512))
    assert st == wire.E_OK and body == b"Z" * 512
    stats = cl.stats()
    assert stats.offloaded_completed >= 1       # the read ran on a DPU
    assert stats.host_cpu_busy_s > 0            # the write burned host CPU


def test_cluster_data_is_actually_sharded(loaded_cluster):
    cl, fids = loaded_cluster
    per_shard_files = {}
    for f in fids:
        per_shard_files.setdefault(cl.locate(f).shard, []).append(f)
    assert len(per_shard_files) == 2            # both shards own files
    for shard, owned in per_shard_files.items():
        srv = cl.servers[shard]
        # every owned file is present locally, none of the others are
        local = {cl.locate(f).local_fid for f in owned}
        assert local <= set(srv.fs.files)


def test_two_clients_share_a_cluster_without_cross_talk(loaded_cluster):
    cl, fids = loaded_cluster
    a, b = ClusterClient(cl), ClusterClient(cl)
    ra = a.read(fids[0], 0, 64)      # rid 1 in BOTH clients' namespaces
    rb = b.read(fids[1], 0, 64)
    sa, body_a = a.wait(ra)
    sb, body_b = b.wait(rb)
    assert (sa, body_a) == (wire.E_OK, bytes([1]) * 64)
    assert (sb, body_b) == (wire.E_OK, bytes([2]) * 64)


# -- the §9.2 KV workload --------------------------------------------------------------

@pytest.fixture()
def kv():
    store = ShardedKVStore(num_shards=2)
    return store, KVClient(store)


def test_kv_get_after_put_is_dpu_served(kv):
    store, c = kv
    loc = c.wait_put(c.put(b"alpha", b"value-1"))
    assert isinstance(loc, KVLocation) and loc.size > 0
    assert c.wait_value(c.get(b"alpha")) == b"value-1"
    assert store.dpu_served_gets() == 1         # offload hit, zero host CPU
    assert store.host_served_gets() == 0


def test_kv_put_ack_location_points_at_the_record(kv):
    store, c = kv
    loc = c.wait_put(c.put(b"where", b"am-i"))
    shard = store.shard_for_key(b"where")
    raw = store.cluster.servers[shard].frontend.read_sync(
        loc.file_id, loc.offset, loc.size)
    from repro.apps.kv_store import decode_record
    assert decode_record(raw) == (b"where", b"am-i")


def test_kv_overwrite_updates_mapping_not_stale(kv):
    store, c = kv
    c.wait_put(c.put(b"k", b"v1"))
    assert c.wait_value(c.get(b"k")) == b"v1"
    c.wait_put(c.put(b"k", b"v2"))              # append; Cache upserts
    assert c.wait_value(c.get(b"k")) == b"v2"
    # still served from the DPU at the NEW location
    assert store.dpu_served_gets() == 2


def test_kv_delete_invalidates_dpu_mapping(kv):
    store, c = kv
    c.wait_put(c.put(b"doomed", b"payload"))
    assert c.wait_value(c.get(b"doomed")) == b"payload"
    shard = store.shard_for_key(b"doomed")
    table = store.cluster.servers[shard].cache_table
    assert table.lookup(b"doomed") is not None
    st, _ = c.net.wait(c.delete(b"doomed"))
    assert st == wire.E_OK
    assert table.lookup(b"doomed") is None      # Invalidate fired on read
    assert c.wait_value(c.get(b"doomed")) is None


def test_kv_invalidate_correct_under_interleaved_writes(kv):
    store, c = kv
    # interleave: PUT a, PUT b, overwrite a, DEL b — all pipelined
    rids = [c.put(b"a", b"a1"), c.put(b"b", b"b1")]
    c.flush()
    rids += [c.put(b"a", b"a2")]
    c.flush()
    for r in rids:
        c.wait_put(r)
    st, _ = c.net.wait(c.delete(b"b"))
    assert st == wire.E_OK
    # deleting b (old log region) must not clobber a's fresh mapping
    assert c.wait_value(c.get(b"a")) == b"a2"
    assert c.wait_value(c.get(b"b")) is None
    shard_a = store.shard_for_key(b"a")
    assert store.cluster.servers[shard_a].cache_table.lookup(b"a") is not None


def test_kv_scales_across_shards_with_nonzero_offload():
    store = ShardedKVStore(num_shards=4)
    c = KVClient(store)
    keys = [f"user:{i}".encode() for i in range(64)]
    for k in keys:
        c.put(k, b"profile-" + k)
    c.flush()
    c.run_until_idle()
    grids = {k: c.get(k) for k in keys}
    for k in keys:
        assert c.wait_value(grids[k]) == b"profile-" + k
    per_shard = store.shard_stats()
    assert sum(1 for s in per_shard if s["puts"] > 0) >= 3   # data spread out
    assert store.dpu_served_gets() == len(keys)              # all offloaded


# -- PR 4 satellites: ring build, batched predicate lookups, KV burst issue -----------

def test_hashring_sort_once_build_matches_incremental_insert():
    """The O(n log n) build must place vnodes exactly like the old
    insertion-sorted build (placement stability across versions)."""
    import bisect
    from repro.distributed.cluster import stable_hash
    for shards, vnodes in ((3, 16), (16, 64)):
        points, owners = [], []
        for shard in range(shards):           # the pre-PR O(n^2) build
            for v in range(vnodes):
                p = stable_hash(f"shard-{shard}-vnode-{v}")
                i = bisect.bisect_left(points, p)
                points.insert(i, p)
                owners.insert(i, shard)
        ring = HashRing(shards, vnodes)
        assert ring._points == points
        assert ring._owners == owners


def test_kv_get_burst_uses_one_batched_cache_lookup(kv):
    store, c = kv
    keys = [b"burst-%d" % i for i in range(12)]
    for k in keys:
        c.put(k, b"v:" + k)
    c.flush()
    c.run_until_idle()
    shard_batches = {i: s["cache"]["batched_lookups"]
                     for i, s in enumerate(store.shard_stats())}
    rids = c.get_many(keys)
    c.flush()
    res = c.net.wait_many(rids)
    assert all(s == wire.E_OK for s, _ in res.values())
    after = store.shard_stats()
    for i, s in enumerate(after):
        # every shard that saw GETs probed its table in burst(s), and the
        # counter is surfaced through the app-level stats
        got = s["cache"]["batched_lookups"] - shard_batches[i]
        if s["dpu_gets"]:
            assert got >= 1
    assert store.dpu_served_gets() == len(keys)


def test_kv_burst_apis_roundtrip(kv):
    store, c = kv
    items = [(b"bk-%d" % i, b"bv-%d" % i) for i in range(10)]
    put_rids = c.put_many(items)
    c.flush()
    for rid in put_rids:
        c.wait_put(rid)
    get_rids = c.get_many([k for k, _ in items])
    c.flush()
    res = c.net.wait_many(get_rids)
    from repro.apps.kv_store import decode_record
    for (k, v), rid in zip(items, get_rids):
        st_, body = res[rid]
        assert st_ == wire.E_OK and decode_record(body) == (k, v)
    del_rids = c.delete_many([k for k, _ in items[:5]])
    c.flush()
    res = c.net.wait_many(del_rids)
    assert all(s == wire.E_OK for s, _ in res.values())
    assert c.wait_value(c.get(items[0][0])) is None
    assert c.wait_value(c.get(items[9][0])) == b"bv-9"
