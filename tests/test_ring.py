"""Progressive ring buffer (DDS §4.1): semantics + concurrency + properties."""

import struct
import threading

import pytest
from hypothesis import given, settings, strategies as st

from repro.core.ring import (DMAEngine, FaRMStyleRing, LockRing,
                             ProgressiveRing, ResponseRing, frame,
                             unframe_batch, OK, RETRY)


def drain(ring, dma, limit=10_000):
    out = []
    for _ in range(limit):
        got = ring.consume(dma)
        if got is None:
            break
        out.extend(unframe_batch(got))
    return out


def test_insert_consume_roundtrip():
    ring = ProgressiveRing(1 << 12)
    dma = DMAEngine()
    msgs = [f"msg-{i}".encode() for i in range(10)]
    for m in msgs:
        assert ring.try_insert(frame(m)) == OK
    assert drain(ring, dma) == msgs


def test_batching_effect_single_dma():
    """N inserted messages come back in ONE consume (natural batching)."""
    ring = ProgressiveRing(1 << 12)
    dma = DMAEngine()
    for i in range(8):
        ring.insert(frame(bytes([i]) * 16))
    before = dma.stats.snapshot()
    batch = ring.consume(dma)
    assert batch is not None and len(unframe_batch(batch)) == 8
    delta = dma.stats.delta(before)
    # one pointer-pair read + one data read (+1 if wrapped) + head write
    assert delta.reads <= 3
    assert delta.writes == 1


def test_pointer_pair_read_is_single_dma():
    """P physically precedes T: the Fig 8b check costs one DMA read."""
    ring = ProgressiveRing(1 << 12)
    dma = DMAEngine()
    ring.insert(frame(b"x"))
    before = dma.stats.snapshot()
    prog, tail = dma.read_u64_pair(ring.host, ring.base)
    assert dma.stats.delta(before).reads == 1
    assert prog == tail


def test_retry_when_outpacing():
    ring = ProgressiveRing(1 << 8, max_progress=64)
    big = frame(b"z" * 40)
    assert ring.try_insert(big) == OK
    assert ring.try_insert(big) == RETRY  # exceeds max allowable progress


def test_wraparound():
    ring = ProgressiveRing(1 << 8)
    dma = DMAEngine()
    for round_ in range(20):  # push far beyond capacity with drains between
        m = frame(bytes([round_]) * 50)
        assert ring.try_insert(m) == OK
        got = drain(ring, dma)
        assert got == [bytes([round_]) * 50]


def test_concurrent_producers_lossless():
    ring = ProgressiveRing(1 << 16)
    dma = DMAEngine()
    n_threads, per_thread = 8, 200
    received = []
    stop = threading.Event()

    def consumer():
        while True:
            got = ring.consume(dma)
            if got:
                received.extend(unframe_batch(got))
            elif stop.is_set():
                # producers have joined => all inserts complete; one final
                # consume drains anything published after our last poll.
                got = ring.consume(dma)
                if got:
                    received.extend(unframe_batch(got))
                    continue
                return

    def producer(tid):
        for i in range(per_thread):
            ring.insert(frame(struct.pack("<II", tid, i)))

    ct = threading.Thread(target=consumer)
    ct.start()
    ps = [threading.Thread(target=producer, args=(t,)) for t in range(n_threads)]
    for p in ps:
        p.start()
    for p in ps:
        p.join()
    stop.set()
    ct.join(timeout=10)
    assert len(received) == n_threads * per_thread
    # per-producer order is preserved even though global order interleaves
    by_tid = {}
    for raw in received:
        tid, i = struct.unpack("<II", raw)
        by_tid.setdefault(tid, []).append(i)
    for tid, seq in by_tid.items():
        assert seq == sorted(seq)


@settings(max_examples=50, deadline=None)
@given(st.lists(st.binary(min_size=1, max_size=64), min_size=1, max_size=64))
def test_property_fifo_single_producer(msgs):
    """Single producer: consumption preserves exact insertion order."""
    ring = ProgressiveRing(1 << 14)
    dma = DMAEngine()
    out = []
    for m in msgs:
        if ring.try_insert(frame(m)) != OK:
            out.extend(drain(ring, dma))
            assert ring.try_insert(frame(m)) == OK
    out.extend(drain(ring, dma))
    assert out == msgs


def test_response_ring_spmc():
    ring = ResponseRing(1 << 12)
    dma = DMAEngine()
    assert ring.produce(dma, frame(b"r1") + frame(b"r2"))
    claimed = ring.try_claim()
    assert claimed is not None
    _, data = claimed
    assert unframe_batch(data) == [b"r1", b"r2"]
    assert ring.try_claim() is None


def test_farm_ring_per_message_dma():
    """FaRM-style: every message costs poll + read + release DMAs."""
    ring = FaRMStyleRing(slots=16, slot_size=64)
    dma = DMAEngine()
    for i in range(4):
        assert ring.try_insert(bytes([i]) * 8) == OK
    before = dma.stats.snapshot()
    got = [ring.consume_one(dma) for _ in range(4)]
    assert got == [bytes([i]) * 8 for i in range(4)]
    delta = dma.stats.delta(before)
    assert delta.reads == 8   # flag poll + payload per message
    assert delta.writes == 4  # release per message


def test_lock_ring_equivalence():
    ring = LockRing(1 << 12)
    dma = DMAEngine()
    msgs = [f"m{i}".encode() for i in range(5)]
    for m in msgs:
        assert ring.try_insert(frame(m)) == OK
    assert unframe_batch(ring.consume(dma)) == msgs
