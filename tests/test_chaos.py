"""Lossy-network survival (the chaos PR).

Covers the fault-injection wire, the exactly-once request layer, and the
graceful-degradation paths end to end:

  * ``FaultWire`` with no schedule armed is byte-identical to the bare
    wire (property, both ``Wire`` and ``FlowDemuxWire`` shapes);
  * the frame checksum rejects ANY single-byte corruption at any offset
    (property), and a corrupted ingress frame is discarded as a loss the
    client's timeout/resend recovers;
  * the server-side dedup/reply cache never double-applies a resent
    mutation under arbitrary seeded drop/dup/reorder/corrupt schedules —
    the KV record log is the ledger oracle (appends are NOT idempotent,
    so a double-apply would leave a second record);
  * a lost ack is answered from the reply cache on resend, not re-run;
  * a heartbeat blip shorter than the supervisor's grace windows does
    not promote; a real partition promotes, and the healed primary
    rejoins as a REPLICA of the shard that took over (no split-brain),
    for both cluster files and the KV store's record logs;
  * a failed DPU degrades transparently: offloaded GETs bounce to the
    host path and the bypass is visible in the stats;
  * shed-retry backoff is jittered per request id — deterministic across
    runs, de-synchronized across clients.
"""

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import vector, wire
from repro.core.client import ClusterClient
from repro.core.dds_server import DDSClient, DDSStorageServer, ServerConfig
from repro.core.faultnet import FaultSchedule, FaultWire, wrap_director
from repro.core.traffic import FiveTuple, FlowDemuxWire, Packet, Wire
from repro.distributed.cluster import DDSCluster
from repro.apps.kv_store import REC_HDR, KVClient, ShardedKVStore


class _Clock:
    now = 0


_FLOW = FiveTuple("10.0.0.2", 7777, "10.0.0.1", 31337)


def _snap(pkt):
    return (pkt.seq, bytes(pkt.payload), pkt.flags, pkt.ack, pkt.csum)


# ---------------------------------------------------------------------------
# Passthrough: an unarmed FaultWire is invisible
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(st.lists(st.binary(min_size=0, max_size=32), max_size=12),
       st.sampled_from([None, "unarmed", "inactive"]))
def test_faultwire_passthrough_byte_identical(payloads, shape):
    """No armed+active schedule, no partitions => byte-identical traffic."""
    sched = {None: None,
             "unarmed": FaultSchedule(seed=3),                  # all rates 0
             "inactive": FaultSchedule(seed=3, drop=1.0,
                                       start_tick=10_000)}[shape]
    bare = Wire("bare")
    wrapped = FaultWire(Wire("inner"), _Clock(), sched)
    for i, p in enumerate(payloads):
        bare.push(Packet(_FLOW, i, p))
        wrapped.push(Packet(_FLOW, i, p))
    assert len(bare) == len(wrapped)
    while True:
        a, b = bare.pop(), wrapped.pop()
        assert (a is None) == (b is None)
        if a is None:
            break
        assert _snap(a) == _snap(b)
    assert all(v == 0 for v in wrapped.totals.values())


@settings(max_examples=10, deadline=None)
@given(st.lists(st.binary(min_size=1, max_size=16), max_size=10))
def test_faultwire_passthrough_demux_shape(payloads):
    bare = FlowDemuxWire("bare")
    wrapped = FaultWire(FlowDemuxWire("inner"), _Clock(), FaultSchedule())
    pkts_a = [Packet(_FLOW, i, p) for i, p in enumerate(payloads)]
    pkts_b = [Packet(_FLOW, i, p) for i, p in enumerate(payloads)]
    bare.push_many(_FLOW, pkts_a)
    wrapped.push_many(_FLOW, pkts_b)
    assert ([_snap(p) for p in bare.drain_flow(_FLOW)]
            == [_snap(p) for p in wrapped.drain_flow(_FLOW)])


def test_faultwire_taxonomy_counters_and_partition():
    clk = _Clock()
    fw = FaultWire(Wire("w"), clk, FaultSchedule(seed=7, drop=1.0))
    for i in range(5):
        fw.push(Packet(_FLOW, i, b"x"))
    assert fw.pop() is None and fw.totals["dropped"] == 5
    stats = fw.injection_stats()
    assert stats["totals"]["dropped"] == 5
    (fc,) = stats["flows"].values()
    assert fc["dropped"] == 5
    # timed partition: drops both directions until the clock passes
    fw2 = FaultWire(Wire("w2"), clk)
    fw2.partition("10.0.0.2", "10.0.0.1", until_tick=5)
    fw2.push(Packet(_FLOW, 0, b"a"))
    fw2.push(Packet(_FLOW.reversed(), 0, b"b"))
    assert fw2.pop() is None and fw2.totals["partition_dropped"] == 2
    clk.now = 5
    fw2.push(Packet(_FLOW, 1, b"c"))
    assert fw2.pop().payload == b"c"


def test_faultwire_delay_held_frames_keep_wire_busy():
    clk = _Clock()
    fw = FaultWire(Wire("w"), clk,
                   FaultSchedule(seed=1, delay=1.0, delay_ticks=(2, 2)))
    fw.push(Packet(_FLOW, 0, b"late"))
    assert fw.pop() is None
    assert bool(fw) and len(fw) == 1   # held frame keeps the server runnable
    clk.now = 2
    assert fw.pop().payload == b"late"
    assert fw.totals["delayed"] == 1 and not fw


# ---------------------------------------------------------------------------
# Frame checksums
# ---------------------------------------------------------------------------


@settings(max_examples=40, deadline=None)
@given(st.binary(min_size=1, max_size=256), st.data())
def test_checksum_rejects_any_single_byte_corruption(payload, data):
    """Position-salted checksum64: every single-byte change is visible."""
    c = vector.checksum64(payload)
    i = data.draw(st.integers(0, len(payload) - 1))
    flip = data.draw(st.integers(1, 255))
    buf = bytearray(payload)
    buf[i] ^= flip
    assert vector.checksum64(bytes(buf)) != c


def test_corrupt_ingress_discarded_and_recovered_by_resend():
    srv = DDSStorageServer(ServerConfig(wire_checksums=True,
                                        device_capacity=1 << 24))
    fid = srv.frontend.create_file("c.dat")
    srv.frontend.write_sync(fid, 0, b"\x0c" * 256)
    srv.run_until_idle()
    cli = DDSClient(srv, timeout_ticks=4)
    t0 = srv.clock.now
    wrap_director(srv.director, srv.clock,
                  ingress=FaultSchedule(seed=11, corrupt=1.0,
                                        stop_tick=t0 + 6))
    status, body = cli.wait(cli.read(fid, 0, 64))
    assert status == wire.E_OK and body == b"\x0c" * 64
    assert srv.director.stats.corrupt_dropped >= 1
    assert cli.timeouts >= 1 and cli.resends >= 1
    assert srv.latency_stats()["wire"]["corrupt_dropped"] >= 1


def test_lost_ack_resend_replays_cached_ack():
    """The ack is dropped; the resent write must NOT re-run — the reply
    cache answers it."""
    srv = DDSStorageServer(ServerConfig(wire_checksums=True, dedup_cache=64,
                                        device_capacity=1 << 24))
    fid = srv.frontend.create_file("a.dat")
    srv.frontend.write_sync(fid, 0, bytes(256))
    srv.run_until_idle()
    cli = DDSClient(srv, timeout_ticks=4)
    _fin, fout = wrap_director(srv.director, srv.clock)
    fout.partition("10.0.0.1", "10.0.0.2", until_tick=srv.clock.now + 10)
    status, _ = cli.wait(cli.write(fid, 0, b"W" * 64))
    assert status == wire.E_OK
    assert srv.host_app.replayed_acks >= 1
    assert cli.wait(cli.read(fid, 0, 64)) == (wire.E_OK, b"W" * 64)
    assert srv.latency_stats()["exactly_once"]["replayed_acks"] >= 1


# ---------------------------------------------------------------------------
# Exactly-once under arbitrary schedules: the KV log is the ledger oracle
# ---------------------------------------------------------------------------


@settings(max_examples=5, deadline=None)
@given(st.integers(0, (1 << 32) - 1))
def test_kv_puts_apply_exactly_once_under_faults(seed):
    """PUT N distinct keys through a seeded drop/dup/reorder/corrupt storm.

    KV appends are not idempotent: if a resent PUT ever re-ran, its key
    would appear twice in the record log.  After the storm quiesces the
    log must hold each key EXACTLY once and every acked key must be
    present (zero lost acked writes, zero duplicate applies)."""
    store = ShardedKVStore(1, ServerConfig(wire_checksums=True,
                                           device_capacity=1 << 24))
    cl = store.cluster
    srv = cl.servers[0]
    fin, fout = wrap_director(
        srv.director, cl.clock,
        ingress=FaultSchedule(seed=seed, drop=0.12, dup=0.12,
                              reorder=0.08, corrupt=0.08),
        responses=FaultSchedule(seed=seed ^ 0x5BD1E995, drop=0.12,
                                dup=0.12, reorder=0.08))
    c = KVClient(store, timeout_ticks=8)
    keys = [b"chaos-%03d" % i for i in range(24)]
    rids = c.submit([("put", k, b"v:" + k) for k in keys])
    res = c.harvest(rids)
    assert all(s == wire.E_OK for s, _ in res.values())
    fin.schedule = None
    fout.schedule = None
    cl.run_until_idle()
    # ledger scan: each key exactly once in the shard's own log
    st0 = store._states[0]
    data = srv.frontend.read_sync(st0.log_fid, 0, st0.log_off) \
        if st0.log_off else b""
    counts: dict[bytes, int] = {}
    pos = 0
    while pos + REC_HDR.size <= len(data):
        klen, vlen = REC_HDR.unpack_from(data, pos)
        key = bytes(data[pos + REC_HDR.size:pos + REC_HDR.size + klen])
        counts[key] = counts.get(key, 0) + 1
        pos += REC_HDR.size + klen + vlen
    assert counts == {k: 1 for k in keys}
    # the storm actually did something on most seeds; don't flake on the
    # quiet ones — just require the bookkeeping to be consistent
    assert fin.injection_stats()["held"] == 0
    # typed round-trip after the storm
    got = c.harvest(c.submit([("get", keys[0])]))
    ((_, (status, body)),) = got.items()
    assert status == wire.E_OK


# ---------------------------------------------------------------------------
# Supervisor grace windows + partition/heal rejoin
# ---------------------------------------------------------------------------


def test_partition_blip_within_grace_does_not_promote():
    cl = DDSCluster(3, ServerConfig(replication=1, heartbeat_timeout_ticks=6))
    g = cl.create_file("blip")
    cl.write_sync(g, 0, b"\x01" * 128)
    victim = cl.locate(g).shard
    # 8 ticks of silence < miss_windows * (timeout + 1) = 14: a blip
    cl.partition(victim, until_tick=cl.clock.now + 8)
    for _ in range(30):
        cl.pump()
    assert not cl.failover_events and not cl.rejoin_events
    assert victim not in cl._dead and cl.epoch == 0
    c = ClusterClient(cl)
    assert c.harvest([c.read(g, 0, 128)]).popitem()[1] \
        == (wire.E_OK, b"\x01" * 128)


def test_partitioned_primary_heals_as_replica_no_split_brain():
    cl = DDSCluster(3, ServerConfig(replication=1, heartbeat_timeout_ticks=4))
    g = cl.create_file("p")
    cl.write_sync(g, 0, b"A" * 128)
    victim = cl.locate(g).shard
    cl.partition(victim, until_tick=cl.clock.now + 40)
    for _ in range(60):
        cl.pump()
        if cl.rejoin_events:
            break
    assert len(cl.failover_events) == 1 and cl.epoch == 1
    assert len(cl.rejoin_events) == 1
    ev = cl.rejoin_events[0]
    assert ev["healed"] == victim
    assert victim not in cl._dead
    # routes stay moved: the healed shard serves no client traffic...
    loc = cl.locate(g)
    assert loc.shard == ev["primary"] != victim
    # ...but it is a full replica again: re-silvered bytes + new mirrors
    assert victim in loc.replicas
    rlfid = loc.replicas[victim]
    assert cl.servers[victim].frontend.read_sync(rlfid, 0, 128) == b"A" * 128
    cl.write_sync(g, 0, b"B" * 128)
    cl.run_until_idle()
    assert cl.servers[victim].frontend.read_sync(rlfid, 0, 128) == b"B" * 128
    assert cl.latency_stats()["rejoins"][0]["healed"] == victim


def test_kv_rejoin_resilvers_record_log():
    store = ShardedKVStore(2, ServerConfig(replication=1,
                                           heartbeat_timeout_ticks=4,
                                           device_capacity=1 << 24))
    cl = store.cluster
    c = KVClient(store, retry_attempts=2)
    keys = [b"k%02d" % i for i in range(8)]
    res = c.harvest(c.submit([("put", k, b"v" + k) for k in keys]))
    assert all(s == wire.E_OK for s, _ in res.values())
    cl.run_until_idle()
    victim = store.shard_for_key(keys[0])
    cl.partition(victim, until_tick=cl.clock.now + 60)
    for _ in range(90):
        cl.pump()
        if cl.rejoin_events:
            break
    assert cl.rejoin_events and cl.rejoin_events[0]["healed"] == victim
    primary = cl.rejoin_events[0]["primary"]
    pst = store._states[primary]
    assert victim in pst.replica_fids
    rlfid = pst.replica_fids[victim]
    # healed copy mirrors the promoted primary's whole log...
    psrv, hsrv = cl.servers[primary], cl.servers[victim]
    assert hsrv.fs.file_size(rlfid) == psrv.fs.file_size(pst.log_fid)
    # ...and a post-heal PUT for an adopted key mirrors before the ack
    rid = c.put(keys[0], b"fresh-after-heal")
    assert c.harvest([rid])[rid][0] == wire.E_OK
    cl.run_until_idle()
    data = hsrv.frontend.read_sync(rlfid, 0, hsrv.fs.file_size(rlfid))
    assert b"fresh-after-heal" in data


# ---------------------------------------------------------------------------
# DPU failure: graceful degradation to the host path
# ---------------------------------------------------------------------------


def test_dpu_failure_bounces_offloaded_gets_to_host():
    srv = DDSStorageServer(ServerConfig(device_capacity=1 << 24))
    fid = srv.frontend.create_file("d.dat")
    srv.frontend.write_sync(fid, 0, bytes(range(256)) * 4)
    srv.run_until_idle()
    cli = DDSClient(srv)
    assert cli.wait(cli.read(fid, 0, 128))[0] == wire.E_OK
    completed_before = srv.offload.stats.completed
    assert completed_before >= 1        # the warm read was DPU-served
    srv.offload.fail()
    status, body = cli.wait(cli.read(fid, 0, 128))
    assert status == wire.E_OK and body == bytes(range(128))
    assert srv.offload.stats.completed == completed_before
    assert srv.director.stats.dpu_bypassed >= 1
    assert srv.latency_stats()["wire"]["dpu_bypassed"] >= 1
    # writes keep working on the host path too
    assert cli.wait(cli.write(fid, 0, b"Z" * 16))[0] == wire.E_OK
    assert cli.wait(cli.read(fid, 0, 16)) == (wire.E_OK, b"Z" * 16)


# ---------------------------------------------------------------------------
# Shed-retry jitter: deterministic, de-synchronized
# ---------------------------------------------------------------------------


def _retry_deadlines(client, rids, retry_after=4):
    hint = wire.encode_shed_hint(0, retry_after)
    got = {rid: (wire.E_SHED, hint) for rid in rids}
    for rid in rids:
        client._replay[rid] = b"stub"   # presence is all the guard checks
    pending: set = set()
    client._backoff.clear()
    client._maybe_retry_shed(got, pending)
    assert pending == set(rids)
    return {rid: due for due, rid in client._backoff}


def test_shed_retry_backoff_jittered_and_deterministic():
    cl = DDSCluster(1, ServerConfig(device_capacity=1 << 24))
    c1 = ClusterClient(cl, retry_attempts=3)
    c2 = ClusterClient(cl, retry_attempts=3)
    rids = list(range(1, 33))
    d1 = _retry_deadlines(c1, rids)
    d2 = _retry_deadlines(c2, rids)
    # deterministic: a pure function of (rid, attempt), identical across
    # clients and runs
    assert d1 == d2
    # jittered: the storm spreads over multiple ticks instead of
    # re-colliding in one
    assert len(set(d1.values())) > 1
