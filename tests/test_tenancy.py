"""Multi-tenant QoS: weighted-fair demux, token-bucket admission, tenancy
observability, and the unified ``submit``/``harvest`` client surface.

Covers the PR-6 tenancy machinery end to end:

  * ``TenantFairQueue`` / ``FlowDemuxWire.pop_many`` PROPERTIES: no tenant
    starves under ANY weight vector (every backlogged tenant is served
    within a bounded number of take rounds), and the queues are
    work-conserving (an idle tenant's share flows to the backlogged ones —
    total service never drops below min(budget, backlog));
  * single-tenant fast path: with one tenant the fair queues are
    byte-identical to the plain FIFOs they replaced (determinism guard for
    every pre-tenancy workload);
  * token-bucket admission conservation: ``granted + shed == offered``
    holds exactly under any arrival pattern, and shed responses carry the
    shedding tenant's bucket state (retry-after hint) as the E_SHED body;
  * sheds are charged to THEIR tenant only: another tenant's outstanding
    counters and latency stats never move;
  * tick determinism: two identical two-tenant interference runs produce
    byte-identical per-tenant latency histograms;
  * the unified ``submit``/``harvest`` surface returns exactly what the
    deprecated ``read_many``/``write_many``/``get_many``/``put_many``/
    ``delete_many``/``wait_many`` wrappers return.
"""

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import wire
from repro.core.client import ClusterClient
from repro.core.dds_server import DDSClient, DDSStorageServer, ServerConfig
from repro.core.lifecycle import TickClock
from repro.core.qos import QoSProfile, TenantAdmission, TokenBucket
from repro.core.traffic import FiveTuple, FlowDemuxWire, Packet, TenantFairQueue
from repro.apps.kv_store import KVClient, ShardedKVStore
from repro.distributed.cluster import DDSCluster


def _flow(tenant: int, port: int = 1000) -> FiveTuple:
    return FiveTuple("10.0.0.2", port + tenant, "10.0.0.1", 7777,
                     tenant=tenant)


# ---------------------------------------------------------------------------
# QoSProfile: validation, presets, reject-unknown-fields
# ---------------------------------------------------------------------------


def test_qos_profile_presets_and_from_dict():
    assert QoSProfile.preset("latency").coalesce_ticks == 0
    assert QoSProfile.preset("throughput").coalesce_ticks > 2
    iso = QoSProfile.preset("isolation")
    assert iso.admission_enabled()
    # from_dict layers overrides on a preset base and rejects typos.
    p = QoSProfile.from_dict({"profile": "latency", "host_drain_slice": 64})
    assert p.coalesce_ticks == 0 and p.host_drain_slice == 64
    with pytest.raises(ValueError, match="unknown QoSProfile field"):
        QoSProfile.from_dict({"coalesce_tick": 3})   # typo'd key is an ERROR
    with pytest.raises(ValueError):
        QoSProfile.preset("nope")
    with pytest.raises(ValueError):
        QoSProfile(prio_interleave=0)
    with pytest.raises(ValueError):
        QoSProfile(tenant_weights={1: 0})            # weights are >= 1
    with pytest.raises(ValueError):
        ServerConfig(qos="no-such-preset")
    # ServerConfig accepts a preset name or a config dict.
    assert ServerConfig(qos="latency").qos.deliver_ticks == 0
    assert ServerConfig(qos={"default_rate": 2.0}).qos.admission_enabled()


def test_qos_profile_weight_rate_accessors():
    p = QoSProfile(tenant_weights={2: 5}, default_rate=4.0,
                   tenant_rates={3: 0.5})
    assert p.weight_of(2) == 5 and p.weight_of(9) == 1
    assert p.rate_of(3) == 0.5 and p.rate_of(9) == 4.0
    assert p.burst_of(9) == 32.0          # default burst = 8x rate
    assert QoSProfile().admission_enabled() is False


# ---------------------------------------------------------------------------
# WFQ properties: no starvation, work conservation
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(weights=st.lists(st.integers(min_value=1, max_value=8),
                        min_size=2, max_size=5),
       backlog=st.integers(min_value=1, max_value=40),
       budget=st.integers(min_value=1, max_value=16))
def test_tenant_fair_queue_no_starvation_any_weights(weights, backlog,
                                                     budget):
    """Under ANY weight vector, every backlogged tenant gets service within
    a bounded number of take rounds — a flooding tenant cannot starve the
    others — and take order is per-tenant FIFO."""
    q = TenantFairQueue()
    prof = QoSProfile(tenant_weights={t + 1: w
                                      for t, w in enumerate(weights)})
    q.weight_of = prof.weight_of
    flows = [_flow(t + 1) for t in range(len(weights))]
    for i in range(backlog):
        for f in flows:
            q.append((f, b"m%d" % i))
    first_service = {}
    rounds = 0
    seen_per_tenant: dict[int, list] = {f.tenant: [] for f in flows}
    while len(q):
        got = q.take(budget)
        assert got, "take() made no progress on a non-empty queue"
        rounds += 1
        for item in got:
            t = item[0].tenant
            first_service.setdefault(t, rounds)
            seen_per_tenant[t].append(item[1])
    # No starvation: every tenant was first served within the rounds one
    # full WRR cycle can take at this budget.
    max_cycle = -(-sum(min(w, backlog) for w in weights) // budget)
    for t, r in first_service.items():
        assert r <= max_cycle
    # Per-tenant FIFO preserved.
    for f in flows:
        assert seen_per_tenant[f.tenant] == [b"m%d" % i
                                             for i in range(backlog)]


@settings(max_examples=25, deadline=None)
@given(budget=st.integers(min_value=1, max_value=32),
       backlog=st.integers(min_value=0, max_value=20))
def test_tenant_fair_queue_work_conserving_when_tenant_idle(budget, backlog):
    """An idle tenant's share flows to backlogged tenants: a take always
    returns min(budget, total backlog) regardless of who is idle."""
    q = TenantFairQueue()
    q.weight_of = QoSProfile(tenant_weights={1: 1, 2: 7}).weight_of
    f1 = _flow(1)
    for i in range(backlog):
        q.append((f1, bytes([i])))       # tenant 2 is entirely idle
    got = q.take(budget)
    assert len(got) == min(budget, backlog)
    assert len(q) == backlog - len(got)


def test_tenant_fair_queue_single_tenant_is_fifo():
    """With one tenant the fair queue IS the deque it replaced."""
    q = TenantFairQueue()
    f = _flow(0)
    items = [(f, bytes([i])) for i in range(10)]
    for it in items:
        q.append(it)
    assert q.take(4) == items[:4]
    assert q.take(100) == items[4:]
    assert not len(q)


def test_flow_demux_wire_fair_pop_across_tenants():
    """A flooding tenant's host-wire backlog cannot monopolize a drain
    slice: equal weights alternate tenants; per-flow FIFO holds."""
    w = FlowDemuxWire("t")
    w.weight_of = QoSProfile().weight_of      # every tenant weighs 1
    hog, victim = _flow(1), _flow(2)
    for i in range(50):
        w.push(Packet(hog, i, bytes([i])))
    w.push(Packet(victim, 0, b"v"))
    got = w.pop_many(4)
    assert len(got) == 4
    # The victim's single packet is served in the FIRST drain slice.
    assert [p.flow.tenant for p in got].count(2) == 1
    hog_payloads = [bytes(p.payload) for p in got if p.flow.tenant == 1]
    assert hog_payloads == [bytes([i]) for i in range(len(hog_payloads))]
    rest = w.pop_many(1000)
    assert len(rest) == 47 and not bool(w)


def test_flow_demux_wire_weighted_share():
    """Weights divide a contended drain slice proportionally."""
    w = FlowDemuxWire("t")
    w.weight_of = QoSProfile(tenant_weights={1: 3, 2: 1}).weight_of
    a, b = _flow(1), _flow(2)
    for i in range(40):
        w.push(Packet(a, i, b"a"))
        w.push(Packet(b, i, b"b"))
    got = w.pop_many(16)
    counts = {1: 0, 2: 0}
    for p in got:
        counts[p.flow.tenant] += 1
    assert counts[1] == 12 and counts[2] == 4   # 3:1 split of the slice


# ---------------------------------------------------------------------------
# Token-bucket admission: conservation + hints
# ---------------------------------------------------------------------------


@settings(max_examples=30, deadline=None)
@given(arrivals=st.lists(st.tuples(st.integers(min_value=1, max_value=3),
                                   st.integers(min_value=0, max_value=12),
                                   st.integers(min_value=0, max_value=3)),
                         min_size=1, max_size=40),
       rate=st.integers(min_value=1, max_value=6))
def test_admission_conservation_granted_plus_shed_is_offered(arrivals,
                                                             rate):
    """Exact conservation under any (tenant, burst-size, tick-gap) arrival
    pattern: every offered request is either granted or shed, and the
    per-tenant shed counts sum to the aggregate."""
    clock = TickClock()
    adm = TenantAdmission(QoSProfile(default_rate=float(rate)), clock)
    granted = 0
    for tenant, n, gap in arrivals:
        for _ in range(gap):
            clock.tick()
        granted += adm.admit(tenant, n)
    assert adm.granted == granted
    assert adm.granted + adm.shed == adm.offered
    assert sum(adm.tenant_shed.values()) == adm.shed
    # retry_after is >= 1 exactly when the bucket is dry.
    for tenant, _, _ in arrivals:
        ra = adm.retry_after(tenant)
        assert ra >= 0


def test_token_bucket_refill_and_retry_after():
    clock_now = 0
    b = TokenBucket(rate=2.0, burst=4.0)
    assert b.grant(clock_now, 10) == 4        # starts full, capped at burst
    assert b.retry_after(clock_now) == 1      # 2/tick -> one tick refills
    assert b.grant(1, 10) == 2                # one tick elapsed: rate tokens
    slow = TokenBucket(rate=0.25, burst=1.0)
    assert slow.grant(0, 1) == 1
    assert slow.retry_after(0) == 4           # ceil(1 / 0.25)


def test_admission_shed_carries_retry_after_hint_and_tenant():
    """An over-limit tenant's requests shed EARLY with the bucket state as
    the E_SHED body; the under-limit tenant on the same server is
    untouched."""
    srv = DDSStorageServer(ServerConfig(
        device_capacity=1 << 24,
        qos=QoSProfile(tenant_rates={7: 1.0}, tenant_bursts={7: 2.0})))
    hog = DDSClient(srv, port=31001, tenant=7)
    good = DDSClient(srv, port=31002, tenant=8)   # no rate: unlimited
    fid = srv.frontend.create_file("adm")
    srv.frontend.write_sync(fid, 0, b"\x01" * 4096)
    srv.run_until_idle()
    hog_rids = hog.submit([("r", fid, 0, 64)] * 6)   # burst 2: 4 must shed
    good_rids = good.submit([("r", fid, 0, 64)] * 6)
    hog_got = hog.harvest(hog_rids)
    good_got = good.harvest(good_rids)
    assert all(s == wire.E_OK for s, _ in good_got.values())
    sheds = {r: v for r, v in hog_got.items() if v[0] == wire.E_SHED}
    assert len(sheds) == 4
    for _, (_, body) in sheds.items():
        tenant, retry_after = wire.decode_shed_hint(body)
        assert tenant == 7 and retry_after >= 1
    assert srv.director.stats.admission_shed == 4
    assert srv.admission.summary()["shed"] == 4
    assert srv.lifecycle.tenant_sheds == {7: 4}
    stats = srv.latency_stats()
    assert stats["admission"]["granted"] + stats["admission"]["shed"] \
        == stats["admission"]["offered"]


def test_cluster_sheds_do_not_touch_other_tenants_counters():
    """A shed is reconciled against the shedding tenant's own connection:
    the other tenant's client drains to zero outstanding with correct
    latency stats and NO shed responses."""
    cluster = DDSCluster(num_shards=2, config=ServerConfig(
        device_capacity=1 << 24,
        qos=QoSProfile(tenant_rates={3: 1.0}, tenant_bursts={3: 1.0})))
    fid = cluster.create_file("iso")
    cluster.write_sync(fid, 0, b"\x02" * 8192)
    hog = ClusterClient(cluster, port=46000, tenant=3)
    good = ClusterClient(cluster, port=46200, tenant=4)
    hog_rids = hog.submit([("r", fid, 0, 64)] * 8)
    good_rids = good.submit([("r", fid, 0, 64)] * 8)
    good_got = good.harvest(good_rids)
    assert all(s == wire.E_OK for s, _ in good_got.values())
    assert good.outstanding() == 0
    hog_got = hog.harvest(hog_rids)
    assert hog.outstanding() == 0
    statuses = [s for s, _ in hog_got.values()]
    assert wire.E_SHED in statuses            # over-limit: some shed
    for s, body in hog_got.values():
        if s == wire.E_SHED:
            assert wire.decode_shed_hint(body)[0] == 3
    # run_until_idle converges even with terminal sheds outstanding.
    hog2 = ClusterClient(cluster, port=46400, tenant=3)
    rids2 = hog2.submit([("r", fid, 0, 64)] * 8)
    hog2.run_until_idle()
    assert hog2.outstanding() == 0            # sheds reconciled, not leaked
    got2 = hog2.harvest(rids2, block=False)
    assert len(got2) == len(rids2)
    stats = cluster.latency_stats()
    assert 4 in stats["tenants"] and "sheds" not in stats["tenants"][4]
    assert stats["tenants"][3]["sheds"] >= 1


# ---------------------------------------------------------------------------
# Tick-deterministic two-tenant interference regression
# ---------------------------------------------------------------------------


def _interference_run() -> tuple[dict, dict]:
    cluster = DDSCluster(num_shards=2, config=ServerConfig(
        device_capacity=1 << 24,
        qos=QoSProfile(default_rate=8.0, default_burst=16.0)))
    fid = cluster.create_file("det")
    cluster.write_sync(fid, 0, b"\x03" * 16384)
    victim = ClusterClient(cluster, port=47000, tenant=1)
    hog = ClusterClient(cluster, port=47200, tenant=2)
    for _ in range(6):
        v = victim.submit([("r", fid, 64 * i, 64) for i in range(4)])
        h = hog.submit([("r", fid, 64 * i, 64) for i in range(24)])
        victim.harvest(v)
        hog.harvest(h, block=False)
        hog.run_until_idle()
    per_shard = [srv.lifecycle.summary() for srv in cluster.servers]
    return cluster.latency_stats(), {"shards": per_shard}


def test_two_tenant_interference_is_tick_deterministic():
    a = _interference_run()
    b = _interference_run()
    assert a == b
    stats = a[0]
    assert 1 in stats["tenants"] and 2 in stats["tenants"]
    assert stats["tenants"][1]["dpu_read"]["count"] > 0


# ---------------------------------------------------------------------------
# Unified submit/harvest surface == deprecated wrappers
# ---------------------------------------------------------------------------


def test_dds_client_submit_harvest_matches_wrappers():
    srv = DDSStorageServer(ServerConfig(device_capacity=1 << 24))
    cli = DDSClient(srv)
    fid = srv.frontend.create_file("uni")
    srv.frontend.write_sync(fid, 0, bytes(range(256)) * 16)
    srv.run_until_idle()
    rids = cli.submit([("w", fid, 0, b"A" * 64),
                       ("read", fid, 64, 32),
                       ("write", fid, 128, b"B" * 16),
                       ("r", fid, 512, 64)])
    got = cli.harvest(rids)
    assert [got[r][0] for r in rids] == [wire.E_OK] * 4
    assert got[rids[1]][1] == (bytes(range(256)) * 16)[64:96]
    assert got[rids[3]][1] == (bytes(range(256)) * 16)[512:576]
    # The batch's writes landed (visible once the pipeline quiesced).
    srv.run_until_idle()
    chk = cli.submit([("r", fid, 0, 64)])
    assert cli.harvest(chk)[chk[0]][1] == b"A" * 64
    # Deprecated wrappers ride the same path.
    wr = cli.write_many([(fid, 256, b"C" * 8)])
    assert cli.wait(wr[0])[0] == wire.E_OK
    # harvest(None) drains whatever already arrived.
    r2 = cli.submit([("r", fid, 128, 16)])
    cli.harvest(r2)
    assert cli.harvest() == {}


def test_cluster_client_submit_mixed_batch_and_harvest_nonblocking():
    cluster = DDSCluster(num_shards=2,
                         config=ServerConfig(device_capacity=1 << 24))
    fids = [cluster.create_file(f"u{i}") for i in range(3)]
    for fid in fids:
        cluster.write_sync(fid, 0, b"\x07" * 4096)
    cli = ClusterClient(cluster, port=48000)
    rids = cli.submit([("w", fids[0], 0, b"x" * 64),
                       ("r", fids[1], 0, 64),
                       ("write", fids[2], 64, b"y" * 64),
                       ("read", fids[0], 1024, 64)])
    got = cli.harvest(rids)
    assert [got[r][0] for r in rids] == [wire.E_OK] * 4
    assert got[rids[1]][1] == b"\x07" * 64
    assert got[rids[3]][1] == b"\x07" * 64
    # read_many/write_many/wait_many wrappers still answer identically.
    r = cli.read_many([(fids[1], 0, 16), (fids[2], 64, 64)])
    got2 = cli.wait_many(r)
    assert got2[r[1]][1] == b"y" * 64
    assert cli.outstanding() == 0
    # Non-blocking harvest returns only what has arrived — never raises.
    r3 = cli.submit([("r", fids[0], 0, 8)])
    part = cli.harvest(r3, block=False)
    assert set(part) <= set(r3)
    cli.harvest(r3)


def test_kv_client_submit_mixed_and_wrappers():
    store = ShardedKVStore(num_shards=2,
                           config=ServerConfig(device_capacity=1 << 24))
    cli = KVClient(store, tenant=5)
    rids = cli.submit([("put", b"k1", b"v1" * 8),
                       ("put", b"k2", b"v2" * 8)])
    got = cli.harvest(rids)
    assert all(s == wire.E_OK for s, _ in got.values())
    rids = cli.submit([("get", b"k1"), ("delete", b"k2")])
    got = cli.harvest(rids)
    assert got[rids[0]][0] == wire.E_OK
    assert got[rids[1]][0] == wire.E_OK
    # After the DEL's ack, the mapping is gone (invalidate-on-read fired).
    assert cli.wait_value(cli.get(b"k2")) is None
    # Deprecated wrappers.
    cli.wait_put(cli.put(b"k3", b"v3"))
    assert cli.wait_value(cli.get(b"k3")) == b"v3"
    g = cli.get_many([b"k1", b"k3"])
    got = cli.net.wait_many(g)
    assert all(s == wire.E_OK for s, _ in got.values())
    p = cli.put_many([(b"k4", b"v4"), (b"k5", b"v5")])
    d = cli.delete_many([b"k4"])
    got = cli.harvest(p + d)
    assert all(s == wire.E_OK for s, _ in got.values())
    # Per-tenant stats accumulated under tenant 5 across shards.
    merged = store.latency_stats()
    assert 5 in merged["tenants"]


def test_tenant_rides_wire_and_stats_once_per_connection():
    """The tenant binds once per client; per-tenant histograms split by
    serving class while the aggregate equals the per-tenant sum."""
    srv = DDSStorageServer(ServerConfig(device_capacity=1 << 24))
    t1 = DDSClient(srv, port=31101, tenant=1)
    t2 = DDSClient(srv, port=31102, tenant=2)
    fid = srv.frontend.create_file("mix")
    srv.frontend.write_sync(fid, 0, b"\x09" * 4096)
    srv.run_until_idle()
    r1 = t1.submit([("r", fid, 0, 64)] * 4)
    r2 = t2.submit([("w", fid, 64 * i, b"z" * 64) for i in range(3)])
    t1.harvest(r1)
    t2.harvest(r2)
    summ = srv.lifecycle.summary()["tenants"]
    assert summ[1]["dpu_read"]["count"] == 4
    assert summ[2]["write"]["count"] == 3
    assert srv.lifecycle.hist["dpu_read"].n == 4
    assert srv.lifecycle.hist["write"].n == 3
