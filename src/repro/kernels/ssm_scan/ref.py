"""Pure-jnp oracle for the gated linear-attention (SSM) scan.

One recurrence covers the framework's attention-free families:

  S_t = diag(exp(w_t)) . S_{t-1} + k_t (x) v_t        (state K x V per head)
  o_t = q_t^T S_t

  * RWKV6 ("Finch"): w_t is a data-dependent per-key-dim log decay.
  * Mamba2 (SSD):    w_t = -softplus(dt) * A broadcast per head (scalar
                     decay), k_t = B_t, v_t = dt * x_t, q_t = C_t.

Shapes: q, k, w: (B, H, S, K); v: (B, H, S, V); init state (B, H, K, V).
Returns (o: (B, H, S, V), final state).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def gla_scan_ref(q, k, v, w, init_state=None):
    B, H, S, K = q.shape
    V = v.shape[-1]
    if init_state is None:
        init_state = jnp.zeros((B, H, K, V), jnp.float32)
    qf, kf, vf = (x.astype(jnp.float32) for x in (q, k, v))
    wf = w.astype(jnp.float32)

    def step(state, xs):
        qt, kt, vt, wt = xs                    # (B,H,K),(B,H,K),(B,H,V),(B,H,K)
        decay = jnp.exp(wt)[..., None]         # (B,H,K,1)
        state = state * decay + kt[..., None] * vt[..., None, :]
        ot = jnp.einsum("bhk,bhkv->bhv", qt, state)
        return state, ot

    xs = (qf.transpose(2, 0, 1, 3), kf.transpose(2, 0, 1, 3),
          vf.transpose(2, 0, 1, 3), wf.transpose(2, 0, 1, 3))
    final, outs = jax.lax.scan(step, init_state, xs)
    o = outs.transpose(1, 2, 0, 3)             # (B,H,S,V)
    return o.astype(q.dtype), final


def gla_decode_step(q, k, v, w, state):
    """Single-token recurrence (serving): q/k/w (B,H,K), v (B,H,V)."""
    decay = jnp.exp(w.astype(jnp.float32))[..., None]
    state = state * decay + (k.astype(jnp.float32)[..., None]
                             * v.astype(jnp.float32)[..., None, :])
    o = jnp.einsum("bhk,bhkv->bhv", q.astype(jnp.float32), state)
    return o.astype(q.dtype), state
