"""Pallas TPU chunked gated-linear-attention scan.

The sequential recurrence is reformulated per chunk of length C so the MXU
does the work (three (C x K)@(K x V)-class matmuls per chunk) instead of S
rank-1 updates:

  within a chunk, with running log-decay  a_i = sum_{j<=i} w_j :
    q~_i = q_i * exp(a_i)            k~_j = k_j * exp(-a_j)
    intra = causal_mask(q~ k~^T) v
    cross = q~ S_chunk_start
    S_next = exp(a_{C-1}) * S + (k~ * exp(a_{C-1}))^T v

Numerical safety: exp(-a_j) explodes for strong decay, so w is clamped to
[-CLAMP, 0] and the chunk size bounds total in-chunk decay; accumulation is
fp32 throughout (VMEM scratch state).

Grid: (B*H, S/C) with the chunk axis sequential ("arbitrary") carrying the
(K, V) state in VMEM scratch.  Block shapes (C, K)/(C, V) are MXU-aligned
for C, K, V multiples of 128 (K=64 still maps acceptably via lane packing).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax < 0.5 exposes CompilerParams as TPUCompilerParams; alias for compat.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

CLAMP = 30.0


def _gla_kernel(q_ref, k_ref, v_ref, w_ref, o_ref, sfin_ref, state_ref, *,
                nchunks: int, C: int, K: int, V: int):
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    q = q_ref[0].astype(jnp.float32)            # (C, K)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)            # (C, V)
    w = jnp.clip(w_ref[0].astype(jnp.float32), -CLAMP, 0.0)
    a = jnp.cumsum(w, axis=0)                   # (C, K) running log decay
    ea = jnp.exp(a)
    q_t = q * ea                                # q~
    # fp32 exponent guard (see ops.gla_scan_xla): saturate exp(-a) at e^60.
    k_t = k * jnp.exp(jnp.minimum(-a, 60.0))    # k~
    s = jax.lax.dot_general(q_t, k_t, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (C, C)
    ii = jax.lax.broadcasted_iota(jnp.int32, (C, C), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (C, C), 1)
    s = jnp.where(jj <= ii, s, 0.0)
    intra = jax.lax.dot_general(s, v, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (C, V)
    cross = jax.lax.dot_general(q_t, state_ref[...], (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    o_ref[0] = (intra + cross).astype(o_ref.dtype)
    # State update: S' = diag(exp(a_last)) S + (k~ * exp(a_last))^T v
    ea_last = ea[C - 1]                          # (K,)
    k_fin = k_t * ea_last[None, :]
    state_ref[...] = (state_ref[...] * ea_last[:, None]
                      + jax.lax.dot_general(k_fin, v, (((0,), (0,)), ((), ())),
                                            preferred_element_type=jnp.float32))

    @pl.when(c == nchunks - 1)
    def _fin():
        sfin_ref[0] = state_ref[...].astype(sfin_ref.dtype)


def gla_scan_pallas(q, k, v, w, chunk: int = 128, interpret: bool = False):
    """q/k/w: (B,H,S,K); v: (B,H,S,V) -> (o, final_state (B,H,K,V) fp32)."""
    B, H, S, K = q.shape
    V = v.shape[-1]
    C = min(chunk, S)
    assert S % C == 0, "pad sequence to chunk multiple"
    nchunks = S // C
    BH = B * H
    qr = q.reshape(BH, S, K)
    kr = k.reshape(BH, S, K)
    vr = v.reshape(BH, S, V)
    wr = w.reshape(BH, S, K)

    kernel = functools.partial(_gla_kernel, nchunks=nchunks, C=C, K=K, V=V)
    o, sfin = pl.pallas_call(
        kernel,
        grid=(BH, nchunks),
        in_specs=[
            pl.BlockSpec((1, C, K), lambda h, c: (h, c, 0)),
            pl.BlockSpec((1, C, K), lambda h, c: (h, c, 0)),
            pl.BlockSpec((1, C, V), lambda h, c: (h, c, 0)),
            pl.BlockSpec((1, C, K), lambda h, c: (h, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, C, V), lambda h, c: (h, c, 0)),
            pl.BlockSpec((1, K, V), lambda h, c: (h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, S, V), q.dtype),
            jax.ShapeDtypeStruct((BH, K, V), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((K, V), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(qr, kr, vr, wr)
    return o.reshape(B, H, S, V), sfin.reshape(B, H, K, V)
