from repro.kernels.ssm_scan.ops import gla_scan

__all__ = ["gla_scan"]
