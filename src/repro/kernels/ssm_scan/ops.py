"""Dispatching wrapper for the chunked GLA/SSM scan.

  * ``pallas``      — Mosaic chunked kernel (TPU)
  * ``xla_chunked`` — same chunked math in pure jnp with lax.scan over
    chunks (portable; used on CPU and in the dry-run)
  * ``naive``       — the per-token recurrence oracle (tests)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.ssm_scan.ref import gla_scan_ref

CLAMP = 30.0


def gla_scan_xla(q, k, v, w, chunk: int = 128, init_state=None):
    B, H, S, K = q.shape
    V = v.shape[-1]
    C = min(chunk, S)
    pad = (-S) % C
    if pad:
        zf = lambda x: jnp.pad(x, ((0, 0), (0, 0), (0, pad), (0, 0)))
        q, k, v, w = zf(q), zf(k), zf(v), zf(w)
    Sp = S + pad
    n = Sp // C
    qf = q.astype(jnp.float32).reshape(B, H, n, C, K)
    kf = k.astype(jnp.float32).reshape(B, H, n, C, K)
    vf = v.astype(jnp.float32).reshape(B, H, n, C, V)
    wf = jnp.clip(w.astype(jnp.float32), -CLAMP, 0.0).reshape(B, H, n, C, K)
    if init_state is None:
        init_state = jnp.zeros((B, H, K, V), jnp.float32)

    ii = jnp.arange(C)[:, None]
    jj = jnp.arange(C)[None, :]
    causal = (jj <= ii)

    def body(state, xs):
        qc, kc, vc, wc = xs                     # (B,H,C,*)
        a = jnp.cumsum(wc, axis=2)
        ea = jnp.exp(a)
        q_t = qc * ea
        # Exponent guard: exp(-a) overflows fp32 past ~88; contributions with
        # -a_j > 60 are multiplied by exp(a_i) <= exp(a_j) < e-60 downstream,
        # so saturating keeps results finite with negligible error.
        k_t = kc * jnp.exp(jnp.minimum(-a, 60.0))
        s = jnp.einsum("bhik,bhjk->bhij", q_t, k_t)
        s = jnp.where(causal[None, None], s, 0.0)
        intra = jnp.einsum("bhij,bhjv->bhiv", s, vc)
        cross = jnp.einsum("bhik,bhkv->bhiv", q_t, state)
        ea_last = ea[:, :, C - 1]               # (B,H,K)
        k_fin = k_t * ea_last[:, :, None, :]
        state = (state * ea_last[..., None]
                 + jnp.einsum("bhik,bhiv->bhkv", k_fin, vc))
        return state, intra + cross

    xs = (qf.transpose(2, 0, 1, 3, 4), kf.transpose(2, 0, 1, 3, 4),
          vf.transpose(2, 0, 1, 3, 4), wf.transpose(2, 0, 1, 3, 4))
    final, outs = jax.lax.scan(body, init_state, xs)
    o = outs.transpose(1, 2, 0, 3, 4).reshape(B, H, Sp, V)[:, :, :S]
    return o.astype(q.dtype), final


def gla_scan(q, k, v, w, chunk: int = 128, impl: str | None = None,
             interpret: bool = False):
    if impl is None:
        impl = "pallas" if jax.default_backend() == "tpu" else "xla_chunked"
    if impl == "pallas":
        from repro.kernels.ssm_scan.kernel import gla_scan_pallas
        return gla_scan_pallas(q, k, v, w, chunk=chunk, interpret=interpret)
    if impl == "xla_chunked":
        return gla_scan_xla(q, k, v, w, chunk=chunk)
    if impl == "naive":
        return gla_scan_ref(q, k, v, w)
    raise ValueError(f"unknown impl {impl}")
