"""Dispatching wrapper for paged decode attention.

  * ``pallas``  — block-table-walking Mosaic kernel (TPU)
  * ``xla``     — gather pages then masked attention (portable; what the
    dry-run lowers on CPU).  The gather IS the straw-man extra copy; on TPU
    the Pallas path removes it (see kernel.py docstring).
"""

from __future__ import annotations

import jax

from repro.kernels.paged_attention.ref import paged_attention_ref


def paged_attention(q, k_pages, v_pages, block_table, seq_lens,
                    scale: float | None = None, impl: str | None = None,
                    interpret: bool = False):
    if impl is None:
        impl = "pallas" if jax.default_backend() == "tpu" else "xla"
    if impl == "pallas":
        from repro.kernels.paged_attention.kernel import paged_attention_pallas
        return paged_attention_pallas(q, k_pages, v_pages, block_table,
                                      seq_lens, scale=scale,
                                      interpret=interpret)
    if impl == "xla":
        return paged_attention_ref(q, k_pages, v_pages, block_table, seq_lens,
                                   scale=scale)
    raise ValueError(f"unknown impl {impl}")
