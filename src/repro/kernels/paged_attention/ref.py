"""Pure-jnp oracle for paged decode attention.

Decode-time attention where the KV cache lives in a paged pool (the DDS
file-mapping analogue: a block table maps each sequence's logical KV pages
to physical pool pages).

Shapes:
  q:           (B, Hq, D)          one new query token per sequence
  k_pages:     (P, page, Hkv, D)   physical page pool
  v_pages:     (P, page, Hkv, D)
  block_table: (B, MaxPages) int32 physical page id per logical page
  seq_lens:    (B,) int32          valid KV length per sequence
  returns      (B, Hq, D)
"""

from __future__ import annotations

import jax.numpy as jnp


def paged_attention_ref(q, k_pages, v_pages, block_table, seq_lens,
                        scale: float | None = None):
    B, Hq, D = q.shape
    P, page, Hkv, _ = k_pages.shape
    G = Hq // Hkv
    if scale is None:
        scale = D ** -0.5
    # Gather each sequence's pages into contiguous KV (the "two-copy
    # straw-man" — fine for an oracle).
    k = k_pages[block_table]                    # (B, MaxPages, page, Hkv, D)
    v = v_pages[block_table]
    Smax = k.shape[1] * page
    k = k.reshape(B, Smax, Hkv, D).astype(jnp.float32)
    v = v.reshape(B, Smax, Hkv, D).astype(jnp.float32)
    k = jnp.repeat(k, G, axis=2)                # (B, Smax, Hq, D)
    v = jnp.repeat(v, G, axis=2)
    qf = q.astype(jnp.float32) * scale
    s = jnp.einsum("bhd,bkhd->bhk", qf, k)      # (B, Hq, Smax)
    kpos = jnp.arange(Smax)[None, None, :]
    mask = kpos < seq_lens[:, None, None]
    s = jnp.where(mask, s, -1e30)
    p = jnp.exp(s - s.max(-1, keepdims=True))
    p = p / (p.sum(-1, keepdims=True) + 1e-30)
    out = jnp.einsum("bhk,bkhd->bhd", p, v)
    return out.astype(q.dtype)
