"""Pallas TPU paged decode-attention kernel.

The TPU-native analogue of DDS zero-copy reads (DESIGN.md §2): instead of
gathering KV pages into a contiguous buffer and then attending (two passes
over HBM — the straw-man of paper §6.2), the kernel walks the block table
and streams each physical page HBM->VMEM exactly once, accumulating the
online softmax in VMEM scratch.  The block table is the file mapping; the
page pool is the segment store.

Design:
  * ``PrefetchScalarGridSpec``: the block table and sequence lengths are
    scalar-prefetch operands, so each grid step's page index map reads
    ``block_table[b, p]`` BEFORE the DMA — the hardware analogue of DDS
    translating (file, offset) -> physical block before issuing the SSD op.
  * Grid = (B, MaxPages), pages innermost (``arbitrary``) so the per-batch
    accumulators live across page steps.
  * Pages past ``ceil(seq_len/page)`` are skipped with ``pl.when`` — like
    unallocated segments, they are never touched.
  * q is laid out (B, Hkv*G, D); scores are computed per kv-head group so
    each page tile is read once for all G query heads of its group.

VMEM per step: page tile (page*Hkv*D*2B, e.g. 64*8*128*2 = 128 KB) + q/acc
((Hq*D)*(2+4)B < 200 KB) — comfortably inside 16 MB for page<=512.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax < 0.5 exposes CompilerParams as TPUCompilerParams; alias for compat.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

NEG_INF = -1e30


def _pa_kernel(block_table, seq_lens,              # scalar prefetch refs
               q_ref, k_ref, v_ref, o_ref,
               acc_ref, m_ref, l_ref, *,
               scale: float, page: int, npages: int, Hkv: int, G: int, D: int):
    b = pl.program_id(0)
    p = pl.program_id(1)

    @pl.when(p == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    seq_len = seq_lens[b]
    used = jax.lax.div(seq_len + page - 1, page)

    @pl.when(p < used)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale          # (Hkv*G, D)
        k = k_ref[0].astype(jnp.float32)                  # (page, Hkv, D)
        v = v_ref[0].astype(jnp.float32)
        qg = q.reshape(Hkv, G, D)
        s = jnp.einsum("hgd,thd->hgt", qg, k,
                       preferred_element_type=jnp.float32)  # (Hkv, G, page)
        kpos = p * page + jax.lax.broadcasted_iota(jnp.int32, s.shape, 2)
        s = jnp.where(kpos < seq_len, s, NEG_INF)
        s = s.reshape(Hkv * G, page)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        pr = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + pr.sum(axis=1, keepdims=True)
        prg = pr.reshape(Hkv, G, page)
        ctx = jnp.einsum("hgt,thd->hgd", prg, v,
                         preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha + ctx.reshape(Hkv * G, D)
        m_ref[...] = m_new

    @pl.when(p == npages - 1)
    def _finish():
        o_ref[0] = (acc_ref[...] / (l_ref[...] + 1e-30)).astype(o_ref.dtype)


def paged_attention_pallas(q, k_pages, v_pages, block_table, seq_lens,
                           scale: float | None = None,
                           interpret: bool = False):
    """q: (B, Hq, D); pools: (P, page, Hkv, D) -> (B, Hq, D)."""
    B, Hq, D = q.shape
    P, page, Hkv, _ = k_pages.shape
    assert Hq % Hkv == 0
    G = Hq // Hkv
    npages = block_table.shape[1]
    if scale is None:
        scale = D ** -0.5

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, npages),
        in_specs=[
            pl.BlockSpec((1, Hq, D), lambda b, p, bt, sl: (b, 0, 0)),
            # The block table translates (sequence, logical page) ->
            # physical pool page BEFORE the DMA is issued.
            pl.BlockSpec((1, page, Hkv, D),
                         lambda b, p, bt, sl: (bt[b, p], 0, 0, 0)),
            pl.BlockSpec((1, page, Hkv, D),
                         lambda b, p, bt, sl: (bt[b, p], 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, Hq, D), lambda b, p, bt, sl: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((Hq, D), jnp.float32),
            pltpu.VMEM((Hq, 1), jnp.float32),
            pltpu.VMEM((Hq, 1), jnp.float32),
        ],
    )
    kernel = functools.partial(_pa_kernel, scale=scale, page=page,
                               npages=npages, Hkv=Hkv, G=G, D=D)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hq, D), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(block_table, seq_lens, q, k_pages, v_pages)
