"""Pallas TPU flash-attention kernel (forward).

Tiled online-softmax attention with GQA, causal masking, and sliding-window
support.  TPU-codesign notes:

  * Grid is ``(batch*kv_heads, q_blocks, k_blocks)`` with the k axis
    innermost and declared ``arbitrary`` so the fp32 accumulators in VMEM
    scratch carry across k iterations (output block revisiting).
  * Block shapes default to (128, head_dim) — MXU-aligned on the matmul dims
    (multiples of 128 on the contraction and lane axes).
  * All q heads of one kv head (the GQA group G) are processed together:
    the q block is (G*bq, D) so the group shares the k/v tiles in VMEM —
    this is the zero-copy principle applied to VMEM: k/v tiles are fetched
    once per group rather than once per query head.
  * Fully-masked tiles (k beyond the causal frontier or before the window)
    are skipped with ``pl.when`` so the causal kernel does ~S^2/2 work.

VMEM budget per step (defaults, D=128, bq=bk=128, G<=8):
  q (G*128*128*2B = 256K max) + k/v (64K) + acc (G*128*128*4B) ~ 1.2 MB << 16 MB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax < 0.5 exposes CompilerParams as TPUCompilerParams; alias for compat.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
               scale: float, causal: bool, window: int | None,
               q_offset: int, bq: int, bk: int, nk: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # Positions of this tile.  q rows are (G, bq) flattened; all G heads of
    # the group share q positions.
    q_start = qi * bq + q_offset
    k_start = ki * bk

    def compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale   # (G*bq, D)
        k = k_ref[0].astype(jnp.float32)              # (bk, D)
        v = v_ref[0].astype(jnp.float32)              # (bk, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (G*bq, bk)
        # Tile rows are (G, bq) flattened g-major: row r -> head g = r // bq,
        # query index r % bq.  All G heads share the same query positions.
        qpos = q_start + (jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) % bq)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = jnp.ones(s.shape, dtype=bool)
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]                            # (G*bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                         # (G*bq, bk)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    if causal or window is not None:
        # Tile-level skip: entirely above the causal diagonal, or entirely
        # left of the earliest window position.
        q_last = q_start + bq - 1
        needed = k_start <= q_last
        if window is not None:
            needed = jnp.logical_and(needed, k_start + bk > q_start - (window - 1))
        pl.when(needed)(compute)
    else:
        compute()

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...] / (l_ref[...] + 1e-30)).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal: bool = True,
                           window: int | None = None,
                           q_offset: int | None = None,
                           scale: float | None = None,
                           block_q: int = 128, block_k: int = 128,
                           interpret: bool = False):
    """q: (B, Sq, Hq, D); k/v: (B, Sk, Hkv, D) -> (B, Sq, Hq, D)."""
    B, Sq, Hq, D = q.shape
    _, Sk, Hkv, _ = k.shape
    assert Hq % Hkv == 0
    G = Hq // Hkv
    if scale is None:
        scale = D ** -0.5
    if q_offset is None:
        q_offset = Sk - Sq
    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    assert Sq % bq == 0 and Sk % bk == 0, "pad sequence to block multiples"
    nq, nk = Sq // bq, Sk // bk
    # Reorder to (B*Hkv, ...) with the G q-heads of each kv head contiguous.
    qr = (q.transpose(0, 2, 1, 3)                        # (B, Hq, Sq, D)
           .reshape(B, Hkv, G, Sq, D)
           .reshape(B * Hkv, G * Sq, D))                 # rows: g-major, q-minor
    kr = k.transpose(0, 2, 1, 3).reshape(B * Hkv, Sk, D)
    vr = v.transpose(0, 2, 1, 3).reshape(B * Hkv, Sk, D)

    grid = (B * Hkv, nq, nk)

    def q_index(h, qi, ki):
        return (h, qi, 0)

    def kv_index(h, qi, ki):
        return (h, ki, 0)

    kernel = functools.partial(
        _fa_kernel, scale=scale, causal=causal, window=window,
        q_offset=q_offset, bq=bq, bk=bk, nk=nk)

    # q block gathers the G head-slices for this q tile: we expose q as
    # (B*Hkv, nq, G*bq, D) by reshaping rows so that tile qi holds rows
    # [g*Sq + qi*bq : ...) for all g — do that reshape up front.
    qr = (qr.reshape(B * Hkv, G, Sq, D)
            .reshape(B * Hkv, G, nq, bq, D)
            .transpose(0, 2, 1, 3, 4)                    # (BH, nq, G, bq, D)
            .reshape(B * Hkv, nq, G * bq, D))

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, G * bq, D), lambda h, qi, ki: (h, qi, 0, 0)),
            pl.BlockSpec((1, bk, D), kv_index),
            pl.BlockSpec((1, bk, D), kv_index),
        ],
        out_specs=pl.BlockSpec((1, 1, G * bq, D), lambda h, qi, ki: (h, qi, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Hkv, nq, G * bq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G * bq, D), jnp.float32),   # acc
            pltpu.VMEM((G * bq, 1), jnp.float32),   # running max m
            pltpu.VMEM((G * bq, 1), jnp.float32),   # running sum l
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qr, kr, vr)

    # (BH, nq, G*bq, D) -> (B, Sq, Hq, D)
    out = (out.reshape(B, Hkv, nq, G, bq, D)
              .transpose(0, 1, 3, 2, 4, 5)               # (B, Hkv, G, nq, bq, D)
              .reshape(B, Hq, Sq, D)
              .transpose(0, 2, 1, 3))
    return out
