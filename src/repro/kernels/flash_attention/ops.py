"""Dispatching wrapper for flash attention.

``flash_attention`` picks the implementation:
  * ``pallas``      — the Mosaic TPU kernel (kernel.py), on TPU backends;
  * ``xla_chunked`` — a pure-jnp blockwise online-softmax implementation
    (lax.scan over KV blocks) with the same memory behaviour: activations
    are O(S * block) instead of O(S^2).  Used on CPU (incl. the multi-pod
    dry-run) and as a portable fallback;
  * ``naive``       — the ref oracle (tests only; materializes S^2).

All implementations share semantics with ``ref.attention_ref``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.ref import attention_ref

NEG_INF = -1e30


def _chunk_body(q, kc, vc, carry, q_start, k_start, *, causal, window, bq, bk,
                k_limit):
    """One KV chunk of online softmax.  q: (B,H,bq,D); kc/vc: (B,H,bk,D)."""
    acc, m, l = carry
    s = jnp.einsum("bhqd,bhkd->bhqk", q, kc,
                   preferred_element_type=jnp.float32)
    qpos = q_start + jnp.arange(bq)[:, None]
    kpos = k_start + jnp.arange(bk)[None, :]
    mask = kpos < k_limit  # padded key positions never attend
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None, None], s, NEG_INF)
    m_new = jnp.maximum(m, s.max(-1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m - m_new)
    l = l * alpha + p.sum(-1, keepdims=True)
    acc = acc * alpha + jnp.einsum("bhqk,bhkd->bhqd", p, vc,
                                   preferred_element_type=jnp.float32)
    return acc, m_new, l


def flash_attention_xla(q, k, v, *, causal=True, window=None, q_offset=None,
                        scale=None, block_q: int = 512, block_k: int = 512):
    B, Sq, Hq, D = q.shape
    _, Sk, Hkv, _ = k.shape
    G = Hq // Hkv
    if scale is None:
        scale = D ** -0.5
    if q_offset is None:
        q_offset = Sk - Sq
    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    # Pad sequences up to block multiples (masked out).
    pq = (-Sq) % bq
    pk = (-Sk) % bk
    qf = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0))) if pq else q
    kf = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0))) if pk else k
    vf = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0))) if pk else v
    Sqp, Skp = Sq + pq, Sk + pk
    nq, nk = Sqp // bq, Skp // bk
    # (B, H, S, D) layouts; kv heads repeated lazily per group.
    qf = qf.transpose(0, 2, 1, 3).astype(jnp.float32) * scale   # (B,Hq,Sq,D)
    kf = kf.transpose(0, 2, 1, 3)
    vf = vf.transpose(0, 2, 1, 3)
    kf = jnp.repeat(kf, G, axis=1).astype(jnp.float32)
    vf = jnp.repeat(vf, G, axis=1).astype(jnp.float32)
    kb = kf.reshape(B, Hq, nk, bk, D).transpose(2, 0, 1, 3, 4)  # (nk,B,H,bk,D)
    vb = vf.reshape(B, Hq, nk, bk, D).transpose(2, 0, 1, 3, 4)

    def per_q_block(qi, qblk):
        q_start = qi * bq + q_offset
        init = (jnp.zeros((B, Hq, bq, D), jnp.float32),
                jnp.full((B, Hq, bq, 1), NEG_INF, jnp.float32),
                jnp.zeros((B, Hq, bq, 1), jnp.float32))

        def body(carry, xs):
            ki, kc, vc = xs
            carry = _chunk_body(qblk, kc, vc, carry, q_start, ki * bk,
                                causal=causal, window=window, bq=bq, bk=bk,
                                k_limit=Sk)
            return carry, None

        (acc, m, l), _ = jax.lax.scan(body, init,
                                      (jnp.arange(nk), kb, vb))
        return acc / (l + 1e-30)

    qb = qf.reshape(B, Hq, nq, bq, D).transpose(2, 0, 1, 3, 4)  # (nq,B,H,bq,D)
    out = jax.lax.map(lambda xs: per_q_block(xs[0], xs[1]),
                      (jnp.arange(nq), qb))                     # (nq,B,H,bq,D)
    out = out.transpose(1, 2, 0, 3, 4).reshape(B, Hq, Sqp, D)
    out = out[:, :, :Sq].transpose(0, 2, 1, 3)
    return out.astype(q.dtype)


def flash_attention(q, k, v, *, causal: bool = True, window: int | None = None,
                    q_offset: int | None = None, scale: float | None = None,
                    impl: str | None = None, block_q: int = 512,
                    block_k: int = 512, interpret: bool = False):
    """GQA flash attention.  See ref.attention_ref for semantics."""
    if impl is None:
        impl = "pallas" if jax.default_backend() == "tpu" else "xla_chunked"
    if impl == "pallas":
        from repro.kernels.flash_attention.kernel import flash_attention_pallas
        return flash_attention_pallas(
            q, k, v, causal=causal, window=window, q_offset=q_offset,
            scale=scale, block_q=min(128, q.shape[1]),
            block_k=min(128, k.shape[1]), interpret=interpret)
    if impl == "xla_chunked":
        return flash_attention_xla(q, k, v, causal=causal, window=window,
                                   q_offset=q_offset, scale=scale,
                                   block_q=block_q, block_k=block_k)
    if impl == "naive":
        return attention_ref(q, k, v, causal=causal, window=window,
                             q_offset=q_offset, scale=scale)
    raise ValueError(f"unknown impl {impl}")
