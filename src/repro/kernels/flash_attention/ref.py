"""Pure-jnp oracle for flash attention (naive full materialization).

Semantics: GQA scaled dot-product attention with optional causal masking and
optional sliding window (a query at position i attends to keys in
``[i - window + 1, i]`` when causal, plus the mask).  fp32 softmax.

Shapes:
  q: (B, Sq, Hq, D)   k, v: (B, Sk, Hkv, D)   with Hq % Hkv == 0
  returns (B, Sq, Hq, D) in q.dtype
"""

from __future__ import annotations

import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True, window: int | None = None,
                  q_offset: int | None = None, scale: float | None = None):
    B, Sq, Hq, D = q.shape
    _, Sk, Hkv, _ = k.shape
    assert Hq % Hkv == 0
    G = Hq // Hkv
    if scale is None:
        scale = D ** -0.5
    if q_offset is None:
        q_offset = Sk - Sq  # decode: queries are the trailing positions
    qf = q.astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    # expand kv heads to query heads
    kf = jnp.repeat(kf, G, axis=2)
    vf = jnp.repeat(vf, G, axis=2)
    logits = jnp.einsum("bqhd,bkhd->bhqk", qf, kf)
    qpos = jnp.arange(Sq)[:, None] + q_offset
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), dtype=bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None, None], logits, -1e30)
    probs = jnp.exp(logits - logits.max(-1, keepdims=True))
    probs = probs / (probs.sum(-1, keepdims=True) + 1e-30)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, vf)
    return out.astype(q.dtype)
