"""Qwen2.5-14B: 48L, d=5120, 40H (GQA kv=8), d_ff=13824, vocab 152064.
QKV bias, SwiGLU.

[hf:Qwen/Qwen2.5-0.5B; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2p5_14b", family="dense",
    num_layers=48, d_model=5120, num_heads=40, num_kv_heads=8,
    d_ff=13824, vocab_size=152064, mlp="swiglu", qkv_bias=True,
    rope_theta=1e6, source="hf:Qwen/Qwen2.5-0.5B; hf",
)
