"""StarCoder2-7B: 32L, d=4608, 36H (GQA kv=4), d_ff=18432, vocab 49152.
GQA + RoPE, plain-GELU MLP, learned biases.

[arXiv:2402.19173; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2_7b", family="dense",
    num_layers=32, d_model=4608, num_heads=36, num_kv_heads=4,
    d_ff=18432, vocab_size=49152, mlp="gelu", norm="ln", qkv_bias=True,
    rope_theta=1e5, source="arXiv:2402.19173; hf",
)
