"""RWKV6-7B ("Finch"): 32L, d=4096, attention-free, d_ff=14336, vocab 65536.
Data-dependent decay; constant-size decode state (runs long_500k).

[arXiv:2404.05892; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6_7b", family="ssm",
    num_layers=32, d_model=4096, num_heads=64, num_kv_heads=64,
    head_dim=64, d_ff=14336, vocab_size=65536, mlp="relu",
    ssm_kind="rwkv6", pin_prefill=False,  # §Perf: pins triple its prefill
    source="arXiv:2404.05892; hf",
)
