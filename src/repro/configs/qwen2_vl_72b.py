"""Qwen2-VL-72B backbone: 80L, d=8192, 64H (GQA kv=8), d_ff=29568,
vocab 152064, M-RoPE, dynamic resolution.  Vision frontend is a STUB —
input_specs provide precomputed patch embeddings.

[arXiv:2409.12191; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2_vl_72b", family="vlm",
    num_layers=80, d_model=8192, num_heads=64, num_kv_heads=8,
    d_ff=29568, vocab_size=152064, mlp="swiglu", qkv_bias=True,
    mrope=True, rope_theta=1e6, frontend="vision",
    source="arXiv:2409.12191; hf",
)
