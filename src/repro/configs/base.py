"""Config schema + shape grid for the assigned architectures.

Every architecture is a ``ModelConfig``; every workload cell is a
``ShapeConfig``.  ``applicable_shapes`` encodes the skip rules from
DESIGN.md §3 (long_500k only for sub-quadratic archs; decode only for archs
with a decoder).
"""

from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass, field


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int | None = None          # default d_model // num_heads
    mlp: str = "swiglu"                  # swiglu | geglu | gelu | relu
    norm: str = "rms"                    # rms | ln
    attention: str = "full"              # full | local_global
    window: int = 1024
    group_size: int = 6                  # local_global: 5 local + 1 global
    rope_theta: float = 1e4
    rope_theta_global: float = 1e6       # gemma3 global layers
    qkv_bias: bool = False
    mrope: bool = False
    tie_embeddings: bool = False
    # MoE
    num_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # SSM / hybrid
    ssm_kind: str = ""                   # mamba2 | rwkv6
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_expand: int = 2
    attn_every: int = 0                  # hybrid: shared attn block period
    # enc-dec
    encoder_layers: int = 0
    decoder_layers: int = 0
    # modality frontend stub ("": none)
    frontend: str = ""                   # audio | vision
    source: str = ""                     # provenance note
    # training memory policy: "full" remat, "dots" (save matmul outputs),
    # or "none" (save everything)
    remat: str = "full"
    # batch-pin activations during prefill lowering (measured per arch:
    # essential for MoE, harmful for the GLA-recurrence prefill of rwkv6)
    pin_prefill: bool = True

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to 256 so the embedding shards evenly over the
        model axis (MaxText-style logical vocab padding)."""
        return ((self.vocab_size + 255) // 256) * 256

    def param_count(self) -> int:
        """Analytic parameter count (embeddings + blocks), for roofline."""
        D, F, V = self.d_model, self.d_ff, self.vocab_size
        hd = self.hd
        emb = V * D * (1 if self.tie_embeddings else 2)
        att = D * self.num_heads * hd * 2 + D * self.num_kv_heads * hd * 2
        gated = self.mlp in ("swiglu", "geglu")
        mlp = D * F * (3 if gated else 2)
        if self.family == "moe":
            mlp = self.num_experts * mlp + D * self.num_experts
        if self.family == "ssm" and self.ssm_kind == "rwkv6":
            att = 5 * D * D + D * 64 * 2     # r/k/v/g/out + decay MLP
        if self.family == "hybrid":
            d_inner = self.ssm_expand * D
            m2 = (D * 2 * d_inner + D * 2 * self.ssm_state * self.ssm_heads
                  + D * self.ssm_heads + d_inner * D)
            n_attn = max(1, self.num_layers // max(1, self.attn_every))
            return emb + self.num_layers * (m2 + mlp) + att * 1  # shared attn
        if self.family == "encdec":
            enc = self.encoder_layers * (att + mlp)
            dec = self.decoder_layers * (att * 2 + mlp)  # + cross attn
            return emb + enc + dec
        return emb + self.num_layers * (att + mlp)

    def active_param_count(self) -> int:
        """MoE: params touched per token (for 6*N_active*D roofline)."""
        if self.family != "moe":
            return self.param_count()
        D, F = self.d_model, self.d_ff
        gated = self.mlp in ("swiglu", "geglu")
        mlp_one = D * F * (3 if gated else 2)
        att = (D * self.num_heads * self.hd * 2
               + D * self.num_kv_heads * self.hd * 2)
        emb = self.vocab_size * D * (1 if self.tie_embeddings else 2)
        return emb + self.num_layers * (att + self.top_k * mlp_one
                                        + D * self.num_experts)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524_288, 1),
}

ARCH_IDS = [
    "seamless_m4t_medium",
    "zamba2_1p2b",
    "gemma3_4b",
    "starcoder2_7b",
    "qwen2p5_14b",
    "tinyllama_1p1b",
    "rwkv6_7b",
    "qwen2_vl_72b",
    "granite_moe_3b_a800m",
    "dbrx_132b",
]

# archs that may run the 500k decode shape (sub-quadratic sequence mixing)
_LONG_OK = {"zamba2_1p2b", "gemma3_4b", "rwkv6_7b"}


def applicable_shapes(arch: str) -> dict[str, str]:
    """shape name -> 'run' or a skip reason (all 40 cells documented)."""
    out: dict[str, str] = {}
    for s in SHAPES.values():
        if s.name == "long_500k" and arch not in _LONG_OK:
            out[s.name] = "skip: pure full-attention arch (DESIGN.md §3)"
        else:
            out[s.name] = "run"
    return out


def get_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.CONFIG


def reduced_config(cfg: ModelConfig) -> ModelConfig:
    """Small same-family config for CPU smoke tests."""
    changes: dict = dict(
        num_layers=min(cfg.num_layers, 4),
        d_model=128,
        num_heads=4,
        num_kv_heads=min(4, max(1, cfg.num_kv_heads * 4 // cfg.num_heads)),
        head_dim=32,
        d_ff=256,
        vocab_size=512,
    )
    if cfg.family == "moe":
        changes.update(num_experts=min(8, cfg.num_experts),
                       top_k=min(2, cfg.top_k), d_ff=64)
    if cfg.ssm_kind == "mamba2":
        changes.update(ssm_state=16, ssm_heads=8)
    if cfg.ssm_kind == "rwkv6":
        changes.update(num_heads=4, head_dim=32)
    if cfg.family == "hybrid":
        changes.update(num_layers=5, attn_every=2)
    if cfg.family == "encdec":
        changes.update(encoder_layers=2, decoder_layers=2)
    if cfg.attention == "local_global":
        changes.update(num_layers=4, group_size=2, window=64)
    return dataclasses.replace(cfg, **changes)
