"""TinyLlama-1.1B: 22L, d=2048, 32H (GQA kv=4), d_ff=5632, vocab 32000.
Llama2-architecture small model; also the end-to-end training example.

[arXiv:2401.02385; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="tinyllama_1p1b", family="dense",
    num_layers=22, d_model=2048, num_heads=32, num_kv_heads=4,
    d_ff=5632, vocab_size=32000, mlp="swiglu",
    rope_theta=1e4, source="arXiv:2401.02385; hf",
)
