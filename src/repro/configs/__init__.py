"""Architecture configs: one module per assigned architecture."""

from repro.configs.base import (ModelConfig, ShapeConfig, SHAPES, ARCH_IDS,
                                get_config, reduced_config, applicable_shapes)

__all__ = ["ModelConfig", "ShapeConfig", "SHAPES", "ARCH_IDS", "get_config",
           "reduced_config", "applicable_shapes"]
