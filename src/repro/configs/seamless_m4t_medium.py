"""SeamlessM4T-medium backbone: 12L enc + 12L dec, d=1024, 16H, vocab 256206.

[arXiv:2308.11596; hf]  Multimodal enc-dec; the audio frontend is a STUB —
input_specs provide precomputed frame embeddings (DESIGN.md §3).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless_m4t_medium", family="encdec",
    num_layers=24, encoder_layers=12, decoder_layers=12,
    d_model=1024, num_heads=16, num_kv_heads=16, d_ff=4096,
    vocab_size=256206, mlp="relu", norm="ln", frontend="audio",
    rope_theta=1e4, source="arXiv:2308.11596; hf",
)
