"""Granite-MoE-3B-A800M: 32L, d=1536, 24H (GQA kv=8), fine-grained MoE:
40 experts top-8, d_ff=512 per expert, vocab 49155.

[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]  NOTE: the pool entry says
both "40e top-8" and "32 experts"; we follow the structured field (40).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite_moe_3b_a800m", family="moe",
    num_layers=32, d_model=1536, num_heads=24, num_kv_heads=8,
    d_ff=512, vocab_size=49155, mlp="swiglu",
    num_experts=40, top_k=8,
    source="hf:ibm-granite/granite-3.0-1b-a400m-base; hf",
)
