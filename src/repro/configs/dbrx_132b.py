"""DBRX-132B: 40L, d=6144, 48H (GQA kv=8), MoE 16 experts top-4,
d_ff=10752 per expert, vocab 100352, fine-grained experts.

[hf:databricks/dbrx-base; unverified]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="dbrx_132b", family="moe",
    num_layers=40, d_model=6144, num_heads=48, num_kv_heads=8,
    d_ff=10752, vocab_size=100352, mlp="swiglu", norm="ln",
    num_experts=16, top_k=4, rope_theta=5e5,
    source="hf:databricks/dbrx-base; unverified",
)
