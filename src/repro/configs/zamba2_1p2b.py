"""Zamba2-1.2B: 38 Mamba2 layers + ONE shared attention block applied
periodically (params reused), d=2048, 32H (GQA kv=32), d_ff=8192, state 64.

[arXiv:2411.15242; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2_1p2b", family="hybrid",
    num_layers=38, d_model=2048, num_heads=32, num_kv_heads=32,
    d_ff=8192, vocab_size=32000, mlp="swiglu",
    ssm_kind="mamba2", ssm_state=64, ssm_heads=64, ssm_expand=2,
    attn_every=6, source="arXiv:2411.15242; hf",
)
