"""Gemma3-4B: 34L, d=2560, 8H (GQA kv=4), d_ff=10240, vocab 262144.
5:1 local:global attention (window 1024), 128k context, tied embeddings.

[hf:google/gemma-3-1b-pt; unverified]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3_4b", family="dense",
    num_layers=34, d_model=2560, num_heads=8, num_kv_heads=4,
    d_ff=10240, vocab_size=262144, mlp="geglu",
    attention="local_global", window=1024, group_size=6,
    rope_theta=1e4, rope_theta_global=1e6, tie_embeddings=True,
    source="hf:google/gemma-3-1b-pt; unverified",
)
