"""Sharded multi-server DDS cluster: scale-out behind consistent hashing.

The paper's deployable unit is ONE storage server host + DPU (Fig 6);
production disaggregated stores run MANY of them behind a thin routing
layer (cf. BPF-oF and disaggregated-DBMS designs in PAPERS.md).  This
module provides that layer:

``HashRing``
    Consistent hashing with virtual nodes.  Placement is stable across
    processes (blake2b, not the salted builtin ``hash``) and adding a shard
    only remaps ~1/N of the key space — the property that makes scale-out
    cheap.

``DDSCluster``
    N independent :class:`DDSStorageServer` instances ("shards"), each with
    its own DPU, traffic director, offload engine and RAM-backed device.
    Files are placed by consistent-hashing their *cluster-global* file id;
    the cluster keeps the global->(shard, local-id) mapping, playing the
    (rarely-consulted, control-plane) metadata service of disaggregated
    designs.  ``pump()``/``run_until_idle()`` drive every shard one step so
    multi-server interleavings stay deterministic and testable.

Client-side batching/pipelining lives in :mod:`repro.core.client`; the
§9.2 KV application on top of the cluster lives in
:mod:`repro.apps.kv_store`.
"""

from __future__ import annotations

import bisect
import hashlib
from dataclasses import dataclass, field, replace
from typing import Callable, Iterable

from repro.core.dds_server import DDSStorageServer, ServerConfig
from repro.core.offload import OffloadAPI


def stable_hash(key: object, salt: bytes = b"") -> int:
    """64-bit process-stable hash of ints/bytes/strs (builtin hash is salted)."""
    if isinstance(key, int):
        raw = key.to_bytes(16, "little", signed=True)
    elif isinstance(key, bytes):
        raw = key
    else:
        raw = str(key).encode()
    return int.from_bytes(hashlib.blake2b(salt + raw, digest_size=8).digest(),
                          "little")


class HashRing:
    """Consistent-hash ring over integer shard ids with virtual nodes."""

    def __init__(self, num_shards: int, vnodes: int = 64):
        if num_shards <= 0:
            raise ValueError("num_shards must be positive")
        self.num_shards = num_shards
        self.vnodes = vnodes
        self._points: list[int] = []
        self._owners: list[int] = []
        for shard in range(num_shards):
            for v in range(vnodes):
                p = stable_hash(f"shard-{shard}-vnode-{v}")
                i = bisect.bisect_left(self._points, p)
                self._points.insert(i, p)
                self._owners.insert(i, shard)

    def shard_for(self, key: object) -> int:
        h = stable_hash(key, salt=b"key:")
        i = bisect.bisect_right(self._points, h)
        if i == len(self._points):
            i = 0  # wrap around the ring
        return self._owners[i]

    def distribution(self, keys: Iterable[object]) -> dict[int, int]:
        out: dict[int, int] = {s: 0 for s in range(self.num_shards)}
        for k in keys:
            out[self.shard_for(k)] += 1
        return out


@dataclass
class ClusterStats:
    """Aggregated across shards (per-shard stats stay on each server)."""
    offloaded_completed: int = 0
    bounced_to_host: int = 0
    host_responses: int = 0
    dpu_time_s: float = 0.0
    host_cpu_busy_s: float = 0.0
    per_shard_busy_s: list[float] = field(default_factory=list)


@dataclass
class FileLocation:
    """Where a cluster-global file id actually lives."""
    shard: int
    local_fid: int


class DDSCluster:
    """N DDS storage servers behind consistent-hash file-id sharding."""

    def __init__(self, num_shards: int = 2,
                 config: ServerConfig | None = None,
                 api_factory: Callable[[int], OffloadAPI | None] | None = None,
                 vnodes: int = 64):
        self.num_shards = num_shards
        base = config or ServerConfig()
        self.ring = HashRing(num_shards, vnodes)
        self.servers: list[DDSStorageServer] = []
        for i in range(num_shards):
            # Each shard listens on its own port so application signatures
            # stay per-server, exactly as N separate Fig-6 boxes would.
            cfg = replace(base, server_port=base.server_port + i)
            api = api_factory(i) if api_factory is not None else None
            self.servers.append(DDSStorageServer(cfg, api))
        self._files: dict[int, FileLocation] = {}
        self._next_fid = 1

    # -- control plane: cluster-global files ---------------------------------------
    def create_file(self, name: str) -> int:
        """Create a file on the shard the ring assigns; return a GLOBAL id."""
        gfid = self._next_fid
        self._next_fid += 1
        shard = self.ring.shard_for(gfid)
        lfid = self.servers[shard].frontend.create_file(f"{name}@{gfid}")
        self._files[gfid] = FileLocation(shard, lfid)
        return gfid

    def locate(self, gfid: int) -> FileLocation:
        loc = self._files.get(gfid)
        if loc is None:
            raise KeyError(f"unknown cluster file id {gfid}")
        return loc

    def shard_for_file(self, gfid: int) -> int:
        return self.locate(gfid).shard

    def write_sync(self, gfid: int, offset: int, data: bytes) -> None:
        """Host-side bulk load (e.g. benchmark setup), bypassing the network."""
        loc = self.locate(gfid)
        self.servers[loc.shard].frontend.write_sync(loc.local_fid, offset, data)
        self.servers[loc.shard].run_until_idle()

    # -- cooperative event loop over every shard ------------------------------------
    def pump(self) -> int:
        work = 0
        for srv in self.servers:
            work += srv.pump()
        return work

    def run_until_idle(self, max_iters: int = 200_000) -> None:
        idle = 0
        for _ in range(max_iters):
            if self.pump() == 0:
                for srv in self.servers:
                    srv.device.drain()
                idle += 1
                if idle >= 3:
                    return
            else:
                idle = 0
        raise TimeoutError("cluster did not go idle")

    # -- aggregate accounting ---------------------------------------------------------
    def stats(self) -> ClusterStats:
        st = ClusterStats()
        for srv in self.servers:
            st.offloaded_completed += srv.offload.stats.completed
            st.bounced_to_host += srv.offload.stats.bounced_to_host
            st.host_responses += srv.director.stats.resp_from_host
            st.dpu_time_s += srv.director.stats.modeled_time_s
            st.host_cpu_busy_s += srv.host_cpu_busy_s
            st.per_shard_busy_s.append(srv.director.stats.modeled_time_s
                                       + srv.host_cpu_busy_s)
        return st

    def makespan_s(self) -> float:
        """Modeled completion time: the busiest shard bounds the cluster."""
        return max(self.stats().per_shard_busy_s, default=0.0)
