"""Sharded multi-server DDS cluster: scale-out behind consistent hashing.

The paper's deployable unit is ONE storage server host + DPU (Fig 6);
production disaggregated stores run MANY of them behind a thin routing
layer (cf. BPF-oF and disaggregated-DBMS designs in PAPERS.md).  This
module provides that layer:

``HashRing``
    Consistent hashing with virtual nodes.  Placement is stable across
    processes (blake2b, not the salted builtin ``hash``) and adding a shard
    only remaps ~1/N of the key space — the property that makes scale-out
    cheap.

``DDSCluster``
    N independent :class:`DDSStorageServer` instances ("shards"), each with
    its own DPU, traffic director, offload engine and RAM-backed device.
    Files are placed by consistent-hashing their *cluster-global* file id;
    the cluster keeps the global->(shard, local-id) mapping, playing the
    (rarely-consulted, control-plane) metadata service of disaggregated
    designs.

``ReadySet``
    The cluster's work-signaled scheduler state: a doorbell-armed set of
    runnable shard indices.  Every work producer — a client pushing into a
    director's ingress, a ring insert, a block-device submission — marks its
    server runnable via the server's ``signal()`` doorbell; ``pump()``
    drains ONLY runnable servers, so the cost of a scheduling round tracks
    *active* work instead of cluster size (the pre-overhaul loop stepped
    every shard on every iteration — wall-clock per op grew with shard
    count even when most shards were idle).

    The no-lost-wakeup discipline: a shard is taken OUT of the set before
    it is stepped, so a doorbell raised concurrently with the step re-arms
    it; after the step it is re-armed while ``server.busy()`` holds
    (pending device completions, undrained rings/wires, in-flight host
    requests).  Stepping order is shard-index order, a subsequence of the
    old poll-everything order, so existing deterministic interleavings are
    preserved.

Client-side batching/pipelining lives in :mod:`repro.core.client`; the
§9.2 KV application on top of the cluster lives in
:mod:`repro.apps.kv_store`.
"""

from __future__ import annotations

import bisect
import hashlib
import threading
from dataclasses import dataclass, field, replace
from typing import Callable, Iterable

from repro.core import wire
from repro.core.client import ShardConnection
from repro.core.dds_server import (DDSStorageServer, ServerConfig,
                                   encode_app_write)
from repro.core.lifecycle import TickClock, TickHistogram
from repro.core.offload import OffloadAPI
from repro.distributed.fault_tolerance import ClusterSupervisor


def stable_hash(key: object, salt: bytes = b"") -> int:
    """64-bit process-stable hash of ints/bytes/strs (builtin hash is salted)."""
    if isinstance(key, int):
        raw = key.to_bytes(16, "little", signed=True)
    elif isinstance(key, bytes):
        raw = key
    else:
        raw = str(key).encode()
    return int.from_bytes(hashlib.blake2b(salt + raw, digest_size=8).digest(),
                          "little")


class HashRing:
    """Consistent-hash ring over integer shard ids with virtual nodes."""

    def __init__(self, num_shards: int, vnodes: int = 64):
        if num_shards <= 0:
            raise ValueError("num_shards must be positive")
        self.num_shards = num_shards
        self.vnodes = vnodes
        self._nodes: set[int] = set(range(num_shards))
        # Build every (point, owner) pair flat and sort ONCE: the old
        # per-vnode ``list.insert`` into the sorted lists was O(n^2) in
        # total vnode count, which bites exactly when scale-out grows the
        # ring (16 shards x 64 vnodes = 1024 quadratic inserts).
        pairs = sorted(
            (stable_hash(f"shard-{shard}-vnode-{v}"), shard)
            for shard in range(num_shards) for v in range(vnodes))
        self._points = [p for p, _ in pairs]   # bisect-ready for shard_for
        self._owners = [s for _, s in pairs]

    def _owner_at(self, h: int) -> int:
        i = bisect.bisect_right(self._points, h)
        if i == len(self._points):
            i = 0  # wrap around the ring
        return self._owners[i]

    def shard_for(self, key: object) -> int:
        return self._owner_at(stable_hash(key, salt=b"key:"))

    def nodes(self) -> list[int]:
        """Current member shard ids, sorted."""
        return sorted(self._nodes)

    def copy(self) -> "HashRing":
        """Cheap structural copy — membership edits on the copy leave the
        original untouched (the pending-ring idiom live resharding uses)."""
        ring = HashRing.__new__(HashRing)
        ring.num_shards = self.num_shards
        ring.vnodes = self.vnodes
        ring._nodes = set(self._nodes)
        ring._points = list(self._points)
        ring._owners = list(self._owners)
        return ring

    def add_node(self, shard: int) -> None:
        """Online membership: splice ``shard``'s vnodes into the ring.

        The merged arrays are identical to a fresh sort-once build over the
        union membership, so incremental growth and from-scratch
        construction agree point-for-point (pinned by test)."""
        if shard in self._nodes:
            return
        self._nodes.add(shard)
        pts = [(stable_hash(f"shard-{shard}-vnode-{v}"), shard)
               for v in range(self.vnodes)]
        pairs = sorted([*zip(self._points, self._owners), *pts])
        self._points = [p for p, _ in pairs]
        self._owners = [s for _, s in pairs]
        self.num_shards = len(self._nodes)

    def remove_node(self, shard: int) -> None:
        """Online membership: drop every vnode owned by ``shard``.  Its
        ranges fall to each vnode's clockwise successor; no other owner's
        ranges move."""
        if shard not in self._nodes or len(self._nodes) <= 1:
            return
        self._nodes.discard(shard)
        pairs = [(p, s) for p, s in zip(self._points, self._owners)
                 if s != shard]
        self._points = [p for p, _ in pairs]
        self._owners = [s for _, s in pairs]
        self.num_shards = len(self._nodes)

    def claimed_ranges(self, shard: int) -> list[tuple[int, int]]:
        """Half-open hash ranges ``[lo, hi)`` owned by ``shard``.  The wrap
        interval is reported as two pieces ``[last_point, 2^64)`` and
        ``[0, first_point)``."""
        out: list[tuple[int, int]] = []
        pts, owners = self._points, self._owners
        for i, owner in enumerate(owners):
            if owner != shard:
                continue
            if i == 0:
                out.append((pts[-1], 1 << 64))
                out.append((0, pts[0]))
            else:
                out.append((pts[i - 1], pts[i]))
        return [(lo, hi) for lo, hi in out if lo < hi]

    @staticmethod
    def remap_fraction(old: "HashRing", new: "HashRing") -> float:
        """Fraction of the 64-bit hash space whose owner differs between
        two rings — the invariant live-migration volume depends on (adding
        one node to n remaps ~1/(n+1); removing one remaps only its own
        share).  Exact interval arithmetic, not sampling: walk the merged
        point set; ownership is constant on each piece."""
        bounds = sorted(set(old._points) | set(new._points))
        if not bounds:
            return 0.0
        moved = 0
        span = 1 << 64
        for j, b in enumerate(bounds):
            hi = bounds[j + 1] if j + 1 < len(bounds) else bounds[0] + span
            if old._owner_at(b) != new._owner_at(b):
                moved += hi - b
        return moved / span

    def successors(self, shard: int, k: int) -> list[int]:
        """The first ``k`` DISTINCT other shards clockwise from ``shard``'s
        first vnode — its replica group.  Deterministic (the ring is), and
        stable under failover because failover repairs a ROUTE table on top
        of the ring instead of removing vnodes (removal would re-home the
        dead shard's keys onto arbitrary ring successors, not onto the
        replicas actually holding the data)."""
        if k <= 0 or self.num_shards <= 1:
            return []
        owners = self._owners
        n = len(owners)
        try:
            i = owners.index(shard)
        except ValueError:
            return []
        out: list[int] = []
        seen = {shard}
        for j in range(1, n):
            o = owners[(i + j) % n]
            if o not in seen:
                seen.add(o)
                out.append(o)
                if len(out) >= k:
                    break
        return out

    def distribution(self, keys: Iterable[object]) -> dict[int, int]:
        out: dict[int, int] = {s: 0 for s in sorted(self._nodes)}
        for k in keys:
            out[self.shard_for(k)] += 1
        return out


@dataclass
class ClusterStats:
    """Aggregated across shards (per-shard stats stay on each server)."""
    offloaded_completed: int = 0
    bounced_to_host: int = 0
    host_responses: int = 0
    dpu_time_s: float = 0.0
    host_cpu_busy_s: float = 0.0
    per_shard_busy_s: list[float] = field(default_factory=list)


@dataclass
class FileLocation:
    """Where a cluster-global file id actually lives.

    ``replicas`` maps replica shard -> that shard's LOCAL fid of the copy
    (replica files are ordinary files on the replica's own SegmentFS).  On
    failover the promoted copy becomes ``(shard, local_fid)`` and leaves
    ``replicas``; the surviving copies stay listed."""
    shard: int
    local_fid: int
    replicas: dict[int, int] = field(default_factory=dict)


class ReadySet:
    """Doorbell-armed set of runnable shard indices (no lost wakeups).

    ``mark`` is the doorbell: idempotent (an armed shard is not re-queued)
    and safe from any thread.  ``take`` atomically snapshots-and-clears the
    set; a mark that races with a take lands in the NEXT snapshot, which is
    exactly the semantics the scheduler's take/step/re-arm cycle needs.
    Snapshots come back in shard-index order so cooperative stepping stays
    deterministic (a subsequence of the old step-everyone order).
    """

    def __init__(self, n: int):
        self._armed = [False] * n
        self._queue: list[int] = []
        self._lock = threading.Lock()
        # ``quiet`` caches "every shard was VERIFIED non-busy and no
        # doorbell has rung since": the scheduler's empty-set fallback scan
        # (a busy() probe per shard) runs at most once per quiet period
        # instead of once per idle pump.  Any mark clears it.
        self.quiet = False

    def mark(self, i: int) -> None:
        if self._armed[i]:   # racy fast path: double-mark is idempotent
            return
        with self._lock:
            self.quiet = False
            if not self._armed[i]:
                self._armed[i] = True
                self._queue.append(i)

    def take(self) -> list[int]:
        if not self._queue:   # racy-but-safe emptiness peek
            return []
        with self._lock:
            out = self._queue
            if not out:
                return []
            self._queue = []
            armed = self._armed
            for i in out:
                armed[i] = False
        out.sort()
        return out

    def grow(self, n: int = 1) -> None:
        """Widen the armed bitmap for newly provisioned shards."""
        with self._lock:
            self._armed.extend([False] * n)

    def __len__(self) -> int:
        return len(self._queue)

    def __bool__(self) -> bool:
        return bool(self._queue)


class _Replicator:
    """Primary-backup write forwarding for ONE primary shard.

    Owns a :class:`~repro.core.client.ShardConnection` to each replica
    target, so forwarded writes ride the SAME host wire, batching and
    ordering guarantees as client traffic (the paper's wire is the only
    transport).  ``forward`` encodes the final on-disk bytes — called at
    the one point where they are known, after the primary's host handler
    rewrote the payload (e.g. a KV PUT into a log record) — as a raw
    ``APP_WRITE`` against the target's replica file, and HOLDS the
    primary's client ack (the ``token`` request id) until every live
    target acked, or the supervisor dropped a dead target.  The client
    therefore never sees an ack for bytes a single crash could lose.

    Replicator flows are epoch-UNTAGGED: replication must keep working
    across the epoch bump its own failover causes.  Replica-side fan-out
    does not chain — a replica never maps its replica files into its own
    replicator, so depth is exactly one (primary-backup, not chain
    replication).
    """

    def __init__(self, primary: int,
                 targets: list[tuple[int, DDSStorageServer]],
                 clock: TickClock):
        self.primary = primary
        self.clock = clock
        # Distinct source ip per primary keeps replicator flows disjoint
        # from every client's (client ports allocate from 10.0.*).
        self.conns = {t: ShardConnection(srv, f"10.1.{primary}.1", 45000 + t)
                      for t, srv in targets}
        self._fid_map: dict[int, dict[int, int]] = {t: {} for t, _ in targets}
        self._next_rrid = 1
        self._hold: dict[int, int] = {}      # token -> outstanding replica acks
        self._rrid_meta: dict[int, tuple[int, int, int]] = {}  # rrid -> (token, target, t0)
        self._pending: dict[int, set[int]] = {t: set() for t, _ in targets}
        self._responses: dict[int, tuple[int, bytes]] = {}
        self._dirty = False
        self.lag = TickHistogram()           # forward tick -> replica-ack tick
        self.forwarded = 0
        self.forwarded_bytes = 0
        self.failures = 0                    # replica error/terminal statuses
        self.dropped = 0                     # acks released by drop_target

    def map_file(self, target: int, primary_fid: int, replica_fid: int) -> None:
        m = self._fid_map.get(target)
        if m is not None:
            m[primary_fid] = replica_fid

    def forward(self, token: int, file_id: int, offset: int, data) -> bool:
        """Forward one acked write; True if the client ack is now held."""
        held = 0
        t0 = self.clock.now
        for t, conn in self.conns.items():
            rfid = self._fid_map[t].get(file_id)
            if rfid is None:
                continue   # unreplicated file (e.g. checkpoints): no hold
            rrid = self._next_rrid
            self._next_rrid += 1
            conn.enqueue(encode_app_write(rrid, rfid, offset, data))
            self._rrid_meta[rrid] = (token, t, t0)
            self._pending[t].add(rrid)
            held += 1
        if not held:
            return False
        self._hold[token] = held
        self._dirty = True
        self.forwarded += held
        self.forwarded_bytes += held * len(data)
        return True

    def holds(self, token: int) -> bool:
        return token in self._hold

    def busy(self) -> bool:
        return self._dirty or bool(self._hold)

    def step(self) -> int:
        """Flush queued forwards, harvest replica acks, release holds."""
        work = 0
        if self._dirty:
            self._dirty = False
            for conn in self.conns.values():
                work += conn.flush()
        resp = self._responses
        for t, conn in self.conns.items():
            conn.collect(resp)
            conn.arrival_order.clear()   # unused here; don't grow unbounded
            pend = self._pending[t]
            if pend and not resp:
                # A replica overload-shed never produces a wire response:
                # reconcile terminal marks so holds cannot wedge forever.
                lt = conn.server.lifecycle
                for rrid in [r for r in pend
                             if lt.take_terminal(conn.flow, r) is not None]:
                    self.failures += 1
                    work += self._resolve(rrid)
        if resp:
            now = self.clock.now
            for rrid in list(resp):
                status, _body = resp.pop(rrid)
                meta = self._rrid_meta.get(rrid)
                if meta is not None:
                    self.lag.add(now - meta[2])
                    if status != wire.E_OK:
                        self.failures += 1
                work += self._resolve(rrid)
        return work

    def _resolve(self, rrid: int) -> int:
        meta = self._rrid_meta.pop(rrid, None)
        if meta is None:
            return 0
        token, target, _t0 = meta
        pend = self._pending.get(target)
        if pend is not None:
            pend.discard(rrid)
        left = self._hold.get(token, 0) - 1
        if left <= 0:
            self._hold.pop(token, None)
        else:
            self._hold[token] = left
        return 1

    def drop_target(self, target: int) -> None:
        """A replica died: stop forwarding to it and release every client
        ack held on replica acks it will never send."""
        if self.conns.pop(target, None) is None:
            return
        self._fid_map.pop(target, None)
        for rrid in list(self._pending.pop(target, ())):
            self.dropped += 1
            self._resolve(rrid)

    def add_target(self, target: int, srv: DDSStorageServer,
                   port: int) -> None:
        """(Re-)arm forwarding to ``target`` — a healed shard rejoining as
        a replica.  ``port`` must be fresh per rejoin generation (the
        target's PEP still holds the dropped connection's sequence state,
        so reusing the old five-tuple would have every forward discarded
        as a stale retransmit)."""
        if target in self.conns:
            return
        self.conns[target] = ShardConnection(
            srv, f"10.1.{self.primary}.1", port)
        self._fid_map.setdefault(target, {})
        self._pending.setdefault(target, set())

    def reset(self) -> None:
        """Demotion: abandon ALL in-flight forwarding state.

        Called when a partitioned ex-primary heals after a replica was
        promoted in its place.  Its held acks answer requests the clients
        already replayed against the repaired ring, and flushing writes
        frozen since before the partition could clobber newer bytes on
        the new primary's replicas — both are dropped on the floor; the
        epoch fence has already made every one of them unservable."""
        for conn in self.conns.values():
            conn._pending.clear()
        self._hold.clear()
        self._rrid_meta.clear()
        for pend in self._pending.values():
            pend.clear()
        self._dirty = False

    def summary(self) -> dict:
        out = {"targets": sorted(self.conns), "forwarded": self.forwarded,
               "bytes": self.forwarded_bytes}
        if self.lag.n:
            out["lag"] = self.lag.summary()
        if self.failures:
            out["failures"] = self.failures
        if self.dropped:
            out["dropped_acks"] = self.dropped
        return out


class DDSCluster:
    """N DDS storage servers behind consistent-hash file-id sharding."""

    def __init__(self, num_shards: int = 2,
                 config: ServerConfig | None = None,
                 api_factory: Callable[[int], OffloadAPI | None] | None = None,
                 vnodes: int = 64, elastic: bool = False):
        self.num_shards = num_shards
        base = config or ServerConfig()
        # Kept for elastic growth: add_shard() provisions new servers from
        # the same template the initial members used.
        self._base_config = base
        self._api_factory = api_factory
        self.elastic = elastic
        self.ring = HashRing(num_shards, vnodes)
        self.servers: list[DDSStorageServer] = []
        self._ready = ReadySet(num_shards)
        self.pump_steps = [0] * num_shards   # per-shard srv.pump() count
        # The cluster's deterministic lifecycle clock: ONE tick per cluster
        # pump step, shared by every shard (devices, file services, rings,
        # lifecycle trackers), so tick latencies are comparable across
        # shards and two identical runs produce identical histograms.
        self.clock = TickClock()
        for i in range(num_shards):
            # Each shard listens on its own port so application signatures
            # stay per-server, exactly as N separate Fig-6 boxes would.
            cfg = replace(base, server_port=base.server_port + i)
            api = api_factory(i) if api_factory is not None else None
            srv = DDSStorageServer(cfg, api)
            srv.adopt_clock(self.clock)
            # Every producer doorbell (client send, ring insert, device
            # submission) for this shard now arms it in the ready set.
            srv.set_doorbell(lambda i=i: self._ready.mark(i))
            self.servers.append(srv)
        self._files: dict[int, FileLocation] = {}
        self._next_fid = 1
        # -- replication / failover state ----------------------------------
        # ``epoch`` is the ring generation, bumped on every failover and
        # stamped onto epoch-aware clients' packets; ``_route`` repairs
        # routing ON TOP of the ring (dead shard -> promoted replica) so
        # vnode placement — and therefore which replica holds which keys —
        # never shifts.  ``replication`` is the effective factor K.
        self.epoch = 0
        self._route: dict[int, int] = {}
        self._dead: set[int] = set()
        self._crash_at: dict[int, int] = {}
        # Timed network partitions: shard -> heal tick.  A partitioned
        # shard looks exactly like a crashed one from the outside (no
        # pumping, no heartbeats, no routing) but its device and files
        # survive — on heal it rejoins as a REPLICA of whoever was
        # promoted in its place (the epoch fence already invalidated
        # every packet it could try to serve, so no split brain).
        self._partitioned: dict[int, int] = {}
        self.replication = (min(base.replication, num_shards - 1)
                            if num_shards > 1 else 0)
        self.failover_events: list[dict] = []
        self.rejoin_events: list[dict] = []
        # Application hook (e.g. the KV store): called as
        # ``on_promote(dead_shard, promoted_shard)`` after ring repair.
        self.on_promote = None
        # ``on_rejoin(healed_shard, primary_shard)``: application-level
        # re-silver after a healed partition rejoins as a replica.
        self.on_rejoin = None
        self.supervisor: ClusterSupervisor | None = None
        # -- elastic resharding state --------------------------------------
        # ``resharder`` is the one active migration driver (None when the
        # membership is stable); committed ring changes append to
        # ``reshard_events`` and finished/aborted migrations summarize into
        # ``reshard_history``.  ``retired`` shards stay allocated (their
        # index is load-bearing) but own no keys and take no traffic.
        self.resharder = None
        self.reshard_events: list[dict] = []
        self.reshard_history: list[dict] = []
        self.reshard_totals = {"keys_migrated": 0, "bytes_streamed": 0,
                               "dual_routed": 0}
        self.retired: set[int] = set()
        if self.replication > 0:
            for i, srv in enumerate(self.servers):
                targets = [(t, self.servers[t])
                           for t in self.ring.successors(i, self.replication)]
                srv.replicator = _Replicator(i, targets, self.clock)
            self.supervisor = ClusterSupervisor(
                self, base.heartbeat_timeout_ticks,
                base.heartbeat_miss_windows)
            for srv in self.servers:
                # Epoch fence: a packet tagged with a pre-failover epoch is
                # refused with a retryable terminal redirect.
                srv.director.epoch_of = lambda: self.epoch
                srv.director.on_stale_epoch = srv._on_stale_epoch
        elif elastic:
            # Unreplicated but elastic: the ownership flip still needs the
            # epoch fence so in-flight pre-flip packets bounce with a
            # retryable redirect instead of landing on the old owner.
            for srv in self.servers:
                srv.director.epoch_of = lambda: self.epoch
                srv.director.on_stale_epoch = srv._on_stale_epoch

    @property
    def failover_armed(self) -> bool:
        return self.supervisor is not None

    def runnable(self) -> list[int]:
        """Currently armed shard indices (introspection/tests only)."""
        return sorted(i for i, a in enumerate(self._ready._armed) if a)

    # -- elastic membership ---------------------------------------------------------
    @property
    def reshard_active(self) -> bool:
        return self.resharder is not None

    def add_shard(self) -> int:
        """Provision one NEW storage server (infra only — the ring is
        untouched until a migration flips ownership to it).

        The new shard gets the same config template as the initial
        members, joins the shared tick clock, ready set and supervisor,
        and — on replicated clusters — gets its own replicator wired by
        the PENDING ring (membership including itself), so its log is
        redundant before it owns a single key."""
        if not (self.failover_armed or self.elastic):
            raise RuntimeError(
                "add_shard requires an elastic or replicated cluster "
                "(the ownership flip needs the epoch fence)")
        i = len(self.servers)
        base = self._base_config
        cfg = replace(base, server_port=base.server_port + i)
        api = self._api_factory(i) if self._api_factory is not None else None
        srv = DDSStorageServer(cfg, api)
        srv.adopt_clock(self.clock)
        srv.set_doorbell(lambda i=i: self._ready.mark(i))
        srv.director.epoch_of = lambda: self.epoch
        srv.director.on_stale_epoch = srv._on_stale_epoch
        self.servers.append(srv)
        self.num_shards = len(self.servers)
        self._ready.grow()
        self.pump_steps.append(0)
        if self.replication > 0:
            pending = self.ring.copy()
            pending.add_node(i)
            targets = [(t, self.servers[t])
                       for t in pending.successors(i, self.replication)
                       if t not in self._dead]
            srv.replicator = _Replicator(i, targets, self.clock)
        if self.supervisor is not None:
            self.supervisor.add_shard(i)
        return i

    def start_reshard(self, resharder) -> None:
        """Install the one active migration driver; it is stepped from
        ``pump()`` and retires itself on completion/abort."""
        if self.resharder is not None:
            raise RuntimeError("a resharding migration is already active")
        if not (self.failover_armed or self.elastic):
            raise RuntimeError("resharding requires elastic=True or replication")
        self.resharder = resharder

    def commit_ring(self, ring: HashRing, event: dict) -> None:
        """The atomic ownership flip: swap the ring and bump the epoch in
        one step.  Every in-flight packet stamped with the old epoch is
        refused by the fence with a retryable redirect; epoch-aware clients
        re-resolve against the new ring and replay."""
        self.ring = ring
        self.epoch += 1
        event = dict(event, epoch=self.epoch, tick=self.clock.now)
        self.reshard_events.append(event)

    def _retire_resharder(self) -> None:
        rs = self.resharder
        if rs is None:
            return
        summary = rs.summary()
        self.reshard_history.append(summary)
        tot = self.reshard_totals
        tot["keys_migrated"] += summary.get("keys_migrated", 0)
        tot["bytes_streamed"] += summary.get("bytes_streamed", 0)
        tot["dual_routed"] += summary.get("dual_routed", 0)
        self.resharder = None

    # -- control plane: cluster-global files ---------------------------------------
    def create_file(self, name: str) -> int:
        """Create a file on the shard the ring assigns; return a GLOBAL id."""
        gfid = self._next_fid
        self._next_fid += 1
        shard = self.route_of(self.ring.shard_for(gfid))
        lfid = self.servers[shard].frontend.create_file(f"{name}@{gfid}")
        loc = FileLocation(shard, lfid)
        if self.replication:
            loc.replicas = self.replicate_file(shard, lfid, f"{name}@{gfid}")
        self._files[gfid] = loc
        return gfid

    def replicate_file(self, primary: int, lfid: int,
                       name: str, ring: HashRing | None = None) -> dict[int, int]:
        """Create replica copies of a shard-LOCAL file on the primary's ring
        successors and register them with its replicator.

        The public API for applications that create files directly on shard
        frontends (the KV store's record logs): every write the primary acks
        against ``lfid`` is thereafter forwarded before the ack releases.
        ``ring`` lets elastic growth place a NEW shard's replicas by the
        pending ring (the new shard is not in ``self.ring`` until the
        ownership flip).  Returns ``{replica shard: replica-local fid}``."""
        out: dict[int, int] = {}
        repl = self.servers[primary].replicator
        if not self.replication or repl is None:
            return out
        for t in (ring or self.ring).successors(primary, self.replication):
            if t in self._dead:
                continue
            rlfid = self.servers[t].frontend.create_file(f"{name}:r{primary}")
            repl.map_file(t, lfid, rlfid)
            out[t] = rlfid
        return out

    def locate(self, gfid: int) -> FileLocation:
        loc = self._files.get(gfid)
        if loc is None:
            raise KeyError(f"unknown cluster file id {gfid}")
        return loc

    def shard_for_file(self, gfid: int) -> int:
        return self.locate(gfid).shard

    def route_of(self, shard: int) -> int:
        """Post-failover routing: follow the repair chain to a live shard.
        Chains are compressed at failover time, so this is usually one
        dict miss; a key's route never lands on a dead shard."""
        r = self._route
        while shard in r:
            shard = r[shard]
        return shard

    def shard_for_key(self, key: object) -> int:
        """Key routing clients should use: ring placement + route repair."""
        return self.route_of(self.ring.shard_for(key))

    def write_sync(self, gfid: int, offset: int, data: bytes) -> None:
        """Host-side bulk load (e.g. benchmark setup), bypassing the network."""
        loc = self.locate(gfid)
        self.servers[loc.shard].frontend.write_sync(loc.local_fid, offset, data)
        self.servers[loc.shard].run_until_idle()
        # The bulk load bypassed the wire (and so the replicator): mirror it
        # onto the replica copies directly, preserving the invariant that
        # replicas hold every byte the primary considers durable.
        for t, rlfid in loc.replicas.items():
            if t in self._dead:
                continue
            self.servers[t].frontend.write_sync(rlfid, offset, data)
            self.servers[t].run_until_idle()

    # -- fault injection + failover -------------------------------------------------
    def crash(self, shard: int) -> None:
        """Deterministic fault injection: power-fail ``shard`` NOW.

        Its device loses every queued-but-unexecuted op (bytes already
        executed stay durable for a recovery mount), it stops being
        scheduled, and its heartbeat goes silent — the supervisor detects
        the death and promotes a replica ``heartbeat_timeout_ticks`` later.
        """
        if shard in self._dead:
            return
        self._dead.add(shard)
        self.servers[shard].device.crash()

    def crash_at(self, shard: int, tick: int) -> None:
        """Schedule ``crash(shard)`` for the first pump at/after ``tick``."""
        self._crash_at[shard] = tick

    def partition(self, shard: int, until_tick: int) -> None:
        """Deterministic fault injection: cut ``shard`` off the network NOW.

        Unlike :meth:`crash`, the device keeps its state.  While
        partitioned the shard is unreachable (not pumped, heartbeats
        silent, routing skips it) — if the partition outlasts the
        supervisor's grace windows a replica is promoted exactly as for a
        crash.  At ``until_tick`` the shard heals and, if it was failed
        over, rejoins the repaired ring AS A REPLICA of its promoted
        successor (see :meth:`_heal`)."""
        if shard in self._dead:
            return
        self._partitioned[shard] = until_tick
        self._dead.add(shard)

    def _heal(self, shard: int) -> None:
        """A partitioned shard's network came back.

        If nothing was promoted (the blip fit inside the supervisor's
        grace windows) the shard simply resumes as primary.  Otherwise
        the split-brain hazard is closed in three moves: (1) its
        replicator abandons every in-flight forward it froze
        pre-partition (``reset`` — the epoch fence already made the
        underlying requests unservable, clients replayed them against
        the new primary); (2) the new primary re-silvers the healed
        shard: every file it now owns is copied over and registered as a
        replica, restoring the redundancy the failover spent; (3) the
        supervisor starts monitoring it again.  The healed shard serves
        no client traffic — routes moved at promotion and stay moved."""
        self._partitioned.pop(shard, None)
        self._dead.discard(shard)
        sup = self.supervisor
        if sup is not None:
            sup.monitor.watch(f"shard{shard}")
            sup._misses.pop(f"shard{shard}", None)
        if shard not in self._route:
            return   # blip shorter than detection: clean resume as primary
        srv = self.servers[shard]
        if srv.replicator is not None:
            srv.replicator.reset()
        primary = self.route_of(shard)
        prepl = self.servers[primary].replicator
        resilvered = 0
        if prepl is not None:
            # Fresh port per rejoin generation: the healed shard's PEP
            # still remembers the old forwarding connection's sequence
            # state, so the epoch salt keeps the five-tuple unique.
            prepl.add_target(shard, srv,
                             port=45000 + shard + 1000 * (self.epoch + 1))
            psrv = self.servers[primary]
            for gfid, loc in self._files.items():
                if loc.shard != primary:
                    continue
                # A pre-partition replica copy may already exist on the
                # healed shard, but its forwarding was dropped at the
                # promotion — recopy the whole file (it missed every
                # partition-era write) and re-register the mapping.
                rlfid = loc.replicas.get(shard)
                if rlfid is None:
                    rlfid = srv.frontend.create_file(f"rejoin@{gfid}")
                size = psrv.fs.file_size(loc.local_fid)
                if size:
                    data = psrv.frontend.read_sync(loc.local_fid, 0, size)
                    srv.frontend.write_sync(rlfid, 0, data)
                    srv.run_until_idle()
                prepl.map_file(shard, loc.local_fid, rlfid)
                loc.replicas[shard] = rlfid
                resilvered += 1
        self.rejoin_events.append(
            {"tick": self.clock.now, "healed": shard, "primary": primary,
             "resilvered": resilvered})
        if self.on_rejoin is not None:
            self.on_rejoin(shard, primary)
        self._ready.mark(shard)

    def _failover(self, dead: int) -> int | None:
        """Promote a replica of ``dead``: drain the promoted shard, adopt
        its replica copies as primaries, repair key routing, release client
        acks held on the dead shard's replica acks, and bump the ring epoch
        (in-flight stale-epoch requests are refused with retryable
        redirects; clients replay against the repaired ring)."""
        # Candidates come from where the replicas actually LIVE (the dead
        # primary's replicator targets), not from recomputing the ring's
        # successors: an elastic flip reshapes the ring without moving
        # replica placement, so post-reshard the two can disagree — and a
        # successor holding no copy would be promoted into data loss.
        repl = self.servers[dead].replicator
        holders = set(repl.conns) if repl is not None else set()
        promoted = None
        for cand in self.ring.successors(dead, self.replication):
            if cand not in self._dead and (not holders or cand in holders):
                promoted = cand
                break
        if promoted is None:
            for cand in sorted(holders):
                if cand not in self._dead:
                    promoted = cand
                    break
        if promoted is not None:
            # Drain FIRST: every forwarded write the dead primary acked is
            # applied on the replica before any adopted file is served.
            self.servers[promoted].run_until_idle()
            prepl = self.servers[promoted].replicator
            for loc in self._files.values():
                if loc.shard != dead:
                    continue
                rlfid = loc.replicas.pop(promoted, None)
                if rlfid is None:
                    continue   # not replicated onto the promoted shard
                loc.shard = promoted
                loc.local_fid = rlfid
                # K >= 2: keep the surviving copies replicated from the
                # new primary (no re-replication of lost copies — the
                # repaired group is one smaller; documented limitation).
                if prepl is not None:
                    for t, rfid in loc.replicas.items():
                        if t not in self._dead:
                            prepl.map_file(t, rlfid, rfid)
            self._route[dead] = promoted
            for k, v in list(self._route.items()):
                if v != dead:
                    continue
                if k == promoted:
                    # Ping-pong promotion (A died onto B, B now dies back
                    # onto a healed A): a self-entry would make route_of
                    # spin forever — the promoted shard routes to itself.
                    del self._route[k]
                else:   # path compression: old chains point at the
                    self._route[k] = promoted   # live end directly
        for i, srv in enumerate(self.servers):
            if i not in self._dead and srv.replicator is not None:
                srv.replicator.drop_target(dead)
        self.epoch += 1
        self.failover_events.append(
            {"tick": self.clock.now, "dead": dead, "promoted": promoted,
             "epoch": self.epoch})
        if promoted is not None and self.on_promote is not None:
            self.on_promote(dead, promoted)
        return promoted

    # -- work-signaled cooperative event loop -----------------------------------------
    def pump(self) -> int:
        """Drain RUNNABLE servers only (doorbell semantics).

        Each runnable shard is taken out of the ready set BEFORE it is
        stepped (a doorbell racing the step re-arms it) and re-armed after
        the step while it produced work or ``busy()`` holds — pending
        device completions, undrained rings, in-flight host requests all
        keep a shard runnable, so wakeups are never lost.

        When the ready set is empty, a verification sweep re-arms any shard
        whose ``busy()`` holds, then latches the ready set's ``quiet`` flag;
        every doorbell (``ReadySet.mark``) clears it, so repeated idle
        pumps cost O(1) regardless of cluster size.  The contract this
        buys: ``pump() == 0`` means every shard was verified non-busy at
        some point since the last doorbell.  Work enqueued WITHOUT ringing
        a doorbell (poking a director wire directly) is caught by the
        sweep only until the first clean sweep latches quiet — after that
        it stays unscheduled until the next doorbell.  Every in-tree
        producer signals (client sends, ring publishes, device
        submissions); a new producer must too.
        """
        self.clock.tick()   # one tick per scheduling step (lifecycle clock)
        if self._crash_at:
            now = self.clock.now
            for shard, at in list(self._crash_at.items()):
                if now >= at:
                    del self._crash_at[shard]
                    self.crash(shard)
        if self._partitioned:
            now = self.clock.now
            for shard, until in list(self._partitioned.items()):
                if now >= until:
                    self._heal(shard)
        sup = self.supervisor
        if sup is not None:
            # Failure detection runs BEFORE the quiet-latch early returns:
            # a dead shard produces no doorbells, so its detection must not
            # depend on other work existing.  Unreplicated clusters skip
            # both calls (sup is None) — zero cost on that path.
            sup.beat_live()
            sup.poll()
        rs_work = 0
        rs = self.resharder
        if rs is not None:
            # The migration driver is pumped like a shard: it reports >=1
            # while a migration is in any live phase, keeping
            # ``run_until_idle`` driving the cluster until the flip (or
            # abort) lands even when no client traffic rings doorbells.
            rs_work = rs.step()
            if rs.phase in ("done", "aborted"):
                self._retire_resharder()
        runnable = self._ready.take()
        servers = self.servers
        dead = self._dead
        if not runnable:
            if self._ready.quiet:
                return rs_work   # verified idle, no doorbell since
            runnable = [i for i, srv in enumerate(servers)
                        if i not in dead and srv.busy()]
            if not runnable:
                self._ready.quiet = True
                return rs_work
        work = 0
        steps = self.pump_steps
        mark = self._ready.mark
        for i in runnable:
            if i in dead:
                continue   # crashed shards never step again
            srv = servers[i]
            steps[i] += 1
            w = srv.pump()
            if w or srv.busy():
                mark(i)
            work += w
        return work + rs_work

    def run_until_idle(self, max_iters: int = 200_000) -> None:
        """Converge on ready-set emptiness plus device drain.

        The common exit is ONE cheap check: ``pump() == 0`` with an empty
        ready set means every shard was verified non-busy (devices drained,
        rings consumed, nothing in flight) — no idle sweeps over all
        servers.  The pre-overhaul three-idle-sweep escape survives only
        for quiescent-but-permanently-busy states (e.g. a shed request's
        forever-outstanding application op), where ``busy()`` never clears
        even though no pump can make progress.
        """
        idle = 0
        for _ in range(max_iters):
            if self.pump():
                idle = 0
                continue
            if not self._ready:
                return   # verified idle: nothing runnable, nothing busy
            for srv in self.servers:
                if srv.device.busy():
                    srv.device.drain()
            idle += 1
            if idle >= 3:
                return
        raise TimeoutError("cluster did not go idle")

    # -- aggregate accounting ---------------------------------------------------------
    def stats(self) -> ClusterStats:
        st = ClusterStats()
        for srv in self.servers:
            st.offloaded_completed += srv.offload.stats.completed
            st.bounced_to_host += srv.offload.stats.bounced_to_host
            st.host_responses += srv.director.stats.resp_from_host
            st.dpu_time_s += srv.director.stats.modeled_time_s
            st.host_cpu_busy_s += srv.host_cpu_busy_s
            st.per_shard_busy_s.append(srv.director.stats.modeled_time_s
                                       + srv.host_cpu_busy_s)
        return st

    def makespan_s(self) -> float:
        """Modeled completion time: the busiest shard bounds the cluster."""
        return max(self.stats().per_shard_busy_s, default=0.0)

    def latency_stats(self) -> dict:
        """Cluster-wide measured tick-latency distributions.

        Merges every shard's per-class lifecycle histograms and device
        completion histograms (all stamped against the SHARED cluster
        clock, so merging is meaningful).  Exact histograms are available
        via ``latency_histograms`` for determinism checks."""
        classes = self._merged_classes()
        dev = TickHistogram()
        dev_prio = TickHistogram()
        sheds = 0
        redirects = 0
        for srv in self.servers:
            sheds += srv.lifecycle.sheds
            redirects += srv.lifecycle.redirects
            dev.merge(srv.device.stats.completion_ticks)
            dev_prio.merge(srv.device.stats.prio_completion_ticks)
        out = {"classes": {c: h.summary() for c, h in classes.items() if h.n}}
        if sheds:
            out["sheds"] = sheds
        if redirects:
            out["redirects"] = redirects
        if dev.n:
            out["device"] = dev.summary()
        if dev_prio.n:
            out["device_prio"] = dev_prio.summary()
        repl = self._replication_summary()
        if repl is not None:
            out["replication"] = repl
        jr_records = jr_bytes = 0
        for srv in self.servers:
            jr_records += srv.fs.journal_replayed_records
            jr_bytes += srv.fs.journal_replayed_bytes
        if jr_records:
            out["journal_replay"] = {"records": jr_records,
                                     "bytes": jr_bytes}
        if self.failover_events:
            out["failover"] = {"epoch": self.epoch,
                               "events": list(self.failover_events)}
        if self.rejoin_events:
            out["rejoins"] = list(self.rejoin_events)
        wire_stats = {"corrupt_dropped": 0, "seq_resyncs": 0,
                      "dpu_bypassed": 0}
        eo = {"dup_suppressed": 0, "replayed_acks": 0}
        for srv in self.servers:
            ds = srv.director.stats
            wire_stats["corrupt_dropped"] += ds.corrupt_dropped
            wire_stats["seq_resyncs"] += ds.seq_resyncs
            wire_stats["dpu_bypassed"] += ds.dpu_bypassed
            eo["dup_suppressed"] += srv.host_app.dup_suppressed
            eo["replayed_acks"] += srv.host_app.replayed_acks
        if any(wire_stats.values()):
            out["wire"] = wire_stats
        if any(eo.values()):
            out["exactly_once"] = eo
        tenants = {t: {c: h.summary() for c, h in per.items() if h.n}
                   for t, per in sorted(self._merged_tenants().items())}
        for t, n in sorted(self._merged_tenant_sheds().items()):
            tenants.setdefault(t, {})["sheds"] = n
        if tenants:
            out["tenants"] = tenants
        admission = [srv.admission.summary() for srv in self.servers
                     if srv.admission is not None]
        if admission:
            out["admission"] = {
                "offered": sum(a["offered"] for a in admission),
                "granted": sum(a["granted"] for a in admission),
                "shed": sum(a["shed"] for a in admission),
            }
        reshard = self._resharding_summary()
        if reshard is not None:
            out["resharding"] = reshard
        return out

    def _resharding_summary(self) -> dict | None:
        """Migration observability: committed ring events, lifetime totals,
        and — while one is live — the active migration's summary."""
        if not (self.reshard_events or self.reshard_history
                or self.resharder is not None):
            return None
        out: dict = {"events": list(self.reshard_events),
                     "totals": dict(self.reshard_totals)}
        if self.reshard_history:
            out["completed"] = list(self.reshard_history)
        if self.resharder is not None:
            out["active"] = self.resharder.summary()
        if self.retired:
            out["retired"] = sorted(self.retired)
        return out

    def _replication_summary(self) -> dict | None:
        """Cluster-wide replication accounting: merged lag histogram (all
        stamps ride the shared clock) + forward/drop counters."""
        lag = TickHistogram()
        forwarded = fbytes = dropped = 0
        any_repl = False
        for srv in self.servers:
            repl = srv.replicator
            if repl is None:
                continue
            any_repl = True
            lag.merge(repl.lag)
            forwarded += repl.forwarded
            fbytes += repl.forwarded_bytes
            dropped += repl.dropped
        if not any_repl:
            return None
        out: dict = {"forwarded": forwarded, "bytes": fbytes}
        if lag.n:
            out["lag"] = lag.summary()
        if dropped:
            out["dropped_acks"] = dropped
        return out

    def _merged_classes(self) -> dict:
        """Every shard's per-class lifecycle histograms, merged (stamps all
        ride the SHARED cluster clock, so merging is meaningful)."""
        classes: dict[str, TickHistogram] = {}
        for srv in self.servers:
            for cls, h in srv.lifecycle.hist.items():
                agg = classes.get(cls)
                if agg is None:
                    agg = classes[cls] = TickHistogram()
                agg.merge(h)
        return classes

    def _merged_tenants(self) -> dict:
        """Per-tenant per-class histograms across shards (tenant 0 — the
        untenanted default — lives only in the aggregate classes)."""
        tenants: dict[int, dict[str, TickHistogram]] = {}
        for srv in self.servers:
            for t, per in srv.lifecycle.tenant_hist.items():
                agg_per = tenants.get(t)
                if agg_per is None:
                    agg_per = tenants[t] = {}
                for cls, h in per.items():
                    agg = agg_per.get(cls)
                    if agg is None:
                        agg = agg_per[cls] = TickHistogram()
                    agg.merge(h)
        return tenants

    def _merged_tenant_sheds(self) -> dict[int, int]:
        sheds: dict[int, int] = {}
        for srv in self.servers:
            for t, n in srv.lifecycle.tenant_sheds.items():
                sheds[t] = sheds.get(t, 0) + n
        return sheds

    def tenant_latency(self, tenant: int, cls: str) -> TickHistogram:
        """Merged cross-shard histogram for one (tenant, class) — the
        tenancy benchmark's victim-p99 probe."""
        agg = TickHistogram()
        for srv in self.servers:
            per = srv.lifecycle.tenant_hist.get(tenant)
            if per is not None:
                h = per.get(cls)
                if h is not None:
                    agg.merge(h)
        return agg

    def latency_histograms(self) -> dict:
        """Exact merged per-class histograms (byte-identical across two
        same-seed runs — the determinism gate compares these)."""
        return {c: h.as_dict()
                for c, h in sorted(self._merged_classes().items()) if h.n}
