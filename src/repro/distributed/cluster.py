"""Sharded multi-server DDS cluster: scale-out behind consistent hashing.

The paper's deployable unit is ONE storage server host + DPU (Fig 6);
production disaggregated stores run MANY of them behind a thin routing
layer (cf. BPF-oF and disaggregated-DBMS designs in PAPERS.md).  This
module provides that layer:

``HashRing``
    Consistent hashing with virtual nodes.  Placement is stable across
    processes (blake2b, not the salted builtin ``hash``) and adding a shard
    only remaps ~1/N of the key space — the property that makes scale-out
    cheap.

``DDSCluster``
    N independent :class:`DDSStorageServer` instances ("shards"), each with
    its own DPU, traffic director, offload engine and RAM-backed device.
    Files are placed by consistent-hashing their *cluster-global* file id;
    the cluster keeps the global->(shard, local-id) mapping, playing the
    (rarely-consulted, control-plane) metadata service of disaggregated
    designs.

``ReadySet``
    The cluster's work-signaled scheduler state: a doorbell-armed set of
    runnable shard indices.  Every work producer — a client pushing into a
    director's ingress, a ring insert, a block-device submission — marks its
    server runnable via the server's ``signal()`` doorbell; ``pump()``
    drains ONLY runnable servers, so the cost of a scheduling round tracks
    *active* work instead of cluster size (the pre-overhaul loop stepped
    every shard on every iteration — wall-clock per op grew with shard
    count even when most shards were idle).

    The no-lost-wakeup discipline: a shard is taken OUT of the set before
    it is stepped, so a doorbell raised concurrently with the step re-arms
    it; after the step it is re-armed while ``server.busy()`` holds
    (pending device completions, undrained rings/wires, in-flight host
    requests).  Stepping order is shard-index order, a subsequence of the
    old poll-everything order, so existing deterministic interleavings are
    preserved.

Client-side batching/pipelining lives in :mod:`repro.core.client`; the
§9.2 KV application on top of the cluster lives in
:mod:`repro.apps.kv_store`.
"""

from __future__ import annotations

import bisect
import hashlib
import threading
from dataclasses import dataclass, field, replace
from typing import Callable, Iterable

from repro.core.dds_server import DDSStorageServer, ServerConfig
from repro.core.lifecycle import TickClock, TickHistogram
from repro.core.offload import OffloadAPI


def stable_hash(key: object, salt: bytes = b"") -> int:
    """64-bit process-stable hash of ints/bytes/strs (builtin hash is salted)."""
    if isinstance(key, int):
        raw = key.to_bytes(16, "little", signed=True)
    elif isinstance(key, bytes):
        raw = key
    else:
        raw = str(key).encode()
    return int.from_bytes(hashlib.blake2b(salt + raw, digest_size=8).digest(),
                          "little")


class HashRing:
    """Consistent-hash ring over integer shard ids with virtual nodes."""

    def __init__(self, num_shards: int, vnodes: int = 64):
        if num_shards <= 0:
            raise ValueError("num_shards must be positive")
        self.num_shards = num_shards
        self.vnodes = vnodes
        # Build every (point, owner) pair flat and sort ONCE: the old
        # per-vnode ``list.insert`` into the sorted lists was O(n^2) in
        # total vnode count, which bites exactly when scale-out grows the
        # ring (16 shards x 64 vnodes = 1024 quadratic inserts).
        pairs = sorted(
            (stable_hash(f"shard-{shard}-vnode-{v}"), shard)
            for shard in range(num_shards) for v in range(vnodes))
        self._points = [p for p, _ in pairs]   # bisect-ready for shard_for
        self._owners = [s for _, s in pairs]

    def shard_for(self, key: object) -> int:
        h = stable_hash(key, salt=b"key:")
        i = bisect.bisect_right(self._points, h)
        if i == len(self._points):
            i = 0  # wrap around the ring
        return self._owners[i]

    def distribution(self, keys: Iterable[object]) -> dict[int, int]:
        out: dict[int, int] = {s: 0 for s in range(self.num_shards)}
        for k in keys:
            out[self.shard_for(k)] += 1
        return out


@dataclass
class ClusterStats:
    """Aggregated across shards (per-shard stats stay on each server)."""
    offloaded_completed: int = 0
    bounced_to_host: int = 0
    host_responses: int = 0
    dpu_time_s: float = 0.0
    host_cpu_busy_s: float = 0.0
    per_shard_busy_s: list[float] = field(default_factory=list)


@dataclass
class FileLocation:
    """Where a cluster-global file id actually lives."""
    shard: int
    local_fid: int


class ReadySet:
    """Doorbell-armed set of runnable shard indices (no lost wakeups).

    ``mark`` is the doorbell: idempotent (an armed shard is not re-queued)
    and safe from any thread.  ``take`` atomically snapshots-and-clears the
    set; a mark that races with a take lands in the NEXT snapshot, which is
    exactly the semantics the scheduler's take/step/re-arm cycle needs.
    Snapshots come back in shard-index order so cooperative stepping stays
    deterministic (a subsequence of the old step-everyone order).
    """

    def __init__(self, n: int):
        self._armed = [False] * n
        self._queue: list[int] = []
        self._lock = threading.Lock()
        # ``quiet`` caches "every shard was VERIFIED non-busy and no
        # doorbell has rung since": the scheduler's empty-set fallback scan
        # (a busy() probe per shard) runs at most once per quiet period
        # instead of once per idle pump.  Any mark clears it.
        self.quiet = False

    def mark(self, i: int) -> None:
        if self._armed[i]:   # racy fast path: double-mark is idempotent
            return
        with self._lock:
            self.quiet = False
            if not self._armed[i]:
                self._armed[i] = True
                self._queue.append(i)

    def take(self) -> list[int]:
        if not self._queue:   # racy-but-safe emptiness peek
            return []
        with self._lock:
            out = self._queue
            if not out:
                return []
            self._queue = []
            armed = self._armed
            for i in out:
                armed[i] = False
        out.sort()
        return out

    def __len__(self) -> int:
        return len(self._queue)

    def __bool__(self) -> bool:
        return bool(self._queue)


class DDSCluster:
    """N DDS storage servers behind consistent-hash file-id sharding."""

    def __init__(self, num_shards: int = 2,
                 config: ServerConfig | None = None,
                 api_factory: Callable[[int], OffloadAPI | None] | None = None,
                 vnodes: int = 64):
        self.num_shards = num_shards
        base = config or ServerConfig()
        self.ring = HashRing(num_shards, vnodes)
        self.servers: list[DDSStorageServer] = []
        self._ready = ReadySet(num_shards)
        self.pump_steps = [0] * num_shards   # per-shard srv.pump() count
        # The cluster's deterministic lifecycle clock: ONE tick per cluster
        # pump step, shared by every shard (devices, file services, rings,
        # lifecycle trackers), so tick latencies are comparable across
        # shards and two identical runs produce identical histograms.
        self.clock = TickClock()
        for i in range(num_shards):
            # Each shard listens on its own port so application signatures
            # stay per-server, exactly as N separate Fig-6 boxes would.
            cfg = replace(base, server_port=base.server_port + i)
            api = api_factory(i) if api_factory is not None else None
            srv = DDSStorageServer(cfg, api)
            srv.adopt_clock(self.clock)
            # Every producer doorbell (client send, ring insert, device
            # submission) for this shard now arms it in the ready set.
            srv.set_doorbell(lambda i=i: self._ready.mark(i))
            self.servers.append(srv)
        self._files: dict[int, FileLocation] = {}
        self._next_fid = 1

    def runnable(self) -> list[int]:
        """Currently armed shard indices (introspection/tests only)."""
        return sorted(i for i, a in enumerate(self._ready._armed) if a)

    # -- control plane: cluster-global files ---------------------------------------
    def create_file(self, name: str) -> int:
        """Create a file on the shard the ring assigns; return a GLOBAL id."""
        gfid = self._next_fid
        self._next_fid += 1
        shard = self.ring.shard_for(gfid)
        lfid = self.servers[shard].frontend.create_file(f"{name}@{gfid}")
        self._files[gfid] = FileLocation(shard, lfid)
        return gfid

    def locate(self, gfid: int) -> FileLocation:
        loc = self._files.get(gfid)
        if loc is None:
            raise KeyError(f"unknown cluster file id {gfid}")
        return loc

    def shard_for_file(self, gfid: int) -> int:
        return self.locate(gfid).shard

    def write_sync(self, gfid: int, offset: int, data: bytes) -> None:
        """Host-side bulk load (e.g. benchmark setup), bypassing the network."""
        loc = self.locate(gfid)
        self.servers[loc.shard].frontend.write_sync(loc.local_fid, offset, data)
        self.servers[loc.shard].run_until_idle()

    # -- work-signaled cooperative event loop -----------------------------------------
    def pump(self) -> int:
        """Drain RUNNABLE servers only (doorbell semantics).

        Each runnable shard is taken out of the ready set BEFORE it is
        stepped (a doorbell racing the step re-arms it) and re-armed after
        the step while it produced work or ``busy()`` holds — pending
        device completions, undrained rings, in-flight host requests all
        keep a shard runnable, so wakeups are never lost.

        When the ready set is empty, a verification sweep re-arms any shard
        whose ``busy()`` holds, then latches the ready set's ``quiet`` flag;
        every doorbell (``ReadySet.mark``) clears it, so repeated idle
        pumps cost O(1) regardless of cluster size.  The contract this
        buys: ``pump() == 0`` means every shard was verified non-busy at
        some point since the last doorbell.  Work enqueued WITHOUT ringing
        a doorbell (poking a director wire directly) is caught by the
        sweep only until the first clean sweep latches quiet — after that
        it stays unscheduled until the next doorbell.  Every in-tree
        producer signals (client sends, ring publishes, device
        submissions); a new producer must too.
        """
        self.clock.tick()   # one tick per scheduling step (lifecycle clock)
        runnable = self._ready.take()
        servers = self.servers
        if not runnable:
            if self._ready.quiet:
                return 0   # verified idle, no doorbell since: nothing to do
            runnable = [i for i, srv in enumerate(servers) if srv.busy()]
            if not runnable:
                self._ready.quiet = True
                return 0
        work = 0
        steps = self.pump_steps
        mark = self._ready.mark
        for i in runnable:
            srv = servers[i]
            steps[i] += 1
            w = srv.pump()
            if w or srv.busy():
                mark(i)
            work += w
        return work

    def run_until_idle(self, max_iters: int = 200_000) -> None:
        """Converge on ready-set emptiness plus device drain.

        The common exit is ONE cheap check: ``pump() == 0`` with an empty
        ready set means every shard was verified non-busy (devices drained,
        rings consumed, nothing in flight) — no idle sweeps over all
        servers.  The pre-overhaul three-idle-sweep escape survives only
        for quiescent-but-permanently-busy states (e.g. a shed request's
        forever-outstanding application op), where ``busy()`` never clears
        even though no pump can make progress.
        """
        idle = 0
        for _ in range(max_iters):
            if self.pump():
                idle = 0
                continue
            if not self._ready:
                return   # verified idle: nothing runnable, nothing busy
            for srv in self.servers:
                if srv.device.busy():
                    srv.device.drain()
            idle += 1
            if idle >= 3:
                return
        raise TimeoutError("cluster did not go idle")

    # -- aggregate accounting ---------------------------------------------------------
    def stats(self) -> ClusterStats:
        st = ClusterStats()
        for srv in self.servers:
            st.offloaded_completed += srv.offload.stats.completed
            st.bounced_to_host += srv.offload.stats.bounced_to_host
            st.host_responses += srv.director.stats.resp_from_host
            st.dpu_time_s += srv.director.stats.modeled_time_s
            st.host_cpu_busy_s += srv.host_cpu_busy_s
            st.per_shard_busy_s.append(srv.director.stats.modeled_time_s
                                       + srv.host_cpu_busy_s)
        return st

    def makespan_s(self) -> float:
        """Modeled completion time: the busiest shard bounds the cluster."""
        return max(self.stats().per_shard_busy_s, default=0.0)

    def latency_stats(self) -> dict:
        """Cluster-wide measured tick-latency distributions.

        Merges every shard's per-class lifecycle histograms and device
        completion histograms (all stamped against the SHARED cluster
        clock, so merging is meaningful).  Exact histograms are available
        via ``latency_histograms`` for determinism checks."""
        classes = self._merged_classes()
        dev = TickHistogram()
        dev_prio = TickHistogram()
        sheds = 0
        for srv in self.servers:
            sheds += srv.lifecycle.sheds
            dev.merge(srv.device.stats.completion_ticks)
            dev_prio.merge(srv.device.stats.prio_completion_ticks)
        out = {"classes": {c: h.summary() for c, h in classes.items() if h.n}}
        if sheds:
            out["sheds"] = sheds
        if dev.n:
            out["device"] = dev.summary()
        if dev_prio.n:
            out["device_prio"] = dev_prio.summary()
        tenants = {t: {c: h.summary() for c, h in per.items() if h.n}
                   for t, per in sorted(self._merged_tenants().items())}
        for t, n in sorted(self._merged_tenant_sheds().items()):
            tenants.setdefault(t, {})["sheds"] = n
        if tenants:
            out["tenants"] = tenants
        admission = [srv.admission.summary() for srv in self.servers
                     if srv.admission is not None]
        if admission:
            out["admission"] = {
                "offered": sum(a["offered"] for a in admission),
                "granted": sum(a["granted"] for a in admission),
                "shed": sum(a["shed"] for a in admission),
            }
        return out

    def _merged_classes(self) -> dict:
        """Every shard's per-class lifecycle histograms, merged (stamps all
        ride the SHARED cluster clock, so merging is meaningful)."""
        classes: dict[str, TickHistogram] = {}
        for srv in self.servers:
            for cls, h in srv.lifecycle.hist.items():
                agg = classes.get(cls)
                if agg is None:
                    agg = classes[cls] = TickHistogram()
                agg.merge(h)
        return classes

    def _merged_tenants(self) -> dict:
        """Per-tenant per-class histograms across shards (tenant 0 — the
        untenanted default — lives only in the aggregate classes)."""
        tenants: dict[int, dict[str, TickHistogram]] = {}
        for srv in self.servers:
            for t, per in srv.lifecycle.tenant_hist.items():
                agg_per = tenants.get(t)
                if agg_per is None:
                    agg_per = tenants[t] = {}
                for cls, h in per.items():
                    agg = agg_per.get(cls)
                    if agg is None:
                        agg = agg_per[cls] = TickHistogram()
                    agg.merge(h)
        return tenants

    def _merged_tenant_sheds(self) -> dict[int, int]:
        sheds: dict[int, int] = {}
        for srv in self.servers:
            for t, n in srv.lifecycle.tenant_sheds.items():
                sheds[t] = sheds.get(t, 0) + n
        return sheds

    def tenant_latency(self, tenant: int, cls: str) -> TickHistogram:
        """Merged cross-shard histogram for one (tenant, class) — the
        tenancy benchmark's victim-p99 probe."""
        agg = TickHistogram()
        for srv in self.servers:
            per = srv.lifecycle.tenant_hist.get(tenant)
            if per is not None:
                h = per.get(cls)
                if h is not None:
                    agg.merge(h)
        return agg

    def latency_histograms(self) -> dict:
        """Exact merged per-class histograms (byte-identical across two
        same-seed runs — the determinism gate compares these)."""
        return {c: h.as_dict()
                for c, h in sorted(self._merged_classes().items()) if h.n}
