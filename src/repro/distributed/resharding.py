"""Elastic resharding: crash-safe live key migration between shards.

Growing or shrinking a :class:`~repro.distributed.cluster.DDSCluster`
means moving the keys whose consistent-hash owner changes between the
old and the new :class:`HashRing`.  The migration must be LIVE (the
cluster keeps serving), CRASH-SAFE (no acked write is ever lost, no
matter which endpoint dies at which phase) and EXACTLY-ONCE (a resent
sync never double-applies).  The driver here reuses the existing data
plane for all of it:

* sync traffic rides :class:`~repro.core.client.ShardConnection` flows
  to the destination — the same host wire, batching and PEP ordering as
  client traffic, exactly like PR 7's replica forwarding;
* writes that race the migration are DUAL-ROUTED: the source's
  ``migrator`` tap (installed on ``DDSStorageServer``) forwards every
  write it executes for a migrating key and HOLDS the client ack until
  the destination holds the bytes too (piggybacking on the server's
  ``_held_acks`` machinery);
* resends reuse the same request id, so the destination's exactly-once
  dedup cache absorbs duplicates;
* the ownership flip is one atomic ring swap + epoch bump — in-flight
  requests stamped with the old epoch bounce off the director's
  ``E_REDIRECT`` fence and the client replays them against the new
  owner.

Phases (journaled on BOTH endpoints so a crash leaves an unambiguous
resume/abort decision)::

    setup ── snapshot owned keys, install taps, arm shields
      │
    stream ─ push the snapshot window-by-window (tokenless syncs)
      │        new writes are forwarded immediately with known bytes
    dual ─── snapshot queue drained; every racing write now holds its
      │        client ack until the destination acks the sync
    flip ─── gate passed (no un-acked tokenless sync remains):
      │        journal intent, then swap ring + bump epoch + invalidate
      │        the source DPU cache for migrated keys
    cleanup ─ drain straggler syncs, grace period for fence-passed
      │        traffic, then drop the source's copies
    done

Any pre-flip fault (endpoint death or demotion) ABORTS: held acks are
released (the bytes are durable at the source, which keeps ownership)
and the destination's partial copy is dropped.  A source death DURING
flip proceeds — the flip gate guarantees the destination already holds
every acked migrating byte.  Short partitions merely stall the driver;
it resumes when the wire heals.

Ordering across the flip: every migration sync carries a PRE-flip
value, while every direct client write to the destination for a
migrated key is POST-flip (the fence re-routes clients only after the
flip).  The destination therefore arms a per-shard write SHIELD during
migration: a late (resent) sync for a key the destination has since
served a direct write for is acked but NOT applied — a stale pre-flip
value can never clobber a newer post-flip one.
"""

from __future__ import annotations

import struct
from collections import deque

from repro.core import wire
from repro.core.client import ShardConnection

# Migration phases in lifecycle order.  ``abort``/``aborted`` branch off
# any pre-flip phase.
PHASES = ("setup", "stream", "dual", "flip", "cleanup", "done",
          "abort", "aborted")
PHASE_CODES = {p: i + 1 for i, p in enumerate(PHASES)}
CODE_PHASES = {c: p for p, c in PHASE_CODES.items()}

# Journal record: (seq, pair_id, phase_code, aux, cursor, tick).
J_REC = struct.Struct("<IIIIQQ")

WINDOW = 32           # max in-flight snapshot syncs per pair
RESEND_TICKS = 64     # first resend deadline (doubles per attempt)
MAX_ATTEMPTS = 8      # pre-flip give-up threshold -> abort
CLEANUP_GRACE = 96    # ticks between flip and dropping source copies

_UNSET = object()     # "no acked loc yet" / "value not supplied"


class MigrationJournal:
    """Crash-consistent migration log, one append-only file per endpoint.

    Records are written through the fs allocator straight to device
    memory (``raw_write`` commits immediately in the model) — NOT via
    the front-end rings, whose synchronous helpers would eat completions
    of concurrent host traffic on a busy shard.  Each record lands on
    both the source's and the destination's journal, so whichever
    endpoint survives a crash can reconstruct the phase cursor.
    """

    def __init__(self, cluster, tag: str):
        self.cluster = cluster
        self.tag = tag
        self._fids: dict[int, int] = {}
        self._off: dict[int, int] = {}
        self._seq = 0

    def attach(self, shard: int) -> None:
        if shard in self._fids:
            return
        srv = self.cluster.servers[shard]
        self._fids[shard] = srv.fs.create_file(
            f"reshard-journal:{self.tag}:{shard}")
        self._off[shard] = 0

    def record(self, shards, pair_id: int, phase: str,
               cursor: int = 0, aux: int = 0) -> None:
        self._seq += 1
        rec = J_REC.pack(self._seq, pair_id, PHASE_CODES[phase], aux,
                         cursor, self.cluster.clock.now)
        cl = self.cluster
        for shard in shards:
            fid = self._fids.get(shard)
            if (fid is None or shard in cl._dead
                    or cl.route_of(shard) != shard):
                continue   # dead/demoted endpoints can't journal
            srv = cl.servers[shard]
            off = self._off[shard]
            srv.fs.ensure_capacity(fid, off + J_REC.size)
            pos = 0
            for phys, n in srv.fs.translate(fid, off, J_REC.size):
                srv.device.raw_write(phys, rec[pos:pos + n])
                pos += n
            self._off[shard] = off + J_REC.size

    def read(self, shard: int) -> list[dict]:
        """Parse ``shard``'s journal (tests + post-crash inspection)."""
        fid = self._fids.get(shard)
        if fid is None:
            return []
        srv = self.cluster.servers[shard]
        out = []
        for off in range(0, self._off.get(shard, 0), J_REC.size):
            buf = b"".join(srv.device.raw_read(phys, n)
                           for phys, n in
                           srv.fs.translate(fid, off, J_REC.size))
            seq, pid, code, aux, cursor, tick = J_REC.unpack(buf)
            out.append({"seq": seq, "pair": pid,
                        "phase": CODE_PHASES.get(code, "?"),
                        "aux": aux, "cursor": cursor, "tick": tick})
        return out


class _Flight:
    """One outstanding sync message (at most one per key per pair)."""

    __slots__ = ("key", "loc", "tokens", "msg", "due", "attempt")

    def __init__(self, key, loc, tokens, msg, due):
        self.key = key
        self.loc = loc
        self.tokens = tokens   # held client-ack request ids
        self.msg = msg
        self.due = due
        self.attempt = 0


class _MigrationPair:
    """Migration state for one (source, dest) shard pair."""

    def __init__(self, pid: int, source: int, dest: int,
                 conn: ShardConnection):
        self.pid = pid
        self.source = source
        self.dest = dest
        self.conn = conn
        self.queue: deque = deque()          # snapshot keys to stream
        self.flight: dict[int, _Flight] = {}  # rrid -> flight
        self.key_flight: dict = {}            # key -> rrid (single-flight)
        self.pending: dict = {}               # key -> [loc, value, tokens]
        self.acked_loc: dict = {}             # key -> last synced loc
        self.streamed: set = set()            # keys acked at least once
        self.responses: dict[int, tuple[int, bytes]] = {}
        self.dirty = False
        self.dropped = False
        self.acked = 0
        self.journaled = 0
        self.snapshot_n = 0
        self.keys_migrated = 0
        self.bytes_streamed = 0
        self.dual_routed = 0
        self.resent = 0
        self.failures = 0


class _SourceTap:
    """Installed as ``srv.migrator`` on each migration SOURCE.

    ``forward`` is called from the server's execute path with the final
    on-disk record bytes of every write — the same hook point as the
    replicator.  It parses the record (never touches the device from tap
    context), routes the key against the NEW ring, and offers the write
    to the matching pair.  Returning True holds the client ack until the
    destination acks the sync.
    """

    def __init__(self, rs: "Resharder", source: int):
        self.rs = rs
        self.source = source
        self.held: set[int] = set()   # client request ids we're holding

    def forward(self, rid: int, file_id: int, offset: int, data) -> bool:
        rs = self.rs
        if rs.phase in ("abort", "aborted", "done"):
            return False
        parsed = rs.app.parse_migration_record(self.source, file_id,
                                               offset, data)
        if parsed is None:
            return False   # not this shard's KV log (journal, replicas...)
        key, loc, value = parsed
        dest = rs.new_ring.shard_for(key)
        if dest == self.source:
            return False   # key not migrating
        pair = rs.pair_by.get((self.source, dest))
        if pair is None or pair.dropped:
            return False
        if rs.phase in ("setup", "stream"):
            # Stream phase: forward with known bytes but do NOT hold the
            # ack — the flip gate only opens once these are all acked.
            rs._offer(pair, key, known=(loc, value))
            return False
        return rs._offer(pair, key, token=rid, known=(loc, value))

    def holds(self, rid: int) -> bool:
        return rid in self.held

    def busy(self) -> bool:
        return bool(self.held)


class Resharder:
    """Drives one ring membership change end to end.

    Installed via ``DDSCluster.start_reshard``; the cluster pump calls
    :meth:`step` every tick.  ``pairs`` is the list of ``(source, dest)``
    shard pairs whose keys move; ``new_ring`` is the target ring that is
    committed atomically at flip; ``retire`` lists shards leaving the
    cluster (shrink).
    """

    def __init__(self, cluster, app, new_ring, pairs, tag: str,
                 retire=()):
        self.cluster = cluster
        self.app = app
        self.new_ring = new_ring
        self.tag = tag
        self.retire = tuple(retire)
        self._pair_specs = list(pairs)
        self.pairs: list[_MigrationPair] = []
        self.pair_by: dict[tuple[int, int], _MigrationPair] = {}
        self.taps: dict[int, _SourceTap] = {}
        self.journal = MigrationJournal(cluster, tag)
        self.phase = "setup"
        self.reason = ""            # populated on abort
        self._next_rrid = 1
        self._flip_tick = -1

    # -- driver ------------------------------------------------------------------

    def step(self) -> int:
        """One migration tick; returns >0 while the migration is live."""
        if self.phase in ("done", "aborted"):
            return 0
        cl = self.cluster
        if self.phase == "setup":
            self._setup()
        if self._scan_faults():
            return 1    # partition stall: resume when the wire heals
        if self.phase in ("done", "aborted"):
            return 1
        now = cl.clock.now
        if self.phase == "abort":
            self._step_abort(now)
            return 1
        if self.phase == "flip":
            self._apply_flip()
        for pair in self.pairs:
            if not pair.dropped:
                self._step_pair(pair, now)
        if self.phase == "stream" and all(
                not p.queue for p in self.pairs if not p.dropped):
            self.phase = "dual"
            for p in self.pairs:
                if not p.dropped:
                    self.journal.record((p.source, p.dest), p.pid,
                                        "dual", cursor=p.acked)
        elif self.phase == "dual" and self._flip_ready():
            # Journal the flip INTENT one tick before applying it: a
            # crash between the two leaves a journaled "flip" record on
            # both endpoints, and the crash matrix resolves it (source
            # death proceeds, destination death aborts).
            self.phase = "flip"
            for p in self.pairs:
                if not p.dropped:
                    self.journal.record((p.source, p.dest), p.pid,
                                        "flip", cursor=p.acked)
        elif self.phase == "cleanup":
            self._maybe_finalize(now)
        return 1

    # -- setup -------------------------------------------------------------------

    def _setup(self) -> None:
        cl = self.cluster
        # Port space disjoint from clients (10.0.*, 40000+) and the
        # replicators (10.1.*, 45000+); the generation term keeps flows
        # fresh across successive migrations (the PEP remembers dropped
        # connections' sequence state).
        gen = 4096 * len(cl.reshard_events)
        sources = set()
        for pid, (s, d) in enumerate(self._pair_specs):
            conn = ShardConnection(cl.servers[d], f"10.2.{s}.1",
                                   47000 + s * 64 + d + gen)
            pair = _MigrationPair(pid, s, d, conn)
            self.pairs.append(pair)
            self.pair_by[(s, d)] = pair
            sources.add(s)
            self.journal.attach(s)
            self.journal.attach(d)
            self.app.arm_shield(d)
        for s in sorted(sources):
            tap = _SourceTap(self, s)
            self.taps[s] = tap
            cl.servers[s].migrator = tap
            # Make every snapshot-time index loc durable so the driver
            # can read record bytes straight from device memory; any
            # write landing after this point carries its bytes through
            # the tap instead.
            cl.servers[s].device.drain()
        ring = self.new_ring
        for pair in self.pairs:
            keys = [k for k in self.app.migration_keys(pair.source)
                    if ring.shard_for(k) == pair.dest]
            pair.queue = deque(keys)
            pair.snapshot_n = len(keys)
            self.journal.record((pair.source, pair.dest), pair.pid,
                                "setup", aux=len(keys))
        self.phase = "stream"

    # -- fault scan --------------------------------------------------------------

    def _scan_faults(self) -> bool:
        """Apply the crash matrix; True means 'stall this tick'."""
        cl = self.cluster
        for pair in self.pairs:
            if pair.dropped:
                continue
            for shard in (pair.source, pair.dest):
                if (shard in cl._partitioned
                        and cl.route_of(shard) == shard):
                    # Partitioned but not failed over: the endpoint will
                    # heal with state intact — stall, don't abort.
                    return True
        for pair in self.pairs:
            if pair.dropped:
                continue
            src_gone = (pair.source in cl._dead
                        or cl.route_of(pair.source) != pair.source)
            dst_gone = (pair.dest in cl._dead
                        or cl.route_of(pair.dest) != pair.dest)
            if not (src_gone or dst_gone):
                continue
            if self.phase in ("setup", "stream", "dual", "abort"):
                if self.phase != "abort":
                    who = pair.source if src_gone else pair.dest
                    self._begin_abort(f"shard{who} lost pre-flip")
                return False
            if self.phase == "flip":
                if dst_gone:
                    # Destination lost before the ring swap: the copy is
                    # gone, ownership never moved — abort cleanly.
                    self._begin_abort(f"shard{pair.dest} lost at flip")
                    return False
                # Source lost at flip: proceed.  The flip gate already
                # guaranteed the destination holds every acked byte.
            elif self.phase == "cleanup":
                # Ownership already moved; a dead endpoint just ends
                # this pair's drain early.  Held acks are released — the
                # bytes were durable at the source before being held.
                self._drop_pair(pair)
        return False

    def _drop_pair(self, pair: "_MigrationPair") -> None:
        pair.dropped = True
        tap = self.taps.get(pair.source)
        if tap is not None:
            for fl in pair.flight.values():
                for t in fl.tokens:
                    tap.held.discard(t)
            for pend in pair.pending.values():
                for t in pend[2]:
                    tap.held.discard(t)
            srv = self.cluster.servers[pair.source]
            if srv.migrator is tap:
                srv.signal()
        pair.flight.clear()
        pair.key_flight.clear()
        pair.pending.clear()

    # -- sync plumbing ------------------------------------------------------------

    def _offer(self, pair: "_MigrationPair", key, token=None,
               known=None) -> bool:
        """Offer one key for sync; True if the client ack is now held.

        Per-key SINGLE FLIGHT: at most one outstanding sync per key.  A
        racing write for an in-flight key parks its (newer) bytes in
        ``pending`` and is refreshed when the flight resolves — the sync
        stream for a key is therefore ordered and ends at the latest
        source-side value, which makes reorder/duplication on the wire
        harmless.
        """
        held = False
        rrid = pair.key_flight.get(key)
        if rrid is not None:
            fl = pair.flight[rrid]
            if known is not None and known[0] != fl.loc:
                pend = pair.pending.get(key)
                if pend is None:
                    pair.pending[key] = pend = [known[0], known[1], []]
                else:
                    pend[0], pend[1] = known
                if token is not None:
                    pend[2].append(token)
                    held = True
            elif token is not None:
                fl.tokens.append(token)
                held = True
        elif key in pair.pending:
            pend = pair.pending[key]
            if known is not None:
                pend[0], pend[1] = known
            if token is not None:
                pend[2].append(token)
                held = True
        else:
            cur = known[0] if known is not None \
                else self.app.index_loc(pair.source, key)
            if pair.acked_loc.get(key, _UNSET) != cur:
                toks = [] if token is None else [token]
                self._send(pair, key, cur, toks,
                           value=known[1] if known is not None else _UNSET)
                held = token is not None
        if held:
            self.taps[pair.source].held.add(token)
            pair.dual_routed += 1
        return held

    def _send(self, pair: "_MigrationPair", key, loc, tokens,
              value=_UNSET) -> None:
        if value is _UNSET:
            value = (None if loc is None
                     else self.app.read_value(pair.source, key, loc))
        rrid = self._next_rrid
        self._next_rrid += 1
        if value is None:
            msg = self.app.encode_migration_del(rrid, key)
        else:
            msg = self.app.encode_migration_put(rrid, key, value)
        fl = _Flight(key, loc, list(tokens), msg,
                     self.cluster.clock.now + RESEND_TICKS)
        pair.flight[rrid] = fl
        pair.key_flight[key] = rrid
        pair.conn.enqueue(msg)
        pair.dirty = True
        pair.bytes_streamed += len(msg)
        if tokens:
            self.taps[pair.source].held.update(tokens)

    def _on_ack(self, pair: "_MigrationPair", rrid: int,
                status: int) -> None:
        fl = pair.flight.pop(rrid, None)
        if fl is None:
            return   # stale/duplicate response
        if pair.key_flight.get(fl.key) == rrid:
            del pair.key_flight[fl.key]
        if status in (wire.E_OK, wire.E_NOENT):
            if fl.key not in pair.streamed:
                pair.streamed.add(fl.key)
                pair.keys_migrated += 1
        else:
            pair.failures += 1
        pair.acked += 1
        pair.acked_loc[fl.key] = fl.loc
        if fl.tokens:
            tap = self.taps.get(pair.source)
            if tap is not None:
                for t in fl.tokens:
                    tap.held.discard(t)
                # Wake the source so its completion loop releases the
                # no-longer-held client acks this tick.
                self.cluster.servers[pair.source].signal()
        if pair.acked - pair.journaled >= 64:
            pair.journaled = pair.acked
            self.journal.record((pair.source, pair.dest), pair.pid,
                                self.phase if self.phase in PHASE_CODES
                                else "stream", cursor=pair.acked)
        pend = pair.pending.pop(fl.key, None)
        if pend is not None:
            loc, value, toks = pend
            self._send(pair, fl.key, loc, toks, value=value)

    def _step_pair(self, pair: "_MigrationPair", now: int) -> None:
        if self.phase == "stream" and pair.queue:
            budget = WINDOW - len(pair.flight)
            while budget > 0 and pair.queue:
                key = pair.queue.popleft()
                if key in pair.key_flight or key in pair.pending:
                    continue   # a tapped write already syncs this key
                cur = self.app.index_loc(pair.source, key)
                if pair.acked_loc.get(key, _UNSET) == cur:
                    continue
                self._send(pair, key, cur, [])
                budget -= 1
        conn = pair.conn
        if pair.dirty:
            pair.dirty = False
            conn.flush()
        resp = pair.responses
        conn.collect(resp)
        conn.arrival_order.clear()
        if resp:
            for rrid in list(resp):
                status, _body = resp.pop(rrid)
                self._on_ack(pair, rrid, status)
        if pair.flight:
            # A destination overload-shed never answers on the wire:
            # reconcile terminal marks into immediate resend deadlines.
            lt = conn.server.lifecycle
            for rrid, fl in pair.flight.items():
                if lt.take_terminal(conn.flow, rrid) is not None:
                    fl.due = now
            for rrid, fl in list(pair.flight.items()):
                if now < fl.due:
                    continue
                fl.attempt += 1
                if (fl.attempt > MAX_ATTEMPTS
                        and self.phase in ("stream", "dual")):
                    self._begin_abort(
                        f"sync to shard{pair.dest} exhausted "
                        f"{MAX_ATTEMPTS} attempts")
                    return
                # Same rrid on the same flow: the destination's dedup
                # cache replays the ack if the original applied.
                conn.enqueue(fl.msg)
                pair.dirty = True
                pair.resent += 1
                fl.due = now + (RESEND_TICKS << min(fl.attempt, 6))
            if pair.dirty:
                pair.dirty = False
                conn.flush()

    # -- flip & cleanup ------------------------------------------------------------

    def _flip_ready(self) -> bool:
        """The gate: every sync WITHOUT a held client ack has landed.

        Token-carrying flights may remain in the air — their client acks
        are still held, so a post-flip source crash cannot lose a write
        any client has seen.
        """
        for pair in self.pairs:
            if pair.dropped:
                continue
            if pair.queue:
                return False
            for fl in pair.flight.values():
                if not fl.tokens:
                    return False
            for pend in pair.pending.values():
                if not pend[2]:
                    return False
        return True

    def _apply_flip(self) -> None:
        cl = self.cluster
        # Invalidate the source DPU cache for every migrated key BEFORE
        # the ring swap: a predicate probe memo taken pre-flip sees the
        # table epoch move and re-resolves.
        for pair in self.pairs:
            if pair.dropped:
                continue
            src = pair.source
            if src in cl._dead or cl.route_of(src) != src:
                continue
            table = cl.servers[src].cache_table
            if table is not None:
                table.delete_many(pair.acked_loc.keys())
        moved = sum(p.keys_migrated for p in self.pairs)
        cl.commit_ring(self.new_ring, {
            "kind": self.tag, "pairs": [(p.source, p.dest)
                                        for p in self.pairs],
            "keys_moved": moved})
        cl.retired.update(self.retire)
        for pair in self.pairs:
            if not pair.dropped:
                self.journal.record((pair.source, pair.dest), pair.pid,
                                    "cleanup", cursor=pair.acked)
        self._flip_tick = cl.clock.now
        self.phase = "cleanup"

    def _maybe_finalize(self, now: int) -> None:
        if now < self._flip_tick + CLEANUP_GRACE:
            return
        for pair in self.pairs:
            if pair.dropped:
                continue
            if pair.flight or pair.pending:
                return
        for tap in self.taps.values():
            if tap.held:
                return
        cl = self.cluster
        for pair in self.pairs:
            if pair.dropped:
                continue
            src = pair.source
            if src in cl._dead or cl.route_of(src) != src:
                continue
            # Drop the source's copies (index + any table entries the
            # fence-passed grace traffic re-warmed).
            self.app.drop_source_keys(src, set(pair.acked_loc))
            self.journal.record((src, pair.dest), pair.pid, "done",
                                cursor=pair.acked)
        self._disarm()
        self.phase = "done"

    # -- abort --------------------------------------------------------------------

    def _begin_abort(self, reason: str) -> None:
        self.phase = "abort"
        self.reason = reason
        cl = self.cluster
        for pair in self.pairs:
            if pair.dropped:
                continue
            self.journal.record((pair.source, pair.dest), pair.pid,
                                "abort", cursor=pair.acked)
            tap = self.taps.get(pair.source)
            # Release every held client ack NOW: the bytes are durable
            # at the source, which keeps ownership after an abort.
            if tap is not None:
                for fl in pair.flight.values():
                    for t in fl.tokens:
                        tap.held.discard(t)
                    fl.tokens.clear()
                for pend in pair.pending.values():
                    for t in pend[2]:
                        tap.held.discard(t)
                srv = cl.servers[pair.source]
                if srv.migrator is tap:
                    srv.signal()
            pair.pending.clear()
            dst_gone = (pair.dest in cl._dead
                        or cl.route_of(pair.dest) != pair.dest)
            if dst_gone:
                # Nothing to drain or clean: the partial copy died with
                # the destination.
                pair.dropped = True
                pair.flight.clear()
                pair.key_flight.clear()

    def _step_abort(self, now: int) -> None:
        """Drain live destinations' in-flight syncs, then drop their
        partial copies.  Draining FIRST matters: a late-applying sync
        after the drop would resurrect a dropped key."""
        cl = self.cluster
        for pair in self.pairs:
            if pair.dropped:
                continue
            if (pair.dest in cl._dead
                    or cl.route_of(pair.dest) != pair.dest):
                pair.dropped = True
                pair.flight.clear()
                pair.key_flight.clear()
                continue
            if pair.flight:
                self._step_pair(pair, now)
        if any(p.flight for p in self.pairs if not p.dropped):
            return
        for pair in self.pairs:
            if pair.dropped:
                continue
            dropped_keys = pair.streamed | set(pair.acked_loc)
            if dropped_keys:
                self.app.drop_dest_keys(pair.dest, dropped_keys)
            self.journal.record((pair.source, pair.dest), pair.pid,
                                "aborted", cursor=pair.acked)
        self._disarm()
        self.phase = "aborted"

    def _disarm(self) -> None:
        cl = self.cluster
        for s, tap in self.taps.items():
            srv = cl.servers[s]
            if srv.migrator is tap:
                srv.migrator = None
        for pair in self.pairs:
            self.app.disarm_shield(pair.dest)

    # -- observability --------------------------------------------------------------

    def summary(self) -> dict:
        per_pair = [{
            "source": p.source, "dest": p.dest,
            "snapshot": p.snapshot_n,
            "keys_migrated": p.keys_migrated,
            "bytes_streamed": p.bytes_streamed,
            "dual_routed": p.dual_routed,
            "resent": p.resent,
            "failures": p.failures,
            "dropped": p.dropped,
        } for p in self.pairs]
        out = {
            "tag": self.tag, "phase": self.phase,
            "keys_migrated": sum(p.keys_migrated for p in self.pairs),
            "bytes_streamed": sum(p.bytes_streamed for p in self.pairs),
            "dual_routed": sum(p.dual_routed for p in self.pairs),
            "resent": sum(p.resent for p in self.pairs),
            "failures": sum(p.failures for p in self.pairs),
            "pairs": per_pair,
        }
        if self.reason:
            out["reason"] = self.reason
        return out
