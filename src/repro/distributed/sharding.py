"""Sharding rules: logical parameter axes -> mesh axes.

The model zoo annotates every parameter with logical axis names
(repro.models.layers docstring).  This module turns those into
``PartitionSpec`` trees for a given mesh and workload kind:

  * **TP**   — "vocab"/"heads"/"ff"/"experts" shard over the ``model`` axis.
  * **FSDP** — "embed" (the d_model dim of weights) shards over ``data``;
    GSPMD inserts the per-layer all-gathers, which overlap with compute
    under the layer scan.  Optimizer state inherits parameter specs, so it
    is automatically ZeRO-sharded.
  * **DP**   — batch dims of inputs/activations shard over ``("pod","data")``
    (or just ``data`` single-pod).
  * **SP**   — for decode shapes whose batch is smaller than the data axis
    (long_500k: batch=1), KV-cache *sequence* dims shard over ``data``
    (sequence parallelism); attention contractions then reduce over it.

Uneven dims (e.g. 8 kv heads over a 16-way model axis, vocab 256206) rely
on GSPMD's implicit padding — correct, if sometimes wasteful; the §Perf
hillclimb addresses the wasteful cases.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig

# ---------------------------------------------------------------------------
# Activation-sharding context.
#
# Weight shardings dominate GSPMD propagation: with FSDP weights (d_model
# sharded over 'data') and only a tiny int32 token input carrying the batch
# sharding, XLA picks feature-sharded/batch-REPLICATED activations and
# all-reduces full-global-batch tensors every layer (measured 52-128 GiB
# per op on gemma3 train_4k — EXPERIMENTS.md §Perf iteration 3).  Models
# therefore pin activations to batch sharding at layer boundaries via
# ``constrain_batch``; the launcher scopes the mesh with
# ``activation_sharding_scope``.
# ---------------------------------------------------------------------------

_ACT_CTX = threading.local()


@contextlib.contextmanager
def activation_sharding_scope(mesh: Mesh, mode: str = "train",
                              skip_axes: frozenset = frozenset()):
    """mode="train": batch-pin activations; mode="decode": only cache/head
    layout pins apply (batch pinning hurts the tiny decode activations).
    ``skip_axes``: mesh axes that are MANUAL in an enclosing shard_map (a
    with_sharding_constraint may not name them)."""
    prev = (getattr(_ACT_CTX, "mesh", None),
            getattr(_ACT_CTX, "mode", "train"),
            getattr(_ACT_CTX, "skip_axes", frozenset()))
    _ACT_CTX.mesh = mesh
    _ACT_CTX.mode = mode
    _ACT_CTX.skip_axes = skip_axes
    try:
        yield
    finally:
        _ACT_CTX.mesh, _ACT_CTX.mode, _ACT_CTX.skip_axes = prev


def constrain_batch(x):
    """Pin dim 0 of an activation to the data-parallel axes (no-op outside
    an activation_sharding_scope or when the batch doesn't divide)."""
    mesh = getattr(_ACT_CTX, "mesh", None)
    if (mesh is None or x.ndim < 2
            or getattr(_ACT_CTX, "mode", "train") == "decode"):
        return x
    dp = dp_axes(mesh)
    n = 1
    for a in dp:
        n *= mesh.shape[a]
    if n <= 1 or x.shape[0] % n != 0:
        return x
    # Non-batch dims stay UNCONSTRAINED: a None would FORCE replication
    # (e.g. gathering the full d_ff of MoE hiddens — §Perf iteration 9).
    spec = P(dp, *([P.UNCONSTRAINED] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def constrain_logits(x):
    """Logits: batch over the DP axes AND vocab over the model axis.
    (Batch-only pinning replicates the vocab dim — a 64 GiB/device fp32
    tensor at 262k vocab; §Perf iteration 7.)"""
    mesh = getattr(_ACT_CTX, "mesh", None)
    if mesh is None or x.ndim < 2:
        return x
    dp = dp_axes(mesh)
    n = 1
    for a in dp:
        n *= mesh.shape[a]
    model_ax = "model" if "model" in mesh.axis_names else None
    if model_ax and x.shape[-1] % mesh.shape["model"] != 0:
        model_ax = None
    bax = dp if (n > 1 and x.shape[0] % n == 0) else None
    if bax is None and model_ax is None:
        return x
    spec = P(bax, *([P.UNCONSTRAINED] * (x.ndim - 2)), model_ax)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def gather_fsdp(w, tp_dim: int | None = None):
    """Explicit just-in-time FSDP: unshard a weight's 'data'-sharded dim
    right before use, keeping the TP dim on 'model'.

    Left to itself, GSPMD often resolves (x batch-'data') @ (w d_model-
    'data') by ALL-REDUCING the f32 activations over 'data' (~0.7 GiB/layer
    on gemma3) instead of all-gathering the ~15 MB weight slice — §Perf
    iteration 12.  Train mode only: serving keeps weights 2D-stationary.
    """
    mesh = getattr(_ACT_CTX, "mesh", None)
    if (mesh is None or getattr(_ACT_CTX, "mode", "train") != "train"
            or "data" not in mesh.axis_names):
        return w
    model_ax = "model" if "model" in mesh.axis_names else None
    if model_ax and tp_dim is not None and w.shape[tp_dim] % mesh.shape["model"]:
        model_ax = None
    entries = [None] * w.ndim
    if tp_dim is not None and model_ax:
        entries[tp_dim] = model_ax
    return jax.lax.with_sharding_constraint(
        w, NamedSharding(mesh, P(*entries)))


def constrain_kv_layout(x):
    """Pin a (..., KV, hd) cache-layout tensor so the model axis sits on
    whichever of its two trailing dims divides — stops the SPMD partitioner
    from flip-flopping cache layouts between the decode-attention einsums
    (its "involuntary full rematerialization" copies the 0.5 GiB cache per
    layer; §Perf iteration 11)."""
    mesh = getattr(_ACT_CTX, "mesh", None)
    if mesh is None or x.ndim < 2 or "model" not in mesh.axis_names:
        return x
    m = mesh.shape["model"]
    kv_ax = "model" if x.shape[-2] % m == 0 else None
    hd_ax = None if kv_ax else ("model" if x.shape[-1] % m == 0 else None)
    if kv_ax is None and hd_ax is None:
        return x
    spec = P(*([P.UNCONSTRAINED] * (x.ndim - 2)), kv_ax, hd_ax)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

# Logical-axis -> mesh-axis rule tables.


def param_rules(mesh: Mesh, cfg: ModelConfig, fsdp: bool = True) -> dict:
    axes = mesh.axis_names
    model_ax = "model" if "model" in axes else None
    data_ax = "data" if ("data" in axes and fsdp) else None
    rules = {
        "vocab": model_ax,
        "embed": data_ax,     # FSDP on the d_model dim of weights
        "heads": model_ax,
        "kv": model_ax,
        "ff": model_ax,
        # Experts are REPLICATED across the model axis; their d_ff is
        # TP-sharded and d_model FSDP-sharded instead, so MoE dispatch
        # never crosses the model axis (see models/moe.py docstring).
        "experts": None,
        "layers": None,
        None: None,
    }
    return rules


def dp_axes(mesh: Mesh) -> tuple:
    skip = getattr(_ACT_CTX, "skip_axes", frozenset())
    return tuple(a for a in ("pod", "data")
                 if a in mesh.axis_names and a not in skip)


def spec_from_axes(axes_leaf: tuple, rules: dict) -> P:
    """Map logical axes to mesh axes; a mesh axis may appear only once per
    spec, so later duplicates are dropped (first occurrence wins — e.g. MoE
    (experts, embed, ff) keeps EP on 'model' and leaves 'ff' replicated).

    Embedding tables ("vocab" present) keep ONLY the vocab TP sharding:
    FSDP-sharding their d_model dim puts the partition on the un/embed
    matmuls' contraction path, which XLA SPMD resolves by all-gathering
    full-global-batch logits (measured 128 GiB/step on gemma3 train_4k —
    EXPERIMENTS.md §Perf iteration 2)."""
    used: set = set()
    out = []
    for a in axes_leaf:
        entry = rules.get(a)
        if a == "embed" and "vocab" in axes_leaf:
            entry = None
        names = (entry if isinstance(entry, (tuple, list))
                 else [entry] if entry else [])
        if any(n in used for n in names):
            entry = None
            names = []
        used.update(names)
        out.append(entry)
    return P(*out)


def param_specs(axes_tree: Any, mesh: Mesh, cfg: ModelConfig,
                fsdp: bool = True) -> Any:
    rules = param_rules(mesh, cfg, fsdp=fsdp)
    return jax.tree_util.tree_map(
        lambda a: spec_from_axes(a, rules), axes_tree,
        is_leaf=lambda x: isinstance(x, tuple))


def param_shardings(axes_tree: Any, mesh: Mesh, cfg: ModelConfig,
                    fsdp: bool = True) -> Any:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s),
        param_specs(axes_tree, mesh, cfg, fsdp),
        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# Input / batch specs.
# ---------------------------------------------------------------------------


def batch_specs(mesh: Mesh, shape: ShapeConfig, cfg: ModelConfig) -> dict:
    """PartitionSpec per input-spec key for a workload cell."""
    dp = dp_axes(mesh)
    ndp = 1
    for a in dp:
        ndp *= mesh.shape[a]
    batch_shardable = shape.global_batch % ndp == 0 and shape.global_batch >= ndp
    bax = dp if batch_shardable else None
    if shape.kind in ("train", "prefill"):
        out = {"tokens": P(bax, None), "labels": P(bax, None),
               "frames": P(bax, None, None), "embeds": P(bax, None, None)}
        if not batch_shardable:
            # SP fallback: shard the sequence dim instead.
            out = {"tokens": P(None, dp), "labels": P(None, dp),
                   "frames": P(None, dp, None), "embeds": P(None, dp, None)}
        return out
    # decode
    seq_ax = None if batch_shardable else "data"
    return {"token": P(bax, None), "kv_len": P(),
            "cache": _CacheSpecRule(bax, seq_ax)}


class _CacheSpecRule:
    """Marker: cache specs are derived per-leaf (see cache_specs)."""

    def __init__(self, batch_ax, seq_ax):
        self.batch_ax = batch_ax
        self.seq_ax = seq_ax


def cache_specs(cache_tree: Any, mesh: Mesh, cfg: ModelConfig,
                shape: ShapeConfig) -> Any:
    """Per-leaf PartitionSpec for KV caches / SSM states, by key pattern.

    Leaf layouts (registry):
      k/v                (L, B, S, KV, hd)
      global_k/v         (G, B, S, KV, hd)
      local_k/v          (G, g-1, B, W, KV, hd)
      tail_k/v           (T, B, W, KV, hd)
      cross_k/v          (L, B, S_enc, KV, hd)
      attn_k/v (hybrid)  (G, B, S, KV, hd)
      groups_conv        (G, E, B, K-1, d_inner)
      groups_gla         (G, E, B, H, state, hd)
      tail_conv/tail_gla (T, B, ...)
      rwkv state tuple   ((L,B,1,D), (L,B,H,hd,hd), (L,B,1,D))
    """
    dp = dp_axes(mesh)
    ndp = 1
    for a in dp:
        ndp *= mesh.shape[a]
    batch_shardable = shape.global_batch % ndp == 0 and shape.global_batch >= ndp
    bax = dp if batch_shardable else None
    seq_ax = None if batch_shardable else "data"
    model_ax = "model" if "model" in mesh.axis_names else None

    msize = mesh.shape.get("model", 1) if model_ax else 1

    def kv_hd_axes(kv_dim: int, hd_dim: int):
        """Place the model axis on whichever of (kv heads, head_dim) divides."""
        if kv_dim % msize == 0:
            return model_ax, None
        if hd_dim % msize == 0:
            return None, model_ax
        return None, None

    def leaf_spec(path, leaf) -> P:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        nd = leaf.ndim
        if "conv" in name:           # (..., B, K-1, d_inner)
            return P(*([None] * (nd - 3)), bax, None, model_ax)
        if "gla" in name:            # (..., B, H, state, hd)
            return P(*([None] * (nd - 4)), bax, model_ax, None, None)
        if nd == 6:                  # (G, g-1, B, W, KV, hd)
            kv_ax, hd_ax = kv_hd_axes(leaf.shape[4], leaf.shape[5])
            return P(None, None, bax, None, kv_ax, hd_ax)
        if nd == 5 and any(t in name for t in ("k", "v")) and "gla" not in name:
            # (L/G/T, B, S-or-W, KV, hd)
            kv_ax, hd_ax = kv_hd_axes(leaf.shape[3], leaf.shape[4])
            sax = seq_ax if leaf.shape[2] > 4096 else None
            return P(None, bax, sax, kv_ax, hd_ax)
        # rwkv tuple leaves: (L,B,1,D) or (L,B,H,hd,hd)
        if nd == 4:
            return P(None, bax, None, model_ax)
        if nd == 5:
            return P(None, bax, model_ax, None, None)
        return P(*([None] * max(0, nd - 2)), bax, None) if nd >= 2 else P(None)

    specs = jax.tree_util.tree_map_with_path(leaf_spec, cache_tree)
    return sanitize_tree(specs, cache_tree, mesh)


def to_shardings(spec_tree: Any, mesh: Mesh) -> Any:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def _axis_size(mesh: Mesh, entry) -> int:
    if entry is None:
        return 1
    if isinstance(entry, (tuple, list)):
        n = 1
        for a in entry:
            n *= mesh.shape[a]
        return n
    return mesh.shape[entry]


def sanitize_spec(spec: P, shape: tuple[int, ...], mesh: Mesh) -> P:
    """Drop mesh axes from dims they don't divide (pjit args must divide).

    e.g. 4 kv heads over a 16-way model axis -> replicated; the hillclimb
    replaces such cases with a better placement rather than padding.
    """
    entries = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for dim, entry in zip(shape, entries):
        if entry is not None and dim % _axis_size(mesh, entry) != 0:
            entry = None
        out.append(entry)
    return P(*out)


def sanitize_tree(spec_tree: Any, shape_tree: Any, mesh: Mesh) -> Any:
    """Apply sanitize_spec leaf-wise (shape_tree: arrays/ShapeDtypeStructs)."""
    return jax.tree_util.tree_map(
        lambda s, x: sanitize_spec(s, x.shape, mesh),
        spec_tree, shape_tree, is_leaf=lambda x: isinstance(x, P))
