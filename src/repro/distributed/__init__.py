"""Distribution layer: sharding rules, fault tolerance, elasticity."""
