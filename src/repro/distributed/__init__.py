"""Distribution layer: sharding rules, fault tolerance, elasticity, and the
multi-server DDS cluster (consistent-hash sharded storage scale-out)."""

from repro.distributed.cluster import (DDSCluster, FileLocation, HashRing,
                                       stable_hash)

__all__ = ["DDSCluster", "FileLocation", "HashRing", "stable_hash"]
