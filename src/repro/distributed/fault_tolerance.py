"""Fault tolerance: heartbeats, stragglers, restart — and cluster failover.

Components:

``HeartbeatMonitor``
    Tracks per-host heartbeats (monotonic step + timestamp).  A host whose
    heartbeat is older than ``timeout_s`` is declared dead.  The timeout's
    UNIT follows the injected ``now`` callable: wall seconds under the
    default ``time.monotonic``, logical TICKS when constructed via
    :meth:`HeartbeatMonitor.on_ticks` against the deterministic
    :class:`~repro.core.lifecycle.TickClock` (the storage cluster's mode —
    wall time would make failover timing depend on interpreter speed).

``StragglerDetector``
    Collects per-host step durations and flags hosts slower than
    ``threshold x`` the fleet median over a sliding window.  Duration units
    are caller-defined (wall seconds for training fleets, ticks for the
    storage cluster's replication-lag feed) — the detector only compares
    ratios, so it is clock-agnostic by construction.

``ClusterSupervisor``
    The storage data plane's failure detector: beats every live shard of a
    replicated ``DDSCluster`` on the shared tick clock, declares a shard
    dead after ``heartbeat_timeout_ticks`` of silence, and drives replica
    promotion + ring repair (``DDSCluster._failover``).

``TrainSupervisor``
    Drives a Trainer with failure injection hooks: on a detected failure it
    restores the latest DDS checkpoint (write-behind saves mean at most
    ``ckpt_every`` steps are replayed) and continues — optionally on a
    SHRUNKEN data-parallel world (elastic restart).  Its liveness clock is
    the trainer's deterministic STEP counter, not wall time — the run loop
    is cooperative, so wall-clock silence says nothing about host death.

All timing here is injected (``now`` callables) so tests are deterministic.
"""

from __future__ import annotations

import statistics
import time
from collections import defaultdict, deque
from dataclasses import dataclass, field
from typing import Callable


@dataclass
class HostState:
    host: str
    last_step: int = -1
    last_beat_s: float = 0.0
    alive: bool = True


class HeartbeatMonitor:
    """Liveness by heartbeat age; ``timeout_s`` is in ``now``'s units."""

    def __init__(self, hosts: list[str], timeout_s: float = 60.0,
                 now: Callable[[], float] = time.monotonic):
        self.timeout_s = timeout_s
        self.now = now
        self.hosts = {h: HostState(h, last_beat_s=now()) for h in hosts}

    @classmethod
    def on_ticks(cls, hosts: list[str], clock,
                 timeout_ticks: int) -> "HeartbeatMonitor":
        """Tick-based monitor on a ``TickClock`` — deterministic timeouts
        (two same-seed runs detect a death at the identical tick)."""
        return cls(hosts, timeout_s=timeout_ticks, now=lambda: clock.now)

    def beat(self, host: str, step: int) -> None:
        st = self.hosts[host]
        st.last_step = step
        st.last_beat_s = self.now()
        st.alive = True

    def dead_hosts(self) -> list[str]:
        t = self.now()
        dead = []
        for st in self.hosts.values():
            if t - st.last_beat_s > self.timeout_s:
                st.alive = False
                dead.append(st.host)
        return dead

    def remove(self, host: str) -> None:
        self.hosts.pop(host, None)

    def watch(self, host: str) -> None:
        """(Re-)monitor ``host`` with a fresh beat — a healed partitioned
        shard rejoining the fleet after its removal at promotion."""
        self.hosts[host] = HostState(host, last_beat_s=self.now())


class StragglerDetector:
    """Flags hosts whose step time exceeds threshold x fleet median."""

    def __init__(self, threshold: float = 1.5, window: int = 16,
                 min_samples: int = 4):
        self.threshold = threshold
        self.window = window
        self.min_samples = min_samples
        self._samples: dict[str, deque] = defaultdict(
            lambda: deque(maxlen=window))

    def record(self, host: str, step_time_s: float) -> None:
        self._samples[host].append(step_time_s)

    def host_median(self, host: str) -> float | None:
        s = self._samples.get(host)
        if not s or len(s) < self.min_samples:
            return None
        return statistics.median(s)

    def stragglers(self) -> list[tuple[str, float]]:
        meds = {h: m for h in self._samples
                if (m := self.host_median(h)) is not None}
        if len(meds) < 2:
            return []
        fleet = statistics.median(meds.values())
        return [(h, m / fleet) for h, m in meds.items()
                if m > self.threshold * fleet]


@dataclass
class FailureEvent:
    step: int
    kind: str          # "crash" | "straggler" | "heartbeat"
    host: str
    action: str        # "restart" | "restart_shrunk" | "promote:shardN" | ...


class ClusterSupervisor:
    """Failure detector + failover driver for a replicated ``DDSCluster``.

    Wired into the cluster pump when ``ServerConfig.replication`` > 0:
    every pump beats each LIVE shard on the shared tick clock (a crashed
    shard's heartbeat goes silent at its crash tick).  ``poll`` counts one
    MISSED WINDOW each time a shard's silence exceeds ``timeout_ticks``,
    then re-arms the window; only after ``miss_windows`` CONSECUTIVE
    missed windows (default 2) does it declare death and drive the
    cluster's replica promotion and ring repair.  A single delayed or
    partitioned heartbeat blip therefore cannot false-promote a live
    primary — the shard gets a full second window to beat again, and any
    real beat resets the count.  Detection latency is exactly
    ``miss_windows * (timeout_ticks + 1)`` pumps, deterministic across
    runs.

    The straggler detector is fed per-shard replication-lag means (ticks
    between a primary's forward and the replica's ack): a replica whose
    lag grows against the fleet is the disaggregated analogue of the slow
    host a training fleet would checkpoint-exclude.
    """

    def __init__(self, cluster, timeout_ticks: int = 16,
                 miss_windows: int = 2):
        self.cluster = cluster
        self.clock = cluster.clock
        self.miss_windows = max(1, miss_windows)
        names = [self._name(i) for i in range(cluster.num_shards)]
        self.monitor = HeartbeatMonitor.on_ticks(names, self.clock,
                                                 timeout_ticks)
        self.detector = StragglerDetector()
        self.events: list[FailureEvent] = []
        self._misses: dict[str, int] = {}   # consecutive missed windows
        self._lag_seen = [(0, 0)] * cluster.num_shards  # (n, total) deltas

    @staticmethod
    def _name(shard: int) -> str:
        return f"shard{shard}"

    def beat_live(self) -> None:
        """One heartbeat per live shard, stamped with the current tick.

        A real beat resets the shard's consecutive-missed-window count:
        a blip that recovers within the grace windows leaves no trace.
        """
        beat = self.monitor.beat
        now = self.clock.now
        dead = self.cluster._dead
        misses = self._misses
        for i in range(self.cluster.num_shards):
            if i not in dead:
                name = self._name(i)
                beat(name, now)
                if misses:
                    misses.pop(name, None)

    def poll(self) -> list[FailureEvent]:
        """Detect newly dead shards; fail each over.  Returns new events."""
        out: list[FailureEvent] = []
        for name in self.monitor.dead_hosts():
            misses = self._misses.get(name, 0) + 1
            if misses < self.miss_windows:
                # Grace window: note the miss and re-arm the timeout —
                # promotion waits for consecutive silence, so a single
                # delay/partition blip cannot split-brain a live primary.
                self._misses[name] = misses
                self.monitor.beat(name, self.clock.now)
                continue
            self._misses.pop(name, None)
            self.monitor.remove(name)
            shard = int(name[len("shard"):])
            promoted = self.cluster._failover(shard)
            ev = FailureEvent(self.clock.now, "heartbeat", name,
                              f"promote:{self._name(promoted)}"
                              if promoted is not None else "unrecoverable")
            self.events.append(ev)
            out.append(ev)
        self._feed_stragglers()
        return out

    def add_shard(self, shard: int) -> None:
        """Monitor a newly provisioned shard (elastic growth): fresh
        heartbeat state plus a straggler-feed slot for its replicator."""
        self.monitor.watch(self._name(shard))
        self._lag_seen.append((0, 0))

    def _feed_stragglers(self) -> None:
        """Record each live primary's mean replication lag since last poll."""
        cl = self.cluster
        for i, srv in enumerate(cl.servers):
            repl = srv.replicator
            if repl is None or i in cl._dead:
                continue
            n, tot = repl.lag.n, repl.lag.total
            pn, pt = self._lag_seen[i]
            if n > pn:
                self.detector.record(self._name(i), (tot - pt) / (n - pn))
                self._lag_seen[i] = (n, tot)


class TrainSupervisor:
    """Checkpoint/restart orchestration around a Trainer.

    ``inject_failure(step)`` may be set by tests/chaos tooling: returning a
    host name at a step simulates that host dying mid-step.
    """

    def __init__(self, trainer, hosts: list[str],
                 monitor: HeartbeatMonitor | None = None,
                 detector: StragglerDetector | None = None,
                 inject_failure: Callable[[int], str | None] = lambda s: None,
                 heartbeat_timeout_steps: int = 25):
        self.trainer = trainer
        self.hosts = list(hosts)
        # Step-counted liveness by default: the supervisor's run loop is
        # cooperative and deterministic, so the trainer's step counter is
        # the clock — the old wall-clock default could declare every host
        # dead across an interpreter pause.
        self.monitor = monitor or HeartbeatMonitor(
            hosts, timeout_s=heartbeat_timeout_steps,
            now=lambda: float(self.trainer.step))
        self.detector = detector or StragglerDetector()
        self.inject_failure = inject_failure
        self.events: list[FailureEvent] = []
        self.restarts = 0

    def run(self, target_step: int) -> list[dict]:
        """Drive training until ``trainer.step`` REACHES target_step —
        crashes rewind to the last checkpoint and the lost steps replay."""
        while self.trainer.step < target_step:
            failed = self.inject_failure(self.trainer.step)
            if failed is not None:
                self._handle_failure(failed, "crash")
                continue
            self.trainer.run(1)
            for h in self.hosts:
                self.monitor.beat(h, self.trainer.step)
        return self.trainer.history

    def _handle_failure(self, host: str, kind: str) -> None:
        """Lose ``host``: restore the latest checkpoint and continue on the
        surviving world (elastic shrink)."""
        self.restarts += 1
        if host in self.hosts:
            self.hosts.remove(host)
        self.monitor.remove(host)
        action = "restart_shrunk" if self.hosts else "restart"
        self.events.append(FailureEvent(self.trainer.step, kind, host, action))
        restored = self.trainer.restore_latest()
        if not restored:
            # No checkpoint yet: restart from step 0 (params already in
            # memory are considered lost; re-init deterministically).
            from repro.train.loop import init_train_state
            (self.trainer.params, self.trainer.opt, self.trainer.comp,
             self.trainer.axes) = init_train_state(self.trainer.api,
                                                   self.trainer.tcfg)
            self.trainer.step = 0
