"""Fault tolerance for 1000+-node runs: heartbeats, stragglers, restart.

Components:

``HeartbeatMonitor``
    Tracks per-host heartbeats (monotonic step + timestamp).  A host whose
    heartbeat is older than ``timeout_s`` is declared dead; the supervisor
    then triggers an elastic restart.

``StragglerDetector``
    Collects per-host step durations and flags hosts slower than
    ``threshold x`` the fleet median over a sliding window.  At pod scale a
    straggler is usually a failing HBM/host: the mitigation (as in
    production TPU fleets) is checkpoint-exclude-restart rather than work
    stealing, so the detector emits *policy decisions*, not reassignments.

``TrainSupervisor``
    Drives a Trainer with failure injection hooks: on a detected failure it
    restores the latest DDS checkpoint (write-behind saves mean at most
    ``ckpt_every`` steps are replayed) and continues — optionally on a
    SHRUNKEN data-parallel world (elastic restart), re-sharding parameter
    rows via ``CheckpointManager.restore_elastic``.

All timing here is injected (``now`` callables) so tests are deterministic.
"""

from __future__ import annotations

import statistics
import time
from collections import defaultdict, deque
from dataclasses import dataclass, field
from typing import Callable


@dataclass
class HostState:
    host: str
    last_step: int = -1
    last_beat_s: float = 0.0
    alive: bool = True


class HeartbeatMonitor:
    def __init__(self, hosts: list[str], timeout_s: float = 60.0,
                 now: Callable[[], float] = time.monotonic):
        self.timeout_s = timeout_s
        self.now = now
        self.hosts = {h: HostState(h, last_beat_s=now()) for h in hosts}

    def beat(self, host: str, step: int) -> None:
        st = self.hosts[host]
        st.last_step = step
        st.last_beat_s = self.now()
        st.alive = True

    def dead_hosts(self) -> list[str]:
        t = self.now()
        dead = []
        for st in self.hosts.values():
            if t - st.last_beat_s > self.timeout_s:
                st.alive = False
                dead.append(st.host)
        return dead

    def remove(self, host: str) -> None:
        self.hosts.pop(host, None)


class StragglerDetector:
    """Flags hosts whose step time exceeds threshold x fleet median."""

    def __init__(self, threshold: float = 1.5, window: int = 16,
                 min_samples: int = 4):
        self.threshold = threshold
        self.window = window
        self.min_samples = min_samples
        self._samples: dict[str, deque] = defaultdict(
            lambda: deque(maxlen=window))

    def record(self, host: str, step_time_s: float) -> None:
        self._samples[host].append(step_time_s)

    def host_median(self, host: str) -> float | None:
        s = self._samples.get(host)
        if not s or len(s) < self.min_samples:
            return None
        return statistics.median(s)

    def stragglers(self) -> list[tuple[str, float]]:
        meds = {h: m for h in self._samples
                if (m := self.host_median(h)) is not None}
        if len(meds) < 2:
            return []
        fleet = statistics.median(meds.values())
        return [(h, m / fleet) for h, m in meds.items()
                if m > self.threshold * fleet]


@dataclass
class FailureEvent:
    step: int
    kind: str          # "crash" | "straggler" | "heartbeat"
    host: str
    action: str        # "restart" | "restart_shrunk"


class TrainSupervisor:
    """Checkpoint/restart orchestration around a Trainer.

    ``inject_failure(step)`` may be set by tests/chaos tooling: returning a
    host name at a step simulates that host dying mid-step.
    """

    def __init__(self, trainer, hosts: list[str],
                 monitor: HeartbeatMonitor | None = None,
                 detector: StragglerDetector | None = None,
                 inject_failure: Callable[[int], str | None] = lambda s: None):
        self.trainer = trainer
        self.hosts = list(hosts)
        self.monitor = monitor or HeartbeatMonitor(hosts)
        self.detector = detector or StragglerDetector()
        self.inject_failure = inject_failure
        self.events: list[FailureEvent] = []
        self.restarts = 0

    def run(self, target_step: int) -> list[dict]:
        """Drive training until ``trainer.step`` REACHES target_step —
        crashes rewind to the last checkpoint and the lost steps replay."""
        while self.trainer.step < target_step:
            failed = self.inject_failure(self.trainer.step)
            if failed is not None:
                self._handle_failure(failed, "crash")
                continue
            self.trainer.run(1)
            for h in self.hosts:
                self.monitor.beat(h, self.trainer.step)
        return self.trainer.history

    def _handle_failure(self, host: str, kind: str) -> None:
        """Lose ``host``: restore the latest checkpoint and continue on the
        surviving world (elastic shrink)."""
        self.restarts += 1
        if host in self.hosts:
            self.hosts.remove(host)
        self.monitor.remove(host)
        action = "restart_shrunk" if self.hosts else "restart"
        self.events.append(FailureEvent(self.trainer.step, kind, host, action))
        restored = self.trainer.restore_latest()
        if not restored:
            # No checkpoint yet: restart from step 0 (params already in
            # memory are considered lost; re-init deterministically).
            from repro.train.loop import init_train_state
            (self.trainer.params, self.trainer.opt, self.trainer.comp,
             self.trainer.axes) = init_train_state(self.trainer.api,
                                                   self.trainer.tcfg)
            self.trainer.step = 0
