"""Training loop layer."""
