"""Distributed training step + Trainer with fault-tolerant checkpointing.

``make_train_step`` builds a pjit-able (params, opt, batch, step) ->
(params, opt, metrics) function:

  * gradients via jax.grad over the registry loss (remat inside the model's
    layer scan keeps activation memory at O(sqrt) levels);
  * optional microbatch gradient accumulation (lax.scan over batch splits);
  * AdamW with global-norm clipping; optimizer state inherits parameter
    sharding (ZeRO via GSPMD);
  * optional int8 error-feedback compression of the cross-pod gradient
    reduction (repro.optim.compression) — the pod axis all-reduce is the
    slowest hop at multi-pod scale.

``Trainer`` drives steps with data from the ring-prefetched pipeline and
checkpoints through the DDS storage path (write-behind, atomic manifest).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed import sharding as sh
from repro.models.registry import ModelAPI
from repro.optim import adamw_init, adamw_update, warmup_cosine
from repro.optim.compression import (compress_tree, decompress_tree,
                                     init_compression)


def _shard_map(f, *, mesh, axis_names, check_vma, in_specs, out_specs):
    """jax.shard_map appeared in jax 0.5; fall back to the experimental API
    (manual over ``axis_names`` only => the rest of the mesh goes in ``auto``)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, axis_names=axis_names,
                             check_vma=check_vma,
                             in_specs=in_specs, out_specs=out_specs)
    from jax.experimental.shard_map import shard_map
    auto = frozenset(mesh.axis_names) - set(axis_names)
    return shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     check_rep=check_vma, auto=auto)


@dataclass
class TrainConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    max_grad_norm: float = 1.0
    microbatch: int = 1           # gradient-accumulation splits
    fsdp: bool = True
    compress_pod_grads: bool = False
    b1: float = 0.9
    b2: float = 0.95


def abstract_init(api: ModelAPI, key=None):
    """(param ShapeDtypeStructs, axes tree) without allocating anything."""
    key = key if key is not None else jax.random.PRNGKey(0)
    captured: dict[str, Any] = {}

    def initfn(k):
        p, a = api.init(k)
        captured["axes"] = a
        return p

    shapes = jax.eval_shape(initfn, key)
    return shapes, captured["axes"]


def _split_micro(batch: dict, n: int) -> dict:
    def sp(x):
        B = x.shape[0]
        return x.reshape(n, B // n, *x.shape[1:])
    return {k: sp(v) for k, v in batch.items()}


def make_train_fn(api: ModelAPI, tcfg: TrainConfig) -> Callable:
    """The un-jitted step (used by both jit and lower paths)."""

    def lr_fn(step):
        return warmup_cosine(step, peak_lr=tcfg.peak_lr,
                             warmup_steps=tcfg.warmup_steps,
                             total_steps=tcfg.total_steps)

    def compute_grads(params, batch):
        def loss_of(p, b):
            loss, metrics = api.loss_fn(p, b)
            return loss, metrics

        if tcfg.microbatch > 1:
            micro = _split_micro(batch, tcfg.microbatch)

            def acc_body(carry, mb):
                g_acc, l_acc = carry
                (loss, _), g = jax.value_and_grad(loss_of, has_aux=True)(
                    params, mb)
                g_acc = jax.tree_util.tree_map(jnp.add, g_acc, g)
                return (g_acc, l_acc + loss), None

            zero_g = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (g, loss_sum), _ = jax.lax.scan(acc_body,
                                            (zero_g, jnp.zeros(())), micro)
            inv = 1.0 / tcfg.microbatch
            g = jax.tree_util.tree_map(lambda x: x * inv, g)
            return g, loss_sum * inv
        (loss, _), g = jax.value_and_grad(loss_of, has_aux=True)(params, batch)
        return g, loss

    def train_step(params, opt_state, comp_state, batch, step):
        grads, loss = compute_grads(params, batch)
        if tcfg.compress_pod_grads and comp_state is not None:
            # int8 error-feedback quantization of the gradient exchange.
            q, scales, comp_state = compress_tree(grads, comp_state)
            grads = decompress_tree(q, scales)
        new_params, new_opt, gnorm = adamw_update(
            grads, opt_state, params, lr_fn(step),
            b1=tcfg.b1, b2=tcfg.b2, weight_decay=tcfg.weight_decay,
            max_grad_norm=tcfg.max_grad_norm)
        metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr_fn(step)}
        return new_params, new_opt, comp_state, metrics

    return train_step


def make_compressed_pod_train_fn(api: ModelAPI, tcfg: TrainConfig,
                                 mesh: Mesh) -> Callable:
    """Train step with WIRE-LEVEL int8 cross-pod gradient exchange.

    shard_map manual over the ``pod`` axis only: each pod computes its
    gradients with GSPMD (data/model stay auto), quantizes them to int8
    with error feedback, and the CROSS-POD exchange is an all-gather of the
    int8 payloads + per-tensor scales — 4x fewer bytes on the slow pod
    links than the fp32 all-reduce GSPMD would insert.  Error-feedback
    residuals live per pod (leading pod dim on the compression state).
    """
    import functools

    from repro.distributed.sharding import activation_sharding_scope
    from repro.optim.compression import CompressionState, _dequantize, _quantize

    npods = mesh.shape["pod"]

    def lr_fn(step):
        return warmup_cosine(step, peak_lr=tcfg.peak_lr,
                             warmup_steps=tcfg.warmup_steps,
                             total_steps=tcfg.total_steps)

    def per_pod(params, comp_err, batch):
        # comp_err arrives with a leading per-pod dim of size 1 (P("pod")).
        comp_err = jax.tree_util.tree_map(lambda e: e[0], comp_err)
        # Inside: manual over 'pod'; data/model remain auto (GSPMD).
        with activation_sharding_scope(mesh, "train",
                                       skip_axes=frozenset({"pod"})):
            def loss_of(p):
                loss, _ = api.loss_fn(p, batch)
                return loss

            loss, grads = jax.value_and_grad(loss_of)(params)

        def exchange(g, e):
            x = g.astype(jnp.float32) + e
            q, s = _quantize(x)
            new_e = x - _dequantize(q, s)
            qg = jax.lax.all_gather(q, "pod")      # int8 on the pod links
            sg = jax.lax.all_gather(s, "pod")
            deq = qg.astype(jnp.float32) * sg.reshape(
                (npods,) + (1,) * g.ndim)
            return deq.mean(0), new_e

        flat_g, treedef = jax.tree_util.tree_flatten(grads)
        flat_e = treedef.flatten_up_to(comp_err)
        outs = [exchange(g, e) for g, e in zip(flat_g, flat_e)]
        mean_g = treedef.unflatten([o[0] for o in outs])
        new_err = treedef.unflatten([o[1][None] for o in outs])  # re-add pod dim
        return mean_g, new_err, jax.lax.pmean(loss, "pod")

    def train_step(params, opt_state, comp_state, batch, step):
        pod_specs = jax.tree_util.tree_map(
            lambda _: jax.sharding.PartitionSpec(), params)
        batch_specs = {k: jax.sharding.PartitionSpec("pod")
                       for k in batch}
        err_specs = jax.tree_util.tree_map(
            lambda _: jax.sharding.PartitionSpec("pod"), params)
        fn = _shard_map(
            per_pod, mesh=mesh, axis_names={"pod"}, check_vma=False,
            in_specs=(pod_specs, err_specs, batch_specs),
            out_specs=(pod_specs, err_specs,
                       jax.sharding.PartitionSpec()))
        grads, new_err, loss = fn(params, comp_state.error, batch)
        new_params, new_opt, gnorm = adamw_update(
            grads, opt_state, params, lr_fn(step),
            b1=tcfg.b1, b2=tcfg.b2, weight_decay=tcfg.weight_decay,
            max_grad_norm=tcfg.max_grad_norm)
        metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr_fn(step)}
        return new_params, new_opt, CompressionState(new_err), metrics

    return train_step


def init_pod_compression(params, npods: int) -> "CompressionState":
    """Per-pod error-feedback residuals (leading pod dim)."""
    from repro.optim.compression import CompressionState
    return CompressionState(error=jax.tree_util.tree_map(
        lambda p: jnp.zeros((npods,) + p.shape, jnp.float32), params))


def make_train_step(api: ModelAPI, mesh: Mesh, axes_tree, tcfg: TrainConfig,
                    batch_spec: dict | None = None):
    """jit the train step with explicit in/out shardings for ``mesh``."""
    pspecs = sh.param_specs(axes_tree, mesh, api.cfg, fsdp=tcfg.fsdp)
    opt_specs = (P(), pspecs, pspecs)  # count, mu, nu
    comp_specs = (pspecs,) if tcfg.compress_pod_grads else None
    dp = sh.dp_axes(mesh)
    bspec = batch_spec or {"tokens": P(dp, None), "labels": P(dp, None),
                           "frames": P(dp, None, None),
                           "embeds": P(dp, None, None)}
    step_fn = make_train_fn(api, tcfg)

    def filter_bspec(batch_like):
        return {k: bspec.get(k, P(dp, None)) for k in batch_like}

    def jit_for(batch_like):
        in_shardings = (pspecs, opt_specs, comp_specs,
                        filter_bspec(batch_like), P())
        out_shardings = (pspecs, opt_specs, comp_specs,
                         {"loss": P(), "grad_norm": P(), "lr": P()})
        return jax.jit(step_fn,
                       in_shardings=jax.tree_util.tree_map(
                           lambda s: NamedSharding(mesh, s), in_shardings,
                           is_leaf=lambda x: isinstance(x, P)),
                       out_shardings=jax.tree_util.tree_map(
                           lambda s: NamedSharding(mesh, s), out_shardings,
                           is_leaf=lambda x: isinstance(x, P)))

    return step_fn, jit_for


def init_train_state(api: ModelAPI, tcfg: TrainConfig, key=None):
    key = key if key is not None else jax.random.PRNGKey(0)
    params, axes = api.init(key)
    opt = adamw_init(params)
    comp = (init_compression(params),) if tcfg.compress_pod_grads else None
    return params, opt, comp, axes


class Trainer:
    """End-to-end driver: pipeline -> train step -> DDS checkpoints."""

    def __init__(self, api: ModelAPI, tcfg: TrainConfig, pipeline,
                 checkpoint_mgr=None, mesh: Mesh | None = None,
                 ckpt_every: int = 100):
        self.api = api
        self.tcfg = tcfg
        self.pipeline = pipeline
        self.ckpt = checkpoint_mgr
        self.ckpt_every = ckpt_every
        self.mesh = mesh
        self.params, self.opt, self.comp, self.axes = init_train_state(
            api, tcfg)
        self.step = 0
        self.history: list[dict] = []
        self._step_fn = jax.jit(make_train_fn(api, tcfg))

    def restore_latest(self) -> bool:
        if self.ckpt is None:
            return False
        latest = self.ckpt.latest_step()
        if latest is None:
            return False
        tree = {"params": self.params, "mu": self.opt.mu, "nu": self.opt.nu}
        back = self.ckpt.restore(latest, tree)
        self.params = back["params"]
        self.opt = self.opt._replace(
            mu=back["mu"], nu=back["nu"],
            count=jnp.asarray(latest, jnp.int32))
        self.step = latest
        return True

    def run(self, steps: int) -> list[dict]:
        for _ in range(steps):
            batch = self.pipeline.batch_at(self.step)
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            self.params, self.opt, self.comp, metrics = self._step_fn(
                self.params, self.opt, self.comp, batch,
                jnp.asarray(self.step, jnp.int32))
            rec = {k: float(v) for k, v in metrics.items()}
            rec["step"] = self.step
            self.history.append(rec)
            self.step += 1
            if self.ckpt is not None and self.step % self.ckpt_every == 0:
                self.ckpt.save_async(
                    self.step,
                    {"params": self.params, "mu": self.opt.mu,
                     "nu": self.opt.nu})
        if self.ckpt is not None:
            self.ckpt.wait_async()
        return self.history
