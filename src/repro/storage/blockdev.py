"""NVMe SSD model: a block device with submission/completion queues.

The container has no NVMe device, so this is a RAM- (or file-) backed block
store with an SPDK-like asynchronous interface: ``submit_read`` /
``submit_write`` enqueue an operation; completions are delivered by
``poll()`` (SPDK-style polling) in submission order per queue.  A service
time model (base latency + bytes/bandwidth, bounded queue depth) accumulates
*modeled* device time for the calibrated benchmarks; nothing ever sleeps.

Zero-copy contract (DDS §4.3/§6.2): ``submit_read`` takes a destination
``memoryview`` and the device writes bytes straight into it — the caller
points it at pre-allocated response/packet space, so no intermediate copy is
ever made.  ``submit_write`` reads from the caller's buffer view directly.
"""

from __future__ import annotations

import threading
from collections import deque
from dataclasses import dataclass, field
from typing import Callable

import numpy as np

from repro.core.lifecycle import TickClock, TickHistogram
from repro.core.vector import block_checksums

# v5-era datacenter NVMe-ish constants (§8.1: 1 TB NVMe SSD, 100-200us access).
DEFAULT_READ_LATENCY_S = 90e-6
DEFAULT_WRITE_LATENCY_S = 25e-6
DEFAULT_BANDWIDTH_BPS = 3.2e9
DEFAULT_QUEUE_DEPTH = 128

STATUS_PENDING = -1
STATUS_OK = 0
STATUS_EINVAL = 22
STATUS_EIO = 5

# Integrity-checksum granularity (see ``enable_checksums``): one 64-bit
# position-salted checksum (repro.core.vector.block_checksums) per 4 KiB of
# media, the protection-information block size of real datacenter NVMe.
CRC_BLOCK = 4096


@dataclass(slots=True)
class IoOp:
    kind: str                      # "read" | "write" | "writev"
    lba: int                       # byte offset on device
    nbytes: int
    buf: memoryview | bytes | list | None   # writev: list of buffer views
    on_complete: Callable[[int], None] | None
    status: int = STATUS_PENDING
    modeled_done_s: float = 0.0
    cookie: int | None = None      # completion-queue tag (see ``reap``)
    submit_tick: int = 0           # logical submission time (TickClock)


@dataclass
class BlockDeviceStats:
    reads: int = 0
    writes: int = 0
    read_bytes: int = 0
    write_bytes: int = 0
    modeled_busy_s: float = 0.0
    max_queue_depth_seen: int = 0
    # Submit -> complete latency in TICKS of the owning scheduler's clock
    # (deterministic; see repro.core.lifecycle).  Split by queue so the
    # priority path's isolation — and the normal path's bounded starvation —
    # are both directly observable.
    completion_ticks: TickHistogram = field(default_factory=TickHistogram)
    prio_completion_ticks: TickHistogram = field(default_factory=TickHistogram)
    # Reads failed with EIO because the media bytes no longer matched their
    # stored block checksum (only with ``enable_checksums()``).
    crc_read_failures: int = 0


class BlockDevice:
    """RAM-backed block device with an async queue interface.

    Two NVMe-style submission queues (each completed strictly in order):

      * the NORMAL queue — host-path reads/writes (the file service), and
      * the PRIORITY queue — latency-critical offloaded reads
        (``submit_read(priority=True)``), which ``poll`` serves FIRST.

    Starvation is bounded by ``prio_interleave``: when the normal queue is
    non-empty, at least ``budget // prio_interleave`` (>= 1) of each poll's
    completion budget is reserved for it, so a sustained priority-read storm
    cannot park writes — they complete within a bounded number of polls of
    submission (property-tested in tests/test_latency.py).
    """

    def __init__(self, capacity: int, block_size: int = 4096,
                 read_latency_s: float = DEFAULT_READ_LATENCY_S,
                 write_latency_s: float = DEFAULT_WRITE_LATENCY_S,
                 bandwidth_Bps: float = DEFAULT_BANDWIDTH_BPS,
                 queue_depth: int = DEFAULT_QUEUE_DEPTH,
                 prio_interleave: int = 4):
        assert capacity % block_size == 0
        self.capacity = capacity
        self.block_size = block_size
        self.read_latency_s = read_latency_s
        self.write_latency_s = write_latency_s
        self.bandwidth_Bps = bandwidth_Bps
        self.queue_depth = queue_depth
        self.prio_interleave = max(1, prio_interleave)
        self._mem = np.zeros(capacity, dtype=np.uint8)
        self._memv = memoryview(self._mem)  # C-speed byte copies in poll()
        self._queue: deque[IoOp] = deque()
        self._pq: deque[IoOp] = deque()     # priority queue (offloaded reads)
        self._cookie_done: list[tuple[int, int]] = []  # completion queue
        self._lock = threading.Lock()
        self._clock_s = 0.0  # modeled device clock
        # Deterministic fault injection (see ``crash``/``inject_torn_writev``).
        self.crashed = False
        self._torn_writev: list[int] | None = None   # [ops_until_tear, chunks]
        self.stats = BlockDeviceStats()
        # Logical clock for submit->complete tick stamps; the owning server
        # (or cluster) replaces it with the shared scheduler clock.  The
        # device never ticks it — schedulers do, once per pump step.
        self.clock = TickClock()
        # Work-signaled scheduling hook: invoked on every submission (and
        # synchronous completion push) so the owning server is marked
        # runnable even when the submitter is not the server's own pump —
        # e.g. an application thread driving the host front-end directly.
        self.doorbell: Callable[[], None] | None = None
        # End-to-end integrity (opt-in): one checksum per CRC_BLOCK of
        # media, refreshed at every commit point and verified on every
        # read — the NVMe protection-information role.  None = disabled.
        self._crc: np.ndarray | None = None

    # -- integrity checksums ------------------------------------------------------
    def enable_checksums(self) -> None:
        """Turn on per-block media checksums (CRC_BLOCK granularity).

        The checksum array is (re)computed over the CURRENT media contents
        in one vectorized pass, then kept current by every commit point
        (``write``/``writev`` completion, the torn-writev prefix, and
        ``raw_write``).  Every subsequent read verifies the blocks it
        touches and completes ``STATUS_EIO`` — without copying bytes out —
        when the media no longer matches, so corruption is detected on the
        callback, burst and cookie read paths alike."""
        assert self.capacity % CRC_BLOCK == 0, "capacity must be CRC_BLOCK-aligned"
        nblk = self.capacity // CRC_BLOCK
        self._crc = block_checksums(self._mem, 0, nblk, CRC_BLOCK).copy()

    def _crc_update(self, lba: int, nbytes: int) -> None:
        """Refresh the stored checksums of every block touched by a commit."""
        if nbytes <= 0:
            return
        b0 = lba // CRC_BLOCK
        b1 = (lba + nbytes - 1) // CRC_BLOCK + 1
        self._crc[b0:b1] = block_checksums(self._mem, b0, b1 - b0, CRC_BLOCK)

    def verify_blocks(self, lba: int = 0, nbytes: int | None = None) -> int:
        """Recompute checksums over ``[lba, lba+nbytes)``; return the number
        of blocks whose media bytes no longer match (0 = clean)."""
        if self._crc is None:
            return 0
        if nbytes is None:
            nbytes = self.capacity - lba
        if nbytes <= 0:
            return 0
        b0 = lba // CRC_BLOCK
        b1 = (lba + nbytes - 1) // CRC_BLOCK + 1
        fresh = block_checksums(self._mem, b0, b1 - b0, CRC_BLOCK)
        return int((fresh != self._crc[b0:b1]).sum())

    def _crc_mismatch(self, lba: int, nbytes: int) -> bool:
        b0 = lba // CRC_BLOCK
        b1 = (lba + nbytes - 1) // CRC_BLOCK + 1
        return bool((block_checksums(self._mem, b0, b1 - b0, CRC_BLOCK)
                     != self._crc[b0:b1]).any())

    # -- submission --------------------------------------------------------------
    # deque.append is atomic under the GIL; poll() still serializes the
    # claim of completion bursts, so submission needs no lock round.
    #
    # Completion delivery is either a per-op ``on_complete`` callback OR a
    # ``cookie``: cookie-tagged completions are queued and handed back in
    # bulk by ``reap()`` — the NVMe completion-queue shape, which lets the
    # file service process a whole burst of completions without a Python
    # closure per submitted op.
    def _enqueue(self, op: IoOp, priority: bool = False) -> IoOp:
        if self.crashed:
            return op   # submission lost; status stays PENDING forever
        if op.lba < 0 or op.lba + op.nbytes > self.capacity:
            op.status = STATUS_EINVAL
            if op.on_complete:
                op.on_complete(STATUS_EINVAL)
            elif op.cookie is not None:
                self._cookie_done.append((op.cookie, STATUS_EINVAL))
                db = self.doorbell
                if db is not None:
                    db()   # a completion is pending: keep the owner runnable
            return op
        op.submit_tick = self.clock.now
        q = self._pq if priority else self._queue
        q.append(op)
        d = len(self._queue) + len(self._pq)
        if d > self.stats.max_queue_depth_seen:
            self.stats.max_queue_depth_seen = d
        db = self.doorbell
        if db is not None:
            db()
        return op

    def submit_read(self, lba: int, nbytes: int, dest: memoryview,
                    on_complete: Callable[[int], None] | None = None,
                    cookie: int | None = None,
                    priority: bool = False) -> IoOp:
        return self._enqueue(IoOp("read", lba, nbytes, dest, on_complete,
                                  cookie=cookie), priority)

    def submit_read_many(self, reads: list, priority: bool = False) -> None:
        """Burst read submission: ONE crash check / tick stamp / depth update /
        doorbell for the whole burst instead of one per op.

        ``reads`` items are ``(lba, nbytes, dest, on_complete)``.  Semantics
        match a loop of ``submit_read`` calls in order: each op is bounds-
        checked individually (EINVAL delivered via its callback), and ops
        land on the queue in list order, so completion order — and therefore
        the modeled clock accumulation — is identical to the scalar path.

        Burst reads skip the ``IoOp`` wrapper entirely: each queue entry is
        a plain ``(lba, nbytes, dest, cb, submit_tick)`` tuple, which costs
        a fraction of a dataclass construction and drops the attribute
        loads in ``poll``.  One entry still equals one device op, so claim
        accounting, queue-depth stats, and tick dynamics are byte-for-byte
        identical to the scalar path.  The op object is unobservable here
        anyway — this API returns ``None`` — and cookie completions are not
        supported on this path (callers pass callbacks).
        """
        if self.crashed:
            return
        now = self.clock.now
        q = self._pq if priority else self._queue
        append = q.append
        cap = self.capacity
        for lba, nbytes, dest, cb in reads:
            if lba < 0 or lba + nbytes > cap:
                if cb is not None:
                    cb(STATUS_EINVAL)
                continue
            append((lba, nbytes, dest, cb, now))
        d = len(self._queue) + len(self._pq)
        if d > self.stats.max_queue_depth_seen:
            self.stats.max_queue_depth_seen = d
        db = self.doorbell
        if db is not None:
            db()

    def submit_write(self, lba: int, data,
                     on_complete: Callable[[int], None] | None = None,
                     cookie: int | None = None) -> IoOp:
        return self._enqueue(IoOp("write", lba, len(data), data, on_complete,
                                  cookie=cookie))

    def submit_writev(self, lba: int, bufs: list,
                      on_complete: Callable[[int], None] | None = None,
                      cookie: int | None = None) -> IoOp:
        """Scatter-gather write: one device op covering ``bufs`` back to back.

        Models an NVMe SGL submission — one queue entry (one base latency)
        for a run of coalesced buffers; bytes stream from each view without
        an intermediate join.
        """
        nbytes = 0
        for b in bufs:
            nbytes += len(b)
        return self._enqueue(IoOp("writev", lba, nbytes, bufs, on_complete,
                                  cookie=cookie))

    def push_completion(self, cookie: int, status: int = STATUS_OK) -> None:
        """Synchronous completion for ops with no device work (empty I/O)."""
        self._cookie_done.append((cookie, status))
        db = self.doorbell
        if db is not None:
            db()

    # -- fault injection ---------------------------------------------------------
    def crash(self) -> None:
        """Power-fail NOW: queued ops and undelivered completions vanish.

        Bytes already executed stay durable in ``_mem`` (``raw_read`` still
        works, so a recovery mount can scan the journal), but nothing
        in-flight survives and the device accepts no further work.  The crash
        model all failover tests build on: an op is durable iff ``poll``
        executed it before the crash tick.
        """
        with self._lock:
            self.crashed = True
            self._queue.clear()
            self._pq.clear()
            self._cookie_done.clear()

    def inject_torn_writev(self, nth: int = 1, chunks: int = 1) -> None:
        """Arm a deterministic torn write: the ``nth`` writev executed from
        now applies only its first ``chunks`` gathered buffers to media and
        then the device power-fails mid-op (no completion, queued ops lost).
        Exercises the exact hazard journaling exists for: a coalesced run
        half-landed in place.
        """
        self._torn_writev = [max(1, nth), max(0, chunks)]

    def queue_len(self) -> int:
        with self._lock:
            return len(self._queue) + len(self._pq)

    def busy(self) -> bool:
        """True while ops are queued or completions await ``reap()``.

        A scheduler wakeup source: a server whose device is busy must stay
        runnable until the backlog is polled AND the completion queue is
        reaped.  All probes are lock-free peeks (cheap on the idle path).
        """
        if self.crashed:
            return False
        return bool(self._queue) or bool(self._pq) or bool(self._cookie_done)

    # -- completion --------------------------------------------------------------
    def poll(self, max_completions: int | None = None) -> int:
        """Execute + complete up to ``max_completions`` queued ops.

        PRIORITY ops are served first (each queue strictly in order); when
        the normal queue is non-empty it keeps a reserved share of the
        budget — ``budget // prio_interleave``, at least 1 — so host writes
        make bounded progress under sustained priority-read load.  The
        burst is claimed under ONE lock round; execution (and the
        completion callbacks) run outside the lock."""
        budget = max_completions if max_completions is not None else self.queue_depth
        if self.crashed:
            return 0
        if not self._queue and not self._pq:   # racy-but-safe peek: skip lock
            return 0
        with self._lock:
            q, pq = self._queue, self._pq
            if not q and not pq:
                return 0
            reserve = min(len(q), max(1, budget // self.prio_interleave)) \
                if pq else len(q)
            k_p = min(len(pq), budget - min(reserve, budget))
            k_n = min(len(q), budget - k_p)
            if k_p == len(pq):          # whole-queue claim: one C-level copy
                ops = list(pq)
                pq.clear()
            else:
                ops = [pq.popleft() for _ in range(k_p)]
            if k_n == len(q):
                ops += q
                q.clear()
            elif k_n:
                ops += [q.popleft() for _ in range(k_n)]
            k = k_p + k_n
        # Inline completion loop: per-op stats folded into one update.
        stats = self.stats
        memv = self._memv
        crc_arr = self._crc
        clock = self._clock_s
        inv_bw = 1.0 / self.bandwidth_Bps
        rlat, wlat = self.read_latency_s, self.write_latency_s
        reads = writes = read_bytes = write_bytes = 0
        cookie_done = self._cookie_done
        cookies_before = len(cookie_done)
        now_tick = self.clock.now
        torn = False
        lat_c = stats.prio_completion_ticks.counts  # inlined histogram add:
        run_d = None                                # the stamp rides every
        run_n = 0                                   # completion; runs of the
        for i, op in enumerate(ops):                # same tick delta (the
            if i == k_p:                            # burst norm) fold into
                if run_n:                           # ONE dict update
                    lat_c[run_d] = lat_c.get(run_d, 0) + run_n
                    run_n = 0
                lat_c = stats.completion_ticks.counts
            if type(op) is tuple:   # burst-read entry: (lba, n, dest, cb, tick)
                lba, n, dest, cb, st = op
                d = now_tick - st
                if d == run_d:
                    run_n += 1
                else:
                    if run_n:
                        lat_c[run_d] = lat_c.get(run_d, 0) + run_n
                    run_d = d
                    run_n = 1
                clock += rlat + n * inv_bw
                reads += 1
                read_bytes += n
                if crc_arr is not None and n and self._crc_mismatch(lba, n):
                    stats.crc_read_failures += 1
                    if cb is not None:
                        cb(STATUS_EIO)   # corrupt media: no bytes delivered
                    continue
                dest[:n] = memv[lba : lba + n]   # mv->mv: cheapest copy path
                if cb is not None:
                    cb(STATUS_OK)
                continue
            d = now_tick - op.submit_tick
            if d == run_d:
                run_n += 1
            else:
                if run_n:
                    lat_c[run_d] = lat_c.get(run_d, 0) + run_n
                run_d = d
                run_n = 1
            n = op.nbytes
            kind = op.kind
            st = STATUS_OK
            if kind == "read":
                clock += rlat + n * inv_bw
                reads += 1
                read_bytes += n
                if crc_arr is not None and n and self._crc_mismatch(op.lba, n):
                    stats.crc_read_failures += 1
                    st = STATUS_EIO   # corrupt media: no bytes delivered
                else:
                    # Write straight into the caller's view (zero-copy contract)
                    op.buf[:n] = memv[op.lba : op.lba + n]
            elif kind == "write":
                clock += wlat + n * inv_bw
                # Read straight from the caller's buffer view (zero-copy)
                memv[op.lba : op.lba + n] = op.buf
                writes += 1
                write_bytes += n
                if crc_arr is not None:
                    self._crc_update(op.lba, n)
            else:  # writev: one op, bytes streamed from each gathered view
                tw = self._torn_writev
                if tw is not None:
                    tw[0] -= 1
                    if tw[0] <= 0:
                        # Power-fail MID-op: a prefix of the gathered
                        # buffers reaches media; the rest — and the op's
                        # completion — never happen.
                        pos = op.lba
                        for b in op.buf[: tw[1]]:
                            ln = len(b)
                            memv[pos : pos + ln] = b
                            pos += ln
                        if crc_arr is not None:   # the prefix DID commit
                            self._crc_update(op.lba, pos - op.lba)
                        self._torn_writev = None
                        torn = True
                        break
                clock += wlat + n * inv_bw
                pos = op.lba
                for b in op.buf:
                    ln = len(b)
                    memv[pos : pos + ln] = b
                    pos += ln
                writes += 1
                write_bytes += n
                if crc_arr is not None:
                    self._crc_update(op.lba, n)
            op.modeled_done_s = clock
            op.status = st
            cb = op.on_complete
            if cb:
                cb(st)
            elif op.cookie is not None:
                cookie_done.append((op.cookie, st))
        if run_n:   # trailing histogram run (also flushed on a torn break)
            lat_c[run_d] = lat_c.get(run_d, 0) + run_n
        self._clock_s = clock
        stats.modeled_busy_s = clock
        stats.reads += reads
        stats.writes += writes
        stats.read_bytes += read_bytes
        stats.write_bytes += write_bytes
        if torn:
            self.crash()   # remaining claimed + queued ops vanish
            return k
        if len(cookie_done) > cookies_before:
            db = self.doorbell
            if db is not None:
                db()   # completions queued for reap: owner stays runnable
        return k

    def reap(self) -> list[tuple[int, int]]:
        """Drain the cookie completion queue: ``[(cookie, status), ...]``."""
        out = self._cookie_done
        if not out:
            return out
        self._cookie_done = []
        return out

    def drain(self) -> None:
        while self.poll(1_000_000):
            pass

    # -- raw access for metadata bootstrap ----------------------------------------
    def raw_read(self, lba: int, nbytes: int) -> bytes:
        return self._mem[lba : lba + nbytes].tobytes()

    def raw_write(self, lba: int, data: bytes) -> None:
        self._mem[lba : lba + len(data)] = np.frombuffer(data, dtype=np.uint8)
        if self._crc is not None:   # raw writes are commits too
            self._crc_update(lba, len(data))
