"""NVMe SSD model: a block device with submission/completion queues.

The container has no NVMe device, so this is a RAM- (or file-) backed block
store with an SPDK-like asynchronous interface: ``submit_read`` /
``submit_write`` enqueue an operation; completions are delivered by
``poll()`` (SPDK-style polling) in submission order per queue.  A service
time model (base latency + bytes/bandwidth, bounded queue depth) accumulates
*modeled* device time for the calibrated benchmarks; nothing ever sleeps.

Zero-copy contract (DDS §4.3/§6.2): ``submit_read`` takes a destination
``memoryview`` and the device writes bytes straight into it — the caller
points it at pre-allocated response/packet space, so no intermediate copy is
ever made.  ``submit_write`` reads from the caller's buffer view directly.
"""

from __future__ import annotations

import threading
from collections import deque
from dataclasses import dataclass, field
from typing import Callable

import numpy as np

# v5-era datacenter NVMe-ish constants (§8.1: 1 TB NVMe SSD, 100-200us access).
DEFAULT_READ_LATENCY_S = 90e-6
DEFAULT_WRITE_LATENCY_S = 25e-6
DEFAULT_BANDWIDTH_BPS = 3.2e9
DEFAULT_QUEUE_DEPTH = 128

STATUS_PENDING = -1
STATUS_OK = 0
STATUS_EINVAL = 22
STATUS_EIO = 5


@dataclass
class IoOp:
    kind: str                      # "read" | "write"
    lba: int                       # byte offset on device
    nbytes: int
    buf: memoryview | bytes | None
    on_complete: Callable[[int], None] | None
    status: int = STATUS_PENDING
    modeled_done_s: float = 0.0


@dataclass
class BlockDeviceStats:
    reads: int = 0
    writes: int = 0
    read_bytes: int = 0
    write_bytes: int = 0
    modeled_busy_s: float = 0.0
    max_queue_depth_seen: int = 0


class BlockDevice:
    """RAM-backed block device with an async queue interface."""

    def __init__(self, capacity: int, block_size: int = 4096,
                 read_latency_s: float = DEFAULT_READ_LATENCY_S,
                 write_latency_s: float = DEFAULT_WRITE_LATENCY_S,
                 bandwidth_Bps: float = DEFAULT_BANDWIDTH_BPS,
                 queue_depth: int = DEFAULT_QUEUE_DEPTH):
        assert capacity % block_size == 0
        self.capacity = capacity
        self.block_size = block_size
        self.read_latency_s = read_latency_s
        self.write_latency_s = write_latency_s
        self.bandwidth_Bps = bandwidth_Bps
        self.queue_depth = queue_depth
        self._mem = np.zeros(capacity, dtype=np.uint8)
        self._queue: deque[IoOp] = deque()
        self._lock = threading.Lock()
        self._clock_s = 0.0  # modeled device clock
        self.stats = BlockDeviceStats()

    # -- submission --------------------------------------------------------------
    def submit_read(self, lba: int, nbytes: int, dest: memoryview,
                    on_complete: Callable[[int], None] | None = None) -> IoOp:
        op = IoOp("read", lba, nbytes, dest, on_complete)
        self._submit(op)
        return op

    def submit_write(self, lba: int, data, on_complete: Callable[[int], None] | None = None) -> IoOp:
        op = IoOp("write", lba, len(data), data, on_complete)
        self._submit(op)
        return op

    def _submit(self, op: IoOp) -> None:
        if op.lba < 0 or op.lba + op.nbytes > self.capacity:
            op.status = STATUS_EINVAL
            if op.on_complete:
                op.on_complete(op.status)
            return
        with self._lock:
            self._queue.append(op)
            d = len(self._queue)
            if d > self.stats.max_queue_depth_seen:
                self.stats.max_queue_depth_seen = d

    def queue_len(self) -> int:
        with self._lock:
            return len(self._queue)

    # -- completion --------------------------------------------------------------
    def poll(self, max_completions: int | None = None) -> int:
        """Execute + complete up to ``max_completions`` queued ops, in order."""
        budget = max_completions if max_completions is not None else self.queue_depth
        done = 0
        while done < budget:
            with self._lock:
                if not self._queue:
                    break
                op = self._queue.popleft()
            self._execute(op)
            done += 1
        return done

    def drain(self) -> None:
        while self.poll(1_000_000):
            pass

    def _execute(self, op: IoOp) -> None:
        lat = self.read_latency_s if op.kind == "read" else self.write_latency_s
        self._clock_s += lat + op.nbytes / self.bandwidth_Bps
        op.modeled_done_s = self._clock_s
        self.stats.modeled_busy_s = self._clock_s
        if op.kind == "read":
            src = self._mem[op.lba : op.lba + op.nbytes]
            dest = op.buf
            # Write straight into the caller's view (zero-copy contract).
            dest[: op.nbytes] = src.tobytes()
            self.stats.reads += 1
            self.stats.read_bytes += op.nbytes
        else:
            data = op.buf
            self._mem[op.lba : op.lba + op.nbytes] = np.frombuffer(
                bytes(data), dtype=np.uint8)
            self.stats.writes += 1
            self.stats.write_bytes += op.nbytes
        op.status = STATUS_OK
        if op.on_complete:
            op.on_complete(op.status)

    # -- raw access for metadata bootstrap ----------------------------------------
    def raw_read(self, lba: int, nbytes: int) -> bytes:
        return self._mem[lba : lba + nbytes].tobytes()

    def raw_write(self, lba: int, data: bytes) -> None:
        self._mem[lba : lba + len(data)] = np.frombuffer(data, dtype=np.uint8)
