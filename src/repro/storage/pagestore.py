"""Page store + KV store on DDS — the paper's two production integrations (§9).

``PageStore`` mirrors the Azure SQL Hyperscale page server (§9.1):

  * pages live in one RBPEX-like file on the storage server;
  * the host "replays log records" by writing whole pages (host path);
  * a ``GetPage@LSN`` network request is offloaded to the DPU iff the cache
    table says its cached LSN >= the requested LSN (``OffPred``), otherwise
    it is forwarded to the host, which serves the freshest copy;
  * ``Cache`` (cache-on-write) keys {page_id -> (file, offset, size, lsn)}
    parsed from the page header; ``Invalidate`` (invalidate-on-read) drops
    entries the host pulls back for modification.

``KVStoreServer`` mirrors the FASTER integration (§9.2): an append-only
record log whose tail lives in host memory (in-place updates / RMW on the
host) and whose older records are flushed to an IDevice implemented with the
DDS front-end library.  Flushing caches {key -> (file, offset, size)} so GET
requests for on-disk records are served entirely by the DPU.

Both classes needed only the four Table-1 functions plus a file — the
"hundreds of lines of code" adoption story of the paper.
"""

from __future__ import annotations

import struct
import threading
from dataclasses import dataclass, field

from repro.core import wire
from repro.core.dds_server import DDSStorageServer, ServerConfig
from repro.core.offload import OffloadAPI, ReadOp, WriteOp

# -- network message formats --------------------------------------------------------
# GetPage@LSN: type, req_id, page_id, lsn
PAGE_GET = 3
PAGE_GET_HDR = struct.Struct("<BQQQ")
# KV GET: type, req_id, klen, key
KV_GET = 4
KV_GET_HDR = struct.Struct("<BQI")
# page on disk: [lsn u64][payload ...]
PAGE_HDR = struct.Struct("<Q")


@dataclass
class PageItem:
    file_id: int
    offset: int
    size: int
    lsn: int


class PageStore:
    """A DDS-backed page server (GetPage@LSN semantics)."""

    def __init__(self, page_size: int = 8192, num_pages: int = 4096,
                 config: ServerConfig | None = None):
        self.page_size = page_size
        self.payload_size = page_size - PAGE_HDR.size
        api = OffloadAPI(self._off_pred, self._off_func,
                         cache=self._cache, invalidate=self._invalidate,
                         response_header=self._resp_header,
                         host_handler=self._host_handler)
        cfg = config or ServerConfig(
            device_capacity=max(1 << 28, 2 * page_size * num_pages))
        self.server = DDSStorageServer(cfg, api)
        self.file_id = self.server.frontend.create_file("rbpex")
        self.server.fs.ensure_capacity(self.file_id, page_size * num_pages)
        self.host_served = 0     # reads that fell back to the host (stale cache)

    # -- Table 1 functions -------------------------------------------------------------
    def _off_pred(self, payload: bytes, table) -> tuple[list[bytes], list[bytes]]:
        from repro.core.dds_server import decode_batch
        host, dpu = [], []
        for m in decode_batch(payload):
            if m and m[0] == PAGE_GET:
                _, rid, page_id, lsn = PAGE_GET_HDR.unpack_from(m, 0)
                item: PageItem | None = table.lookup(page_id) if table else None
                # Offload iff the DPU's view of the page is fresh enough (§9.1).
                if item is not None and item.lsn >= lsn:
                    dpu.append(m)
                else:
                    host.append(m)
            else:
                host.append(m)
        return host, dpu

    def _off_func(self, msg: bytes, table) -> ReadOp | None:
        if not msg or msg[0] != PAGE_GET:
            return None
        _, rid, page_id, lsn = PAGE_GET_HDR.unpack_from(msg, 0)
        item: PageItem | None = table.lookup(page_id) if table else None
        if item is None:
            return None
        return ReadOp(item.file_id, item.offset, item.size)

    def _cache(self, op: WriteOp) -> list[tuple[object, object]]:
        """cache-on-write: every aligned page fully covered by the write."""
        out = []
        if op.offset % self.page_size != 0:
            return out  # unaligned partial write: leave cache alone (host-fresh)
        pos = 0
        while pos + self.page_size <= len(op.data):
            off = op.offset + pos
            (lsn,) = PAGE_HDR.unpack_from(op.data, pos)
            page_id = off // self.page_size
            out.append((page_id, PageItem(op.file_id, off, self.page_size, lsn)))
            pos += self.page_size
        return out

    def _invalidate(self, op: ReadOp) -> list[object]:
        """invalidate-on-read: the host pulled these pages back to modify."""
        first = op.offset // self.page_size
        last = (op.offset + op.size - 1) // self.page_size
        return list(range(first, last + 1))

    def _resp_header(self, msg: bytes, op: ReadOp, err: int) -> bytes:
        from repro.core.dds_server import APP_RESP_HDR
        req_id = PAGE_GET_HDR.unpack_from(msg, 0)[1] if msg else 0
        return APP_RESP_HDR.pack(req_id, err, op.size if err == wire.E_OK else 0)

    def _host_handler(self, msg: bytes) -> tuple:
        """Host serves GetPage when the DPU cache is stale (partial offload)."""
        if msg and msg[0] == PAGE_GET:
            _, req_id, page_id, lsn = PAGE_GET_HDR.unpack_from(msg, 0)
            self.host_served += 1
            return ("r", req_id, self.file_id, page_id * self.page_size,
                    self.page_size)
        return ("resp", 0, wire.E_INVAL, b"")

    # -- host-side page replay (log apply writes whole pages) ---------------------------
    def replay(self, page_id: int, lsn: int, payload: bytes) -> None:
        assert len(payload) <= self.payload_size
        page = PAGE_HDR.pack(lsn) + payload.ljust(self.payload_size, b"\x00")
        self.server.frontend.write_sync(self.file_id, page_id * self.page_size,
                                        page)
        self.server.run_until_idle()

    def host_read_for_update(self, page_id: int) -> bytes:
        """Host reads a page to modify it -> invalidate-on-read fires."""
        data = self.server.frontend.read_sync(self.file_id,
                                              page_id * self.page_size,
                                              self.page_size)
        self.server.run_until_idle()
        return data

    @staticmethod
    def encode_get(req_id: int, page_id: int, lsn: int) -> bytes:
        return PAGE_GET_HDR.pack(PAGE_GET, req_id, page_id, lsn)

    @staticmethod
    def decode_page(data: bytes) -> tuple[int, bytes]:
        (lsn,) = PAGE_HDR.unpack_from(data, 0)
        return lsn, data[PAGE_HDR.size:]


@dataclass
class KVItem:
    file_id: int
    offset: int
    size: int


class KVStoreServer:
    """FASTER-like disaggregated KV service with DDS offloading (§9.2)."""

    REC_HDR = struct.Struct("<II")  # klen, vlen

    def __init__(self, memory_budget: int = 1 << 20,
                 config: ServerConfig | None = None):
        api = OffloadAPI(self._off_pred, self._off_func,
                         cache=self._cache, invalidate=None,
                         response_header=self._resp_header,
                         host_handler=self._host_handler)
        self.server = DDSStorageServer(config or ServerConfig(), api)
        self.file_id = self.server.frontend.create_file("kvlog")
        self.memory_budget = memory_budget
        self._tail: dict[bytes, bytes] = {}        # in-memory mutable log tail
        self._tail_bytes = 0
        self._index: dict[bytes, KVItem] = {}      # host hash index (disk part)
        self._log_off = 0
        self._pending_flush: dict[int, bytes] = {}  # offset -> key (Cache needs it)
        self._lock = threading.Lock()

    # -- Table 1 functions ---------------------------------------------------------------
    def _off_pred(self, payload: bytes, table) -> tuple[list[bytes], list[bytes]]:
        from repro.core.dds_server import decode_batch
        host, dpu = [], []
        for m in decode_batch(payload):
            if m and m[0] == KV_GET:
                _, rid, klen = KV_GET_HDR.unpack_from(m, 0)
                # decode_batch returns memoryviews; the table key must hash
                key = bytes(m[KV_GET_HDR.size : KV_GET_HDR.size + klen])
                if table is not None and table.lookup(key) is not None:
                    dpu.append(m)      # on-disk record: the DPU serves it
                else:
                    host.append(m)     # in the mutable tail (or missing)
            else:
                host.append(m)
        return host, dpu

    def _off_func(self, msg: bytes, table) -> ReadOp | None:
        if not msg or msg[0] != KV_GET:
            return None
        _, rid, klen = KV_GET_HDR.unpack_from(msg, 0)
        key = bytes(msg[KV_GET_HDR.size : KV_GET_HDR.size + klen])
        item: KVItem | None = table.lookup(key) if table else None
        if item is None:
            return None
        return ReadOp(item.file_id, item.offset, item.size)

    def _cache(self, op: WriteOp) -> list[tuple[object, object]]:
        """cache-on-write: parse flushed records, cache their locations."""
        out = []
        pos = 0
        while pos + self.REC_HDR.size <= len(op.data):
            klen, vlen = self.REC_HDR.unpack_from(op.data, pos)
            total = self.REC_HDR.size + klen + vlen
            key = bytes(op.data[pos + self.REC_HDR.size : pos + self.REC_HDR.size + klen])
            out.append((key, KVItem(op.file_id, op.offset + pos, total)))
            pos += total
        return out

    def _resp_header(self, msg: bytes, op: ReadOp, err: int) -> bytes:
        from repro.core.dds_server import APP_RESP_HDR
        req_id = KV_GET_HDR.unpack_from(msg, 0)[1] if msg else 0
        return APP_RESP_HDR.pack(req_id, err, op.size if err == wire.E_OK else 0)

    def _host_handler(self, msg: bytes) -> tuple:
        """GETs for tail-resident records execute on the host (§9.2/§2)."""
        if msg and msg[0] == KV_GET:
            _, req_id, klen = KV_GET_HDR.unpack_from(msg, 0)
            # msg may be a zero-copy view; dict keys must be real bytes
            key = bytes(msg[KV_GET_HDR.size : KV_GET_HDR.size + klen])
            with self._lock:
                val = self._tail.get(key)
            if val is not None:
                body = self.REC_HDR.pack(len(key), len(val)) + key + val
                return ("resp", req_id, wire.E_OK, body)
            item = self._index.get(key)
            if item is not None:  # not yet in the DPU cache table
                return ("r", req_id, item.file_id, item.offset, item.size)
            return ("resp", req_id, wire.E_NOENT, b"")
        return ("resp", 0, wire.E_INVAL, b"")

    # -- host operations -----------------------------------------------------------------
    def upsert(self, key: bytes, value: bytes) -> None:
        with self._lock:
            old = self._tail.get(key)
            self._tail[key] = value
            self._tail_bytes += len(key) + len(value) - (
                len(old) + len(key) if old is not None else 0)
        if self._tail_bytes > self.memory_budget:
            self.flush()

    def rmw(self, key: bytes, fn) -> bytes:
        """Read-modify-write executes on the host (warm data, big cache: §2)."""
        with self._lock:
            cur = self._tail.get(key)
        if cur is None:
            item = self._index.get(key)
            if item is not None:
                raw = self.server.frontend.read_sync(item.file_id, item.offset,
                                                     item.size)
                klen, vlen = self.REC_HDR.unpack_from(raw, 0)
                cur = raw[self.REC_HDR.size + klen:]
        new = fn(cur)
        self.upsert(key, new)
        return new

    def flush(self) -> None:
        """Flush the tail to the IDevice (DDS front-end) — fires Cache()."""
        with self._lock:
            recs, keys = [], []
            for k, v in self._tail.items():
                recs.append(self.REC_HDR.pack(len(k), len(v)) + k + v)
                keys.append(k)
            blob = b"".join(recs)
            base = self._log_off
            self._log_off += len(blob)
            self._tail.clear()
            self._tail_bytes = 0
        if not blob:
            return
        self.server.frontend.write_sync(self.file_id, base, blob)
        # Update the host index to the on-disk location as well.
        pos = 0
        for r, k in zip(recs, keys):
            self._index[k] = KVItem(self.file_id, base + pos, len(r))
            pos += len(r)
        self.server.run_until_idle()

    def get_local(self, key: bytes) -> bytes | None:
        with self._lock:
            if key in self._tail:
                return self._tail[key]
        item = self._index.get(key)
        if item is None:
            return None
        raw = self.server.frontend.read_sync(item.file_id, item.offset, item.size)
        klen, vlen = self.REC_HDR.unpack_from(raw, 0)
        return raw[self.REC_HDR.size + klen:]

    @staticmethod
    def encode_get(req_id: int, key: bytes) -> bytes:
        return KV_GET_HDR.pack(KV_GET, req_id, len(key)) + key

    @staticmethod
    def decode_record(data: bytes) -> tuple[bytes, bytes]:
        klen, vlen = KVStoreServer.REC_HDR.unpack_from(data, 0)
        k = data[KVStoreServer.REC_HDR.size : KVStoreServer.REC_HDR.size + klen]
        v = data[KVStoreServer.REC_HDR.size + klen :
                 KVStoreServer.REC_HDR.size + klen + vlen]
        return k, v
