"""Storage substrates: block device model, page store, checkpointing."""
