"""Distributed checkpointing on the DDS storage path.

Division of labor follows the paper's partial-offload policy (§3):

  * **Saves** are complex, durable, and batched — they take the HOST path
    (DDS front-end library -> DMA rings -> DPU file service).  Saves can be
    asynchronous (write-behind thread), so the train loop never blocks on
    storage: the paper's non-blocking WriteFile + notification groups.

  * **Restores** are simple cold reads — exactly what DDS offloads.  Byte
    ranges of checkpoint files are read back, optionally *resharded onto a
    different mesh* (elastic restart after losing nodes): each host reads
    only the contiguous ranges its new shards need.

Atomic commit: leaf files are written first, the JSON manifest is written
LAST and fsync'd; a checkpoint without a manifest is invisible.  This gives
crash consistency without rename support in the segment FS.
"""

from __future__ import annotations

import json
import threading
import time
from dataclasses import dataclass, field
from typing import Any

import numpy as np

import jax

from repro.core.dds_server import DDSStorageServer


def _leaf_paths(tree: Any) -> list[tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out.append((name or "leaf", leaf))
    return out


@dataclass
class CheckpointInfo:
    step: int
    nbytes: int
    wall_s: float
    leaves: int


class CheckpointManager:
    """Save/restore pytrees to a DDS storage server."""

    MANIFEST_PREFIX = "manifest-"

    def __init__(self, server: DDSStorageServer, keep: int = 3):
        self.server = server
        self.keep = keep
        self._history: list[CheckpointInfo] = []
        self._async_thread: threading.Thread | None = None
        self._async_err: list[BaseException] = []
        self._lock = threading.Lock()

    # -- save -------------------------------------------------------------------------
    def save(self, step: int, tree: Any) -> CheckpointInfo:
        t0 = time.perf_counter()
        fe = self.server.frontend
        leaves = _leaf_paths(tree)
        manifest: dict[str, Any] = {"step": step, "leaves": {}}
        total = 0
        for name, leaf in leaves:
            arr = np.asarray(jax.device_get(leaf))
            raw = arr.tobytes()
            fid = fe.create_file(f"ckpt-{step}/{name}")
            fe.write_sync(fid, 0, raw)
            manifest["leaves"][name] = {
                "file_id": fid, "shape": list(arr.shape),
                "dtype": str(arr.dtype), "nbytes": len(raw),
            }
            total += len(raw)
        # Commit point: manifest written last + metadata fsync.
        mid = fe.create_file(f"{self.MANIFEST_PREFIX}{step}")
        fe.write_sync(mid, 0, json.dumps(manifest).encode())
        fe.fsync()
        self.server.run_until_idle()
        info = CheckpointInfo(step, total, time.perf_counter() - t0, len(leaves))
        with self._lock:
            self._history.append(info)
        self._gc()
        return info

    def save_async(self, step: int, tree: Any) -> None:
        """Write-behind save; call ``wait_async`` before depending on it."""
        self.wait_async()
        host_tree = jax.tree_util.tree_map(lambda x: np.asarray(jax.device_get(x)),
                                           tree)

        def work():
            try:
                self.save(step, host_tree)
            except BaseException as e:  # surfaced by wait_async
                self._async_err.append(e)

        self._async_thread = threading.Thread(target=work, daemon=True,
                                              name=f"ckpt-save-{step}")
        self._async_thread.start()

    def wait_async(self) -> None:
        if self._async_thread is not None:
            self._async_thread.join()
            self._async_thread = None
        if self._async_err:
            raise self._async_err.pop()

    # -- discovery ------------------------------------------------------------------------
    def _manifests(self) -> dict[int, int]:
        """step -> manifest file id, scanning the root directory."""
        out = {}
        for fid, meta in self.server.fs.files.items():
            if meta.name.startswith(self.MANIFEST_PREFIX):
                try:
                    out[int(meta.name[len(self.MANIFEST_PREFIX):])] = fid
                except ValueError:
                    pass
        return out

    def latest_step(self) -> int | None:
        steps = self._manifests()
        return max(steps) if steps else None

    def _read_manifest(self, step: int) -> dict:
        mid = self._manifests().get(step)
        if mid is None:
            raise FileNotFoundError(f"no committed checkpoint for step {step}")
        size = self.server.fs.file_size(mid)
        raw = self.server.frontend.read_sync(mid, 0, size)
        return json.loads(raw.decode())

    # -- restore -----------------------------------------------------------------------------
    def restore(self, step: int, template: Any | None = None) -> Any:
        """Full restore.  With ``template``, returns a matching pytree."""
        manifest = self._read_manifest(step)
        arrays: dict[str, np.ndarray] = {}
        for name, m in manifest["leaves"].items():
            raw = self.server.frontend.read_sync(m["file_id"], 0, m["nbytes"])
            arrays[name] = np.frombuffer(raw, dtype=m["dtype"]).reshape(m["shape"])
        if template is None:
            return arrays
        out_leaves = []
        for name, _ in _leaf_paths(template):
            if name not in arrays:
                raise KeyError(f"checkpoint missing leaf {name}")
            out_leaves.append(arrays[name])
        treedef = jax.tree_util.tree_structure(template)
        return jax.tree_util.tree_unflatten(treedef, out_leaves)

    def restore_shard(self, step: int, name: str,
                      start_row: int, end_row: int) -> np.ndarray:
        """Elastic restore: read ONLY the byte range of rows [start, end).

        Row-sharding over axis 0 (FSDP) makes each shard a contiguous byte
        range — the cold, simple read the DPU offload path is built for.
        A new mesh shape just changes the (start,end) each host requests.
        """
        manifest = self._read_manifest(step)
        m = manifest["leaves"][name]
        shape, dtype = m["shape"], np.dtype(m["dtype"])
        if not shape:
            raise ValueError("cannot row-shard a scalar leaf")
        row_bytes = int(np.prod(shape[1:], dtype=np.int64)) * dtype.itemsize
        off = start_row * row_bytes
        n = (end_row - start_row) * row_bytes
        raw = self.server.frontend.read_sync(m["file_id"], off, n)
        return np.frombuffer(raw, dtype=dtype).reshape([end_row - start_row]
                                                       + shape[1:])

    def restore_elastic(self, step: int, template: Any,
                        shard_index: int, num_shards: int) -> Any:
        """Restore this host's row-shards for a num_shards-way layout."""
        out_leaves = []
        for name, leaf in _leaf_paths(template):
            shape = np.shape(leaf)
            if not shape or shape[0] % num_shards != 0:
                out_leaves.append(np.asarray(self.restore(step)[name]))
                continue
            rows = shape[0] // num_shards
            out_leaves.append(self.restore_shard(
                step, name, shard_index * rows, (shard_index + 1) * rows))
        treedef = jax.tree_util.tree_structure(template)
        return jax.tree_util.tree_unflatten(treedef, out_leaves)

    # -- retention -----------------------------------------------------------------------------
    def _gc(self) -> None:
        steps = sorted(self._manifests())
        fe = self.server.frontend
        while len(steps) > self.keep:
            victim = steps.pop(0)
            manifest = self._read_manifest(victim)
            mid = self._manifests()[victim]
            for m in manifest["leaves"].values():
                fe.delete_file(m["file_id"])
            fe.delete_file(mid)
        self.server.run_until_idle()
