"""Applications built on the DDS cluster (the paper's §9 adoption story)."""

from repro.apps.kv_store import KVClient, KVLocation, ShardedKVStore

__all__ = ["KVClient", "KVLocation", "ShardedKVStore"]
