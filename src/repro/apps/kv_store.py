"""Sharded KV store on the DDS cluster — the §9.2 workload, scaled out.

Each shard of a :class:`~repro.distributed.cluster.DDSCluster` holds one
append-only record log.  The four Table-1 functions per shard:

  * ``OffPred``   — a GET whose key is in the DPU cache table goes to the
    DPU; everything else (PUT/DEL, cold GETs) goes to the host.
  * ``OffFunc``   — key -> cached ``(file, offset, size)`` -> ``ReadOp``.
  * ``Cache``     — cache-on-write: when the host appends records to the
    log, their locations are inserted, so subsequent GETs are served
    entirely on the DPU (zero host CPU).
  * ``Invalidate``— invalidate-on-read: when the host pulls a record back
    (DELETE / read-modify-write), its cache entry is dropped before the
    host proceeds — the DPU can never serve a record the host is mutating.

``PUT`` executes on the host (§2: writes need the big cores + memory) and
its ack carries the record's on-disk location ``(file_id, offset, size)``.
Overwrites append a fresh record; ``Cache`` upserts the key to the new
location, and ``Invalidate`` ignores stale log offsets so an overwrite can
never knock out the newer mapping.

Routing is by consistent-hashing the KEY over the cluster ring, so the
same thin :class:`~repro.core.client.ClusterClient` pipelining applies.
"""

from __future__ import annotations

import bisect
import struct
from dataclasses import dataclass, field
from typing import NamedTuple

import numpy as np

from repro.core import vector, wire
from repro.core.client import ClusterClient
from repro.core.dds_server import APP_RESP_HDR, ServerConfig, decode_batch
from repro.core.offload import OffloadAPI, ReadOp, WriteOp
from repro.distributed.cluster import DDSCluster
from repro.distributed.resharding import Resharder

# -- network message formats (batched with the §8.1 framing) -------------------------
KV_PUT = 16
KV_GET = 17
KV_DEL = 18
KV_MPUT = 19   # migration sync PUT (elastic resharding; shield-checked)
KV_MDEL = 20   # migration sync DEL
PUT_HDR = struct.Struct("<BQII")   # type, req_id, klen, vlen
GET_HDR = struct.Struct("<BQI")    # type, req_id, klen
REC_HDR = struct.Struct("<II")     # klen, vlen (on-disk record header)
LOC = struct.Struct("<IQI")        # file_id, offset, size (PUT ack body)

# A DELETE appends a TOMBSTONE record (header flag bit in vlen, key, no
# value bytes): deletes ride the same log/replication/ack-hold path as
# PUTs, so a replica promotion can no longer resurrect a deleted key.
TOMBSTONE = 1 << 31
_VLEN_MASK = TOMBSTONE - 1

# Unified-surface op spellings -> latency class for the issue-tick stamp.
_KV_CLS = {"get": "r", "put": "w", "delete": "w"}


def encode_put(req_id: int, key: bytes, value: bytes) -> bytes:
    return PUT_HDR.pack(KV_PUT, req_id, len(key), len(value)) + key + value


def encode_get(req_id: int, key: bytes) -> bytes:
    return GET_HDR.pack(KV_GET, req_id, len(key)) + key


def encode_del(req_id: int, key: bytes) -> bytes:
    return GET_HDR.pack(KV_DEL, req_id, len(key)) + key


def decode_record(data: bytes) -> tuple[bytes, bytes | None]:
    klen, vlen = REC_HDR.unpack_from(data, 0)
    k = data[REC_HDR.size : REC_HDR.size + klen]
    if vlen & TOMBSTONE:
        return k, None
    v = data[REC_HDR.size + klen : REC_HDR.size + klen + vlen]
    return k, v


class KVLocation(NamedTuple):
    """Immutable record location; a NamedTuple (C-speed construction —
    one is minted per PUT on the cache-on-write path)."""
    file_id: int
    offset: int
    size: int

    @staticmethod
    def decode(body: bytes) -> "KVLocation":
        return KVLocation(*LOC.unpack_from(body, 0))

    def encode(self) -> bytes:
        return LOC.pack(self.file_id, self.offset, self.size)


@dataclass
class _ShardState:
    """Host-side per-shard state (the storage application on that host)."""
    log_fid: int = -1                 # shard-LOCAL file id of the record log
    log_off: int = 0                  # append tail
    index: dict = field(default_factory=dict)      # key -> KVLocation
    at_offset: dict = field(default_factory=dict)  # log offset -> (key, size)
    offsets: list = field(default_factory=list)    # sorted (log appends only)
    # Replication: where OUR log is mirrored (replica shard -> its local
    # fid), and the log copies WE adopted at a promotion — local fid ->
    # (at_offset, offsets) invalidation view.  Adopted logs are read-only
    # (new PUTs for adopted keys append to our OWN log), so each fid's
    # offset space stays internally consistent.
    replica_fids: dict = field(default_factory=dict)
    adopted: dict = field(default_factory=dict)
    adopted_records: int = 0
    adopted_bytes: int = 0
    puts: int = 0
    dels: int = 0
    host_gets: int = 0
    # Elastic resharding: per-key heat sketch (bounded, halve-on-overflow)
    # for hot-shard detection, the migration-destination write SHIELD
    # (keys directly written while a migration is armed — a late resent
    # sync for one is stale by construction and must not apply), and the
    # applied/skipped sync counters.
    heat: dict = field(default_factory=dict)
    shield: set | None = None
    mig_puts: int = 0
    mig_dels: int = 0
    mig_skipped: int = 0


class ShardedKVStore:
    """N-shard KV service; every shard is a full DDS storage server."""

    def __init__(self, num_shards: int = 2,
                 config: ServerConfig | None = None, vnodes: int = 64,
                 elastic: bool = False):
        self._states = [_ShardState() for _ in range(num_shards)]
        self._heat_base = [0] * num_shards
        self.cluster = DDSCluster(num_shards, config,
                                  api_factory=self._api_for, vnodes=vnodes,
                                  elastic=elastic)
        for st, srv in zip(self._states, self.cluster.servers):
            st.log_fid = srv.frontend.create_file("kvlog")
            srv.run_until_idle()
        if self.cluster.replication:
            # Mirror every record log onto its ring successors: a PUT ack
            # releases only after the replicas hold the record, and a crash
            # promotes a replica (``_on_promote`` rebuilds the index from
            # the adopted log copy).
            for s, st in enumerate(self._states):
                st.replica_fids = self.cluster.replicate_file(
                    s, st.log_fid, "kvlog")
            self.cluster.on_promote = self._on_promote
            self.cluster.on_rejoin = self._on_rejoin

    def shard_for_key(self, key: bytes) -> int:
        return self.cluster.shard_for_key(key)

    def _on_promote(self, dead: int, promoted: int) -> None:
        """Adopt the dead shard's log copy on the promoted shard.

        Scans the replica log (every record the dead primary ever acked is
        in it — acks were held on replication), rebuilding the host index
        with later records winning, and registers an invalidation view so
        the DPU can never serve an adopted record the host is mutating.
        DPU cache entries for adopted keys are dropped-then-warmed so a
        stale mapping can never survive the promotion.

        Deletes are logged as TOMBSTONE records, so a key deleted on the
        dead primary after its last PUT stays deleted here: the scan's
        later-wins rule resolves it to the tombstone, which drops the
        key instead of adopting it.
        """
        fid = self._states[dead].replica_fids.get(promoted, -1)
        if fid < 0:
            return
        st = self._states[promoted]
        srv = self.cluster.servers[promoted]
        size = srv.fs.file_size(fid)
        data = srv.frontend.read_sync(fid, 0, size) if size else b""
        adopted_index: dict[bytes, KVLocation | None] = {}
        at_offset: dict = {}
        offsets: list = []
        pos = 0
        while pos + REC_HDR.size <= len(data):
            klen, vlen = REC_HDR.unpack_from(data, pos)
            total = REC_HDR.size + klen + (vlen & _VLEN_MASK)
            if pos + total > len(data):
                break   # torn tail record: never acked, drop it
            key = bytes(data[pos + REC_HDR.size : pos + REC_HDR.size + klen])
            # later wins; a tombstone resolves the key to DELETED
            adopted_index[key] = None if vlen & TOMBSTONE \
                else KVLocation(fid, pos, total)
            at_offset[pos] = (key, total)
            offsets.append(pos)
            pos += total
        st.adopted[fid] = (at_offset, offsets)
        st.adopted_records += len(offsets)
        st.adopted_bytes += pos
        table = srv.cache_table
        for key, loc in adopted_index.items():
            if table is not None:
                table.delete(key)     # a stale pre-failover mapping
            if loc is None:
                st.index.pop(key, None)   # tombstoned on the dead primary
                continue
            st.index[key] = loc   # key spaces are ring-disjoint: no clobber
            if table is not None:
                table.insert(key, loc)  # warm: post-failover GETs DPU-serve

    def _on_rejoin(self, healed: int, primary: int) -> None:
        """Re-silver the promoted primary's record log onto a healed shard.

        A partitioned shard that missed enough heartbeat windows was failed
        over; when its network comes back, ``DDSCluster._heal`` demotes it
        to a replica of ``primary`` and re-arms the replication connection.
        The cluster re-silvers its OWN file table, but the KV record logs
        are application files — so copy the primary's log (which now also
        carries every post-promotion PUT for the healed shard's adopted
        keys) and register the mapping so future appends mirror before the
        ack releases, restoring the redundancy the failover spent."""
        pst = self._states[primary]
        psrv = self.cluster.servers[primary]
        hsrv = self.cluster.servers[healed]
        prepl = psrv.replicator
        if prepl is None:
            return
        # A pre-partition copy may already exist (the healed shard was a
        # ring successor of the primary from construction) but its
        # forwarding was dropped at the promotion — the log is append-only,
        # so top up the missed tail and re-register the mapping.
        rlfid = pst.replica_fids.get(healed)
        if rlfid is None:
            rlfid = hsrv.frontend.create_file(f"kvlog:r{primary}")
        have = hsrv.fs.file_size(rlfid)
        psize = psrv.fs.file_size(pst.log_fid)
        if psize > have:
            data = psrv.frontend.read_sync(pst.log_fid, have, psize - have)
            hsrv.frontend.write_sync(rlfid, have, data)
            hsrv.run_until_idle()
        prepl.map_file(healed, pst.log_fid, rlfid)
        pst.replica_fids[healed] = rlfid

    # -- Table 1 functions, closed over one shard's state ---------------------------
    def _api_for(self, shard: int) -> OffloadAPI:
        st = self._states[shard]
        # Single-probe handoff: the predicate's burst probe already resolved
        # every DPU-bound GET, so its results ride to ``prepare_read_many``
        # keyed by message identity (the SAME view objects flow demux ->
        # fair queue -> engine).  Entries hold (msg, loc): the reference
        # keeps the view alive, so an id() can never be reused while its
        # entry exists, and the ``is`` check at pop time makes a hit exact.
        # ``epoch`` guards staleness — ANY table mutation between probe and
        # use invalidates the memo and the engine re-probes, preserving
        # scalar re-probe semantics bit-for-bit.
        probe_memo: dict[int, tuple] = {}
        memo_state = [-1]   # table.epoch the memo entries were probed at

        def off_pred(payload: bytes, table) -> tuple[list[bytes], list[bytes]]:
            """Route a network batch: cached GETs -> DPU, the rest -> host.

            The whole batch's GET keys are probed with ONE
            :meth:`~repro.core.cache_table.CacheTable.lookup_many` burst
            (single stats round) instead of a lock/stats round per key;
            relative message order within each output list is preserved
            (PUT-then-DEL of one key must reach the host in order).

            A uniform all-GET batch (one key size repeated — the GET-storm
            shape) is routed columnar: the opcode and klen columns are
            checked with two array compares, keys are sliced at fixed
            strides, and only the key materialization and the burst probe
            remain per-message work."""
            mv = payload if isinstance(payload, memoryview) \
                else memoryview(payload)
            end = len(mv)
            if table is not None and end >= 512:
                u = vector.uniform_stride(mv, 4, 0, min_frames=20)
                if u is not None and u[0] * u[1] == end:
                    cnt, stride, _ = u
                    a = np.frombuffer(mv, dtype=np.uint8,
                                      count=end).reshape(cnt, stride)
                    # frame offset 4 = opcode; 13..17 = GET_HDR klen word
                    if (a[:, 4] == KV_GET).all() \
                            and (a[:, 13:17] == a[0, 13:17]).all():
                        klen = int.from_bytes(mv[13:17], "little")
                        k0 = 4 + GET_HDR.size
                        if k0 + klen <= stride:
                            keys = [bytes(mv[i * stride + k0:
                                             i * stride + k0 + klen])
                                    for i in range(cnt)]
                            hits = table.lookup_many(keys)
                            msgs = [mv[i * stride + 4:(i + 1) * stride]
                                    for i in range(cnt)]
                            ep = table.epoch
                            if ep != memo_state[0] \
                                    or len(probe_memo) > 16384:
                                probe_memo.clear()
                                memo_state[0] = ep
                            if all(h is not None for h in hits):
                                for m, h in zip(msgs, hits):
                                    probe_memo[id(m)] = (m, h)
                                return [], msgs
                            host, dpu = [], []
                            for m, h in zip(msgs, hits):
                                if h is not None:
                                    probe_memo[id(m)] = (m, h)
                                    dpu.append(m)
                                else:
                                    host.append(m)
                            return host, dpu
            msgs = decode_batch(mv)
            # decode_batch hands out memoryviews; the cache table needs a
            # hashable key, so materialize ONLY the keys.
            keys = []
            hdr = GET_HDR.size
            for m in msgs:
                if m and m[0] == KV_GET:
                    klen = GET_HDR.unpack_from(m, 0)[2]
                    keys.append(bytes(m[hdr : hdr + klen]))
            hits = iter(table.lookup_many(keys)) if (table is not None and keys) \
                else iter(())
            host, dpu = [], []
            for m in msgs:
                if m and m[0] == KV_GET and table is not None:
                    if next(hits) is not None:
                        dpu.append(m)
                        continue
                host.append(m)
            return host, dpu

        def off_func(msg: bytes, table) -> ReadOp | None:
            if not msg or msg[0] != KV_GET:
                return None
            _, rid, klen = GET_HDR.unpack_from(msg, 0)
            key = bytes(msg[GET_HDR.size : GET_HDR.size + klen])
            loc: KVLocation | None = table.lookup(key) if table else None
            if loc is None:
                return None
            return ReadOp(loc.file_id, loc.offset, loc.size)

        def prepare_read(msg, table) -> tuple[ReadOp, bytes] | None:
            """Fused OffFunc + ok-response-header (one parse per GET),
            mirroring the default app's fast path."""
            if not msg or msg[0] != KV_GET:
                return None
            _, rid, klen = GET_HDR.unpack_from(msg, 0)
            key = bytes(msg[GET_HDR.size : GET_HDR.size + klen])
            loc: KVLocation | None = table.lookup(key) if table else None
            if loc is None:
                return None
            return (ReadOp(loc.file_id, loc.offset, loc.size),
                    APP_RESP_HDR.pack(rid, wire.E_OK, loc.size))

        def prepare_read_many(msgs: list, table) -> list:
            """Burst form of ``prepare_read``: ONE ``lookup_many`` probe
            covers every GET the offload engine pulled this step (the
            engine previously re-probed the table once per request on top
            of the predicate's burst probe — the single hottest scalar
            loop on the offloaded-GET path).

            Uniform bursts (every message a GET of one frame size — the
            storm shape) decode columnar: one join, one structured-dtype
            view for the rid/klen columns, and one preassembled response-
            header arena instead of a ``Struct.pack`` per request."""
            hdr = GET_HDR.size
            n = len(msgs)
            keys: list = []
            if table is not None and n >= 8:
                ln = len(msgs[0])
                if ln > hdr and all(len(m) == ln for m in msgs):
                    buf = b"".join(msgs)
                    cols = np.frombuffer(buf, dtype={
                        "names": ["op", "rid", "klen"],
                        "formats": ["u1", "<u8", "<u4"],
                        "offsets": [0, 1, 9], "itemsize": ln})
                    if ((cols["op"] == KV_GET).all()
                            and (cols["klen"] == ln - hdr).all()):
                        end = n * ln
                        # Batch-pack the OK response headers: fill the rid /
                        # status / nbytes columns of one arena, then slice.
                        arena = np.zeros(n, dtype={
                            "names": ["rid", "status", "nbytes"],
                            "formats": ["<u8", "<u4", "<u4"],
                            "offsets": [0, 8, 12], "itemsize": 16})
                        arena["rid"] = cols["rid"]
                        arena["status"] = wire.E_OK
                        locs = None
                        if probe_memo and table.epoch == memo_state[0]:
                            # Predicate probe still valid: consume it.  The
                            # memo holds only HITS, so the miss branches
                            # vanish from the fill below.
                            locs = []
                            pop = probe_memo.pop
                            for m in msgs:
                                e = pop(id(m), None)
                                if e is None or e[0] is not m:
                                    locs = None
                                    break
                                locs.append(e[1])
                        # KVLocation IS the read op (same file_id / offset /
                        # size fields the engine reads): returning it
                        # directly skips a per-request ReadOp construction.
                        if locs is not None:
                            arena["nbytes"] = [l.size for l in locs]
                            ab = arena.tobytes()
                            return [(l, ab[i16:i16 + 16])
                                    for l, i16 in zip(
                                        locs, range(0, 16 * n, 16))]
                        keys = [buf[o + hdr:o + ln]
                                for o in range(0, end, ln)]
                        locs = table.lookup_many(keys)
                        arena["nbytes"] = [0 if l is None else l.size
                                           for l in locs]
                        ab = arena.tobytes()
                        return [None if loc is None else
                                (loc, ab[i16:i16 + 16])
                                for loc, i16 in zip(locs,
                                                    range(0, 16 * n, 16))]
            metas: list = []
            for m in msgs:
                if m and m[0] == KV_GET:
                    _, rid, klen = GET_HDR.unpack_from(m, 0)
                    keys.append(bytes(m[hdr:hdr + klen]))
                    metas.append(rid)
                else:
                    metas.append(None)
            locs = iter(table.lookup_many(keys)) if (table is not None
                                                     and keys) else iter(())
            pack = APP_RESP_HDR.pack
            ok = wire.E_OK
            out: list = []
            for rid in metas:
                if rid is None:
                    out.append(None)
                    continue
                loc = next(locs)
                out.append(None if loc is None else
                           (loc, pack(rid, ok, loc.size)))
            return out

        def cache(op: WriteOp) -> list[tuple[object, object]]:
            if op.file_id != st.log_fid:
                return []
            out, pos = [], 0
            while pos + REC_HDR.size <= len(op.data):
                klen, vlen = REC_HDR.unpack_from(op.data, pos)
                total = REC_HDR.size + klen + (vlen & _VLEN_MASK)
                key = bytes(op.data[pos + REC_HDR.size
                                    : pos + REC_HDR.size + klen])
                # A tombstone record maps the key to None: cache-on-write
                # becomes invalidate-on-write for deletes (the DPU drops
                # the mapping before the delete's ack can release).
                out.append((key, None) if vlen & TOMBSTONE else
                           (key, KVLocation(op.file_id, op.offset + pos,
                                            total)))
                pos += total
            return out

        def invalidate(op: ReadOp) -> list[object]:
            """Host pulled [offset, offset+size) of the log back: drop the
            cache entries of records in that range — UNLESS the index
            already points the key at a newer offset outside the range
            (an overwrite must not invalidate its own fresh mapping).

            ``offsets`` is sorted (logs only append), so the scan is a
            bisect plus the overlapped window; records whose mapping is
            resolved here are tombstoned out of ``at_offset`` so no read
            pays for them twice.  The view is picked per fid: our own log,
            or a log copy adopted at a replica promotion."""
            if op.file_id == st.log_fid:
                at_offset, offsets = st.at_offset, st.offsets
            else:
                view = st.adopted.get(op.file_id)
                if view is None:
                    return []
                at_offset, offsets = view
            keys = []
            j = max(bisect.bisect_right(offsets, op.offset) - 1, 0)
            while j < len(offsets):
                off = offsets[j]
                j += 1
                if off >= op.offset + op.size:
                    break
                ent = at_offset.get(off)
                if ent is None:
                    continue  # tombstoned by an earlier invalidation
                key, size = ent
                if off + size <= op.offset:
                    continue  # record just before the range; no overlap
                cur: KVLocation | None = st.index.get(key)
                if cur is not None and (
                        cur.file_id != op.file_id
                        or not (cur.offset < op.offset + op.size
                                and cur.offset + cur.size > op.offset)):
                    # Key lives elsewhere now — a newer offset, or a fresh
                    # record in a DIFFERENT log (a post-promotion overwrite
                    # of an adopted key): keep its fresh mapping, and this
                    # stale record can never matter again — prune it.
                    del at_offset[off]
                    continue
                keys.append(key)
                del at_offset[off]
            return keys

        def response_header(msg: bytes, op: ReadOp, err: int) -> bytes:
            req_id = GET_HDR.unpack_from(msg, 0)[1] if msg else 0
            return APP_RESP_HDR.pack(req_id, err,
                                     op.size if err == wire.E_OK else 0)

        def heat_touch(key: bytes) -> None:
            """Bounded per-key heat sketch: halve-and-prune on overflow so
            a long Zipf run keeps only the genuinely hot tail."""
            h = st.heat
            h[key] = h.get(key, 0) + 1
            if len(h) > 128:
                for k, v in list(h.items()):
                    v >>= 1
                    if v:
                        h[k] = v
                    else:
                        del h[k]

        def append_record(req_id: int, key: bytes,
                          rec: bytes, body: bytes) -> tuple:
            loc = KVLocation(st.log_fid, st.log_off, len(rec))
            st.log_off += len(rec)
            st.at_offset[loc.offset] = (key, loc.size)
            st.offsets.append(loc.offset)   # log appends: stays sorted
            return ("w", req_id, loc.file_id, loc.offset, rec, body)

        def host_handler(msg: bytes) -> tuple:
            typ = msg[0] if msg else 0
            if typ == KV_PUT:
                _, req_id, klen, vlen = PUT_HDR.unpack_from(msg, 0)
                # msg may be a zero-copy view: the index key must be real
                # bytes; the record join consumes the value view directly.
                key = bytes(msg[PUT_HDR.size : PUT_HDR.size + klen])
                value = msg[PUT_HDR.size + klen : PUT_HDR.size + klen + vlen]
                rec = b"".join((REC_HDR.pack(klen, vlen), key, value))
                loc = KVLocation(st.log_fid, st.log_off, len(rec))
                st.log_off += len(rec)
                st.index[key] = loc
                st.at_offset[loc.offset] = (key, loc.size)
                st.offsets.append(loc.offset)   # log appends: stays sorted
                st.puts += 1
                heat_touch(key)
                if st.shield is not None:
                    st.shield.add(key)
                # Append to the log; Cache() fires on the write -> next GET
                # for this key is DPU-served.  The ack returns the location.
                return ("w", req_id, loc.file_id, loc.offset, rec, loc.encode())
            if typ == KV_GET:
                _, req_id, klen = GET_HDR.unpack_from(msg, 0)
                key = bytes(msg[GET_HDR.size : GET_HDR.size + klen])
                loc = st.index.get(key)
                st.host_gets += 1
                heat_touch(key)
                if loc is None:
                    return ("resp", req_id, wire.E_NOENT, b"")
                return ("r", req_id, loc.file_id, loc.offset, loc.size)
            if typ == KV_DEL:
                _, req_id, klen = GET_HDR.unpack_from(msg, 0)
                key = bytes(msg[GET_HDR.size : GET_HDR.size + klen])
                heat_touch(key)
                if st.shield is not None:
                    st.shield.add(key)
                if st.index.pop(key, None) is None:
                    return ("resp", req_id, wire.E_NOENT, b"")
                st.dels += 1
                # Tombstone append: the delete rides the same log write /
                # replication / ack-hold path as a PUT, and Cache() drops
                # the DPU mapping when the record lands (a promoted
                # replica's log scan sees the delete too — no
                # resurrection).
                rec = REC_HDR.pack(klen, TOMBSTONE) + key
                return append_record(req_id, key, rec, b"")
            if typ == KV_MPUT:
                # Migration sync from the resharding source.  If this key
                # was directly written here since the shield armed, the
                # sync is STALE (every migration value predates the
                # ownership flip; every direct write postdates it) — ack
                # it without applying.
                _, req_id, klen, vlen = PUT_HDR.unpack_from(msg, 0)
                key = bytes(msg[PUT_HDR.size : PUT_HDR.size + klen])
                if st.shield is not None and key in st.shield:
                    st.mig_skipped += 1
                    return ("resp", req_id, wire.E_OK, b"")
                value = msg[PUT_HDR.size + klen : PUT_HDR.size + klen + vlen]
                rec = b"".join((REC_HDR.pack(klen, vlen), key, value))
                loc = KVLocation(st.log_fid, st.log_off, len(rec))
                st.index[key] = loc
                st.mig_puts += 1
                return append_record(req_id, key, rec, loc.encode())
            if typ == KV_MDEL:
                _, req_id, klen = GET_HDR.unpack_from(msg, 0)
                key = bytes(msg[GET_HDR.size : GET_HDR.size + klen])
                if st.shield is not None and key in st.shield:
                    st.mig_skipped += 1
                    return ("resp", req_id, wire.E_OK, b"")
                if st.index.pop(key, None) is None:
                    return ("resp", req_id, wire.E_NOENT, b"")
                st.mig_dels += 1
                rec = REC_HDR.pack(klen, TOMBSTONE) + key
                return append_record(req_id, key, rec, b"")
            return ("resp", 0, wire.E_INVAL, b"")

        return OffloadAPI(off_pred, off_func, cache=cache,
                          invalidate=invalidate,
                          response_header=response_header,
                          host_handler=host_handler,
                          prepare_read=prepare_read,
                          prepare_read_many=prepare_read_many,
                          # Lifecycle classifier: GETs are reads; PUT/DEL
                          # are writes (mutations) in the latency stats.
                          read_types=frozenset({KV_GET}))

    # -- elastic membership (online resharding) -----------------------------------------
    def add_shard(self) -> int:
        """Grow the cluster by one shard and start a LIVE migration of the
        keys the new ring assigns to it.  Returns the new shard id; the
        migration runs inside the cluster pump (``run_until_idle`` or any
        client traffic drives it) and flips ownership atomically once the
        destination holds every migrating byte."""
        cl = self.cluster
        if cl.resharder is not None:
            raise RuntimeError("a resharding migration is already active")
        new = len(cl.servers)
        # State first: the ``_api_for`` closure binds by index at server
        # construction, so the slot must exist before ``cl.add_shard``.
        self._states.append(_ShardState())
        self._heat_base.append(0)
        try:
            cl.add_shard()
        except Exception:
            self._states.pop()
            self._heat_base.pop()
            raise
        st = self._states[new]
        srv = cl.servers[new]
        st.log_fid = srv.frontend.create_file("kvlog")
        srv.run_until_idle()
        pending = cl.ring.copy()
        pending.add_node(new)
        if cl.replication:
            st.replica_fids = cl.replicate_file(new, st.log_fid, "kvlog",
                                                ring=pending)
        sources = sorted({cl.route_of(n) for n in cl.ring.nodes()}
                         - {new} - cl._dead)
        cl.start_reshard(Resharder(cl, self, pending,
                                   [(s, new) for s in sources],
                                   tag=f"add:{new}"))
        return new

    def remove_shard(self, shard: int) -> None:
        """Drain ``shard`` out of the ring: stream its keys to their new
        owners, then flip.  The server keeps running until the flip (it
        must serve reads and dual-route writes during the migration); it
        is marked retired afterwards."""
        cl = self.cluster
        if cl.resharder is not None:
            raise RuntimeError("a resharding migration is already active")
        if shard not in cl.ring.nodes():
            raise ValueError(f"shard {shard} is not a ring member")
        src = cl.route_of(shard)
        if src in cl._dead:
            raise ValueError(f"shard {shard} has no live server")
        pending = cl.ring.copy()
        pending.remove_node(shard)
        dests = sorted(set(pending.nodes()) - {src} - cl._dead)
        cl.start_reshard(Resharder(cl, self, pending,
                                   [(src, d) for d in dests],
                                   tag=f"remove:{shard}", retire=(shard,)))

    # -- resharding adapter (driven by distributed.resharding.Resharder) ----------------
    def migration_keys(self, shard: int) -> list:
        """Deterministic snapshot of the keys ``shard`` currently owns."""
        return sorted(self._states[shard].index)

    def index_loc(self, shard: int, key: bytes):
        return self._states[shard].index.get(key)

    def read_value(self, shard: int, key: bytes, loc: KVLocation) -> bytes:
        """Read a record's value bytes straight from device memory.

        The front-end's synchronous read helper would eat concurrent host
        completions on a busy shard (and its invalidate-on-read hook
        would evict the source's own DPU entries for streamed keys) — the
        migration driver instead translates through the fs map and reads
        the committed bytes raw.  Safe by construction: the driver only
        reads snapshot-time locations, made durable by a device drain at
        migration setup; every later write carries its bytes through the
        source tap."""
        srv = self.cluster.servers[shard]
        data = b"".join(srv.device.raw_read(phys, n) for phys, n in
                        srv.fs.translate(loc.file_id, loc.offset, loc.size))
        return decode_record(data)[1]

    def parse_migration_record(self, shard: int, file_id: int, offset: int,
                               data) -> tuple | None:
        """Parse a tapped write into ``(key, loc, value)``; None if the
        write is not this shard's KV log (journal, replica copies...).
        Tombstones parse to ``(key, None, None)``."""
        st = self._states[shard]
        if file_id != st.log_fid or len(data) < REC_HDR.size:
            return None
        klen, vlen = REC_HDR.unpack_from(data, 0)
        key = bytes(data[REC_HDR.size : REC_HDR.size + klen])
        if vlen & TOMBSTONE:
            return key, None, None
        total = REC_HDR.size + klen + (vlen & _VLEN_MASK)
        return (key, KVLocation(file_id, offset, total),
                bytes(data[REC_HDR.size + klen : total]))

    @staticmethod
    def encode_migration_put(rrid: int, key: bytes, value: bytes) -> bytes:
        return PUT_HDR.pack(KV_MPUT, rrid, len(key), len(value)) + key + value

    @staticmethod
    def encode_migration_del(rrid: int, key: bytes) -> bytes:
        return GET_HDR.pack(KV_MDEL, rrid, len(key)) + key

    def arm_shield(self, shard: int) -> None:
        self._states[shard].shield = set()

    def disarm_shield(self, shard: int) -> None:
        if shard < len(self._states):
            self._states[shard].shield = None

    def _drop_keys(self, shard: int, keys) -> None:
        st = self._states[shard]
        table = self.cluster.servers[shard].cache_table
        for k in keys:
            st.index.pop(k, None)
            if table is not None:
                table.delete(k)

    def drop_source_keys(self, shard: int, keys) -> None:
        """Post-flip cleanup: the source sheds its copies of migrated
        keys (index + any DPU entries fence-passed traffic re-warmed)."""
        self._drop_keys(shard, keys)

    def drop_dest_keys(self, shard: int, keys) -> None:
        """Abort: the destination sheds the partial copy it streamed."""
        self._drop_keys(shard, keys)

    # -- hot-shard detection -------------------------------------------------------------
    def shard_heat(self) -> list[int]:
        """Per-shard ops since the previous call (PUT+GET+DEL, host and
        DPU paths) — the skew signal ``hot_shards`` thresholds against."""
        out = []
        for i, (st, srv) in enumerate(zip(self._states,
                                          self.cluster.servers)):
            total = (st.puts + st.dels + st.host_gets
                     + srv.offload.stats.completed)
            out.append(total - self._heat_base[i])
            self._heat_base[i] = total
        return out

    def hot_shards(self, factor: float = 2.0,
                   min_ops: int = 64) -> list[int]:
        """Shards whose heat exceeds ``factor``x the live-shard mean (and
        ``min_ops`` absolute) — candidates for an ``add_shard`` rebalance."""
        heat = self.shard_heat()
        cl = self.cluster
        live = [h for i, h in enumerate(heat)
                if i not in cl._dead and i not in cl.retired]
        if not live:
            return []
        mean = sum(live) / len(live)
        floor = max(float(min_ops), factor * mean)
        return [i for i, h in enumerate(heat)
                if h >= floor and i not in cl._dead
                and i not in cl.retired]

    # -- observability -----------------------------------------------------------------
    def dpu_served_gets(self) -> int:
        return sum(s.offload.stats.completed for s in self.cluster.servers)

    def host_served_gets(self) -> int:
        return sum(st.host_gets for st in self._states)

    def shard_stats(self) -> list[dict]:
        """Per-shard stats, including the DPU cache table's counters.

        ``cache`` surfaces :class:`~repro.core.cache_table.CacheTableStats`
        (lookups/hits on the director's predicate path, inserts from
        cache-on-write, deletes from invalidate-on-read, cuckoo kicks), so
        an operator can see hit rate and insert pressure per shard."""
        out = []
        for st, srv in zip(self._states, self.cluster.servers):
            ent = {"puts": st.puts, "dels": st.dels,
                   "host_gets": st.host_gets,
                   "dpu_gets": srv.offload.stats.completed,
                   "log_bytes": st.log_off,
                   "cache": srv.cache_table.stats.as_dict(),
                   "cache_items": len(srv.cache_table),
                   "latency": srv.lifecycle.summary()}
            if st.adopted_records:
                ent["adopted_records"] = st.adopted_records
                ent["adopted_bytes"] = st.adopted_bytes
            if st.heat:
                top = sorted(st.heat.items(), key=lambda kv: -kv[1])[:4]
                ent["hot_keys"] = [
                    (k.decode("latin1") if isinstance(k, (bytes, bytearray))
                     else str(k), v) for k, v in top]
            if st.mig_puts or st.mig_dels or st.mig_skipped:
                ent["migration"] = {"applied_puts": st.mig_puts,
                                    "applied_dels": st.mig_dels,
                                    "stale_skipped": st.mig_skipped}
            if st.shield is not None:
                ent["migration_shielded"] = len(st.shield)
            if srv.replicator is not None:
                ent["replication"] = srv.replicator.summary()
            ha = srv.host_app
            if ha.dup_suppressed or ha.replayed_acks:
                ent["exactly_once"] = {"dup_suppressed": ha.dup_suppressed,
                                       "replayed_acks": ha.replayed_acks}
            if srv.director.stats.dpu_bypassed:
                ent["dpu_bypassed"] = srv.director.stats.dpu_bypassed
            out.append(ent)
        return out

    def latency_stats(self) -> dict:
        """Cluster-wide measured tick-latency per class (see README)."""
        return self.cluster.latency_stats()


class KVClient:
    """Key-routed client: batches/pipelines PUT/GET/DEL across shards.

    ``tenant`` binds once per client; every shard connection underneath
    carries it, so the servers' QoS layer (fair demux, admission, per-
    tenant stats) attributes all of this client's traffic without any
    per-call tenant argument.  The unified burst surface is
    :meth:`submit` / :meth:`harvest`; ``get_many``/``put_many``/
    ``delete_many`` remain as thin deprecated wrappers.
    """

    def __init__(self, store: ShardedKVStore, ip: str = "10.0.0.9",
                 port: int | None = None, shard_cache: int = 1 << 16,
                 tenant: int = 0, retry_attempts: int = 0,
                 timeout_ticks: int = 0):
        self.store = store
        self.tenant = tenant
        self.net = ClusterClient(store.cluster, ip=ip, port=port,
                                 tenant=tenant,
                                 retry_attempts=retry_attempts,
                                 timeout_ticks=timeout_ticks)
        # Consistent-hash placement is stable WITHIN a ring epoch, so the
        # key->shard mapping is cacheable: repeat traffic skips the blake2b
        # ring walk (bounded to keep pathological key churn from growing
        # without limit).  A failover's epoch bump flushes the cache — the
        # dead shard's keys now route to the promoted replica.
        self._shard_of: dict[bytes, int] = {}
        self._shard_cache = shard_cache
        self._epoch_seen = store.cluster.epoch

    def _shard(self, key: bytes) -> int:
        cl = self.store.cluster
        if cl.epoch != self._epoch_seen:
            self._epoch_seen = cl.epoch
            self._shard_of.clear()
        shard = self._shard_of.get(key)
        if shard is None:
            shard = self.store.shard_for_key(key)
            if len(self._shard_of) >= self._shard_cache:
                self._shard_of.clear()
            self._shard_of[key] = shard
        return shard

    def put(self, key: bytes, value: bytes) -> int:
        return self.net.send_raw(self._shard(key),
                                 lambda rid: encode_put(rid, key, value),
                                 cls="w", key=key)

    def get(self, key: bytes) -> int:
        return self.net.send_raw(self._shard(key),
                                 lambda rid: encode_get(rid, key), key=key)

    def delete(self, key: bytes) -> int:
        return self.net.send_raw(self._shard(key),
                                 lambda rid: encode_del(rid, key),
                                 cls="w", key=key)

    # -- unified burst surface --------------------------------------------------------
    def submit(self, ops: list[tuple]) -> list[int]:
        """Issue a burst of KV operations; one handle (request id) per op,
        in order.  Ops are ``("get", key)``, ``("put", key, value)`` or
        ``("delete", key)`` and mix freely in one batch (one rid-range
        reservation, one flush round).  Harvest with :meth:`harvest`;
        ``get_many``/``put_many``/``delete_many`` are thin deprecated
        wrappers over this."""
        shard = self._shard
        shards = [shard(op[1]) for op in ops]
        cls = [_KV_CLS[op[0]] for op in ops]

        def build(rid: int, i: int) -> bytes:
            op = ops[i]
            kind = op[0]
            if kind == "get":
                return encode_get(rid, op[1])
            if kind == "put":
                return encode_put(rid, op[1], op[2])
            return encode_del(rid, op[1])

        return self.net.issue_many(shards, build, cls=cls,
                                   keys=[op[1] for op in ops])

    def harvest(self, handles=None, block: bool = True,
                max_iters: int = 200_000) -> dict[int, tuple[int, bytes]]:
        """Collect raw ``{handle: (status, body)}`` responses — see
        :meth:`ClusterClient.harvest`.  Shed requests resolve terminally as
        ``(wire.E_SHED, hint)``; typed decoding stays with ``wait_put`` /
        ``wait_value``."""
        return self.net.harvest(handles, block=block, max_iters=max_iters)

    def _send_many(self, keys: list, encode, cls: str = "r") -> list[int]:
        shard = self._shard
        return self.net.issue_many([shard(k) for k in keys],
                                   lambda rid, i: encode(rid, keys[i]),
                                   cls=cls, keys=keys)

    def get_many(self, keys: list) -> list[int]:
        """Deprecated: ``submit([("get", k), ...])``."""
        return self._send_many(keys, encode_get)

    def delete_many(self, keys: list) -> list[int]:
        """Deprecated: ``submit([("delete", k), ...])``."""
        return self._send_many(keys, encode_del, cls="w")

    def put_many(self, items: list) -> list[int]:
        """Deprecated: ``submit([("put", k, v), ...])``."""
        shard = self._shard
        return self.net.issue_many(
            [shard(k) for k, _ in items],
            lambda rid, i: encode_put(rid, items[i][0], items[i][1]),
            cls="w", keys=[k for k, _ in items])

    # -- scheduling + typed waits -----------------------------------------------------
    @property
    def latency(self):
        """End-to-end read/write tick latency (issue -> drain).  The
        DPU-vs-host split for GETs lives in ``store.latency_stats()``,
        where it is exact."""
        return self.net.latency

    def flush(self) -> int:
        return self.net.flush()

    def pump(self) -> int:
        return self.net.pump()

    def run_until_idle(self) -> None:
        self.net.run_until_idle()

    def wait_put(self, rid: int) -> KVLocation:
        status, body = self.net.wait(rid)
        if status != wire.E_OK:
            raise IOError(f"PUT failed with status {status}")
        return KVLocation.decode(body)

    def wait_value(self, rid: int) -> bytes | None:
        status, body = self.net.wait(rid)
        if status == wire.E_NOENT:
            return None
        if status != wire.E_OK:
            raise IOError(f"GET failed with status {status}")
        return decode_record(body)[1]
