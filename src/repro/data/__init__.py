"""Data pipeline substrate."""
