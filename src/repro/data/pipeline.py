"""Training data pipeline with DDS-ring prefetch.

Deterministic synthetic token streams (seeded counter-based PCG) stand in for
a tokenized corpus — fully reproducible across restarts and elastic reshapes:
batch ``step`` for data-parallel rank ``r`` is a pure function of
``(seed, step, r)``, so a restarted or re-scaled job never replays or skips
examples.

``RingPrefetcher`` stages serialized batches through a DDS progressive ring
(§4.1) — the same lock-free MPSC discipline the storage path uses — so the
host training thread never blocks on the loader: it polls the ring
(non-blocking PollWait semantics) while the producer thread stays ahead.
"""

from __future__ import annotations

import struct
import threading
from dataclasses import dataclass

import numpy as np

from repro.core.ring import DMAEngine, ProgressiveRing, frame, unframe_batch


@dataclass
class BatchSpec:
    global_batch: int
    seq_len: int
    vocab_size: int


class TokenPipeline:
    """Deterministic sharded token stream.

    ``structured=True`` produces learnable sequences (noisy affine
    next-token process) so training demos show real loss descent; the
    default uniform stream has an irreducible loss floor of ln(vocab).
    """

    def __init__(self, spec: BatchSpec, seed: int = 0,
                 rank: int = 0, world: int = 1, structured: bool = False,
                 noise: float = 0.05):
        if spec.global_batch % world != 0:
            raise ValueError("global batch must divide by world size")
        self.spec = spec
        self.seed = seed
        self.rank = rank
        self.world = world
        self.structured = structured
        self.noise = noise
        self.local_batch = spec.global_batch // world

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        """Pure function of (seed, step, rank): elastic-restart safe."""
        s = self.spec
        rng = np.random.Generator(np.random.PCG64(
            (self.seed * 1_000_003 + step) * 65_537 + self.rank))
        if not self.structured:
            tokens = rng.integers(0, s.vocab_size,
                                  size=(self.local_batch, s.seq_len + 1),
                                  dtype=np.int32)
            return {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}
        # Learnable process: each sequence repeats a random motif (copy task
        # — induction heads pick this up within tens of steps), plus noise.
        B, S, V = self.local_batch, s.seq_len + 1, s.vocab_size
        m = int(rng.choice([8, 16, 32]))
        motifs = rng.integers(0, V, size=(B, m))
        reps = -(-S // m)
        toks = np.tile(motifs, (1, reps))[:, :S]
        flip = rng.random((B, S)) < self.noise
        toks[flip] = rng.integers(0, V, size=int(flip.sum()))
        toks = toks.astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


_PF_HDR = struct.Struct("<QII")  # step, batch, seq


class RingPrefetcher:
    """Producer thread serializes batches into a progressive ring."""

    def __init__(self, pipeline: TokenPipeline, depth: int = 4):
        self.pipeline = pipeline
        s = pipeline.spec
        per_batch = (_PF_HDR.size + 4
                     + 2 * pipeline.local_batch * s.seq_len * 4 + 64)
        cap = 1 << max(12, (depth * per_batch).bit_length())
        self.ring = ProgressiveRing(cap, max_progress=cap // 2,
                                    name="data-prefetch")
        self.dma = DMAEngine()
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None
        self._produced = 0
        self._carry = b""

    def _serialize(self, step: int, batch: dict[str, np.ndarray]) -> bytes:
        t, l = batch["tokens"], batch["labels"]
        hdr = _PF_HDR.pack(step, t.shape[0], t.shape[1])
        return hdr + t.tobytes() + l.tobytes()

    @staticmethod
    def deserialize(raw: bytes) -> tuple[int, dict[str, np.ndarray]]:
        step, b, s = _PF_HDR.unpack_from(raw, 0)
        n = b * s * 4
        off = _PF_HDR.size
        tokens = np.frombuffer(raw, np.int32, b * s, off).reshape(b, s)
        labels = np.frombuffer(raw, np.int32, b * s, off + n).reshape(b, s)
        return step, {"tokens": tokens, "labels": labels}

    def start(self) -> None:
        self._stop.clear()
        self._thread = threading.Thread(target=self._produce, daemon=True,
                                        name="data-prefetch")
        self._thread.start()

    def stop(self) -> None:
        self._stop.set()
        if self._thread:
            self._thread.join(timeout=5)
            self._thread = None

    def _produce(self) -> None:
        step = 0
        while not self._stop.is_set():
            msg = frame(self._serialize(step, self.pipeline.batch_at(step)))
            while not self._stop.is_set():
                if self.ring.try_insert(msg) == "OK":
                    step += 1
                    self._produced += 1
                    break
                self._stop.wait(1e-4)  # ring full: training is behind

    def produce_one(self, step: int) -> bool:
        """Cooperative (threadless) production for deterministic tests."""
        msg = frame(self._serialize(step, self.pipeline.batch_at(step)))
        return self.ring.try_insert(msg) == "OK"

    def next_batch(self, spin: int = 2_000_000) -> tuple[int, dict[str, np.ndarray]]:
        """Non-blocking poll loop over the ring consumer side."""
        for _ in range(spin):
            msgs = unframe_batch(self._carry) if self._carry else []
            if msgs:
                first, rest = msgs[0], msgs[1:]
                # unframe_batch returns views over _carry; materialize the
                # re-framed remainder before _carry is rebound.
                self._carry = b"".join(
                    struct.pack("<I", len(m)) + bytes(m) for m in rest)
                return self.deserialize(first)
            got = self.ring.consume(self.dma)
            if got is not None:
                self._carry = got
        raise TimeoutError("prefetch ring starved")
