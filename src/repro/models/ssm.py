"""Attention-free sequence mixers: Mamba2 (SSD) and RWKV6 ("Finch").

Both reduce to the gated-linear-attention recurrence executed by
``repro.kernels.ssm_scan`` (chunked matmul form for train/prefill, O(1)
recurrent state for decode):

    S_t = diag(exp(w_t)) S_{t-1} + k_t (x) v_t ;   o_t = q_t^T S_t

* **Mamba2**: per-head scalar decay  w_t = -softplus(dt_t) * exp(A_h),
  k = B-projection, v = dt * x, q = C-projection, plus the depthwise
  short conv on the input and a gated output (SiLU(z) * y) with RMS norm.
* **RWKV6**: per-key-dim data-dependent decay w_t from a low-rank MLP,
  token-shift mixing on the inputs, receptance r as q, and a gated output.

Decode carries (conv tail, GLA state) — constant memory in sequence length,
which is why the rwkv6/zamba2 archs run the 500k-context shape.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.ssm_scan import gla_scan
from repro.kernels.ssm_scan.ref import gla_decode_step
from repro.distributed.sharding import gather_fsdp
from repro.models.layers import ParamFactory, rms_norm

CONV_K = 4  # mamba short-conv width


# ---------------------------------------------------------------------------
# Mamba2 block.
# ---------------------------------------------------------------------------


def init_mamba2(key, d_model: int, state: int, num_heads: int,
                head_dim: int | None = None, expand: int = 2,
                dtype=jnp.bfloat16):
    """d_inner = expand*d_model split into num_heads of head_dim."""
    d_inner = expand * d_model
    head_dim = head_dim or d_inner // num_heads
    assert num_heads * head_dim == d_inner
    p = ParamFactory(key, dtype)
    p.dense("in_xz", (d_model, 2 * d_inner), ("embed", "heads"))
    p.dense("in_bc", (d_model, 2 * state * num_heads), ("embed", "heads"))
    p.dense("in_dt", (d_model, num_heads), ("embed", "heads"))
    p.zeros("conv", (CONV_K, d_inner), (None, "heads"))
    p.zeros("A_log", (num_heads,), ("heads",), dtype=jnp.float32)
    p.zeros("D", (num_heads,), ("heads",), dtype=jnp.float32)
    # dt ~ softplus(x@W + bias) ~ 0.01: slow default decay (mamba2 init
    # range dt in [1e-3, 1e-1]); keeps chunk-cumulative log-decay bounded.
    p.const("dt_bias", (num_heads,), ("heads",), -4.6, dtype=jnp.float32)
    p.zeros("norm_w", (d_inner,), ("heads",))
    p.dense("out", (d_inner, d_model), ("heads", "embed"))
    return p.params, p.axes


def _short_conv(x, w, tail=None):
    """Depthwise causal conv along S.  x: (B,S,C); w: (K,C).

    ``tail`` (B, K-1, C) carries the last K-1 inputs for decode; returns
    (out, new_tail).
    """
    B, S, C = x.shape
    if tail is None:
        tail = jnp.zeros((B, CONV_K - 1, C), x.dtype)
    xp = jnp.concatenate([tail, x], axis=1)          # (B, S+K-1, C)
    out = jnp.zeros_like(x)
    for i in range(CONV_K):
        out = out + xp[:, i : i + S] * w[i][None, None]
    new_tail = xp[:, -(CONV_K - 1):]
    return out, new_tail


def mamba2_fwd(params, x, *, state: int, num_heads: int, chunk: int = 128,
               carry=None, decode: bool = False):
    """x: (B, S, D).  carry = (conv_tail, gla_state) for decode continuity."""
    B, S, D = x.shape
    H = num_heads
    d_inner = params["in_xz"].shape[1] // 2
    hd = d_inner // H
    xz = x @ gather_fsdp(params["in_xz"], tp_dim=1)
    xs, z = jnp.split(xz, 2, axis=-1)
    conv_tail = carry[0] if carry is not None else None
    xs, new_tail = _short_conv(xs, params["conv"], conv_tail)
    xs = jax.nn.silu(xs)
    bc = x @ gather_fsdp(params["in_bc"], tp_dim=1)
    bmat, cmat = jnp.split(bc, 2, axis=-1)            # (B,S,H*state)
    dt = jax.nn.softplus((x @ params["in_dt"]).astype(jnp.float32)
                         + params["dt_bias"])          # (B,S,H)
    A = -jnp.exp(params["A_log"])                      # (H,) negative
    w = (dt * A[None, None]).astype(jnp.float32)       # (B,S,H) log-decay <= 0

    # GLA form: per head, K=state, V=head_dim.
    q = cmat.reshape(B, S, H, state).transpose(0, 2, 1, 3)
    k = bmat.reshape(B, S, H, state).transpose(0, 2, 1, 3)
    v = (xs.reshape(B, S, H, hd) * dt[..., None].astype(xs.dtype)
         ).transpose(0, 2, 1, 3)
    wk = jnp.broadcast_to(w.transpose(0, 2, 1)[..., None], k.shape)

    gla_state = carry[1] if carry is not None else None
    if decode and S == 1:
        if gla_state is None:
            gla_state = jnp.zeros((B, H, state, hd), jnp.float32)
        o, new_state = gla_decode_step(q[:, :, 0], k[:, :, 0], v[:, :, 0],
                                       wk[:, :, 0], gla_state)
        o = o[:, :, None]                              # (B,H,1,hd)
    else:
        o, new_state = gla_scan(q, k, v, wk, chunk=chunk)
    y = o.transpose(0, 2, 1, 3).reshape(B, S, d_inner)
    y = y + xs * jnp.repeat(params["D"], hd)[None, None].astype(xs.dtype)
    y = rms_norm(y, params["norm_w"]) * jax.nn.silu(z)
    return y @ gather_fsdp(params["out"], tp_dim=0), (new_tail, new_state)


# ---------------------------------------------------------------------------
# RWKV6 block (time mixing; channel mixing is a gated MLP in the stack).
# ---------------------------------------------------------------------------


def init_rwkv6(key, d_model: int, num_heads: int, decay_rank: int = 64,
               dtype=jnp.bfloat16):
    hd = d_model // num_heads
    p = ParamFactory(key, dtype)
    for n in ("r", "k", "v", "g"):
        p.dense(f"w_{n}", (d_model, d_model), ("embed", "heads"))
    # token-shift mix coefficients (one per stream)
    p.zeros("mix", (5, d_model), (None, "embed"))
    # data-dependent decay: low-rank MLP  d_model -> rank -> d_model
    p.dense("wd_a", (d_model, decay_rank), ("embed", None))
    p.dense("wd_b", (decay_rank, d_model), (None, "heads"))
    # w = -exp(decay_base + dd): base -5 => per-token log-decay ~ -0.007,
    # matching RWKV6's slow-decay init and bounding chunk exponents.
    p.const("decay_base", (d_model,), ("heads",), -5.0, dtype=jnp.float32)
    p.zeros("ln_w", (d_model,), ("heads",))
    p.dense("out", (d_model, d_model), ("heads", "embed"))
    return p.params, p.axes


def rwkv6_fwd(params, x, *, num_heads: int, chunk: int = 128,
              carry=None, decode: bool = False):
    """x: (B, S, D).  carry = (prev_token, gla_state)."""
    B, S, D = x.shape
    H = num_heads
    hd = D // H
    prev = carry[0] if carry is not None else jnp.zeros((B, 1, D), x.dtype)
    shifted = jnp.concatenate([prev, x[:, :-1]], axis=1)

    def mixed(i):
        m = params["mix"][i][None, None]
        return x + (shifted - x) * m

    r = mixed(0) @ gather_fsdp(params["w_r"], tp_dim=1)
    kk = mixed(1) @ gather_fsdp(params["w_k"], tp_dim=1)
    vv = mixed(2) @ gather_fsdp(params["w_v"], tp_dim=1)
    g = mixed(3) @ gather_fsdp(params["w_g"], tp_dim=1)
    # data-dependent per-channel log decay (Finch):
    dd = jnp.tanh(mixed(4) @ params["wd_a"]) @ params["wd_b"]
    w = -jnp.exp(params["decay_base"] + dd.astype(jnp.float32))  # (B,S,D) < 0

    q = r.reshape(B, S, H, hd).transpose(0, 2, 1, 3)
    k = kk.reshape(B, S, H, hd).transpose(0, 2, 1, 3)
    v = vv.reshape(B, S, H, hd).transpose(0, 2, 1, 3)
    wk = w.reshape(B, S, H, hd).transpose(0, 2, 1, 3)

    gla_state = carry[1] if carry is not None else None
    if decode and S == 1:
        if gla_state is None:
            gla_state = jnp.zeros((B, H, hd, hd), jnp.float32)
        o, new_state = gla_decode_step(q[:, :, 0], k[:, :, 0], v[:, :, 0],
                                       wk[:, :, 0], gla_state)
        o = o[:, :, None]
    else:
        o, new_state = gla_scan(q, k, v, wk, chunk=chunk)
    y = o.transpose(0, 2, 1, 3).reshape(B, S, D)
    y = rms_norm(y, params["ln_w"]) * jax.nn.silu(g)
    new_prev = x[:, -1:]
    return y @ gather_fsdp(params["out"], tp_dim=0), (new_prev, new_state)
