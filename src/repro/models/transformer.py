"""Decoder-only LM covering the dense, MoE, and VLM families.

Layer stacks are SCANNED (params stacked on a leading "layers" axis) so
compile time is O(1) in depth — essential for the 40-cell dry-run of 80-layer
models.  The gemma3-style local:global pattern uses a *grouped* scan: each
group holds (group_size - 1) sliding-window layers plus one global layer, so
decode caches are heterogeneous — window-sized rings for local layers, full
length for global layers — which is what makes the 500k-context shape fit.

Entry points (all pure, pjit-able):
  init_lm(cfg, key)                      -> (params, logical-axes tree)
  lm_forward(params, cfg, tokens, ...)   -> logits          (train)
  lm_init_cache(cfg, batch, cache_len)   -> cache pytree    (ShapeDtypeStruct-safe)
  lm_prefill(params, cfg, tokens, ...)   -> (logits, cache)
  lm_decode_step(params, cfg, cache, kv_len, token) -> (logits, cache)
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain_batch, constrain_logits
from repro.models import layers as L
from repro.models.moe import init_moe, moe_fwd

# ---------------------------------------------------------------------------
# Block init.
# ---------------------------------------------------------------------------


def _attn_cfg(cfg: ModelConfig, *, window=None, theta=None) -> L.AttnConfig:
    return L.AttnConfig(
        d_model=cfg.d_model, num_heads=cfg.num_heads,
        num_kv_heads=cfg.num_kv_heads, head_dim=cfg.hd,
        qkv_bias=cfg.qkv_bias, rope_theta=theta or cfg.rope_theta,
        mrope=cfg.mrope, causal=True, window=window)


def init_block(cfg: ModelConfig, key) -> tuple[dict, dict]:
    """One decoder block: norm -> attn -> norm -> mlp/moe."""
    p = L.ParamFactory(key)
    ap, aa = L.init_attention(p._split(), _attn_cfg(cfg))
    p.params["attn"], p.axes["attn"] = ap, aa
    if cfg.norm == "rms":
        p.zeros("norm1", (cfg.d_model,), ("embed",))
        p.zeros("norm2", (cfg.d_model,), ("embed",))
    else:
        p.ones("norm1_w", (cfg.d_model,), ("embed",))
        p.zeros("norm1_b", (cfg.d_model,), ("embed",))
        p.ones("norm2_w", (cfg.d_model,), ("embed",))
        p.zeros("norm2_b", (cfg.d_model,), ("embed",))
    if cfg.family == "moe":
        mp, ma = init_moe(p._split(), cfg.d_model, cfg.d_ff, cfg.num_experts,
                          cfg.top_k, cfg.mlp)
        p.params["moe"], p.axes["moe"] = mp, ma
    else:
        mp, ma = L.init_mlp(p._split(), cfg.d_model, cfg.d_ff, cfg.mlp)
        p.params["mlp"], p.axes["mlp"] = mp, ma
    return p.params, p.axes


def _norm1(params, cfg, x):
    if cfg.norm == "rms":
        return L.rms_norm(x, params["norm1"])
    return L.layer_norm(x, params["norm1_w"], params["norm1_b"])


def _norm2(params, cfg, x):
    if cfg.norm == "rms":
        return L.rms_norm(x, params["norm2"])
    return L.layer_norm(x, params["norm2_w"], params["norm2_b"])


def _mix(params, cfg, h):
    if cfg.family == "moe":
        return moe_fwd(params["moe"], h, num_experts=cfg.num_experts,
                       top_k=cfg.top_k, kind=cfg.mlp,
                       capacity_factor=cfg.capacity_factor)
    return L.mlp_fwd(params["mlp"], h, cfg.mlp), {"aux_loss": jnp.zeros((), jnp.float32)}


def block_fwd(params, x, cfg: ModelConfig, positions, *,
              window=None, theta=None):
    """Full-sequence block.  Returns (x, (k, v), aux_loss)."""
    x = constrain_batch(x)  # keep activations batch-sharded (DP/FSDP)
    acfg = _attn_cfg(cfg, window=window, theta=theta)
    a, kv = L.attention_fwd(params["attn"], _norm1(params, cfg, x), acfg,
                            positions)
    x = x + a
    m, aux = _mix(params, cfg, _norm2(params, cfg, x))
    return x + m, kv, aux["aux_loss"]


def block_decode(params, x, cfg: ModelConfig, k_cache, v_cache, kv_len,
                 positions, *, window=None, theta=None):
    acfg = _attn_cfg(cfg, window=window, theta=theta)
    a, k_cache, v_cache = L.attention_decode(
        params["attn"], _norm1(params, cfg, x), acfg, k_cache, v_cache,
        kv_len, positions)
    x = x + a
    m, _ = _mix(params, cfg, _norm2(params, cfg, x))
    return x + m, k_cache, v_cache


# ---------------------------------------------------------------------------
# Model init.
# ---------------------------------------------------------------------------


def init_lm(cfg: ModelConfig, key) -> tuple[dict, dict]:
    keys = jax.random.split(key, 4)
    params: dict[str, Any] = {}
    axes: dict[str, Any] = {}
    ep, ea = L.init_embedding(keys[0], cfg.padded_vocab, cfg.d_model,
                              cfg.tie_embeddings)
    params["embedding"], axes["embedding"] = ep, ea
    if cfg.attention == "local_global":
        gsz = cfg.group_size
        n_groups = cfg.num_layers // gsz
        tail = cfg.num_layers - n_groups * gsz

        def init_local(k):
            return init_block(cfg, k)

        def init_group(k):
            k1, k2 = jax.random.split(k)
            lp, la = L.stack_layer_params(init_local, k1, gsz - 1)
            gp, ga = init_block(cfg, k2)
            return {"local": lp, "global": gp}, {"local": la, "global": ga}

        gp, ga = L.stack_layer_params(init_group, keys[1], n_groups)
        params["groups"], axes["groups"] = gp, ga
        if tail:
            tp, ta = L.stack_layer_params(init_local, keys[2], tail)
            params["tail"], axes["tail"] = tp, ta
    else:
        bp, ba = L.stack_layer_params(lambda k: init_block(cfg, k),
                                      keys[1], cfg.num_layers)
        params["blocks"], axes["blocks"] = bp, ba
    params["final_norm"] = jnp.zeros((cfg.d_model,), jnp.bfloat16)
    axes["final_norm"] = ("embed",)
    return params, axes


def _final(params, cfg, x):
    x = constrain_batch(x)
    x = L.rms_norm(x, params["final_norm"])
    return constrain_logits(L.unembed_fwd(params["embedding"], x))


def _positions(cfg: ModelConfig, B: int, S: int, offset=0):
    pos = jnp.arange(S)[None] + offset
    pos = jnp.broadcast_to(pos, (B, S))
    if cfg.mrope:
        return jnp.broadcast_to(pos[..., None], (B, S, 3))
    return pos


# ---------------------------------------------------------------------------
# Training forward.
# ---------------------------------------------------------------------------


def lm_forward(params, cfg: ModelConfig, tokens, embeds=None,
               remat: bool = True):
    """tokens: (B, S) int32.  ``embeds``: optional (B, V, d_model) prefix
    embeddings (VLM patch / audio frame stub) overriding the first V slots.
    Returns (logits, aux_loss)."""
    B, S = tokens.shape
    x = L.embed_fwd(params["embedding"], tokens)
    if embeds is not None:
        V = embeds.shape[1]
        x = jnp.concatenate([embeds.astype(x.dtype), x[:, V:]], axis=1)
    pos = _positions(cfg, B, S)

    if cfg.attention == "local_global":
        x, aux = _forward_local_global(params, cfg, x, pos, remat)
    else:
        def body(carry, blk):
            x, aux = carry
            x, _, a = block_fwd(blk, x, cfg, pos)
            return (x, aux + a), None

        if remat:
            body = L.maybe_remat(body, cfg.remat)
        (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                   params["blocks"])
    return _final(params, cfg, x), aux


def _forward_local_global(params, cfg, x, pos, remat):
    def group_body(carry, grp):
        x, aux = carry

        def local_body(c, blk):
            xx, aa = c
            xx, _, a = block_fwd(blk, xx, cfg, pos, window=cfg.window,
                                 theta=cfg.rope_theta)
            return (xx, aa + a), None

        (x, aux), _ = jax.lax.scan(local_body, (x, aux), grp["local"])
        x, _, a = block_fwd(grp["global"], x, cfg, pos,
                            theta=cfg.rope_theta_global)
        return (x, aux + a), None

    if remat:
        group_body = L.maybe_remat(group_body, cfg.remat)
    (x, aux), _ = jax.lax.scan(group_body, (x, jnp.zeros((), jnp.float32)),
                               params["groups"])
    if "tail" in params:
        def tail_body(c, blk):
            xx, aa = c
            xx, _, a = block_fwd(blk, xx, cfg, pos, window=cfg.window)
            return (xx, aa + a), None

        if remat:
            tail_body = L.maybe_remat(tail_body, cfg.remat)
        (x, aux), _ = jax.lax.scan(tail_body, (x, aux), params["tail"])
    return x, aux


# ---------------------------------------------------------------------------
# KV cache: init / prefill / decode.
# ---------------------------------------------------------------------------


def lm_init_cache(cfg: ModelConfig, batch: int, cache_len: int,
                  dtype=jnp.bfloat16):
    KV, hd = cfg.num_kv_heads, cfg.hd
    if cfg.attention == "local_global":
        gsz = cfg.group_size
        n_groups = cfg.num_layers // gsz
        tail = cfg.num_layers - n_groups * gsz
        W = min(cfg.window, cache_len)
        cache = {
            "local_k": jnp.zeros((n_groups, gsz - 1, batch, W, KV, hd), dtype),
            "local_v": jnp.zeros((n_groups, gsz - 1, batch, W, KV, hd), dtype),
            "global_k": jnp.zeros((n_groups, batch, cache_len, KV, hd), dtype),
            "global_v": jnp.zeros((n_groups, batch, cache_len, KV, hd), dtype),
        }
        if tail:
            cache["tail_k"] = jnp.zeros((tail, batch, W, KV, hd), dtype)
            cache["tail_v"] = jnp.zeros((tail, batch, W, KV, hd), dtype)
        return cache
    Lr = cfg.num_layers
    return {"k": jnp.zeros((Lr, batch, cache_len, KV, hd), dtype),
            "v": jnp.zeros((Lr, batch, cache_len, KV, hd), dtype)}


def lm_decode_step(params, cfg: ModelConfig, cache: dict, kv_len, token,
                   embeds=None):
    """token: (B, 1) int32; kv_len: existing valid cache entries.
    Returns (logits (B, vocab), new cache)."""
    B = token.shape[0]
    x = L.embed_fwd(params["embedding"], token)
    pos = _positions(cfg, B, 1, offset=kv_len)

    if cfg.attention == "local_global":
        x, cache = _decode_local_global(params, cfg, x, cache, kv_len, pos)
    else:
        def body(x, blk_cache):
            blk, kc, vc = blk_cache
            x, kc, vc = block_decode(blk, x, cfg, kc, vc, kv_len, pos)
            return x, (kc, vc)

        x, (k_new, v_new) = jax.lax.scan(
            body, x, (params["blocks"], cache["k"], cache["v"]))
        cache = {"k": k_new, "v": v_new}
    return _final(params, cfg, x)[:, 0], cache


def _decode_local_global(params, cfg, x, cache, kv_len, pos):
    def group_body(x, xs):
        grp, lk, lv, gk, gv = xs

        def local_body(x, xs2):
            blk, kc, vc = xs2
            x, kc, vc = block_decode(blk, x, cfg, kc, vc, kv_len, pos,
                                     window=cfg.window, theta=cfg.rope_theta)
            return x, (kc, vc)

        x, (lk, lv) = jax.lax.scan(local_body, x, (grp["local"], lk, lv))
        x, gk, gv = block_decode(grp["global"], x, cfg, gk, gv, kv_len, pos,
                                 theta=cfg.rope_theta_global)
        return x, (lk, lv, gk, gv)

    x, (lk, lv, gk, gv) = jax.lax.scan(
        group_body, x, (params["groups"], cache["local_k"], cache["local_v"],
                        cache["global_k"], cache["global_v"]))
    new = dict(cache, local_k=lk, local_v=lv, global_k=gk, global_v=gv)
    if "tail" in params:
        def tail_body(x, xs2):
            blk, kc, vc = xs2
            x, kc, vc = block_decode(blk, x, cfg, kc, vc, kv_len, pos,
                                     window=cfg.window)
            return x, (kc, vc)

        x, (tk, tv) = jax.lax.scan(tail_body, x,
                                   (params["tail"], cache["tail_k"],
                                    cache["tail_v"]))
        new["tail_k"], new["tail_v"] = tk, tv
    return x, new


def lm_prefill(params, cfg: ModelConfig, tokens, cache_len: int | None = None,
               embeds=None):
    """Run the full prompt, returning (last-token logits, filled cache).

    The cache is filled by re-running attention projections per layer inside
    the same scan that computes the forward pass (kv returned by each block).
    """
    B, S = tokens.shape
    cache_len = cache_len or S
    x = L.embed_fwd(params["embedding"], tokens)
    if embeds is not None:
        V = embeds.shape[1]
        x = jnp.concatenate([embeds.astype(x.dtype), x[:, V:]], axis=1)
    pos = _positions(cfg, B, S)

    if cfg.attention == "local_global":
        return _prefill_local_global(params, cfg, x, pos, cache_len)

    def body(x, blk):
        x, (k, v), _ = block_fwd(blk, x, cfg, pos)
        return x, (k, v)

    x, (ks, vs) = jax.lax.scan(body, x, params["blocks"])
    pad = cache_len - S
    if pad > 0:
        zf = lambda a: jnp.pad(a, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        ks, vs = zf(ks), zf(vs)
    logits = _final(params, cfg, x[:, -1:])[:, 0]
    return logits, {"k": ks, "v": vs}


def _prefill_local_global(params, cfg, x, pos, cache_len):
    W = min(cfg.window, cache_len)
    S_in = x.shape[1]

    def ring(a):
        """Store position p at ring index p %% W (decode slot convention)."""
        if S_in <= W:  # positions 0..S_in-1 land at indices 0..S_in-1
            return jnp.pad(a, ((0, 0), (0, W - S_in), (0, 0), (0, 0)))
        return jnp.roll(a[:, -W:], S_in % W, axis=1)

    def group_body(x, grp):
        def local_body(x, blk):
            x, (k, v), _ = block_fwd(blk, x, cfg, pos, window=cfg.window,
                                     theta=cfg.rope_theta)
            return x, (ring(k), ring(v))

        x, (lk, lv) = jax.lax.scan(local_body, x, grp["local"])
        x, (gk, gv), _ = block_fwd(grp["global"], x, cfg, pos,
                                   theta=cfg.rope_theta_global)
        return x, (lk, lv, gk, gv)

    x, (lk, lv, gk, gv) = jax.lax.scan(group_body, x, params["groups"])
    S = x.shape[1]
    pad = cache_len - S
    if pad > 0:
        zf = lambda a: jnp.pad(a, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        gk, gv = zf(gk), zf(gv)
    cache = {"local_k": lk, "local_v": lv, "global_k": gk, "global_v": gv}
    if "tail" in params:
        def tail_body(x, blk):
            x, (k, v), _ = block_fwd(blk, x, cfg, pos, window=cfg.window)
            return x, (ring(k), ring(v))

        x, (tk, tv) = jax.lax.scan(tail_body, x, params["tail"])
        cache["tail_k"], cache["tail_v"] = tk, tv
    logits = _final(params, cfg, x[:, -1:])[:, 0]
    return logits, cache


# ---------------------------------------------------------------------------
# Paged decode (DDS-style block-table serving for dense/MoE/VLM archs).
# ---------------------------------------------------------------------------


def lm_init_paged_cache(cfg: ModelConfig, batch: int, max_len: int,
                        page: int = 128, dtype=jnp.bfloat16):
    """Paged KV pool + block table per layer (the DDS file-mapping analogue:
    logical (sequence, position) -> physical pool page).

    Pool pages are allocated contiguously per sequence up front; a serving
    engine integrates `PagedKVEngine` to spill/fetch cold pages through the
    DDS store, remapping table entries as pages move.
    """
    if cfg.attention == "local_global":
        raise NotImplementedError("paged decode targets uniform-cache archs")
    KV, hd, Lr = cfg.num_kv_heads, cfg.hd, cfg.num_layers
    pages_per_seq = -(-max_len // page)
    npages = batch * pages_per_seq
    table = (jnp.arange(batch * pages_per_seq, dtype=jnp.int32)
             .reshape(batch, pages_per_seq))
    return {
        "k_pool": jnp.zeros((Lr, npages, page, KV, hd), dtype),
        "v_pool": jnp.zeros((Lr, npages, page, KV, hd), dtype),
        "block_table": table,            # shared across layers here
        "page": page,
    }


def lm_decode_step_paged(params, cfg: ModelConfig, cache: dict, kv_len,
                         token):
    """One-token decode over the paged pool via the paged-attention op.

    kv_len: number of existing valid positions (uniform across the batch in
    this entry point; the batch scheduler handles ragged lengths by passing
    per-sequence seq_lens to the kernel)."""
    from repro.kernels.paged_attention import paged_attention
    B = token.shape[0]
    page = cache["page"]
    table = cache["block_table"]
    x = L.embed_fwd(params["embedding"], token)
    pos = _positions(cfg, B, 1, offset=kv_len)
    acfg = _attn_cfg(cfg)
    slot_page = kv_len // page
    slot_off = kv_len % page
    phys = table[:, slot_page]                        # (B,) physical pages

    def body(x, xs):
        blk, k_pool, v_pool = xs
        h = _norm1(blk, cfg, x)
        q, k_new, v_new = L._qkv(blk["attn"], h, acfg, pos)
        # Write the new token's K/V into its page (translate-then-write).
        k_pool = k_pool.at[phys, slot_off].set(
            k_new[:, 0].astype(k_pool.dtype))
        v_pool = v_pool.at[phys, slot_off].set(
            v_new[:, 0].astype(v_pool.dtype))
        seq_lens = jnp.full((B,), kv_len + 1, jnp.int32)
        o = paged_attention(q[:, 0], k_pool, v_pool, table, seq_lens)
        o = o.reshape(B, 1, cfg.num_heads * cfg.hd)
        x = x + o @ blk["attn"]["wo"]
        m, _ = _mix(blk, cfg, _norm2(blk, cfg, x))
        return x + m, (k_pool, v_pool)

    x, (k_pool, v_pool) = jax.lax.scan(
        body, x, (params["blocks"], cache["k_pool"], cache["v_pool"]))
    new_cache = dict(cache, k_pool=k_pool, v_pool=v_pool)
    return _final(params, cfg, x)[:, 0], new_cache
