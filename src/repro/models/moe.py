"""Mixture-of-Experts layer (granite-moe, dbrx) with sort-based dispatch.

Top-k routing with capacity: token->expert assignments are argsorted by
expert id, scattered into per-expert buffers of capacity
``C = ceil(T * top_k / E * capacity_factor)``, run through batched expert
FFNs — einsum over the (experts, capacity, d) buffer so the expert dim can
be sharded over the model axis (expert parallelism) — and gathered back with
router-probability weighting.  Tokens beyond an expert's capacity are
dropped (standard capacity-based MoE; the auxiliary load-balance loss keeps
drops rare).

This avoids the (tokens, E, C) one-hot dispatch tensor, whose memory is
infeasible at 32k-sequence scale; memory here is O(E * C * d) = the expert
buffers themselves.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain_batch, gather_fsdp
from repro.models.layers import ParamFactory


def init_moe(key, d_model: int, d_ff: int, num_experts: int, top_k: int,
             kind: str = "swiglu", dtype=jnp.bfloat16):
    p = ParamFactory(key, dtype)
    E = num_experts
    p.dense("router", (d_model, E), ("embed", None), scale=0.02)
    if kind in ("swiglu", "geglu"):
        p.dense("wi_gate", (E, d_model, d_ff), ("experts", "embed", "ff"))
        p.dense("wi_up", (E, d_model, d_ff), ("experts", "embed", "ff"))
    else:
        p.dense("wi_up", (E, d_model, d_ff), ("experts", "embed", "ff"))
    p.dense("wo", (E, d_ff, d_model), ("experts", "ff", "embed"))
    return p.params, p.axes


def moe_fwd(params, x, *, num_experts: int, top_k: int,
            kind: str = "swiglu", capacity_factor: float = 1.25):
    """x: (B, S, D) -> (out, aux) where aux has the load-balancing loss.

    Dispatch is PER BATCH ROW (vmapped over B): sort, position-in-expert,
    scatter and gather all act within one row, so with the batch dim
    data-sharded every dispatch op partitions locally — no global sort
    network, no cross-shard gathers (the naive global-token dispatch cost
    192 GiB of all-gather per step on dbrx train_4k; §Perf iteration 8).
    Per-row capacity C = S*K/E * cf bounds compute overhead at exactly the
    capacity factor.  Expert weights are laid out (E, D, F) with F
    TP-sharded and D FSDP-sharded ("ff"/"embed" axes): every device holds a
    slice of EVERY expert, so no token ever crosses the model axis.
    """
    B, S, D = x.shape
    E, K = num_experts, top_k
    logits = (x @ params["router"]).astype(jnp.float32)        # (B, S, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, K)              # (B, S, K)
    gate_vals = gate_vals / (gate_vals.sum(-1, keepdims=True) + 1e-9)

    # Load-balance loss (Switch-style): E * sum_e f_e * p_e.
    me = probs.mean(axis=(0, 1))
    fe = jax.nn.one_hot(gate_idx[..., 0], E,
                        dtype=jnp.float32).mean(axis=(0, 1))
    aux_loss = E * jnp.sum(fe * me)

    A = S * K
    C = int(max(1, -(-A * capacity_factor // E)))

    def dispatch_row(xr, exp_r, gate_r):
        """One batch row: xr (S, D); exp_r/gate_r (S, K)."""
        flat_exp = exp_r.reshape(A)
        flat_tok = jnp.repeat(jnp.arange(S), K)
        flat_gate = gate_r.reshape(A)
        order = jnp.argsort(flat_exp)
        sexp = flat_exp[order]
        stok = flat_tok[order]
        sgate = flat_gate[order]
        run_start = jnp.searchsorted(sexp, sexp, side="left")
        pos = jnp.arange(A) - run_start
        keep = pos < C
        buf = jnp.zeros((E, C, D), xr.dtype)
        src = jnp.where(keep[:, None], xr[stok], 0)
        buf = buf.at[jnp.where(keep, sexp, 0),
                     jnp.where(keep, pos, 0)].add(src)
        return buf, (sexp, stok, sgate, pos, keep)

    buf, book = jax.vmap(dispatch_row)(x, gate_idx, gate_vals)  # (B,E,C,D)
    buf = constrain_batch(buf)   # keep dispatch buffers batch-sharded

    # ---- expert FFN: F is TP-sharded, D FSDP-sharded; all experts local ----
    if kind in ("swiglu", "geglu"):
        act = jax.nn.silu if kind == "swiglu" else (
            lambda t: jax.nn.gelu(t, approximate=True))
        h = (act(jnp.einsum("becd,edf->becf", buf,
                            gather_fsdp(params["wi_gate"], tp_dim=2)))
             * jnp.einsum("becd,edf->becf", buf,
                          gather_fsdp(params["wi_up"], tp_dim=2)))
    else:
        h = jax.nn.gelu(jnp.einsum("becd,edf->becf", buf,
                                   gather_fsdp(params["wi_up"], tp_dim=2)),
                        approximate=True)
    h = constrain_batch(h)
    out_buf = constrain_batch(
        jnp.einsum("becf,efd->becd", h,
                   gather_fsdp(params["wo"], tp_dim=1)))        # (B,E,C,D)

    def gather_row(obuf, bk):
        sexp, stok, sgate, pos, keep = bk
        vals = obuf[jnp.where(keep, sexp, 0), jnp.where(keep, pos, 0)]
        vals = jnp.where(keep[:, None], vals, 0) * sgate[:, None].astype(
            obuf.dtype)
        return jnp.zeros((S, D), obuf.dtype).at[stok].add(vals)

    out = constrain_batch(jax.vmap(gather_row)(out_buf, book))  # (B, S, D)
    return out, {"aux_loss": aux_loss,
                 "dropped_frac": 1.0 - jnp.mean(
                     book[4].astype(jnp.float32))}
