"""Zamba2-style hybrid stack: Mamba2 backbone + ONE shared attention block.

Structure (arXiv:2411.15242): ``num_layers`` Mamba2 blocks; after every
``attn_every`` blocks, a SINGLE shared transformer block (attention + MLP,
parameters reused at every application) refreshes global context.  The stack
is scanned over groups of ``attn_every`` Mamba blocks (plus a Mamba-only
tail when ``num_layers % attn_every != 0``), with the shared block applied
once per group.

Decode state: per-Mamba-layer (conv tail, GLA state) — O(1) in sequence —
plus one KV cache per shared-attention application (num_groups caches).
Attention KV grows with context, but only num_groups ~= 6 of them exist, so
the 500k shape stays feasible (DESIGN.md §3).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain_batch, constrain_logits
from repro.models import layers as L
from repro.models.ssm import CONV_K, init_mamba2, mamba2_fwd


def _attn_cfg(cfg: ModelConfig) -> L.AttnConfig:
    return L.AttnConfig(d_model=cfg.d_model, num_heads=cfg.num_heads,
                        num_kv_heads=cfg.num_kv_heads, head_dim=cfg.hd,
                        rope_theta=cfg.rope_theta, causal=True)


def init_mamba_block(cfg: ModelConfig, key):
    p = L.ParamFactory(key)
    mp, ma = init_mamba2(p._split(), cfg.d_model, cfg.ssm_state,
                         cfg.ssm_heads, expand=cfg.ssm_expand)
    p.params["mamba"], p.axes["mamba"] = mp, ma
    p.zeros("norm", (cfg.d_model,), ("embed",))
    return p.params, p.axes


def init_shared_attn(cfg: ModelConfig, key):
    p = L.ParamFactory(key)
    ap, aa = L.init_attention(p._split(), _attn_cfg(cfg))
    p.params["attn"], p.axes["attn"] = ap, aa
    mp, ma = L.init_mlp(p._split(), cfg.d_model, cfg.d_ff, cfg.mlp)
    p.params["mlp"], p.axes["mlp"] = mp, ma
    p.zeros("norm1", (cfg.d_model,), ("embed",))
    p.zeros("norm2", (cfg.d_model,), ("embed",))
    return p.params, p.axes


def init_hybrid_lm(cfg: ModelConfig, key):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    params, axes = {}, {}
    ep, ea = L.init_embedding(k1, cfg.padded_vocab, cfg.d_model,
                              cfg.tie_embeddings)
    params["embedding"], axes["embedding"] = ep, ea
    n_groups = cfg.num_layers // cfg.attn_every
    tail = cfg.num_layers - n_groups * cfg.attn_every

    def init_group(k):
        return L.stack_layer_params(lambda kk: init_mamba_block(cfg, kk), k,
                                    cfg.attn_every)

    gp, ga = L.stack_layer_params(init_group, k2, n_groups)
    params["groups"], axes["groups"] = gp, ga
    sp, sa = init_shared_attn(cfg, k3)  # ONE shared block (reused)
    params["shared_attn"], axes["shared_attn"] = sp, sa
    if tail:
        tp, ta = L.stack_layer_params(lambda kk: init_mamba_block(cfg, kk),
                                      k4, tail)
        params["tail"], axes["tail"] = tp, ta
    params["final_norm"] = jnp.zeros((cfg.d_model,), jnp.bfloat16)
    axes["final_norm"] = ("embed",)
    return params, axes


def hybrid_state(cfg: ModelConfig, batch: int, cache_len: int,
                 dtype=jnp.bfloat16):
    """(mamba carries per layer, shared-attn KV caches per application)."""
    n_groups = cfg.num_layers // cfg.attn_every
    tail = cfg.num_layers - n_groups * cfg.attn_every
    d_inner = cfg.ssm_expand * cfg.d_model
    hd_m = d_inner // cfg.ssm_heads

    def carries(n):
        return (jnp.zeros((n, batch, CONV_K - 1, d_inner), dtype),
                jnp.zeros((n, batch, cfg.ssm_heads, cfg.ssm_state, hd_m),
                          jnp.float32))

    state = {
        "groups_conv": carries(n_groups * cfg.attn_every)[0].reshape(
            n_groups, cfg.attn_every, batch, CONV_K - 1, d_inner),
        "groups_gla": carries(n_groups * cfg.attn_every)[1].reshape(
            n_groups, cfg.attn_every, batch, cfg.ssm_heads, cfg.ssm_state,
            hd_m),
        "attn_k": jnp.zeros((n_groups, batch, cache_len, cfg.num_kv_heads,
                             cfg.hd), dtype),
        "attn_v": jnp.zeros((n_groups, batch, cache_len, cfg.num_kv_heads,
                             cfg.hd), dtype),
    }
    if tail:
        state["tail_conv"], state["tail_gla"] = carries(tail)
    return state


def _mamba_block(cfg, blk, x, carry, decode):
    x = constrain_batch(x)
    out, new_carry = mamba2_fwd(blk["mamba"], L.rms_norm(x, blk["norm"]),
                                state=cfg.ssm_state, num_heads=cfg.ssm_heads,
                                carry=carry, decode=decode)
    return x + out, new_carry


def _shared_attn_fwd(cfg, sp, x, pos):
    x = constrain_batch(x)
    a, kv = L.attention_fwd(sp["attn"], L.rms_norm(x, sp["norm1"]),
                            _attn_cfg(cfg), pos)
    x = x + a
    m = L.mlp_fwd(sp["mlp"], L.rms_norm(x, sp["norm2"]), cfg.mlp)
    return x + m, kv


def _shared_attn_decode(cfg, sp, x, kc, vc, kv_len, pos):
    a, kc, vc = L.attention_decode(sp["attn"], L.rms_norm(x, sp["norm1"]),
                                   _attn_cfg(cfg), kc, vc, kv_len, pos)
    x = x + a
    m = L.mlp_fwd(sp["mlp"], L.rms_norm(x, sp["norm2"]), cfg.mlp)
    return x + m, kc, vc


def hybrid_forward(params, cfg: ModelConfig, tokens, embeds=None,
                   remat: bool = True):
    B, S = tokens.shape
    x = L.embed_fwd(params["embedding"], tokens)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    sp = params["shared_attn"]

    def group_body(x, grp):
        def mamba_body(x, blk):
            x, _ = _mamba_block(cfg, blk, x, None, decode=False)
            return x, None

        x, _ = jax.lax.scan(mamba_body, x, grp)
        x, _ = _shared_attn_fwd(cfg, sp, x, pos)
        return x, None

    if remat:
        group_body = L.maybe_remat(group_body, cfg.remat)
    x, _ = jax.lax.scan(group_body, x, params["groups"])
    if "tail" in params:
        def tail_body(x, blk):
            x, _ = _mamba_block(cfg, blk, x, None, decode=False)
            return x, None

        x, _ = jax.lax.scan(tail_body, x, params["tail"])
    x = L.rms_norm(x, params["final_norm"])
    return (constrain_logits(L.unembed_fwd(params["embedding"], x)),
            jnp.zeros((), jnp.float32))


def hybrid_prefill(params, cfg: ModelConfig, tokens, cache_len=None,
                   embeds=None):
    B, S = tokens.shape
    cache_len = cache_len or S
    x = L.embed_fwd(params["embedding"], tokens)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    sp = params["shared_attn"]

    def group_body(x, grp):
        def mamba_body(x, blk):
            x, carry = _mamba_block(cfg, blk, x, None, decode=False)
            return x, carry

        x, carries = jax.lax.scan(mamba_body, x, grp)
        x, (k, v) = _shared_attn_fwd(cfg, sp, x, pos)
        pad = cache_len - S
        if pad > 0:
            k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        return x, (carries, k, v)

    x, (gc, ks, vs) = jax.lax.scan(group_body, x, params["groups"])
    state = {"groups_conv": gc[0], "groups_gla": gc[1],
             "attn_k": ks, "attn_v": vs}
    if "tail" in params:
        def tail_body(x, blk):
            x, carry = _mamba_block(cfg, blk, x, None, decode=False)
            return x, carry

        x, tc = jax.lax.scan(tail_body, x, params["tail"])
        state["tail_conv"], state["tail_gla"] = tc
    x = L.rms_norm(x, params["final_norm"])
    logits = L.unembed_fwd(params["embedding"], x[:, -1:])[:, 0]
    return logits, state


def hybrid_decode_step(params, cfg: ModelConfig, state, kv_len, token,
                       embeds=None):
    B = token.shape[0]
    x = L.embed_fwd(params["embedding"], token)
    pos = jnp.broadcast_to(jnp.arange(1)[None], (B, 1)) + kv_len
    sp = params["shared_attn"]

    def group_body(x, xs):
        grp, conv, gla, kc, vc = xs

        def mamba_body(x, xs2):
            blk, c, g = xs2
            x, (nc, ng) = _mamba_block(cfg, blk, x, (c, g), decode=True)
            return x, (nc, ng)

        x, (nconv, ngla) = jax.lax.scan(mamba_body, x, (grp, conv, gla))
        x, kc, vc = _shared_attn_decode(cfg, sp, x, kc, vc, kv_len, pos)
        return x, (nconv, ngla, kc, vc)

    x, (gc, gg, ks, vs) = jax.lax.scan(
        group_body, x, (params["groups"], state["groups_conv"],
                        state["groups_gla"], state["attn_k"],
                        state["attn_v"]))
    new = dict(state, groups_conv=gc, groups_gla=gg, attn_k=ks, attn_v=vs)
    if "tail" in params:
        def tail_body(x, xs2):
            blk, c, g = xs2
            x, (nc, ng) = _mamba_block(cfg, blk, x, (c, g), decode=True)
            return x, (nc, ng)

        x, (tc, tg) = jax.lax.scan(tail_body, x,
                                   (params["tail"], state["tail_conv"],
                                    state["tail_gla"]))
        new["tail_conv"], new["tail_gla"] = tc, tg
    x = L.rms_norm(x, params["final_norm"])
    return L.unembed_fwd(params["embedding"], x)[:, 0], new
