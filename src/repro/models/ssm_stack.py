"""RWKV6 language model stack (attention-free).

Block = RWKV6 time mixing + channel mixing (token-shifted squared-ReLU MLP).
Decode state is O(1) in sequence length — (prev token, per-head K x V state)
per layer — which is why this arch runs the 500k-context decode shape.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain_batch, constrain_logits
from repro.models import layers as L
from repro.models.ssm import init_rwkv6, rwkv6_fwd


def init_channel_mix(key, d_model: int, d_ff: int):
    p = L.ParamFactory(key)
    p.dense("wk", (d_model, d_ff), ("embed", "ff"))
    p.dense("wv", (d_ff, d_model), ("ff", "embed"))
    p.dense("wr", (d_model, d_model), ("embed", "embed"))
    p.zeros("mix", (2, d_model), (None, "embed"))
    return p.params, p.axes


def channel_mix_fwd(params, x, prev=None):
    """Token-shifted squared-ReLU channel mix.  Returns (out, last_token)."""
    B, S, D = x.shape
    if prev is None:
        prev = jnp.zeros((B, 1, D), x.dtype)
    shifted = jnp.concatenate([prev, x[:, :-1]], axis=1)
    xk = x + (shifted - x) * params["mix"][0][None, None]
    xr = x + (shifted - x) * params["mix"][1][None, None]
    from repro.distributed.sharding import gather_fsdp
    k = jnp.square(jax.nn.relu(xk @ gather_fsdp(params["wk"], tp_dim=1)))
    out = (jax.nn.sigmoid(xr @ gather_fsdp(params["wr"], tp_dim=1))
           * (k @ gather_fsdp(params["wv"], tp_dim=0)))
    return out, x[:, -1:]


def init_rwkv_block(cfg: ModelConfig, key):
    p = L.ParamFactory(key)
    tp, ta = init_rwkv6(p._split(), cfg.d_model, cfg.num_heads)
    p.params["time"], p.axes["time"] = tp, ta
    cp, ca = init_channel_mix(p._split(), cfg.d_model, cfg.d_ff)
    p.params["chan"], p.axes["chan"] = cp, ca
    p.zeros("norm1", (cfg.d_model,), ("embed",))
    p.zeros("norm2", (cfg.d_model,), ("embed",))
    return p.params, p.axes


def init_rwkv_lm(cfg: ModelConfig, key):
    k1, k2 = jax.random.split(key)
    params, axes = {}, {}
    ep, ea = L.init_embedding(k1, cfg.padded_vocab, cfg.d_model,
                              cfg.tie_embeddings)
    params["embedding"], axes["embedding"] = ep, ea
    bp, ba = L.stack_layer_params(lambda k: init_rwkv_block(cfg, k), k2,
                                  cfg.num_layers)
    params["blocks"], axes["blocks"] = bp, ba
    params["final_norm"] = jnp.zeros((cfg.d_model,), jnp.bfloat16)
    axes["final_norm"] = ("embed",)
    return params, axes


def _block(cfg, blk, x, carry, decode):
    x = constrain_batch(x)
    t_out, t_carry = rwkv6_fwd(blk["time"], L.rms_norm(x, blk["norm1"]),
                               num_heads=cfg.num_heads,
                               carry=(carry[0], carry[1]), decode=decode)
    x = x + t_out
    c_out, c_prev = channel_mix_fwd(blk["chan"], L.rms_norm(x, blk["norm2"]),
                                    prev=carry[2])
    return x + c_out, (t_carry[0], t_carry[1], c_prev)


def rwkv_init_state(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16):
    Lr, D, H = cfg.num_layers, cfg.d_model, cfg.num_heads
    hd = cfg.hd
    return (jnp.zeros((Lr, batch, 1, D), dtype),           # time-mix prev token
            jnp.zeros((Lr, batch, H, hd, hd), jnp.float32),  # GLA state
            jnp.zeros((Lr, batch, 1, D), dtype))            # chan-mix prev token


def rwkv_forward(params, cfg: ModelConfig, tokens, embeds=None,
                 remat: bool = True):
    B, S = tokens.shape
    x = L.embed_fwd(params["embedding"], tokens)
    state = rwkv_init_state(cfg, B)

    def body(x, xs):
        blk, s0, s1, s2 = xs
        x, _ = _block(cfg, blk, x, (s0, s1, s2), decode=False)
        return x, None

    if remat:
        body = L.maybe_remat(body, cfg.remat)
    x, _ = jax.lax.scan(body, x, (params["blocks"],) + state)
    x = constrain_batch(L.rms_norm(x, params["final_norm"]))
    return (constrain_logits(L.unembed_fwd(params["embedding"], x)),
            jnp.zeros((), jnp.float32))


def rwkv_prefill(params, cfg: ModelConfig, tokens, embeds=None):
    B, S = tokens.shape
    x = L.embed_fwd(params["embedding"], tokens)
    state = rwkv_init_state(cfg, B)

    def body(x, xs):
        blk, s0, s1, s2 = xs
        x, new = _block(cfg, blk, x, (s0, s1, s2), decode=False)
        return x, new

    x, new_state = jax.lax.scan(body, x, (params["blocks"],) + state)
    x = L.rms_norm(x, params["final_norm"])
    logits = L.unembed_fwd(params["embedding"], x[:, -1:])[:, 0]
    return logits, new_state


def rwkv_decode_step(params, cfg: ModelConfig, state, kv_len, token,
                     embeds=None):
    x = L.embed_fwd(params["embedding"], token)

    def body(x, xs):
        blk, s0, s1, s2 = xs
        x, new = _block(cfg, blk, x, (s0, s1, s2), decode=True)
        return x, new

    x, new_state = jax.lax.scan(body, x, (params["blocks"],) + state)
    x = L.rms_norm(x, params["final_norm"])
    return L.unembed_fwd(params["embedding"], x)[:, 0], new_state
