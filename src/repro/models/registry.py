"""Model registry: one uniform API over all 10 architectures.

``build_model(cfg)`` returns a :class:`ModelAPI` whose members are pure
functions (pjit-able).  ``input_specs(shape)`` produces the
ShapeDtypeStruct stand-ins for the dry-run — including the stub modality
frontends (audio frames / vision patches) for the multimodal archs.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import encdec as ED
from repro.models import hybrid as HY
from repro.models import ssm_stack as SS
from repro.models import transformer as TF

VLM_PATCH_TOKENS = 1024    # stub vision prefix length
AUDIO_FRAME_STRIDE = 1     # stub: one embedding per frame position


def cross_entropy(logits, labels):
    """Sharded-softmax cross entropy.

    All reductions run over the vocab axis FIRST (max, sum-exp, label
    contraction), so with vocab TP-sharded the only collectives are
    (B, S)-sized psums — never an all-gather/all-reduce of the full logits
    (which at 262k vocab costs ~100x the step's other collectives).
    ``take_along_axis`` is avoided: a gather over a sharded vocab dim makes
    GSPMD materialize the full logits.
    """
    logits = logits.astype(jnp.float32)
    m = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
    lse = jnp.log(jnp.sum(jnp.exp(logits - m), axis=-1)) + m[..., 0]
    v_idx = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
    label_logit = jnp.sum(
        jnp.where(v_idx == labels[..., None].astype(jnp.int32), logits, 0.0),
        axis=-1)
    return jnp.mean(lse - label_logit)


@dataclasses.dataclass(frozen=True)
class ModelAPI:
    cfg: ModelConfig
    init: Callable          # (key) -> (params, axes)
    forward: Callable       # (params, batch) -> (logits, aux)
    loss_fn: Callable       # (params, batch) -> (loss, metrics)
    init_cache: Callable    # (batch, cache_len) -> cache pytree
    prefill: Callable       # (params, batch) -> (logits, cache)
    decode_step: Callable   # (params, cache, kv_len, token) -> (logits, cache)
    input_specs: Callable   # (ShapeConfig) -> dict of ShapeDtypeStruct


def _loss_wrapper(forward, moe_aux_weight=0.01):
    def loss_fn(params, batch):
        logits, aux = forward(params, batch)
        loss = cross_entropy(logits, batch["labels"])
        total = loss + moe_aux_weight * aux
        return total, {"xent": loss, "aux": aux}
    return loss_fn


def _token_specs(shape: ShapeConfig, batch_override: int | None = None):
    B = batch_override or shape.global_batch
    S = shape.seq_len
    return {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
            "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}


def build_model(cfg: ModelConfig) -> ModelAPI:
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        return _build_transformer(cfg)
    if fam == "ssm":
        return _build_ssm(cfg)
    if fam == "hybrid":
        return _build_hybrid(cfg)
    if fam == "encdec":
        return _build_encdec(cfg)
    raise ValueError(f"unknown family {fam}")


# ---------------------------------------------------------------------------


def _build_transformer(cfg: ModelConfig) -> ModelAPI:
    def forward(params, batch):
        return TF.lm_forward(params, cfg, batch["tokens"],
                             embeds=batch.get("embeds"))

    def init_cache(batch: int, cache_len: int):
        return TF.lm_init_cache(cfg, batch, cache_len)

    def prefill(params, batch, cache_len=None):
        return TF.lm_prefill(params, cfg, batch["tokens"],
                             cache_len=cache_len, embeds=batch.get("embeds"))

    def decode_step(params, cache, kv_len, token):
        return TF.lm_decode_step(params, cfg, cache, kv_len, token)

    def input_specs(shape: ShapeConfig):
        if shape.kind == "train" or shape.kind == "prefill":
            specs = _token_specs(shape)
            if cfg.family == "vlm":
                V = min(VLM_PATCH_TOKENS, shape.seq_len // 4)
                specs["embeds"] = jax.ShapeDtypeStruct(
                    (shape.global_batch, V, cfg.d_model), jnp.bfloat16)
            if shape.kind == "prefill":
                specs.pop("labels")
            return specs
        # decode: one token + full cache of seq_len entries
        B = shape.global_batch
        cache = jax.eval_shape(lambda: init_cache(B, shape.seq_len + 1))
        return {"token": jax.ShapeDtypeStruct((B, 1), jnp.int32),
                "kv_len": jax.ShapeDtypeStruct((), jnp.int32),
                "cache": cache}

    return ModelAPI(cfg, lambda key: TF.init_lm(cfg, key), forward,
                    _loss_wrapper(forward), init_cache, prefill, decode_step,
                    input_specs)


def _build_ssm(cfg: ModelConfig) -> ModelAPI:
    def forward(params, batch):
        return SS.rwkv_forward(params, cfg, batch["tokens"])

    def init_cache(batch: int, cache_len: int):
        return SS.rwkv_init_state(cfg, batch)   # O(1): no cache_len

    def prefill(params, batch, cache_len=None):
        return SS.rwkv_prefill(params, cfg, batch["tokens"])

    def decode_step(params, cache, kv_len, token):
        return SS.rwkv_decode_step(params, cfg, cache, kv_len, token)

    def input_specs(shape: ShapeConfig):
        if shape.kind in ("train", "prefill"):
            specs = _token_specs(shape)
            if shape.kind == "prefill":
                specs.pop("labels")
            return specs
        B = shape.global_batch
        cache = jax.eval_shape(lambda: init_cache(B, shape.seq_len))
        return {"token": jax.ShapeDtypeStruct((B, 1), jnp.int32),
                "kv_len": jax.ShapeDtypeStruct((), jnp.int32),
                "cache": cache}

    return ModelAPI(cfg, lambda key: SS.init_rwkv_lm(cfg, key), forward,
                    _loss_wrapper(forward), init_cache, prefill, decode_step,
                    input_specs)


def _build_hybrid(cfg: ModelConfig) -> ModelAPI:
    def forward(params, batch):
        return HY.hybrid_forward(params, cfg, batch["tokens"])

    def init_cache(batch: int, cache_len: int):
        return HY.hybrid_state(cfg, batch, cache_len)

    def prefill(params, batch, cache_len=None):
        return HY.hybrid_prefill(params, cfg, batch["tokens"],
                                 cache_len=cache_len)

    def decode_step(params, cache, kv_len, token):
        return HY.hybrid_decode_step(params, cfg, cache, kv_len, token)

    def input_specs(shape: ShapeConfig):
        if shape.kind in ("train", "prefill"):
            specs = _token_specs(shape)
            if shape.kind == "prefill":
                specs.pop("labels")
            return specs
        B = shape.global_batch
        cache = jax.eval_shape(lambda: init_cache(B, shape.seq_len + 1))
        return {"token": jax.ShapeDtypeStruct((B, 1), jnp.int32),
                "kv_len": jax.ShapeDtypeStruct((), jnp.int32),
                "cache": cache}

    return ModelAPI(cfg, lambda key: HY.init_hybrid_lm(cfg, key), forward,
                    _loss_wrapper(forward), init_cache, prefill, decode_step,
                    input_specs)


def _build_encdec(cfg: ModelConfig) -> ModelAPI:
    DEC_PREFILL_FRac = 8  # decoder prompt = seq_len/8 during prefill cells

    def forward(params, batch):
        return ED.encdec_forward(params, cfg, batch["tokens"],
                                 batch["frames"])

    def init_cache(batch: int, cache_len: int, enc_len: int | None = None):
        return ED.encdec_init_cache(cfg, batch, cache_len,
                                    enc_len or cache_len)

    def prefill(params, batch, cache_len=None):
        return ED.encdec_prefill(params, cfg, batch["tokens"],
                                 batch["frames"], cache_len=cache_len)

    def decode_step(params, cache, kv_len, token):
        return ED.encdec_decode_step(params, cfg, cache, kv_len, token)

    def input_specs(shape: ShapeConfig):
        B, S = shape.global_batch, shape.seq_len
        frames = jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.bfloat16)
        if shape.kind == "train":
            return {**_token_specs(shape), "frames": frames}
        if shape.kind == "prefill":
            Sdec = max(1, S // DEC_PREFILL_FRac)
            return {"tokens": jax.ShapeDtypeStruct((B, Sdec), jnp.int32),
                    "frames": frames}
        cache = jax.eval_shape(
            lambda: init_cache(B, shape.seq_len + 1, shape.seq_len))
        return {"token": jax.ShapeDtypeStruct((B, 1), jnp.int32),
                "kv_len": jax.ShapeDtypeStruct((), jnp.int32),
                "cache": cache}

    return ModelAPI(cfg, lambda key: ED.init_encdec(cfg, key), forward,
                    _loss_wrapper(forward), init_cache, prefill, decode_step,
                    input_specs)
