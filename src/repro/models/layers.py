"""Shared model layers: params-with-logical-axes, norms, RoPE/M-RoPE,
GQA attention (train / prefill / decode, full + sliding window), MLPs.

Everything is pure-functional: ``init_*`` build parameter pytrees, ``*_fwd``
apply them.  Each init also records a parallel *axes tree* whose leaves are
tuples of logical axis names (e.g. ``("embed", "heads")``); the distribution
layer (repro.distributed.sharding) maps logical names to mesh axes, giving
per-architecture TP/FSDP/EP sharding without touching model code.

Logical axis vocabulary:
  "vocab"   embedding rows            -> model axis (TP)
  "embed"   the d_model dim           -> FSDP (data axis) on weights
  "heads"   q heads * head_dim        -> model axis (TP)
  "kv"      kv heads * head_dim       -> model if divisible, else replicated
  "ff"      MLP hidden                -> model axis (TP)
  "experts" MoE expert dim            -> model axis (EP)
  "layers"  stacked scan dim          -> never sharded
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.distributed.sharding import gather_fsdp
from repro.kernels.flash_attention import flash_attention

# ---------------------------------------------------------------------------
# Parameter factory with logical axes.
# ---------------------------------------------------------------------------


class ParamFactory:
    """Creates params and records logical axes in one pass."""

    def __init__(self, key: jax.Array, dtype=jnp.bfloat16):
        self._key = key
        self.dtype = dtype
        self.params: dict[str, Any] = {}
        self.axes: dict[str, Any] = {}

    def _split(self) -> jax.Array:
        self._key, k = jax.random.split(self._key)
        return k

    def dense(self, name: str, shape: tuple[int, ...], axes: tuple,
              scale: float | None = None, dtype=None) -> None:
        assert len(shape) == len(axes)
        if scale is None:
            scale = shape[0] ** -0.5  # fan-in
        self.params[name] = (jax.random.normal(self._split(), shape,
                                               dtype or self.dtype) * scale)
        self.axes[name] = axes

    def zeros(self, name: str, shape: tuple[int, ...], axes: tuple,
              dtype=None) -> None:
        self.params[name] = jnp.zeros(shape, dtype or self.dtype)
        self.axes[name] = axes

    def ones(self, name: str, shape: tuple[int, ...], axes: tuple,
             dtype=None) -> None:
        self.params[name] = jnp.ones(shape, dtype or self.dtype)
        self.axes[name] = axes

    def const(self, name: str, shape: tuple[int, ...], axes: tuple,
              value: float, dtype=None) -> None:
        self.params[name] = jnp.full(shape, value, dtype or self.dtype)
        self.axes[name] = axes

    def sub(self, name: str) -> "ParamFactory":
        child = ParamFactory(self._split(), self.dtype)
        self.params[name] = child.params
        self.axes[name] = child.axes
        return child


def stack_layer_params(init_fn, key: jax.Array, num: int):
    """vmap an init over layer keys -> params stacked on a leading axis.

    Returns (stacked params, axes tree with "layers" prepended).
    """
    keys = jax.random.split(key, num)
    params = jax.vmap(lambda k: init_fn(k)[0])(keys)
    _, axes = init_fn(key)  # structure only
    axes = jax.tree_util.tree_map(
        lambda a: ("layers",) + tuple(a), axes,
        is_leaf=lambda x: isinstance(x, tuple))
    return params, axes


def maybe_remat(fn, policy: str):
    """Wrap a scan body in jax.checkpoint per the config's remat policy."""
    if policy == "none":
        return fn
    if policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
            prevent_cse=False)
    return jax.checkpoint(fn, prevent_cse=False)  # "full"


# ---------------------------------------------------------------------------
# Norms.
# ---------------------------------------------------------------------------


def rms_norm(x, w, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * (1.0 + w.astype(jnp.float32))
    return out.astype(x.dtype)


def layer_norm(x, w, b, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    out = out * w.astype(jnp.float32) + b.astype(jnp.float32)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary embeddings (RoPE and Qwen2-VL M-RoPE).
# ---------------------------------------------------------------------------


def _rope_freqs(head_dim: int, theta: float):
    half = head_dim // 2
    return theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)


def apply_rope(x, positions, theta: float = 1e4):
    """x: (B, S, H, D) with D even; positions: (B, S) absolute indices."""
    B, S, H, D = x.shape
    freqs = _rope_freqs(D, theta)                       # (D/2,)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (B,S,D/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


def apply_mrope(x, positions_3d, theta: float = 1e6,
                sections: tuple[int, int, int] = (16, 24, 24)):
    """Qwen2-VL multimodal RoPE: the head dim is split into (temporal,
    height, width) sections, each rotated by its own position stream.

    x: (B, S, H, D); positions_3d: (B, S, 3).  ``sections`` are in
    half-dim units and must sum to D//2.
    """
    B, S, H, D = x.shape
    half = D // 2
    assert sum(sections) == half, "mrope sections must sum to head_dim/2"
    freqs = _rope_freqs(D, theta)                        # (half,)
    sec_id = jnp.repeat(jnp.arange(3), jnp.array(sections),
                        total_repeat_length=half)        # (half,) in {0,1,2}
    pos = jnp.take_along_axis(
        positions_3d.astype(jnp.float32),                # (B,S,3)
        jnp.broadcast_to(sec_id[None, None, :], (B, S, half)).astype(jnp.int32),
        axis=2)                                          # (B,S,half)
    ang = pos * freqs[None, None, :]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA) with KV-cache support.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AttnConfig:
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    qkv_bias: bool = False
    rope_theta: float = 1e4
    mrope: bool = False
    causal: bool = True
    window: int | None = None    # sliding window (None = full)
    block_q: int = 512
    block_k: int = 512


def init_attention(key, cfg: AttnConfig, dtype=jnp.bfloat16):
    p = ParamFactory(key, dtype)
    D, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    p.dense("wq", (D, H * hd), ("embed", "heads"))
    p.dense("wk", (D, KV * hd), ("embed", "kv"))
    p.dense("wv", (D, KV * hd), ("embed", "kv"))
    p.dense("wo", (H * hd, D), ("heads", "embed"))
    if cfg.qkv_bias:
        p.zeros("bq", (H * hd,), ("heads",))
        p.zeros("bk", (KV * hd,), ("kv",))
        p.zeros("bv", (KV * hd,), ("kv",))
    return p.params, p.axes


def _qkv(params, x, cfg: AttnConfig, positions):
    B, S, _ = x.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = x @ gather_fsdp(params["wq"], tp_dim=1)
    k = x @ gather_fsdp(params["wk"], tp_dim=1)
    v = x @ gather_fsdp(params["wv"], tp_dim=1)
    if cfg.qkv_bias:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, S, KV, hd)
    v = v.reshape(B, S, KV, hd)
    if cfg.mrope:
        pos3 = (positions[..., None].astype(jnp.int32)
                if positions.ndim == 2 else positions)
        if pos3.shape[-1] != 3:  # text-only stream: t=h=w=position
            pos3 = jnp.broadcast_to(pos3, (*pos3.shape[:-1], 3))
        q = apply_mrope(q, pos3, cfg.rope_theta, _mrope_sections(hd))
        k = apply_mrope(k, pos3, cfg.rope_theta, _mrope_sections(hd))
    else:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _mrope_sections(head_dim: int) -> tuple[int, int, int]:
    half = head_dim // 2
    t = half - 2 * (3 * half // 8)
    return (t, 3 * half // 8, 3 * half // 8)


def attention_fwd(params, x, cfg: AttnConfig, positions=None):
    """Full-sequence attention (training / prefill).  x: (B, S, D)."""
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    q, k, v = _qkv(params, x, cfg, positions)

    # Checkpoint the attention op: its chunked online-softmax carries are
    # recomputed in the backward instead of being saved per (layer x chunk)
    # — the jnp analogue of a flash-attention backward kernel.  Cuts train
    # temp memory ~10x at 4k seq (EXPERIMENTS.md §Perf iteration 6).
    attn = jax.checkpoint(
        lambda q, k, v: flash_attention(
            q, k, v, causal=cfg.causal, window=cfg.window, q_offset=0,
            block_q=cfg.block_q, block_k=cfg.block_k))
    out = attn(q, k, v)
    out = out.reshape(B, S, cfg.num_heads * cfg.head_dim)
    return out @ gather_fsdp(params["wo"], tp_dim=0), (k, v)


def attention_decode(params, x, cfg: AttnConfig, k_cache, v_cache,
                     kv_len: int, positions):
    """One-token decode against a filled cache.

    x: (B, 1, D); k_cache/v_cache: (B, S_cache, KV, hd) where entries
    [0, kv_len) are valid roped keys.  For sliding-window layers the cache
    is a ring of size ``window`` (attention is permutation-invariant, so
    ring order does not matter).  Returns (out, new_k_cache, new_v_cache).
    """
    B = x.shape[0]
    q, k_new, v_new = _qkv(params, x, cfg, positions)
    S_cache = k_cache.shape[1]
    slot = kv_len % S_cache if cfg.window is not None else kv_len
    slot = jnp.asarray(slot) % S_cache
    k_cache = jax.lax.dynamic_update_slice_in_dim(
        k_cache, k_new.astype(k_cache.dtype), slot, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(
        v_cache, v_new.astype(v_cache.dtype), slot, axis=1)
    valid = jnp.minimum(kv_len + 1, S_cache)
    out = _decode_attend(q, k_cache, v_cache, valid, cfg)
    out = out.reshape(B, 1, cfg.num_heads * cfg.head_dim)
    return out @ params["wo"], k_cache, v_cache


def _decode_attend(q, k_cache, v_cache, valid_len, cfg: AttnConfig):
    """Masked non-causal attention of one query over the cache (fp32 softmax)."""
    from repro.distributed.sharding import constrain_kv_layout
    B, _, H, hd = q.shape
    KV = k_cache.shape[2]
    G = H // KV
    qf = q.astype(jnp.float32) * (hd ** -0.5)           # (B,1,H,hd)
    kf = constrain_kv_layout(k_cache.astype(jnp.float32))
    vf = constrain_kv_layout(v_cache.astype(jnp.float32))
    qg = qf.reshape(B, KV, G, hd)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, kf)           # (B,KV,G,S)
    kpos = jnp.arange(k_cache.shape[1])
    mask = kpos[None, None, None, :] < valid_len
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p, vf)
    return o.reshape(B, 1, H, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# MLPs.
# ---------------------------------------------------------------------------


def init_mlp(key, d_model: int, d_ff: int, kind: str = "swiglu",
             dtype=jnp.bfloat16):
    p = ParamFactory(key, dtype)
    if kind in ("swiglu", "geglu"):
        p.dense("wi_gate", (d_model, d_ff), ("embed", "ff"))
        p.dense("wi_up", (d_model, d_ff), ("embed", "ff"))
    else:  # "gelu" / "relu": plain 2-layer MLP
        p.dense("wi_up", (d_model, d_ff), ("embed", "ff"))
    p.dense("wo", (d_ff, d_model), ("ff", "embed"))
    return p.params, p.axes


def mlp_fwd(params, x, kind: str = "swiglu"):
    if kind == "swiglu":
        h = (jax.nn.silu(x @ gather_fsdp(params["wi_gate"], tp_dim=1))
             * (x @ gather_fsdp(params["wi_up"], tp_dim=1)))
    elif kind == "geglu":
        h = (jax.nn.gelu(x @ gather_fsdp(params["wi_gate"], tp_dim=1),
                         approximate=True)
             * (x @ gather_fsdp(params["wi_up"], tp_dim=1)))
    elif kind == "gelu":
        h = jax.nn.gelu(x @ gather_fsdp(params["wi_up"], tp_dim=1),
                        approximate=True)
    elif kind == "relu":
        h = jax.nn.relu(x @ gather_fsdp(params["wi_up"], tp_dim=1))
    else:
        raise ValueError(kind)
    return h @ gather_fsdp(params["wo"], tp_dim=0)


# ---------------------------------------------------------------------------
# Embedding / unembedding.
# ---------------------------------------------------------------------------


def init_embedding(key, vocab: int, d_model: int, tie: bool = False,
                   dtype=jnp.bfloat16):
    p = ParamFactory(key, dtype)
    p.dense("embed", (vocab, d_model), ("vocab", "embed"), scale=0.02)
    if not tie:
        p.dense("unembed", (d_model, vocab), ("embed", "vocab"))
    return p.params, p.axes


def embed_fwd(params, tokens):
    return jnp.take(params["embed"], tokens, axis=0)


def unembed_fwd(params, x):
    if "unembed" in params:
        return x @ params["unembed"]
    return x @ params["embed"].T  # tied
