"""Encoder-decoder backbone (SeamlessM4T-medium).

Encoder: bidirectional attention blocks over audio-frame embeddings — the
modality frontend is a STUB: ``input_specs`` provides precomputed frame
embeddings (B, S_enc, d_model), per the assignment note.

Decoder: causal self-attention + cross-attention to encoder states + MLP.
Decode keeps a self-attention KV cache and precomputed cross KV per layer.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain_batch, constrain_logits
from repro.models import layers as L


def _self_cfg(cfg: ModelConfig, causal: bool) -> L.AttnConfig:
    return L.AttnConfig(d_model=cfg.d_model, num_heads=cfg.num_heads,
                        num_kv_heads=cfg.num_kv_heads, head_dim=cfg.hd,
                        rope_theta=cfg.rope_theta, causal=causal)


def init_enc_block(cfg: ModelConfig, key):
    p = L.ParamFactory(key)
    ap, aa = L.init_attention(p._split(), _self_cfg(cfg, False))
    p.params["attn"], p.axes["attn"] = ap, aa
    mp, ma = L.init_mlp(p._split(), cfg.d_model, cfg.d_ff, cfg.mlp)
    p.params["mlp"], p.axes["mlp"] = mp, ma
    for n in ("norm1", "norm2"):
        p.ones(f"{n}_w", (cfg.d_model,), ("embed",))
        p.zeros(f"{n}_b", (cfg.d_model,), ("embed",))
    return p.params, p.axes


def init_dec_block(cfg: ModelConfig, key):
    p = L.ParamFactory(key)
    ap, aa = L.init_attention(p._split(), _self_cfg(cfg, True))
    p.params["self_attn"], p.axes["self_attn"] = ap, aa
    cp, ca = L.init_attention(p._split(), _self_cfg(cfg, False))
    p.params["cross_attn"], p.axes["cross_attn"] = cp, ca
    mp, ma = L.init_mlp(p._split(), cfg.d_model, cfg.d_ff, cfg.mlp)
    p.params["mlp"], p.axes["mlp"] = mp, ma
    for n in ("norm1", "norm2", "norm3"):
        p.ones(f"{n}_w", (cfg.d_model,), ("embed",))
        p.zeros(f"{n}_b", (cfg.d_model,), ("embed",))
    return p.params, p.axes


def init_encdec(cfg: ModelConfig, key):
    ks = jax.random.split(key, 3)
    params, axes = {}, {}
    ep, ea = L.init_embedding(ks[0], cfg.padded_vocab, cfg.d_model,
                              cfg.tie_embeddings)
    params["embedding"], axes["embedding"] = ep, ea
    bp, ba = L.stack_layer_params(lambda k: init_enc_block(cfg, k), ks[1],
                                  cfg.encoder_layers)
    params["encoder"], axes["encoder"] = bp, ba
    dp, da = L.stack_layer_params(lambda k: init_dec_block(cfg, k), ks[2],
                                  cfg.decoder_layers)
    params["decoder"], axes["decoder"] = dp, da
    params["final_norm"] = jnp.zeros((cfg.d_model,), jnp.bfloat16)
    axes["final_norm"] = ("embed",)
    return params, axes


def _ln(p, n, x):
    return L.layer_norm(x, p[f"{n}_w"], p[f"{n}_b"])


def encode(params, cfg: ModelConfig, frames, remat: bool = True):
    """frames: (B, S_enc, d_model) stub embeddings -> encoder states."""
    B, S, _ = frames.shape
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    x = frames.astype(jnp.bfloat16)

    def body(x, blk):
        x = constrain_batch(x)
        a, _ = L.attention_fwd(blk["attn"], _ln(blk, "norm1", x),
                               _self_cfg(cfg, False), pos)
        x = x + a
        m = L.mlp_fwd(blk["mlp"], _ln(blk, "norm2", x), cfg.mlp)
        return x + m, None

    if remat:
        body = L.maybe_remat(body, cfg.remat)
    x, _ = jax.lax.scan(body, x, params["encoder"])
    return x


def _cross_kv(blk, cfg, enc_states):
    """Precompute cross-attention K/V from encoder states (per layer)."""
    B, S, _ = enc_states.shape
    KV, hd = cfg.num_kv_heads, cfg.hd
    k = (enc_states @ blk["cross_attn"]["wk"]).reshape(B, S, KV, hd)
    v = (enc_states @ blk["cross_attn"]["wv"]).reshape(B, S, KV, hd)
    return k, v


def _cross_attend(blk, cfg, x, ck, cv):
    """Query x against fixed cross K/V (no rope on cross attention)."""
    B, S, _ = x.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd
    q = (x @ blk["cross_attn"]["wq"]).reshape(B, S, H, hd)
    from repro.kernels.flash_attention import flash_attention
    o = flash_attention(q, ck, cv, causal=False, q_offset=0)
    o = o.reshape(B, S, H * hd)
    return o @ blk["cross_attn"]["wo"]


def dec_forward(params, cfg: ModelConfig, tokens, enc_states,
                remat: bool = True):
    """Teacher-forced decoder over full target sequence."""
    B, S = tokens.shape
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    x = L.embed_fwd(params["embedding"], tokens)

    def body(x, blk):
        x = constrain_batch(x)
        a, _ = L.attention_fwd(blk["self_attn"], _ln(blk, "norm1", x),
                               _self_cfg(cfg, True), pos)
        x = x + a
        ck, cv = _cross_kv(blk, cfg, enc_states)
        x = x + _cross_attend(blk, cfg, _ln(blk, "norm2", x), ck, cv)
        m = L.mlp_fwd(blk["mlp"], _ln(blk, "norm3", x), cfg.mlp)
        return x + m, None

    if remat:
        body = L.maybe_remat(body, cfg.remat)
    x, _ = jax.lax.scan(body, x, params["decoder"])
    x = constrain_batch(L.rms_norm(x, params["final_norm"]))
    return constrain_logits(L.unembed_fwd(params["embedding"], x))


def encdec_forward(params, cfg: ModelConfig, tokens, frames,
                   remat: bool = True):
    """End-to-end training forward: returns (logits, aux=0)."""
    enc = encode(params, cfg, frames, remat)
    return dec_forward(params, cfg, tokens, enc, remat), jnp.zeros(
        (), jnp.float32)


def encdec_init_cache(cfg: ModelConfig, batch: int, cache_len: int,
                      enc_len: int, dtype=jnp.bfloat16):
    Ld, KV, hd = cfg.decoder_layers, cfg.num_kv_heads, cfg.hd
    return {
        "k": jnp.zeros((Ld, batch, cache_len, KV, hd), dtype),
        "v": jnp.zeros((Ld, batch, cache_len, KV, hd), dtype),
        "cross_k": jnp.zeros((Ld, batch, enc_len, KV, hd), dtype),
        "cross_v": jnp.zeros((Ld, batch, enc_len, KV, hd), dtype),
    }


def encdec_prefill(params, cfg: ModelConfig, tokens, frames,
                   cache_len: int | None = None):
    """Encode source + prefill decoder prompt.  Returns (logits, cache)."""
    enc = encode(params, cfg, frames, remat=False)
    B, S = tokens.shape
    cache_len = cache_len or S
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    x = L.embed_fwd(params["embedding"], tokens)

    def body(x, blk):
        a, (k, v) = L.attention_fwd(blk["self_attn"], _ln(blk, "norm1", x),
                                    _self_cfg(cfg, True), pos)
        x = x + a
        ck, cv = _cross_kv(blk, cfg, enc)
        x = x + _cross_attend(blk, cfg, _ln(blk, "norm2", x), ck, cv)
        m = L.mlp_fwd(blk["mlp"], _ln(blk, "norm3", x), cfg.mlp)
        pad = cache_len - S
        if pad > 0:
            k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        return x + m, (k, v, ck, cv)

    x, (ks, vs, cks, cvs) = jax.lax.scan(body, x, params["decoder"])
    x = L.rms_norm(x, params["final_norm"])
    logits = L.unembed_fwd(params["embedding"], x[:, -1:])[:, 0]
    return logits, {"k": ks, "v": vs, "cross_k": cks, "cross_v": cvs}


def encdec_decode_step(params, cfg: ModelConfig, cache, kv_len, token,
                       embeds=None):
    B = token.shape[0]
    x = L.embed_fwd(params["embedding"], token)
    pos = jnp.broadcast_to(jnp.arange(1)[None], (B, 1)) + kv_len

    def body(x, xs):
        blk, kc, vc, ck, cv = xs
        a, kc, vc = L.attention_decode(blk["self_attn"],
                                       _ln(blk, "norm1", x),
                                       _self_cfg(cfg, True), kc, vc,
                                       kv_len, pos)
        x = x + a
        x = x + _cross_attend(blk, cfg, _ln(blk, "norm2", x), ck, cv)
        m = L.mlp_fwd(blk["mlp"], _ln(blk, "norm3", x), cfg.mlp)
        return x + m, (kc, vc)

    x, (ks, vs) = jax.lax.scan(body, x, (params["decoder"], cache["k"],
                                         cache["v"], cache["cross_k"],
                                         cache["cross_v"]))
    x = L.rms_norm(x, params["final_norm"])
    logits = L.unembed_fwd(params["embedding"], x)[:, 0]
    return logits, dict(cache, k=ks, v=vs)
