"""Serving launcher: ``python -m repro.launch.serve --arch <id> ...``

Stands up the continuous-batching scheduler for an architecture (reduced
config on CPU) and serves synthetic requests, reporting decode throughput
and the DDS KV-paging statistics when --paged is set.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_config, reduced_config
from repro.models.registry import build_model
from repro.serve.engine import BatchScheduler, PagedKVEngine, Request
from repro.storage.pagestore import PageStore


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="tinyllama_1p1b")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--paged", action="store_true",
                    help="demonstrate DDS KV-block paging")
    args = ap.parse_args()

    cfg = reduced_config(get_config(args.arch)) if args.reduced else \
        get_config(args.arch)
    api = build_model(cfg)
    params, _ = api.init(jax.random.PRNGKey(0))
    sched = BatchScheduler(api, params, slots=args.slots,
                           cache_len=args.cache_len)
    rng = np.random.default_rng(0)
    for rid in range(args.requests):
        sched.submit(Request(rid, rng.integers(0, cfg.vocab_size, size=4),
                             max_new=args.max_new))
    t0 = time.time()
    done = steps = 0
    while done < args.requests and steps < 10_000:
        done += sched.step()
        steps += 1
    dt = time.time() - t0
    toks = args.requests * args.max_new
    print(f"arch={cfg.name}: {args.requests} requests x {args.max_new} "
          f"tokens over {args.slots} slots: {steps} steps, "
          f"{toks / dt:,.0f} tok/s (CPU)")

    if args.paged:
        store = PageStore(page_size=4096, num_pages=256)
        eng = PagedKVEngine(store, block_bytes=2048, hbm_blocks=8)
        for blk in range(24):
            eng.put_block(0, 0, blk, bytes(2048))
        for blk in range(4):
            eng.get_block(0, 0, blk)
        print(f"kv paging: spills={eng.spills} offload_fetches={eng.fetches} "
              f"hbm_hits={eng.hits}")


if __name__ == "__main__":
    main()
