"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state: callers decide when devices are materialized.

Target hardware: TPU v5e pods — 256 chips/pod arranged (16, 16) as
(data, model); the multi-pod mesh prepends a ``pod`` axis (2 pods = 512
chips).  Axis meanings:

  pod    cross-pod data parallelism (slow DCN/optical links; gradient
         all-reduce only, optionally int8-compressed)
  data   in-pod data parallelism + FSDP parameter sharding
  model  tensor/expert parallelism (fast ICI)
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(devices: int | None = None):
    """Small mesh over whatever devices exist (tests / smoke runs)."""
    n = devices or len(jax.devices())
    if n == 1:
        return jax.make_mesh((1, 1), ("data", "model"))
    d = max(1, n // 2)
    return jax.make_mesh((d, n // d), ("data", "model"))


# v5e hardware constants (roofline denominators).
PEAK_FLOPS_BF16 = 197e12       # per chip
HBM_BW = 819e9                 # bytes/s per chip
ICI_LINK_BW = 50e9             # bytes/s per link
CHIPS_PER_POD = 256
HBM_PER_CHIP = 16 * 1024 ** 3
