"""Training launcher: ``python -m repro.launch.train --arch <id> ...``

Selects an architecture config, builds the mesh-aware train step, and runs
steps with DDS checkpointing and the ring-prefetched pipeline.  On a real
TPU slice, mesh axes map onto the pod topology via ``make_production_mesh``;
on CPU the test mesh is used and widths can be scaled down.
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax

from repro.configs import ARCH_IDS, get_config, reduced_config
from repro.core.dds_server import DDSStorageServer, ServerConfig
from repro.data.pipeline import BatchSpec, TokenPipeline
from repro.models.registry import build_model
from repro.storage.checkpoint import CheckpointManager
from repro.train.loop import TrainConfig, Trainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="tinyllama_1p1b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--reduced", action="store_true",
                    help="reduced same-family config (CPU-runnable)")
    ap.add_argument("--compress-pod-grads", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)
    api = build_model(cfg)
    print(f"arch={cfg.name} family={cfg.family} "
          f"params~{cfg.param_count() / 1e9:.2f}B "
          f"devices={len(jax.devices())}")

    pipeline = TokenPipeline(BatchSpec(args.batch, args.seq, cfg.vocab_size),
                             seed=0)
    ckpt = CheckpointManager(
        DDSStorageServer(ServerConfig(device_capacity=1 << 30)), keep=3)
    tcfg = TrainConfig(peak_lr=args.lr, warmup_steps=max(2, args.steps // 10),
                       total_steps=args.steps, microbatch=args.microbatch,
                       compress_pod_grads=args.compress_pod_grads)
    trainer = Trainer(api, tcfg, pipeline, checkpoint_mgr=ckpt,
                      ckpt_every=args.ckpt_every)
    if trainer.restore_latest():
        print(f"resumed at step {trainer.step}")
    t0 = time.time()
    hist = trainer.run(args.steps)
    dt = time.time() - t0
    print(f"{args.steps} steps in {dt:.1f}s; "
          f"loss {hist[0]['loss']:.4f} -> {hist[-1]['loss']:.4f}")


if __name__ == "__main__":
    main()
