import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: for each cell
the appropriate step function (train_step / prefill / decode_step) is
lowered with explicit in/out shardings onto the production mesh
(single-pod 16x16 and multi-pod 2x16x16), compiled, and its
``memory_analysis()`` / ``cost_analysis()`` + collective-bytes breakdown
(parsed from the compiled HLO) are written to ``results/dryrun/*.json`` —
the inputs to the §Roofline analysis.

NOTE: the two lines above MUST run before any other import — jax locks the
device count at first initialization.

Usage:
  python -m repro.launch.dryrun --arch tinyllama_1p1b --shape train_4k
  python -m repro.launch.dryrun --all --mesh both
"""

import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import (SHAPES, ARCH_IDS, applicable_shapes, get_config)
from repro.distributed import sharding as sh
from repro.launch.mesh import (CHIPS_PER_POD, HBM_BW, ICI_LINK_BW,
                               PEAK_FLOPS_BF16, make_production_mesh)
from repro.models.registry import build_model
from repro.serve.engine import make_serve_fns
from repro.train.loop import TrainConfig, abstract_init, make_train_fn

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

_SHAPE_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*\(?([a-z0-9]+)\[([\d,]*)\][^=]*?"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)",
)

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "u64": 8, "s64": 8,
                "u32": 4, "s32": 4, "u16": 2, "s16": 2, "u8": 1, "s8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}


def collective_bytes(hlo_text: str) -> dict[str, float]:
    """Sum result bytes of every collective op in the compiled HLO."""
    out: dict[str, float] = {op: 0.0 for op in COLLECTIVE_OPS}
    counts: dict[str, int] = {op: 0 for op in COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        if not any(op in line for op in COLLECTIVE_OPS):
            continue
        m = _SHAPE_RE.match(line)
        if not m:
            continue
        dtype, dims, op = m.groups()
        if "-start" in line and f"{op}-start" not in line:
            pass
        nbytes = _DTYPE_BYTES.get(dtype, 4)
        for d in dims.split(","):
            if d:
                nbytes *= int(d)
        out[op] += nbytes
        counts[op] += 1
    out_counts = {f"n_{k}": v for k, v in counts.items()}
    return {**out, **out_counts}


def _ns_tree(mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def with_layers(cfg, n: int):
    """Same architecture with a reduced layer count (roofline two-point
    extrapolation: XLA cost analysis counts a scan body ONCE, so totals are
    reconstructed from two depths: body=(f(2u)-f(u))/u, total=f0+L*body)."""
    import dataclasses
    changes: dict = {"num_layers": n}
    if cfg.family == "encdec":
        changes.update(encoder_layers=max(1, n // 2),
                       decoder_layers=max(1, n // 2))
    return dataclasses.replace(cfg, **changes)


def layer_unit(cfg) -> int:
    """Layer-count granularity that keeps the arch's group structure valid."""
    if cfg.attention == "local_global":
        return cfg.group_size
    if cfg.family == "hybrid":
        return cfg.attn_every
    if cfg.family == "encdec":
        return 2
    return 1


def lower_cell(arch: str, shape_name: str, mesh, *, fsdp: bool = True,
               microbatch: int = 1, layers: int | None = None):
    """Lower the cell's step fn.  Returns (lowered, meta)."""
    cfg = get_config(arch)
    if layers is not None:
        cfg = with_layers(cfg, layers)
    api = build_model(cfg)
    shape = SHAPES[shape_name]
    specs = api.input_specs(shape)
    pshapes, axes = abstract_init(api)

    if shape.kind == "train":
        from repro.optim import AdamWState
        tcfg = TrainConfig(microbatch=microbatch, fsdp=fsdp)
        step = make_train_fn(api, tcfg)
        pspecs = sh.param_specs(axes, mesh, cfg, fsdp=fsdp)
        pspecs = sh.sanitize_tree(pspecs, pshapes, mesh)
        opt_specs = AdamWState(P(), pspecs, pspecs)
        bspecs = sh.batch_specs(mesh, shape, cfg)
        in_b = {k: bspecs.get(k, P(sh.dp_axes(mesh), None)) for k in specs}
        in_b = sh.sanitize_tree(in_b, specs, mesh)
        in_sh = (_ns_tree(mesh, pspecs), _ns_tree(mesh, opt_specs), None,
                 _ns_tree(mesh, in_b), NamedSharding(mesh, P()))
        out_sh = (_ns_tree(mesh, pspecs), _ns_tree(mesh, opt_specs), None,
                  _ns_tree(mesh, {"loss": P(), "grad_norm": P(), "lr": P()}))
        f32 = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
        opt_shapes = AdamWState(
            jax.ShapeDtypeStruct((), jnp.int32),
            jax.tree_util.tree_map(f32, pshapes),
            jax.tree_util.tree_map(f32, pshapes))
        stepno = jax.ShapeDtypeStruct((), jnp.int32)
        fn = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh)
        lowered = fn.lower(pshapes, opt_shapes, None, specs, stepno)
    elif shape.kind == "prefill":
        prefill_jit, _ = make_serve_fns(api, mesh, axes, shape)
        fn = prefill_jit(specs)
        lowered = fn.lower(pshapes, specs)
    else:  # decode
        _, decode_jit = make_serve_fns(api, mesh, axes, shape)
        fn = decode_jit(specs["cache"])
        lowered = fn.lower(pshapes, specs["cache"], specs["kv_len"],
                           specs["token"])
    return lowered, {"arch": arch, "shape": shape_name, "kind": shape.kind,
                     "cfg": cfg}


def analyze(lowered, compiled, mesh, cfg, shape_name) -> dict:
    cost = compiled.cost_analysis()
    mem = compiled.memory_analysis()
    nchips = 1
    for v in mesh.shape.values():
        nchips *= v
    flops = float(cost.get("flops", 0.0))
    bytes_acc = float(cost.get("bytes accessed", 0.0))
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    coll_total = sum(v for k, v in coll.items() if not k.startswith("n_"))
    # Per-chip roofline terms (seconds). cost_analysis is per-device on SPMD.
    compute_s = flops / PEAK_FLOPS_BF16
    memory_s = bytes_acc / HBM_BW
    collective_s = coll_total / ICI_LINK_BW
    shape = SHAPES[shape_name]
    if shape.kind == "train":
        model_flops = 6 * cfg.active_param_count() * shape.global_batch * shape.seq_len
    elif shape.kind == "prefill":
        model_flops = 2 * cfg.active_param_count() * shape.global_batch * shape.seq_len
    else:
        model_flops = 2 * cfg.active_param_count() * shape.global_batch
    out = {
        "nchips": nchips,
        "hlo_flops_per_chip": flops,
        "hlo_bytes_per_chip": bytes_acc,
        "collective_bytes_per_chip": coll_total,
        "collectives": coll,
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "dominant": max(("compute", compute_s), ("memory", memory_s),
                        ("collective", collective_s), key=lambda t: t[1])[0],
        "model_flops_global": model_flops,
        "useful_flops_ratio": (model_flops / (flops * nchips)
                               if flops else 0.0),
        "memory_analysis": {
            "argument_size_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_size_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_size_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "generated_code_size_bytes":
                getattr(mem, "generated_code_size_in_bytes", 0),
        },
    }
    return out


def run_cell(arch: str, shape_name: str, mesh_kind: str, outdir: str,
             *, fsdp: bool = True, microbatch: int = 1,
             verbose: bool = True, layers: int | None = None) -> dict:
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    rec: dict = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                 "status": "ok", "layers_override": layers,
                 "fsdp": fsdp, "microbatch": microbatch}
    try:
        # Batch-pinned activations help train/prefill (big activations,
        # FSDP weights) but hurt decode, where activations are tiny and the
        # cheap plan gathers THEM, not the 2D-sharded weights; decode mode
        # keeps only the KV-cache layout pins.  Per-arch pin_prefill lets
        # GLA-recurrence archs opt out for prefill (EXPERIMENTS §Perf).
        kind = SHAPES[shape_name].kind
        cfg0 = get_config(arch)
        mode = ("decode" if kind == "decode"
                or (kind == "prefill" and not cfg0.pin_prefill) else "train")
        with mesh, sh.activation_sharding_scope(mesh, mode):
            lowered, meta = lower_cell(arch, shape_name, mesh, fsdp=fsdp,
                                       microbatch=microbatch, layers=layers)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            rec.update(analyze(lowered, compiled, mesh, meta["cfg"],
                               shape_name))
            rec["lower_s"] = round(t_lower, 2)
            rec["compile_s"] = round(t_compile, 2)
            if verbose:
                print(compiled.memory_analysis())
                ca = compiled.cost_analysis()
                print({k: ca[k] for k in ("flops", "bytes accessed")
                       if k in ca})
    except Exception as e:  # a failure here is a bug in the system
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    os.makedirs(outdir, exist_ok=True)
    suffix = f"__L{layers}" if layers is not None else ""
    path = os.path.join(outdir,
                        f"{arch}__{shape_name}__{mesh_kind}{suffix}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1, default=str)
    if verbose:
        dom = rec.get("dominant", "-")
        print(f"[{rec['status']}] {arch} x {shape_name} x {mesh_kind} "
              f"dominant={dom} ({time.time() - t0:.1f}s)")
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--layers", type=int, default=None,
                    help="override layer count (roofline extrapolation)")
    args = ap.parse_args()

    cells: list[tuple[str, str]] = []
    if args.all:
        for arch in ARCH_IDS:
            for sname, status in applicable_shapes(arch).items():
                if status == "run":
                    cells.append((arch, sname))
                else:
                    rec = {"arch": arch, "shape": sname, "status": "skipped",
                           "reason": status}
                    os.makedirs(args.out, exist_ok=True)
                    for mk in (["single", "multi"] if args.mesh == "both"
                               else [args.mesh]):
                        with open(os.path.join(
                                args.out,
                                f"{arch}__{sname}__{mk}.json"), "w") as f:
                            json.dump(dict(rec, mesh=mk), f, indent=1)
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all")
        cells = [(args.arch, args.shape)]

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    failures = 0
    for arch, sname in cells:
        for mk in meshes:
            rec = run_cell(arch, sname, mk, args.out,
                           fsdp=not args.no_fsdp,
                           microbatch=args.microbatch, layers=args.layers)
            failures += rec["status"] == "error"
    if failures:
        raise SystemExit(f"{failures} cells failed")


if __name__ == "__main__":
    main()
