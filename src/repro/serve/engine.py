"""Serving engine: prefill/decode steps + DDS-backed KV-block offloading.

``make_serve_fns`` builds the pjit-able serve entry points the dry-run
lowers for the decode/prefill cells.

``PagedKVEngine`` is the DDS integration (DESIGN.md §2.2): KV blocks of a
long context are pages in a store.  Hot/recent blocks live "on the host"
(HBM pool, accessed via the paged-attention kernel's block table); cold
blocks spill to the DDS page store (storage server) and are fetched back
through the OFFLOAD path — cold, simple, read-only reads, exactly what the
paper offloads — while writes (new KV blocks) take the host path.

``BatchScheduler`` is a minimal continuous-batching front: requests join or
leave decode slots between steps.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.distributed import sharding as sh
from repro.models.registry import ModelAPI


PREFILL_2D_BYTES = 4 << 30   # 1D-TP weights above this per chip -> go 2D


def make_serve_fns(api: ModelAPI, mesh: Mesh, axes_tree,
                   shape: ShapeConfig, pshapes=None):
    """Returns (prefill_jit, decode_jit) with explicit shardings.

    DECODE always uses 2D weight sharding (model TP x data): weights stay
    stationary on both axes and the tiny decode activations move instead —
    16x less per-chip parameter traffic for the 132B MoE (§Perf it. 10).
    PREFILL has train-sized activations, so the per-layer weight gathers 2D
    costs only pay off when 1D-TP weights don't fit comfortably
    (> PREFILL_2D_BYTES/chip); small models keep 1D TP (the baseline-sweep
    regression on small-arch prefill cells motivated this split).
    """
    if pshapes is None:
        from repro.train.loop import abstract_init
        pshapes, _ = abstract_init(api)
    model_size = mesh.shape.get("model", 1)
    params_1d = sum(
        int(np.prod(p.shape)) * 2
        for p in jax.tree_util.tree_leaves(pshapes)) // max(1, model_size)
    prefill_fsdp = params_1d > PREFILL_2D_BYTES
    pspecs_prefill = sh.sanitize_tree(
        sh.param_specs(axes_tree, mesh, api.cfg, fsdp=prefill_fsdp),
        pshapes, mesh)
    pspecs = sh.sanitize_tree(
        sh.param_specs(axes_tree, mesh, api.cfg, fsdp=True), pshapes, mesh)
    dp = sh.dp_axes(mesh)
    ns = lambda s: NamedSharding(mesh, s)

    def decode_jit(cache_like):
        cspecs = sh.cache_specs(cache_like, mesh, api.cfg, shape)
        in_sh = (jax.tree_util.tree_map(ns, pspecs,
                                        is_leaf=lambda x: isinstance(x, P)),
                 jax.tree_util.tree_map(ns, cspecs,
                                        is_leaf=lambda x: isinstance(x, P)),
                 ns(P()),
                 ns(P(dp if shape.global_batch >= _ndp(mesh) else None, None)))
        out_sh = (ns(P(dp if shape.global_batch >= _ndp(mesh) else None,
                       None)),
                  jax.tree_util.tree_map(ns, cspecs,
                                         is_leaf=lambda x: isinstance(x, P)))
        return jax.jit(api.decode_step, in_shardings=in_sh,
                       out_shardings=out_sh)

    def prefill_jit(batch_like):
        bspecs = sh.batch_specs(mesh, shape, api.cfg)
        in_b = {k: ns(bspecs.get(k, P(dp, None))) for k in batch_like}
        in_sh = (jax.tree_util.tree_map(ns, pspecs_prefill,
                                        is_leaf=lambda x: isinstance(x, P)),
                 in_b)
        return jax.jit(api.prefill, in_shardings=in_sh)

    return prefill_jit, decode_jit


def _ndp(mesh: Mesh) -> int:
    n = 1
    for a in sh.dp_axes(mesh):
        n *= mesh.shape[a]
    return n


# ---------------------------------------------------------------------------
# DDS-backed paged KV offloading.
# ---------------------------------------------------------------------------


@dataclass
class KVBlockMeta:
    seq_id: int
    layer: int
    block: int
    version: int


class PagedKVEngine:
    """HBM block pool + DDS page store spillover for long-context decode.

    The HBM pool holds ``hbm_blocks`` KV pages; a block table maps
    (sequence, logical block) -> pool slot.  When the pool overflows, the
    coldest blocks are written to the DDS page store (HOST path — writes
    belong on the host, §3) and their slots recycled.  A query that needs a
    cold block triggers a fetch via the OFFLOAD path (DPU-served read).
    """

    def __init__(self, page_store, block_bytes: int, hbm_blocks: int):
        from repro.storage.pagestore import PageStore
        self.store = page_store
        self.block_bytes = block_bytes
        self.hbm_blocks = hbm_blocks
        self.pool: dict[int, tuple[int, int, int]] = {}  # slot -> (seq,layer,blk)
        self.where: dict[tuple[int, int, int], int] = {}  # key -> slot
        self.lru: deque = deque()
        self.versions: dict[tuple[int, int, int], int] = {}
        self.spills = 0
        self.fetches = 0
        self.hits = 0
        self._client = None
        self._page_ids: dict[tuple[int, int, int], int] = {}

    def _page_id(self, key: tuple[int, int, int]) -> int:
        """Dense page ids (the page store's file is offset = id * page_size)."""
        pid = self._page_ids.get(key)
        if pid is None:
            pid = len(self._page_ids)
            self._page_ids[key] = pid
        return pid

    def put_block(self, seq: int, layer: int, blk: int, data: bytes) -> int:
        """New KV block (decode write).  Returns the HBM slot."""
        key = (seq, layer, blk)
        ver = self.versions.get(key, 0) + 1
        self.versions[key] = ver
        if len(self.pool) >= self.hbm_blocks:
            self._evict_one()
        slot = self._free_slot()
        self.pool[slot] = key
        self.where[key] = slot
        self.lru.append(key)
        # Write-through to the store on the HOST path (durable + cacheable).
        self.store.replay(self._page_id(key), ver, data[: self.store.payload_size])
        return slot

    def _free_slot(self) -> int:
        used = set(self.pool)
        for s in range(self.hbm_blocks):
            if s not in used:
                return s
        raise RuntimeError("pool full after eviction")

    def _evict_one(self) -> None:
        while self.lru:
            key = self.lru.popleft()
            slot = self.where.get(key)
            if slot is not None and self.pool.get(slot) == key:
                del self.pool[slot]
                del self.where[key]
                self.spills += 1
                return

    def get_block(self, seq: int, layer: int, blk: int) -> bytes | None:
        """Fetch a block; cold blocks come back via the DPU offload path."""
        key = (seq, layer, blk)
        if key in self.where:
            self.hits += 1
            self.lru.append(key)  # refresh
            return None  # already in HBM; caller uses the block table
        from repro.core.dds_server import DDSClient, encode_batch
        from repro.storage.pagestore import PageStore
        if self._client is None:
            self._client = DDSClient(self.store.server)
        rid = self._client._next_req
        self._client._next_req += 1
        msg = PageStore.encode_get(rid, self._page_id(key),
                                   self.versions.get(key, 0))
        self._client._send(encode_batch([msg]))
        status, body = self._client.wait(rid)
        self.fetches += 1
        if status != 0:
            return None
        _, payload = PageStore.decode_page(body)
        return payload


# ---------------------------------------------------------------------------
# Continuous batching (minimal).
# ---------------------------------------------------------------------------


@dataclass
class Request:
    rid: int
    prompt: np.ndarray
    max_new: int
    generated: list[int] = field(default_factory=list)
    done: bool = False


class BatchScheduler:
    """Slot-based continuous batching over a fixed decode batch."""

    def __init__(self, api: ModelAPI, params, slots: int, cache_len: int):
        self.api = api
        self.params = params
        self.slots = slots
        self.cache_len = cache_len
        self.queue: deque[Request] = deque()
        self.active: list[Request | None] = [None] * slots
        self.kv_len = 0
        self.cache = api.init_cache(slots, cache_len)
        self.tokens = np.zeros((slots, 1), np.int32)
        self._decode = jax.jit(api.decode_step)

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _admit(self) -> None:
        for i in range(self.slots):
            if self.active[i] is None and self.queue:
                req = self.queue.popleft()
                self.active[i] = req
                self.tokens[i, 0] = int(req.prompt[-1])

    def step(self) -> int:
        """One decode step for all active slots; returns #completed."""
        self._admit()
        if not any(self.active):
            return 0
        logits, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(self.kv_len, jnp.int32),
            jnp.asarray(self.tokens))
        self.kv_len = min(self.kv_len + 1, self.cache_len - 1)
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        done = 0
        for i, req in enumerate(self.active):
            if req is None:
                continue
            tok = int(nxt[i])
            req.generated.append(tok)
            self.tokens[i, 0] = tok
            if len(req.generated) >= req.max_new:
                req.done = True
                self.active[i] = None
                done += 1
        return done
