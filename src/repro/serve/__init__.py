"""Serving layer."""
