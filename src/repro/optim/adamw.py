"""AdamW with decoupled weight decay and global-norm clipping.

Pure-pytree implementation (no optax dependency).  Optimizer state inherits
the parameter sharding: under GSPMD the first/second moments partition the
same way the parameters do, so FSDP/TP sharding of a model automatically
ZeRO-shards its optimizer state.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    count: jnp.ndarray
    mu: Any
    nu: Any


def adamw_init(params: Any) -> AdamWState:
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return AdamWState(
        count=jnp.zeros((), jnp.int32),
        mu=jax.tree_util.tree_map(zeros, params),
        nu=jax.tree_util.tree_map(zeros, params),
    )


def global_norm(tree: Any) -> jnp.ndarray:
    sq = jax.tree_util.tree_map(
        lambda g: jnp.sum(jnp.square(g.astype(jnp.float32))), tree)
    return jnp.sqrt(sum(jax.tree_util.tree_leaves(sq)))


def clip_by_global_norm(grads: Any, max_norm: float) -> tuple[Any, jnp.ndarray]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale, grads), norm


def adamw_update(grads: Any, state: AdamWState, params: Any,
                 lr: jnp.ndarray | float,
                 b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1,
                 max_grad_norm: float | None = 1.0) -> tuple[Any, AdamWState, jnp.ndarray]:
    """Returns (new_params, new_state, pre-clip grad norm)."""
    if max_grad_norm is not None:
        grads, norm = clip_by_global_norm(grads, max_grad_norm)
    else:
        norm = global_norm(grads)
    count = state.count + 1
    c = count.astype(jnp.float32)
    bc1 = 1.0 - b1 ** c
    bc2 = 1.0 - b2 ** c

    def upd(g, m, v, p):
        g32 = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g32
        v = b2 * v + (1 - b2) * jnp.square(g32)
        step = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
        # Decoupled weight decay only on matrices/embeddings (ndim >= 2).
        wd = weight_decay if p.ndim >= 2 else 0.0
        newp = p.astype(jnp.float32) - lr * (step + wd * p.astype(jnp.float32))
        return newp.astype(p.dtype), m, v

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(count, new_m, new_v), norm
