"""Int8 error-feedback gradient compression for cross-pod all-reduce.

At multi-pod scale the pod-to-pod (data-center network / optical ICI) links
are the slowest hop of the gradient all-reduce.  Compressing gradients to
int8 with per-tensor scales cuts the cross-pod collective bytes 4x
(fp32->int8) while error feedback keeps the *accumulated* quantization error
bounded: the residual of each round is added back before the next
quantization, so the compressed-SGD fixed point matches the exact one.

Usage in the train step (pod axis only — intra-pod reduces stay exact):

    grads = shard_map(lambda g: psum_int8(g, 'pod'), ...)(grads)

``compress_tree``/``decompress_tree`` are also used stand-alone by the
checkpoint delta path.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class CompressionState(NamedTuple):
    error: Any  # per-leaf residual feedback


def init_compression(params: Any) -> CompressionState:
    return CompressionState(
        error=jax.tree_util.tree_map(
            lambda p: jnp.zeros_like(p, dtype=jnp.float32), params))


def _quantize(x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compress_tree(grads: Any, state: CompressionState
                  ) -> tuple[Any, Any, CompressionState]:
    """Returns (int8 tree, scale tree, new state with residuals)."""
    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = treedef.flatten_up_to(state.error)
    qs, scales, errs = [], [], []
    for g, e in zip(flat_g, flat_e):
        x = g.astype(jnp.float32) + e
        q, s = _quantize(x)
        errs.append(x - _dequantize(q, s))  # error feedback residual
        qs.append(q)
        scales.append(s)
    return (treedef.unflatten(qs), treedef.unflatten(scales),
            CompressionState(treedef.unflatten(errs)))


def decompress_tree(qtree: Any, scales: Any) -> Any:
    return jax.tree_util.tree_map(_dequantize, qtree, scales)


def compressed_ratio(grads: Any) -> float:
    """Bytes saved: int8+scale vs fp32 payload."""
    total = sum(g.size * 4 for g in jax.tree_util.tree_leaves(grads))
    comp = sum(g.size + 4 for g in jax.tree_util.tree_leaves(grads))
    return comp / total
