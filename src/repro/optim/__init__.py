"""Optimizers, LR schedules, gradient compression."""

from repro.optim.adamw import AdamWState, adamw_init, adamw_update
from repro.optim.schedules import warmup_cosine
from repro.optim.compression import (CompressionState, compress_tree,
                                     decompress_tree, init_compression)

__all__ = ["AdamWState", "adamw_init", "adamw_update", "warmup_cosine",
           "CompressionState", "compress_tree", "decompress_tree",
           "init_compression"]
