r"""DDS storage server: wires rings + file service + director + offload engine.

This is the deployable unit of the paper (Fig 6): one storage server host
with a DPU.  It also defines the storage-disaggregated benchmark application
of §8.1 (random file I/O over the network, batched requests) whose OffPred /
OffFunc are the paper's 30/20-line examples — reads encode file id, offset
and size directly, so ``Cache``/``Invalidate`` are not needed; writes go to
the host.

Components and their threads (all cooperatively schedulable for tests):

  client --> director.ingress --(signature+predicate)--> offload engine --> SSD
         \-> (host-bound) --> split connection --> host app (DDS front end)
                                                     --> rings --> file service --> SSD

``DDSStorageServer.pump()`` drives every component one step; ``run_until_idle``
loops until no component has work, giving deterministic end-to-end tests.
"""

from __future__ import annotations

import struct
import threading
from collections import deque
from dataclasses import dataclass, field

import numpy as np

from repro.core import vector, wire
from repro.core.cache_table import CacheTable
from repro.core.file_service import FileServiceRunner, SegmentFS
from repro.core.host_lib import DDSFrontEnd
from repro.core.lifecycle import (ClientLatency, LifecycleTracker, TickClock,
                                  TickHistogram)
from repro.core.offload import OffloadAPI, OffloadEngine, ReadOp, WriteOp
from repro.core.qos import QoSProfile, TenantAdmission
from repro.core.ring import DMAEngine
from repro.core.traffic import (ApplicationSignature, FiveTuple, Packet,
                                TrafficDirector, FLAG_SYN)
from repro.storage.blockdev import BlockDevice

# ---------------------------------------------------------------------------
# The benchmark application protocol (§8.1).
# ---------------------------------------------------------------------------

APP_READ = 1
APP_WRITE = 2
APP_HDR = struct.Struct("<BQIQI")        # type, req_id, file_id, offset, nbytes
APP_RESP_HDR = struct.Struct("<QII")     # req_id, status, nbytes


def encode_app_read(req_id: int, file_id: int, offset: int, nbytes: int) -> bytes:
    return APP_HDR.pack(APP_READ, req_id, file_id, offset, nbytes)


def encode_app_write(req_id: int, file_id: int, offset: int, data) -> bytes:
    """Encode a write request; ``data`` may be bytes or a memoryview.

    ``join`` consumes buffer views directly, so a memoryview source is
    copied exactly once — into the outgoing message — never materialized
    into an intermediate ``bytes`` first."""
    return b"".join((APP_HDR.pack(APP_WRITE, req_id, file_id, offset,
                                  len(data)), data))


def encode_batch(msgs: list[bytes]) -> bytes:
    """Batch several app messages into one network message (§6.1 batching).

    The generator join is the fast encode kernel on CPython: the
    array-at-a-time pack (:func:`repro.core.vector.pack_frames`) only
    reaches parity around 4096 uniform frames (crossover measured by
    ``benchmarks/micro/kernels_ab.py``), so it is reserved for bursts of
    that scale and the join keeps every realistic batch.
    """
    if len(msgs) >= 4096:
        return bytes(vector.pack_frames(msgs))
    return b"".join(struct.pack("<I", len(m)) + m for m in msgs)


_BATCH_LEN = struct.Struct("<I")


def decode_batch(payload) -> list[memoryview]:
    """Split a batched network message into per-message ZERO-COPY views.

    Messages are ``memoryview`` slices of the packet buffer — header fields
    unpack in place (``Struct.unpack_from`` accepts views) and payload bytes
    are never duplicated on the decode path.  Callers that need a hashable
    key (cache-table lookups) convert just that field with ``bytes(...)``.

    A large fixed-stride batch is proven uniform with one array compare
    (:func:`repro.core.vector.uniform_stride`) and sliced columnar — no
    per-message length unpack; anything irregular falls through to the
    scalar walk.
    """
    mv = payload if isinstance(payload, memoryview) else memoryview(payload)
    out, off, end = [], 0, len(mv)
    if end >= 512:
        u = vector.uniform_stride(mv, 4, 0, min_frames=20)
        if u is not None:
            cnt, stride, _ = u
            out = [mv[i * stride + 4:(i + 1) * stride] for i in range(cnt)]
            off = cnt * stride
    unpack = _BATCH_LEN.unpack_from
    while off < end:
        (n,) = unpack(mv, off)
        off += 4
        out.append(mv[off : off + n])
        off += n
    return out


def reassemble_responses(rx: bytearray, responses: dict,
                         order: list | None = None) -> int:
    """Peel complete APP_RESP_HDR-framed responses off a client rx buffer.

    Shared by every client (single-server and cluster shard connections) so
    the framing logic lives in exactly one place.  The buffer is parsed with
    a running offset and consumed bytes are trimmed ONCE at the end (the old
    per-response ``del rx[:total]`` made a buffer of n small responses cost
    O(n^2) byte moves); a trailing partial response is left for the next
    call.  Returns the number of responses extracted."""
    n = 0
    off, end = 0, len(rx)
    hdr_size = APP_RESP_HDR.size
    unpack = APP_RESP_HDR.unpack_from
    mv = memoryview(rx)
    if end >= 512:
        # Uniform-stride fast path: one structured-dtype view decodes the
        # req-id / status columns for the whole burst (the nbytes word at
        # offset 12 doubles as the stride proof); payload copies and dict
        # fills remain per-response, header unpacking does not.  A
        # trailing partial frame (or a differently-sized tail) falls
        # through to the scalar walk below.
        u = vector.uniform_stride(mv, hdr_size, 12, min_frames=20)
        if u is not None:
            cnt, stride, nbytes = u
            cols = np.frombuffer(mv, count=cnt, dtype=np.dtype(
                {"names": ["rid", "status"], "formats": ["<u8", "<u4"],
                 "offsets": [0, 8], "itemsize": stride}))
            rids = cols["rid"].tolist()
            stats = cols["status"].tolist()
            del cols   # drop the buffer export before the trim below
            # ONE strided gather peels every payload out of the burst; the
            # per-response work shrinks to a C-level bytes slice.
            blob = np.frombuffer(mv, dtype=np.uint8, count=cnt * stride) \
                .reshape(cnt, stride)[:, hdr_size:].tobytes()
            for i, rid in enumerate(rids):
                s = i * nbytes
                responses[rid] = (stats[i], blob[s:s + nbytes])
            if order is not None:
                order.extend(rids)
            off = cnt * stride
            n = cnt
    while end - off >= hdr_size:
        req_id, status, nbytes = unpack(mv, off)
        total = hdr_size + nbytes
        if end - off < total:
            break
        responses[req_id] = (status, bytes(mv[off + hdr_size : off + total]))
        if order is not None:
            order.append(req_id)
        off += total
        n += 1
    # A bytearray with an exported view cannot be resized: release first.
    mv.release()
    if off:
        del rx[:off]
    return n


def drain_client_flow(director, resp_flow, rx: bytearray, responses: dict,
                      order: list | None = None) -> int:
    """THE response-drain implementation every client shares.

    Takes this flow's (possibly segmented) packets off the director's
    demuxed ``to_client`` wire in one O(1) swap — no scanning past other
    clients' traffic — appends their payloads to the connection rx buffer,
    and reassembles completed responses.  Returns packets drained."""
    pkts = director.to_client.drain_flow(resp_flow)
    if not pkts:
        return 0
    release: list[int] = []
    pool = None
    payloads = []
    payload_append = payloads.append
    for pkt in pkts:
        if pkt.csum != -1 and vector.checksum64(pkt.payload) != pkt.csum:
            # Stamped checksum mismatch: the frame was damaged in flight.
            # Discard it as a loss — the client's timeout/resend machinery
            # recovers the response; delivering torn bytes would poison the
            # rx stream reassembly below.
            director.stats.corrupt_dropped += 1
            pkt.consumed()
            continue
        payload_append(pkt.payload)
        ref = pkt.pool_ref
        if ref is not None:   # TX-completion: reclaim the pool block
            pkt.pool_ref = None
            pool = ref[0]
            release.append(ref[1])
    # One join + one extend: n small bytearray appends would realloc the
    # rx buffer piecemeal and re-touch its tail n times.
    if payloads:
        rx += b"".join(payloads) if len(payloads) > 1 else payloads[0]
    if release:
        pool.release_many(release)  # one lock round for the whole drain
    reassemble_responses(rx, responses, order)
    return len(pkts)


def default_off_pred(payload: bytes, table) -> tuple[list[bytes], list[bytes]]:
    """The paper's simple example: reads -> DPU, writes -> host (§6.1).

    On a uniform batch the opcode bytes form one strided column; when they
    are ALL reads (the hot-path shape) the split is decided with a single
    array compare instead of a per-message branch.
    """
    mv = payload if isinstance(payload, memoryview) else memoryview(payload)
    end = len(mv)
    if end >= 512:
        u = vector.uniform_stride(mv, 4, 0, min_frames=20)
        if u is not None and u[0] * u[1] == end:
            cnt, stride, _ = u
            ops = np.frombuffer(mv, dtype=np.uint8,
                                count=cnt * stride).reshape(cnt, stride)[:, 4]
            if (ops == APP_READ).all():
                return [], [mv[i * stride + 4:(i + 1) * stride]
                            for i in range(cnt)]
    host, dpu = [], []
    for m in decode_batch(mv):
        if m and m[0] == APP_READ:
            dpu.append(m)
        else:
            host.append(m)
    return host, dpu


def default_off_func(msg: bytes, table) -> ReadOp | None:
    """File id/offset/size are encoded in the request (§8.2 footnote 4)."""
    typ, req_id, file_id, offset, nbytes = APP_HDR.unpack_from(msg, 0)
    if typ != APP_READ:
        return None
    return ReadOp(file_id, offset, nbytes)


def app_response_header(msg: bytes, op: ReadOp, err: int) -> bytes:
    if msg:
        _, req_id, *_ = APP_HDR.unpack_from(msg, 0)
    else:
        req_id = 0
    return APP_RESP_HDR.pack(req_id, err, op.size if err == wire.E_OK else 0)


def default_prepare_read(msg, table) -> tuple[ReadOp, bytes] | None:
    """Fused OffFunc + ok-response-header: ONE header parse per request."""
    typ, req_id, file_id, offset, nbytes = APP_HDR.unpack_from(msg, 0)
    if typ != APP_READ:
        return None
    return (ReadOp(file_id, offset, nbytes),
            APP_RESP_HDR.pack(req_id, wire.E_OK, nbytes))


# Lifecycle classifier for the §8.1 benchmark protocol: which message type
# bytes are reads (everything else counts as a write / mutation).
DEFAULT_READ_TYPES = frozenset({APP_READ})


@dataclass
class ServerConfig:
    """Structural sizing of one server + its scheduling/QoS policy.

    The per-feature scheduling knobs that accreted over PRs 3-5
    (``coalesce_ticks``, ``coalesce_cap``, ``prio_interleave``,
    ``deliver_ticks``, ``host_drain_slice``, ``read_write_fence``,
    ``device_queue_depth``) now live on :class:`~repro.core.qos.QoSProfile`
    together with the tenancy controls (weights, token-bucket rates).
    ``qos`` accepts a profile instance or a preset name
    (``"latency"`` / ``"throughput"`` / ``"isolation"``).
    """

    device_capacity: int = 1 << 28          # 256 MiB RAM "SSD"
    segment_size: int = 1 << 20
    server_port: int = 5000
    director_cores: int = 1
    offload_ring: int = 256
    offload_pool: int = 1 << 24
    zero_copy: bool = True
    userspace_stack: bool = True             # TLDK vs Linux-on-DPU (Fig 19)
    cache_items: int = 1 << 16
    offload_enabled: bool = True             # False => all requests to host
    # Requests pulled per engine step (and ingress demux burst).  The
    # vectorized data plane amortizes its fixed numpy cost over this burst:
    # raise it for throughput-bound storms, keep the default for
    # latency-sensitive configs (a pulled request commits to this step).
    offload_burst: int = 64
    qos: QoSProfile | str = field(default_factory=QoSProfile)
    # Crash consistency: segments reserved for the SegmentFS redo journal
    # (0 disables journaling; silently disabled on devices too small to
    # hold metadata + journal + one data segment).
    journal_segments: int = 2
    # Replication factor for a DDSCluster built from this config: each
    # shard's acked writes are forwarded to this many ring-successor
    # replicas BEFORE the client sees the ack.  0 = unreplicated.
    replication: int = 0
    # Failover detection: ticks of heartbeat silence before the cluster
    # supervisor counts one missed window; promotion fires only after
    # ``heartbeat_miss_windows`` CONSECUTIVE missed windows, so a single
    # delayed/partitioned heartbeat blip cannot false-promote a live
    # primary.  Detection latency is
    # ``heartbeat_miss_windows * (heartbeat_timeout_ticks + 1)`` pumps.
    heartbeat_timeout_ticks: int = 16
    heartbeat_miss_windows: int = 2
    # Lossy-network survival (see README "Network fault model"): when set,
    # every wire frame (requests, host/DPU responses) is stamped with a
    # ``vector.checksum64`` of its payload and verified at the receive
    # edge — a bit-corrupted frame is discarded as a loss instead of
    # delivering torn bytes, and the client's timeout/resend recovers it.
    wire_checksums: bool = False
    # Exactly-once mutations: per-(flow, request-id) server-side dedup /
    # reply cache capacity (completed entries; in-flight markers are
    # bounded by the in-flight window).  A resent mutation whose original
    # is still executing is suppressed; one whose ack was already sent
    # replays the CACHED ack without re-executing.  0 disables.
    dedup_cache: int = 1024
    # End-to-end integrity: per-4KiB media checksums on the block device,
    # refreshed at every write commit (including the torn-writev prefix)
    # and verified on every read — a corrupted-media read completes E_IO
    # instead of returning garbage.  Journal records carry their own body
    # checksum regardless of this knob (replay always verifies).
    verify_checksums: bool = False

    def __post_init__(self):
        if self.journal_segments < 0 or self.replication < 0:
            raise ValueError("journal_segments/replication must be >= 0")
        if self.heartbeat_timeout_ticks < 1:
            raise ValueError("heartbeat_timeout_ticks must be >= 1")
        if self.heartbeat_miss_windows < 1:
            raise ValueError("heartbeat_miss_windows must be >= 1")
        if self.dedup_cache < 0:
            raise ValueError("dedup_cache must be >= 0")
        if isinstance(self.qos, str):
            self.qos = QoSProfile.preset(self.qos)
        elif isinstance(self.qos, dict):
            self.qos = QoSProfile.from_dict(self.qos)
        elif not isinstance(self.qos, QoSProfile):
            raise ValueError(f"ServerConfig.qos must be a QoSProfile, "
                             f"preset name, or dict; got {self.qos!r}")


# Admission sheds happen BEFORE any execution path parses the message, so
# the rid for the terminal mark comes from the protocol layout: both the
# §8.1 app header (<BQIQI) and the KV headers (<BQ...) carry req_id as a
# u64 at byte offset 1.
_REQ_ID_U64_AT_1 = struct.Struct("<Q")

# Dedup-cache miss sentinel: ``None`` is a real value (pending marker).
_DEDUP_MISS = object()


def default_req_id_of(msg) -> int:
    return _REQ_ID_U64_AT_1.unpack_from(msg, 1)[0]


class DDSStorageServer:
    """One storage server host + its DPU (Fig 6)."""

    def __init__(self, config: ServerConfig | None = None,
                 api: OffloadAPI | None = None):
        self.config = config or ServerConfig()
        cfg = self.config
        # Work-signaled scheduling (see distributed.cluster.DDSCluster):
        # ``signal()`` marks this server runnable in whatever scheduler owns
        # it.  Installed via ``set_doorbell``; standalone servers run with
        # no doorbell and ``signal`` is a no-op.
        self._doorbell = None
        # Deterministic request-lifecycle clock: ONE tick per pump step.  A
        # standalone server owns (and ticks) its own; a DDSCluster installs
        # its shared clock via ``adopt_clock`` and ticks once per cluster
        # pump instead.
        self.clock = TickClock()
        self._owns_clock = True
        q = cfg.qos
        self.device = BlockDevice(cfg.device_capacity,
                                  queue_depth=q.device_queue_depth,
                                  prio_interleave=q.prio_interleave)
        self.device.doorbell = self.signal
        self.device.clock = self.clock
        if cfg.verify_checksums:
            self.device.enable_checksums()
        js = cfg.journal_segments
        if cfg.device_capacity // cfg.segment_size < 2 + js:
            js = 0   # device too small for a journal: run unjournaled
        self.fs = SegmentFS(self.device, cfg.segment_size,
                            journal_segments=js)
        self.dma = DMAEngine()
        self.cache_table = CacheTable(cfg.cache_items)
        self.api = api or OffloadAPI(default_off_pred, default_off_func,
                                     prepare_read=default_prepare_read)
        self.lifecycle = LifecycleTracker(
            self.clock, self.api.read_types or DEFAULT_READ_TYPES)
        # Traffic director: signature matches any client talking to our port.
        sig = (ApplicationSignature(dst_port=cfg.server_port)
               if cfg.offload_enabled else
               ApplicationSignature(dst_port=-1))  # match nothing: host-only
        self.director = TrafficDirector(
            sig, self.api.off_pred, self.cache_table,
            ncores=cfg.director_cores, host_port=cfg.server_port,
            userspace_stack=cfg.userspace_stack)
        # Frame integrity: stamp responses (and have clients stamp
        # requests) with payload checksums; the receive edges verify and
        # discard corrupt frames as losses.
        self.director.stamp_checksums = cfg.wire_checksums
        # Tenancy: weighted-fair service on the offload queue and the host
        # wire's drain; token-bucket admission (when configured) sheds at
        # the demux via the lifecycle tracker's terminal marks.
        self.director.offload_queue.weight_of = q.weight_of
        self.director.to_host.weight_of = q.weight_of
        self.admission: TenantAdmission | None = None
        if q.admission_enabled():
            self.admission = TenantAdmission(q, self.clock)
            self.director.admit = self.admission.admit
            self.director.on_shed = self._on_admission_shed
        # File service with cache-on-write / invalidate-on-read hooks (§6.1).
        # Hooks are wired ONLY when the application actually installed the
        # Table-1 functions — the default §8.1 app has neither, and a None
        # hook lets the write path skip per-request cache bookkeeping.
        self.file_service = FileServiceRunner(
            self.fs, self.dma, zero_copy=cfg.zero_copy,
            cache_hook=(self._cache_on_write
                        if self.api.cache is not None else None),
            invalidate_hook=(self._invalidate_on_read
                             if self.api.invalidate is not None else None),
            clock=self.clock,
            coalesce_ticks=q.coalesce_ticks,
            deliver_ticks=q.deliver_ticks,
            coalesce_cap=q.coalesce_cap,
            shed_hook=self._on_shed)
        if q.read_write_fence:
            self.file_service.track_writes = True
        self.offload = OffloadEngine(
            self.fs, self.director, self.api, self.cache_table,
            ring_size=cfg.offload_ring, pool_size=cfg.offload_pool,
            zero_copy=cfg.zero_copy,
            app_header=self.api.response_header or app_response_header)
        self.offload.lifecycle = self.lifecycle
        if q.read_write_fence:
            self.offload.busy_files = self.file_service.write_inflight
        self._host_drain_slice = q.host_drain_slice
        self._offload_burst = cfg.offload_burst
        # The host storage application, adopting the DDS front-end library.
        # Its request rings ring our doorbell on every producer publish.
        self.frontend = DDSFrontEnd(self.file_service, doorbell=self.signal)
        self.host_app = _HostApp(self)
        self.host_cpu_busy_s = 0.0   # modeled host CPU seconds consumed
        # Primary-backup replication (installed by DDSCluster when
        # ``config.replication`` > 0): forwards acked writes to replica
        # shards and gates client write acks on replica acks.
        self.replicator = None
        # Live-migration tap (installed by the resharding driver while this
        # shard is a migration SOURCE): dual-routes writes for keys moving
        # to their new owner and can hold client acks until the destination
        # holds the bytes — the replicator's sibling on the same hooks.
        self.migrator = None

    # -- work-signaled scheduling hooks --------------------------------------------
    def set_doorbell(self, doorbell) -> None:
        """Install the scheduler's mark-runnable callback (cluster layer)."""
        self._doorbell = doorbell

    def adopt_clock(self, clock: TickClock) -> None:
        """Share a scheduler-owned tick clock (cluster layer): every stamp
        point — device, file service, rings, lifecycle — rebinds to it, and
        this server stops ticking in ``pump`` (the owner ticks, once per
        scheduling step, keeping tick latencies comparable across shards)."""
        self.clock = clock
        self._owns_clock = False
        self.device.clock = clock
        self.lifecycle.clock = clock
        self.file_service.adopt_clock(clock)
        if self.admission is not None:
            self.admission.clock = clock   # buckets refill on the shared clock
        if self.replicator is not None:
            self.replicator.clock = clock

    def _on_shed(self, frontend_rid: int) -> None:
        """A host-path request was shed (bounded E_NOSPC path gave up).

        Three things must happen or the system wedges in a busy-forever
        state with a client spinning on a timeout: the host app's in-flight
        entry is dropped, the front-end's booked op is cancelled (so
        ``any_outstanding`` clears), and the ORIGINAL application request is
        marked shed in the lifecycle tracker, where the client's ``wait``
        finds the terminal status."""
        info = self.host_app._inflight.pop(frontend_rid, None)
        self.frontend.cancel(frontend_rid)
        if info is None:
            # Either not an application op (a direct front-end user), or the
            # shed fired INSIDE frontend.submit_many (the ring-full
            # on_retry re-entrantly steps the file service) — before
            # _execute_burst could record the in-flight meta.  Park the rid:
            # the host app reconciles it right after booking, so the mark
            # is never lost and the meta never leaks.
            self.host_app._orphan_sheds.add(frontend_rid)
            return
        host_flow, _typ, req_id = info[:3]
        # The shed request will never complete: clear its dedup pending
        # marker so a client retry is executed as a fresh request instead
        # of being suppressed against an execution that died.
        self.host_app._dedup.pop((host_flow, req_id), None)
        client_flow = self.director._client_flow_of.get(host_flow, host_flow)
        # Overload sheds carry a minimal hint: the tenant plus retry-after 1
        # (the bounded E_NOSPC path gave up THIS tick; next tick may admit).
        self.lifecycle.mark_shed(
            client_flow, req_id,
            wire.encode_shed_hint(getattr(client_flow, "tenant", 0), 1))

    def _on_admission_shed(self, client_flow: FiveTuple, msg) -> None:
        """Token-bucket admission dropped ``msg`` at the director's demux.

        The request never reaches any execution path, so the terminal mark
        is made here — keyed by the ORIGINAL client flow and the request id
        extracted straight from the message header — with the shedding
        tenant's bucket state (retry-after ticks) as the E_SHED hint."""
        req_id_of = self.api.req_id_of or default_req_id_of
        hint = wire.encode_shed_hint(
            client_flow.tenant, self.admission.retry_after(client_flow.tenant))
        self.lifecycle.mark_shed(client_flow, req_id_of(msg), hint)

    def _on_stale_epoch(self, client_flow: FiveTuple, payload,
                        current_epoch: int) -> None:
        """A packet tagged with a pre-failover ring epoch hit the director.

        Its requests are refused wholesale with a retryable terminal
        redirect (the shed plumbing's sibling): each request id is marked
        ``E_REDIRECT`` in the lifecycle tracker with the CURRENT epoch as
        the hint, so the client re-routes on the repaired ring and
        resubmits the same ids."""
        req_id_of = self.api.req_id_of or default_req_id_of
        hint = wire.encode_redirect_hint(current_epoch)
        for m in decode_batch(payload):
            self.lifecycle.mark_redirect(client_flow, req_id_of(m), hint)

    def signal(self) -> None:
        """Mark this server runnable.  Called by every work producer: client
        sends into the director's ingress, ring inserts, block-device
        submissions/synchronous completions.  No-op standalone."""
        db = self._doorbell
        if db is not None:
            db()

    def busy(self) -> bool:
        """True while pumping this server could make progress.

        THE no-lost-wakeup predicate: the cluster scheduler re-arms a
        stepped server while this holds, so a server with queued ingress,
        undrained offload work, in-flight contexts, pending device
        completions, or host-path state can never be parked.  Quiescence
        (``pump() == 0``) is deliberately weaker — a shed request leaves an
        application op permanently outstanding without making the server
        non-idle — which is why ``run_until_idle`` keeps its idle-sweep
        escape hatch.  Ordered cheapest-first; every probe is lock-free.
        """
        return (self.device.busy()
                or self.offload.in_flight()
                or self.director.busy()
                or self.host_app.busy()
                or self.file_service.busy()
                or self.frontend.any_outstanding()
                or (self.replicator is not None and self.replicator.busy())
                or (self.migrator is not None and self.migrator.busy()))

    # -- §6.1 hooks: translate file-service ops into user Cache/Invalidate ----------
    # (called with plain header fields: the file service's data plane keeps
    # no per-request objects, see FileServiceRunner._submit_burst)
    def _cache_on_write(self, file_id: int, offset: int, payload) -> None:
        if self.api.cache is not None:
            self.offload.on_host_write(WriteOp(file_id, offset, payload))

    def _invalidate_on_read(self, file_id: int, offset: int, nbytes: int) -> None:
        if self.api.invalidate is not None:
            self.offload.on_host_read(ReadOp(file_id, offset, nbytes))

    # -- cooperative event loop ---------------------------------------------------------
    def pump(self) -> int:
        """One scheduling step, in PRIORITY order.

        The tick clock advances first (standalone servers; a cluster ticks
        its shared clock once per cluster pump).  Then the step is the
        early-priority-demux discipline: ingress is classified, the offload
        engine serves predicate-positive reads — which also ride the
        device's priority queue — BEFORE any host-path work runs, and the
        host wire is drained in a bounded slice so one hot flow cannot
        monopolize the step."""
        if self._owns_clock:
            self.clock.tick()
        burst = self._offload_burst
        work = self.director.step_n(burst)  # whole ingress burst, 1 lock round
        work += self.offload.step(burst)  # polls device + completes internally
        if self.replicator is not None:
            work += self.replicator.step()   # forwarded writes + replica acks
        host_work = self.host_app.step(self._host_drain_slice)
        # The host path (file service rings + completion polling) only runs
        # when it can have work; the offloaded fast path never pays for it.
        if host_work or self._host_path_busy():
            work += self.file_service.step()
            self.device.poll()
            work += self.offload.complete_pending()
            work += self.host_app.poll_completions()
        return work + host_work

    def latency_stats(self) -> dict:
        """Measured tick-latency distributions (see README)."""
        dev = self.device.stats
        out = {"classes": self.lifecycle.summary()}
        if self.admission is not None:
            out["admission"] = self.admission.summary()
        if self.replicator is not None:
            out["replication"] = self.replicator.summary()
        if self.fs.journal_replayed_records:
            out["journal_replay"] = {
                "records": self.fs.journal_replayed_records,
                "bytes": self.fs.journal_replayed_bytes}
        if dev.completion_ticks.n:
            out["device"] = dev.completion_ticks.summary()
        if dev.prio_completion_ticks.n:
            out["device_prio"] = dev.prio_completion_ticks.summary()
        res = TickHistogram()
        for g in self.file_service.groups.values():
            if g.req_ring.residency is not None:
                res.merge(g.req_ring.residency)
        if res.n:
            out["ring_residency"] = res.summary()
        ds = self.director.stats
        if ds.corrupt_dropped or ds.seq_resyncs or ds.dpu_bypassed:
            out["wire"] = {"corrupt_dropped": ds.corrupt_dropped,
                           "seq_resyncs": ds.seq_resyncs,
                           "dpu_bypassed": ds.dpu_bypassed}
        ha = self.host_app
        if ha.dup_suppressed or ha.replayed_acks:
            out["exactly_once"] = {"dup_suppressed": ha.dup_suppressed,
                                   "replayed_acks": ha.replayed_acks}
        return out

    def _host_path_busy(self) -> bool:
        return (self.host_app.busy()
                or self.frontend.any_outstanding()
                or self.file_service.busy())

    def run_until_idle(self, max_iters: int = 200_000) -> None:
        idle = 0
        for _ in range(max_iters):
            if self.pump() == 0:
                self.device.drain()
                idle += 1
                if idle >= 3:
                    return
            else:
                idle = 0
        raise TimeoutError("server did not go idle")


class _HostApp:
    """The storage application on the host, using the DDS front-end library.

    Executes host-bound requests (writes, non-offloadable reads) and replies
    through the traffic director.  Each request costs modeled host CPU time —
    this is what Figs 2/14 measure and what offloading eliminates.
    """

    # Modeled per-request host costs (µs), calibrated to §1/§8 (Fig 2:
    # network module dominates; 17 cores @156K pages/s ≈ 109 µs/page total).
    HOST_NET_US = 45.0     # DBMS network module + OS stack per request
    HOST_FS_US = 25.0      # OS file system / storage stack per request
    HOST_APP_US = 10.0     # request parsing, bookkeeping

    def __init__(self, server: DDSStorageServer):
        self.server = server
        self._inflight: dict[int, tuple] = {}  # rid -> (host_flow, app req)
        self._burst: list[tuple] = []          # (host_flow, msg) drained batch
        # Write acks gated on replication: locally durable, awaiting the
        # replica's ack (rid -> (host_flow, req_id, error, body, t0)).  The
        # client NEVER sees an ack for bytes a shard crash could lose.
        self._held_acks: dict[int, tuple] = {}
        # Rids shed during frontend.submit_many's re-entrant file-service
        # step, BEFORE their in-flight meta was recorded (see
        # DDSStorageServer._on_shed); reconciled right after booking.
        self._orphan_sheds: set[int] = set()
        self._files_ready = False
        # Exactly-once mutation dedup / reply cache (armed by
        # ``ServerConfig.dedup_cache``): (host_flow, req_id) -> None while
        # the original execution is in flight (a resend is suppressed; the
        # eventual ack answers both), or the completed ack bytes (a resend
        # replays the CACHED ack without re-executing — a resent KV PUT
        # must not append a second log record).  Only COMPLETED entries
        # enter the FIFO eviction queue; pending markers are bounded by
        # the in-flight window and removed on shed.
        self._dedup_cap = server.config.dedup_cache
        self._dedup: dict[tuple, bytes | None] = {}
        self._dedup_fifo: deque[tuple] = deque()
        self.dup_suppressed = 0
        self.replayed_acks = 0

    def _dedup_complete(self, key: tuple, resp: bytes) -> None:
        """Record a mutation's final ack for replay; FIFO-evict old acks."""
        if key not in self._dedup:
            return   # marker was shed/evicted: nothing to fill
        self._dedup[key] = resp
        fifo = self._dedup_fifo
        fifo.append(key)
        while len(fifo) > self._dedup_cap:
            old = fifo.popleft()
            # Only completed entries ride the FIFO, so eviction can never
            # kill a pending marker (a later completion with the same key
            # re-appends; the stale queue entry is then a no-op pop).
            if self._dedup.get(old) is not None:
                self._dedup.pop(old, None)

    def busy(self) -> bool:
        """True while host requests are in flight (pump must keep stepping)."""
        return bool(self._inflight) or bool(self._held_acks)

    def step(self, max_pkts: int | None = None) -> int:
        """Drain a bounded slice of the host wire, then execute the WHOLE
        burst in one pass.

        Collect-then-execute lets the file I/O of a burst issue through
        ``DDSFrontEnd.submit_many`` (bulk rid reservation + one ring
        reservation per group) instead of one ring round trip per message.
        ``max_pkts`` bounds the slice per pump step (tail-latency: a hot
        flow's backlog cannot delay other flows' responses a whole step —
        the remainder stays on the wire and the server stays runnable)."""
        n = self.server.director.drain_host_wire(self._collect, max_pkts)
        if self._burst:
            self._execute_burst()
        return n

    def _collect(self, host_flow: FiveTuple, payload) -> None:
        if not payload:
            return  # SYN/control packet hardware-forwarded to the host
        burst = self._burst
        if host_flow.src_ip == "dpu-proxy":
            # PEP split connection: one app message.  Keep it a zero-copy
            # view — write payloads ride it into the request ring untouched.
            burst.append((host_flow,
                          payload if isinstance(payload, memoryview)
                          else memoryview(payload)))
            return
        # hw-forwarded original batch; the HOST app owns its messages
        # (it indexes/hashes them), so materialize real bytes here —
        # host-path copies are exactly what offloading avoids.
        for m in decode_batch(payload):
            burst.append((host_flow, bytes(m)))

    def _execute_burst(self) -> None:
        msgs = self._burst
        self._burst = []
        srv = self.server
        handler = srv.api.host_handler
        hdr_size = APP_HDR.size
        # Lifecycle ingress stamp: rides the in-flight meta tuple (no
        # per-request tracker state).  Taken at burst execution, which runs
        # in the SAME pump step (same tick) as the director's demux unless
        # a bounded drain slice deferred the packet.
        now = srv.clock.now
        lt = srv.lifecycle
        read_types = lt.read_types
        dedup = self._dedup if self._dedup_cap else None
        req_id_of = srv.api.req_id_of or default_req_id_of
        submits: list[tuple] = []   # ("w"|"r", file_id, offset, data|nbytes)
        metas: list[tuple] = []  # (host_flow, typ, req_id, nbytes, ack, t0, dkey)
        responses: dict[FiveTuple, list] = {}  # immediate 'resp' actions
        n_resp = 0
        for host_flow, m in msgs:
            typ = m[0] if m else 0
            # Exactly-once mutations: the dedup check MUST run before the
            # handler — a KV PUT mutates index/log state inside the
            # handler, so a resent PUT reaching it would apply twice.
            dkey = None
            if dedup is not None and typ not in read_types and len(m) >= 9:
                dkey = (host_flow, req_id_of(m))
                prev = dedup.get(dkey, _DEDUP_MISS)
                if prev is not _DEDUP_MISS:
                    if prev is None:
                        # Original still executing: drop the resend; the
                        # eventual (single) ack answers both copies.
                        self.dup_suppressed += 1
                    else:
                        # Already acked: replay the CACHED ack verbatim.
                        self.replayed_acks += 1
                        n_resp += 1
                        responses.setdefault(host_flow, []).append(prev)
                    continue
                dedup[dkey] = None   # pending marker
            if typ not in (APP_READ, APP_WRITE) and handler is not None:
                action = handler(m)
                kind = action[0]
                if kind == "resp":
                    _, req_id, status, body = action
                    n_resp += 1
                    # Served inline this tick: a zero-delta completion.
                    cls = "host_read" if typ in read_types else "write"
                    lt.hist[cls].add(0)
                    if host_flow.tenant:
                        lt.add_tenant(host_flow.tenant, cls, 0)
                    resp = APP_RESP_HDR.pack(req_id, status, len(body)) + body
                    if dkey is not None:
                        self._dedup_complete(dkey, resp)
                    responses.setdefault(host_flow, []).append(resp)
                elif kind == "w":
                    # ('w', req_id, fid, off, data[, resp_body]) — the
                    # optional 6th element is echoed in the write ack (e.g.
                    # a KV PUT returning its on-disk location, §9.2).
                    _, req_id, file_id, offset, data = action[:5]
                    submits.append(("w", file_id, offset, data))
                    metas.append((host_flow, APP_WRITE, req_id, len(data),
                                  action[5] if len(action) > 5 else b"", now,
                                  dkey))
                else:
                    _, req_id, file_id, offset, nbytes = action
                    submits.append(("r", file_id, offset, nbytes))
                    metas.append((host_flow, APP_READ, req_id, nbytes, b"",
                                  now, dkey))
                continue
            typ, req_id, file_id, offset, nbytes = APP_HDR.unpack_from(m, 0)
            if typ == APP_WRITE:
                submits.append(("w", file_id, offset,
                                m[hdr_size : hdr_size + nbytes]))
            else:
                submits.append(("r", file_id, offset, nbytes))
            metas.append((host_flow, typ, req_id, nbytes, b"", now, dkey))
        # Modeled host CPU: network + app cost PER MESSAGE (batching the
        # simulator does not change what the host cores would burn), plus
        # the network cost of each immediate response.
        srv.host_cpu_busy_s += ((self.HOST_NET_US + self.HOST_APP_US)
                                * len(msgs) + self.HOST_NET_US * n_resp) * 1e-6
        for flow, batch in responses.items():
            srv.director.host_response_many(flow, batch)
        if submits:
            rids = srv.frontend.submit_many(submits)
            inflight = self._inflight
            for rid, meta in zip(rids, metas):
                inflight[rid] = meta
            repl = srv.replicator
            if repl is not None:
                # Primary-backup forward at the one point where the final
                # on-disk bytes are known (KV handlers rewrite payloads into
                # log records): the replica applies the identical bytes at
                # the identical file offset through its own host path.
                for rid, sub in zip(rids, submits):
                    if sub[0] == "w":
                        repl.forward(rid, sub[1], sub[2], sub[3])
            mig = srv.migrator
            if mig is not None:
                # Live migration dual-route: writes whose key already moved
                # (or is moving) to a new owner are synced to the
                # destination; during the dual-write phase the client ack is
                # additionally held until the destination acked.
                for rid, sub in zip(rids, submits):
                    if sub[0] == "w":
                        mig.forward(rid, sub[1], sub[2], sub[3])
            orphans = self._orphan_sheds
            if orphans:
                # A shed fired inside submit_many (re-entrant ring-full
                # step) before the meta above existed: finish the terminal
                # marking now so nothing leaks and clients see E_SHED.  An
                # orphan that does not match this burst was a direct
                # front-end user's op (never ours) — drop it.
                lt = srv.lifecycle
                cf_of = srv.director._client_flow_of
                for rid in orphans:
                    meta = inflight.pop(rid, None)
                    if meta is not None:
                        if meta[6] is not None:
                            self._dedup.pop(meta[6], None)
                        cf = cf_of.get(meta[0], meta[0])
                        lt.mark_shed(cf, meta[2], wire.encode_shed_hint(
                            getattr(cf, "tenant", 0), 1))
                orphans.clear()

    def poll_completions(self) -> int:
        srv = self.server
        inflight = self._inflight
        per_flow: dict[FiveTuple, list] = {}
        n = 0
        hist = srv.lifecycle.hist
        now = srv.clock.now
        r_add = hist["host_read"].add
        w_add = hist["write"].add
        tenant_add = srv.lifecycle.add_tenant
        repl = srv.replicator
        mig = srv.migrator
        for gid in list(srv.frontend._groups):
            for c in srv.frontend.poll_wait(gid, 0.0):
                info = inflight.pop(c.request_id, None)
                if info is None:
                    continue
                host_flow, typ, req_id, nbytes, ack_body, t0, dkey = info
                if (typ != APP_READ
                        and ((repl is not None and repl.holds(c.request_id))
                             or (mig is not None
                                 and mig.holds(c.request_id)))):
                    # Locally durable but the replica has not acked: HOLD
                    # the client ack (released below once the replica — or
                    # the supervisor dropping a dead replica — signs off).
                    body = ack_body if c.error == wire.E_OK else b""
                    self._held_acks[c.request_id] = (host_flow, req_id,
                                                     c.error, body, t0, dkey)
                    continue
                delta = now - t0
                if typ == APP_READ:
                    body = c.data if c.error == wire.E_OK else b""
                    r_add(delta)   # response-publish lifecycle stamp
                else:
                    body = ack_body if c.error == wire.E_OK else b""
                    w_add(delta)
                if host_flow.tenant:
                    tenant_add(host_flow.tenant,
                               "host_read" if typ == APP_READ else "write",
                               delta)
                resp = APP_RESP_HDR.pack(req_id, c.error, len(body)) + body
                if dkey is not None:
                    self._dedup_complete(dkey, resp)
                per_flow.setdefault(host_flow, []).append(resp)
                n += 1
        held = self._held_acks
        if held and (repl is not None or mig is not None):
            for rid in [r for r in held
                        if not (repl is not None and repl.holds(r))
                        and not (mig is not None and mig.holds(r))]:
                host_flow, req_id, err, body, t0, dkey = held.pop(rid)
                delta = now - t0
                w_add(delta)
                if host_flow.tenant:
                    tenant_add(host_flow.tenant, "write", delta)
                resp = APP_RESP_HDR.pack(req_id, err, len(body)) + body
                if dkey is not None:
                    self._dedup_complete(dkey, resp)
                per_flow.setdefault(host_flow, []).append(resp)
                n += 1
        if n:
            srv.host_cpu_busy_s += self.HOST_NET_US * 1e-6 * n  # response path
            for flow, batch in per_flow.items():
                srv.director.host_response_many(flow, batch)
        return n


# Unified-surface op spellings -> the wire batch kind ("r"/"w").
_OP_KIND = {"r": "r", "read": "r", "w": "w", "write": "w"}


class DDSClient:
    """A compute-server client for the benchmark app (batching, outstanding).

    ``tenant`` binds once per connection: every request issued through this
    client rides a flow carrying that tenant id, which the server's QoS
    layer (weighted-fair demux, token-bucket admission, per-tenant stats)
    keys on.  The unified burst surface is :meth:`submit` /
    :meth:`harvest`; ``write_many``/``send_batch`` remain as thin
    deprecated wrappers.
    """

    def __init__(self, server: DDSStorageServer, ip: str = "10.0.0.2",
                 port: int = 31337, tenant: int = 0,
                 timeout_ticks: int = 0):
        self.server = server
        self.flow = FiveTuple(ip, port, "10.0.0.1", server.config.server_port,
                              tenant=tenant)
        self.tenant = tenant
        self._resp_flow = self.flow.reversed()
        self._seq = 1  # after SYN
        self._next_req = 1
        self._lock = threading.Lock()
        self.responses: dict[int, tuple[int, bytes]] = {}
        self._rx_buf = bytearray()
        # Issue-tick stamps + end-to-end (issue -> drain) per-class latency
        # (read/write; the dpu/host split for reads is exact in the
        # server's lifecycle histograms).
        self._issued_r: dict[int, int] = {}
        self._issued_w: dict[int, int] = {}
        self.latency = ClientLatency()
        # Ring epoch this client believes in, stamped on every packet.  -1
        # (the default) means epoch-unaware: the director accepts untagged
        # packets unconditionally.  Epoch-aware clients (>= 0) additionally
        # keep each outstanding request's encoded message so an E_REDIRECT
        # can be answered by resubmitting the SAME request id.
        self.epoch = -1
        self._replay: dict[int, bytes] = {}
        # Lossy-wire recovery: after ``timeout_ticks`` of silence ``wait``
        # resends the request from its replay note with doubled backoff
        # (the server's dedup cache makes the resend exactly-once).  0 =
        # timeouts off (lossless-wire behavior, the default).
        self.timeout_ticks = timeout_ticks
        self.timeouts = 0
        self.resends = 0
        server.director.ingress.push(Packet(self.flow, 0, b"", flags=FLAG_SYN))
        server.signal()
        server.director.step()

    def _send(self, payload: bytes) -> None:
        pkt = Packet(self.flow, self._seq, payload, epoch=self.epoch)
        if self.server.director.stamp_checksums:
            pkt.csum = vector.checksum64(payload)
        self.server.director.ingress.push(pkt)
        self._seq += len(payload)
        self.server.signal()   # client sends are a scheduler wakeup source

    def read(self, file_id: int, offset: int, nbytes: int) -> int:
        with self._lock:
            rid = self._next_req
            self._next_req += 1
        self._issued_r[rid] = self.server.clock.now
        msg = encode_app_read(rid, file_id, offset, nbytes)
        if self.epoch >= 0 or self.timeout_ticks:
            self._replay[rid] = msg
        self._send(encode_batch([msg]))
        return rid

    def write(self, file_id: int, offset: int, data: bytes) -> int:
        with self._lock:
            rid = self._next_req
            self._next_req += 1
        self._issued_w[rid] = self.server.clock.now
        msg = encode_app_write(rid, file_id, offset, data)
        if self.epoch >= 0 or self.timeout_ticks:
            self._replay[rid] = msg
        self._send(encode_batch([msg]))
        return rid

    # -- unified burst surface --------------------------------------------------------
    def submit(self, ops: list[tuple]) -> list[int]:
        """Issue a burst of operations in ONE network message; returns one
        handle (request id) per op, in order.

        Ops are ``("r"|"read", file_id, offset, nbytes)`` or
        ``("w"|"write", file_id, offset, data)``.  The connection's tenant
        rides the flow, so tenant context is carried once per batch — never
        per call.  Harvest results with :meth:`harvest`.
        """
        return self.send_batch([(_OP_KIND[op[0]],) + tuple(op[1:])
                                for op in ops])

    def harvest(self, handles=None, block: bool = True,
                max_iters: int = 200_000) -> dict[int, tuple[int, bytes]]:
        """Collect responses: ``{handle: (status, body)}``.

        ``handles=None`` harvests whatever has already arrived (one drain;
        never pumps).  With explicit handles and ``block=True`` this pumps
        until EVERY handle resolves — requests the server shed terminally
        resolve as ``(wire.E_SHED, hint)`` where the hint decodes with
        :func:`repro.core.wire.decode_shed_hint`.
        """
        self.collect()
        responses = self.responses
        if handles is None:
            out = dict(responses)
            responses.clear()
            return out
        out = {}
        lt = self.server.lifecycle
        pending = [rid for rid in handles if rid not in responses]
        for rid in handles:
            if rid in responses:
                out[rid] = responses.pop(rid)
        if not block:
            for rid in list(pending):
                term = lt.take_terminal(self.flow, rid)
                if term is not None:
                    self._issued_r.pop(rid, None)
                    self._issued_w.pop(rid, None)
                    self._replay.pop(rid, None)
                    out[rid] = term
                    pending.remove(rid)
            return out
        for rid in pending:
            out[rid] = self.wait(rid, max_iters)
        return {rid: out[rid] for rid in handles if rid in out}

    def send_batch(self, msgs: list[tuple]) -> list[int]:
        """msgs: list of ("r", fid, off, n) / ("w", fid, off, data).

        Deprecated spelling of :meth:`submit` (kept as a thin wrapper
        target; prefer ``submit``, which also accepts the long op names).
        """
        encoded, rids = [], []
        now = self.server.clock.now
        with self._lock:
            for m in msgs:
                rid = self._next_req
                self._next_req += 1
                rids.append(rid)
                if m[0] == "r":
                    encoded.append(encode_app_read(rid, m[1], m[2], m[3]))
                    self._issued_r[rid] = now
                else:
                    encoded.append(encode_app_write(rid, m[1], m[2], m[3]))
                    self._issued_w[rid] = now
        if self.epoch >= 0 or self.timeout_ticks:
            for rid, msg in zip(rids, encoded):
                self._replay[rid] = msg
        self._send(encode_batch(encoded))
        return rids

    def write_many(self, writes: list[tuple]) -> list[int]:
        """Issue a burst of ``(file_id, offset, data)`` writes in ONE
        network message — the write-side mirror of the cluster client's
        ``read_many``: one rid-range reservation, one batched send."""
        n = len(writes)
        with self._lock:
            first = self._next_req
            self._next_req += n
        rids = list(range(first, first + n))
        now = self.server.clock.now
        for rid in rids:
            self._issued_w[rid] = now
        self._send(encode_batch([encode_app_write(rid, fid, off, data)
                                 for rid, (fid, off, data)
                                 in zip(rids, writes)]))
        return rids

    # -- response collection ---------------------------------------------------------
    def collect(self) -> int:
        """Drain OUR flow's responses off the demuxed client wire (shared
        implementation with the cluster's shard connections)."""
        order: list[int] = []
        n = drain_client_flow(self.server.director, self._resp_flow,
                              self._rx_buf, self.responses, order)
        if order:
            self._record_latency(order)
        return n

    def _record_latency(self, rids: list[int]) -> None:
        """End-to-end issue->drain ticks, classified read/write at issue."""
        latency = self.latency
        now = self.server.clock.now
        reads = self._issued_r
        writes = self._issued_w
        for rid in rids:
            t0 = reads.pop(rid, None)
            if t0 is not None:
                latency.record("read", now - t0)
                continue
            t0 = writes.pop(rid, None)
            if t0 is not None:
                latency.record("write", now - t0)

    def wait(self, rid: int, max_iters: int = 200_000) -> tuple[int, bytes]:
        # ``pump()`` already polls the device whenever the offload engine or
        # the host path is busy; the old unconditional per-spin
        # ``device.poll()`` here was pure overhead on idle iterations.
        lt = self.server.lifecycle
        tmo = self.timeout_ticks
        clock = self.server.clock
        deadline = clock.now + tmo if tmo else None
        attempt = 0
        for _ in range(max_iters):
            self.collect()
            if rid in self.responses:
                self._replay.pop(rid, None)
                return self.responses.pop(rid)
            if deadline is not None and clock.now >= deadline:
                # Tick-based timeout: the request or its response was lost
                # on the wire.  Resend from the replay note with doubled
                # backoff — the server's dedup cache suppresses the copy
                # (or replays the cached ack) if the original survived.
                msg = self._replay.get(rid)
                if msg is not None:
                    self.timeouts += 1
                    self.resends += 1
                    self._send(encode_batch([msg]))
                attempt += 1
                deadline = clock.now + (tmo << min(attempt, 6))
            term = lt.take_terminal(self.flow, rid)
            if term is not None:
                code, hint = term
                if code == wire.E_REDIRECT and rid in self._replay:
                    # Retryable: adopt the repaired ring's epoch and
                    # resubmit the SAME request id (the old owner never
                    # answered it, so the id cannot alias).
                    self.epoch = max(self.epoch,
                                     wire.decode_redirect_hint(hint))
                    self._send(encode_batch([self._replay[rid]]))
                    continue
                # Terminal: the request was shed under overload or by
                # admission — no response will EVER arrive.  Surface it
                # (with the retry-after hint as the body) instead of
                # spinning the full iteration budget into a timeout.
                self._issued_r.pop(rid, None)
                self._issued_w.pop(rid, None)
                self._replay.pop(rid, None)
                return (code, hint)
            self.server.pump()
        raise TimeoutError(f"no response for request {rid}")
