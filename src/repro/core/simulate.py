"""Calibrated performance model of the DDS testbed (§8).

The container has no BlueField-2, NVMe SSD, or 100 Gbps NIC, so the paper's
*absolute* hardware numbers are reproduced with an explicit queueing model:
every storage solution is a pipeline of stages, each with a per-request CPU
cost on some resource (host cores / DPU Arm cores / SSD / wire), a base
latency, and a capacity.  Throughput is capped by the slowest stage; latency
is the sum of base latencies inflated by M/M/1-style contention; host CPU
cores consumed = throughput x per-request host CPU time.

Stage constants are CALIBRATED to the paper's measured anchors (cited inline)
— the model is a reproduction of the paper's *numbers and relationships*, not
an independent measurement.  The relative, hardware-independent claims (ring
design, zero-copy, cache table) are measured for real in ``benchmarks/``.

Anchors (paper §8-§9):
  * baseline TCP+NTFS reads:   390 K IOPS peak, 10.7 host cores, 11 ms    (Figs 14a/15a)
  * DDS front-end (host) read: 580 K IOPS peak,  6.5 host cores, ~1.8 ms  (6x lower)
  * DDS offloaded reads:       730 K IOPS peak,  ~0 host cores, 780 us    (Figs 14a/15a)
  * zero-copy off:             520 K IOPS peak, 250 us @peak              (Fig 23)
  * writes: baseline 210 K @48 ms tail; DDS files 290 K @3 ms tail        (Figs 14b/15b)
  * Hyperscale page server: 90 K @4.4 ms p99 -> DDS 160 K @1.3 ms         (Fig 24)
  * FASTER KV: 340 K op/s @20 cores, 13/18 ms -> DDS 970 K, 0 cores, 300 us (Figs 25/26)
  * TCP echo: DPU halves RTT (Fig 4); TLDK 3x lower than Linux-on-DPU (Fig 19)
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field


@dataclass
class Stage:
    name: str
    where: str                 # 'host' | 'dpu' | 'ssd' | 'wire'
    cpu_us: float = 0.0        # busy time per request on this resource
    latency_us: float = 0.0    # uncontended per-request latency
    servers: float = 1.0       # parallel servers (cores, queue slots)
    cap_kiops: float = math.inf


@dataclass
class Solution:
    name: str
    stages: list[Stage]
    note: str = ""
    tail_factor: float = 3.0     # p99 / p50 at load

    def peak_kiops(self) -> float:
        peak = math.inf
        for s in self.stages:
            peak = min(peak, s.cap_kiops)
            if s.cpu_us > 0:
                peak = min(peak, s.servers * 1e3 / s.cpu_us)  # kiops
        return peak

    def base_latency_us(self) -> float:
        return sum(s.latency_us for s in self.stages)

    def evaluate(self, target_kiops: float) -> "Operating":
        ach = min(target_kiops, self.peak_kiops() * 0.999)
        host_cores = sum(ach * 1e3 * s.cpu_us * 1e-6
                         for s in self.stages if s.where == "host")
        dpu_cores = sum(ach * 1e3 * s.cpu_us * 1e-6
                        for s in self.stages if s.where == "dpu")
        # Single bounded-utilization M/M/1-style inflation: at the operating
        # peak every solution runs at u=0.9 => x5.26 over its base latency.
        u = min(0.9, ach / max(self.peak_kiops(), 1e-9) * 0.9)
        infl = 1.0 / (1.0 - u * u)
        p50 = self.base_latency_us() * infl
        p99 = p50 * self.tail_factor
        return Operating(self.name, ach, host_cores, dpu_cores, p50, p99)


@dataclass
class Operating:
    name: str
    kiops: float
    host_cores: float
    dpu_cores: float
    p50_us: float
    p99_us: float


# ---------------------------------------------------------------------------
# Calibrated stage libraries (1 KB random reads unless noted).
# ---------------------------------------------------------------------------

def _ssd(cap_kiops: float = 733.0) -> Stage:
    # 1 TB NVMe: ~730 K 1KB IOPS ceiling observed by DDS offloading (Fig 14a).
    return Stage("ssd", "ssd", latency_us=95.0, servers=128, cap_kiops=cap_kiops)


def baseline_tcp_ntfs_read() -> Solution:
    """(5) Windows sockets TCP/IP + NTFS: 390 K peak, 10.7 cores, 11 ms."""
    return Solution("tcp+windows-files", [
        Stage("dbms-net", "host", cpu_us=14.0, latency_us=120.0, servers=17,
              cap_kiops=391.0),
        Stage("os-net", "host", cpu_us=6.4, latency_us=60.0, servers=17),
        Stage("os-fs", "host", cpu_us=5.0, latency_us=1810.0, servers=17),
        Stage("app", "host", cpu_us=2.0, latency_us=10.0, servers=17),
        _ssd(),
    ], note="baseline of Figs 14/15")


def dds_frontend_read() -> Solution:
    """(6) TCP + DDS files: host keeps network; file exec on the DPU."""
    return Solution("tcp+dds-files", [
        Stage("dbms-net", "host", cpu_us=8.0, latency_us=120.0, servers=10,
              cap_kiops=581.0),
        Stage("os-net", "host", cpu_us=2.2, latency_us=60.0, servers=10),
        Stage("dds-lib", "host", cpu_us=1.0, latency_us=5.0, servers=10),
        Stage("dma-ring", "dpu", cpu_us=0.6, latency_us=8.0, servers=1),
        Stage("dpu-file-svc", "dpu", cpu_us=1.0, latency_us=12.0, servers=1),
        _ssd(),
    ], note="DDS front-end library; 6x latency cut (Fig 15a)")


def dds_offload_read(zero_copy: bool = True) -> Solution:
    """(9) full DDS offloading: requests never touch the host.
    3 Arm cores (§7): DMA, SPDK file service, director+engine colocated."""
    copies = 0.0 if zero_copy else 0.55    # per-request Arm memcpy time
    cap = 733.0 if zero_copy else 521.0    # Fig 23: 730 K vs 520 K
    lat = 14.0 if zero_copy else 22.0      # Fig 23: 170 us vs 250 us at peak
    return Solution("dds-offload" + ("" if zero_copy else "-nocopy"), [
        Stage("td+offload-engine", "dpu", cpu_us=1.2 + copies, latency_us=lat,
              servers=1, cap_kiops=cap),
        Stage("dpu-file-svc", "dpu", cpu_us=1.1, latency_us=12.0, servers=1),
        _ssd(cap),
    ], note="zero host CPU; 780 us @730 K (Fig 15a)")


def baseline_write() -> Solution:
    return Solution("tcp+windows-files-write", [
        Stage("dbms-net", "host", cpu_us=14.0, latency_us=120.0, servers=12,
              cap_kiops=211.0),
        Stage("os-net", "host", cpu_us=6.4, latency_us=60.0, servers=12),
        Stage("os-fs-write", "host", cpu_us=8.0, latency_us=2850.0,
              servers=12),
        _ssd(290.0),
    ], note="48 ms tail at 210 K (Fig 15b)")


def dds_frontend_write() -> Solution:
    return Solution("tcp+dds-files-write", [
        Stage("dbms-net", "host", cpu_us=8.0, latency_us=60.0, servers=8,
              cap_kiops=291.0),
        Stage("os-net", "host", cpu_us=2.2, latency_us=60.0, servers=8),
        Stage("dds-lib", "host", cpu_us=1.0, latency_us=5.0, servers=8),
        Stage("dma-ring", "dpu", cpu_us=0.6, latency_us=8.0, servers=1),
        Stage("dpu-file-svc", "dpu", cpu_us=1.2, latency_us=30.0, servers=1),
        Stage("ssd-write", "ssd", latency_us=30.0, servers=128,
              cap_kiops=320.0),
    ], note="3 ms tail at 290 K (Fig 15b)")


# -- Fig 16: the ten solutions ---------------------------------------------------

def detailed_comparison() -> list[Solution]:
    local_ntfs = Solution("local+windows-files", [
        Stage("os-fs", "host", cpu_us=5.0, latency_us=140.0, servers=6,
              cap_kiops=452.0),
        _ssd(),
    ], note="(1) local SSD via NTFS")
    local_dds = Solution("local+dds-files", [
        Stage("dds-lib", "host", cpu_us=1.0, latency_us=5.0, servers=4,
              cap_kiops=733.0),
        Stage("dma-ring", "dpu", cpu_us=0.6, latency_us=8.0, servers=1),
        Stage("dpu-file-svc", "dpu", cpu_us=1.0, latency_us=12.0, servers=1),
        _ssd(),
    ], note="(2) local files executed on the DPU")
    smb = Solution("smb", [
        Stage("smb-stack", "host", cpu_us=30.0, latency_us=700.0, servers=8,
              cap_kiops=121.0),
        Stage("os-fs", "host", cpu_us=5.0, latency_us=140.0, servers=8),
        _ssd(),
    ], note="(3) Windows remote file service")
    smb_direct = Solution("smb-direct", [
        Stage("smb-rdma", "host", cpu_us=16.0, latency_us=260.0, servers=8,
              cap_kiops=182.0),
        Stage("os-fs", "host", cpu_us=5.0, latency_us=140.0, servers=8),
        _ssd(),
    ], note="(4) SMB over RDMA")
    redy_win = Solution("redy+windows-files", [
        Stage("redy-rpc", "host", cpu_us=9.0, latency_us=25.0, servers=4,
              cap_kiops=733.0),   # burns polling cores on both ends
        Stage("os-fs", "host", cpu_us=5.0, latency_us=140.0, servers=8),
        _ssd(),
    ], note="(7) RDMA RPC + host files; polls cores")
    redy_dds = Solution("redy+dds-files", [
        Stage("redy-rpc", "host", cpu_us=9.0, latency_us=25.0, servers=4,
              cap_kiops=733.0),
        Stage("dds-lib", "host", cpu_us=1.0, latency_us=5.0, servers=4),
        Stage("dma-ring", "dpu", cpu_us=0.6, latency_us=8.0, servers=1),
        Stage("dpu-file-svc", "dpu", cpu_us=1.0, latency_us=12.0, servers=1),
        _ssd(),
    ], note="(8) low latency, but client+server poll cores")
    dds_rdma = Solution("dds-offload-rdma", [
        Stage("rdma-nic", "dpu", cpu_us=0.8, latency_us=3.0, servers=1,
              cap_kiops=733.0),
        Stage("offload-engine", "dpu", cpu_us=1.2, latency_us=6.0, servers=1),
        Stage("dpu-file-svc", "dpu", cpu_us=1.0, latency_us=12.0, servers=1),
        _ssd(),
    ], note="(10) near-local cost/latency")
    return [local_ntfs, local_dds, smb, smb_direct,
            baseline_tcp_ntfs_read(), dds_frontend_read(),
            redy_win, redy_dds, dds_offload_read(), dds_rdma]


# -- §9 integrations -----------------------------------------------------------------

def hyperscale_page_server(dds: bool) -> Solution:
    """GetPage@LSN serving (8 KB pages, RBPEX on local SSD) — Fig 24."""
    if not dds:
        return Solution("hyperscale-baseline", [
            Stage("sql-net", "host", cpu_us=60.0, latency_us=90.0, servers=17,
                  cap_kiops=91.0),
            Stage("os-fs", "host", cpu_us=14.0, latency_us=60.0, servers=17),
            Stage("ssd-8k", "ssd", latency_us=130.0, servers=128, cap_kiops=180.0),
        ], note="4.4 ms p99 @90 K (Fig 24)")
    return Solution("hyperscale-dds", [
        Stage("tldk", "dpu", cpu_us=2.2, latency_us=8.0, servers=1,
              cap_kiops=161.0),
        Stage("offload-engine", "dpu", cpu_us=1.6, latency_us=6.0, servers=1),
        Stage("dpu-file-svc", "dpu", cpu_us=1.4, latency_us=12.0, servers=1),
        Stage("ssd-8k", "ssd", latency_us=130.0, servers=128, cap_kiops=185.0),
    ], note="1.3 ms @160 K (Fig 24)", tail_factor=1.6)


def faster_kv(dds: bool) -> Solution:
    """YCSB uniform reads on disaggregated FASTER (8 B kv) — Figs 25/26."""
    if not dds:
        return Solution("faster-baseline", [
            Stage("kv-net", "host", cpu_us=40.0, latency_us=400.0, servers=20,
                  cap_kiops=341.0),
            Stage("faster-index", "host", cpu_us=6.0, latency_us=30.0, servers=20),
            Stage("idevice", "host", cpu_us=12.0, latency_us=2000.0, servers=20),
            Stage("ssd-rec", "ssd", latency_us=95.0, servers=128,
                  cap_kiops=400.0),
        ], note="20 cores, 13/18 ms @340 K (Figs 25/26)", tail_factor=1.4)
    return Solution("faster-dds", [
        Stage("tldk", "dpu", cpu_us=1.6, latency_us=8.0, servers=2,
              cap_kiops=971.0),
        Stage("offload-engine", "dpu", cpu_us=0.8, latency_us=6.0, servers=1),
        Stage("dpu-file-svc", "dpu", cpu_us=0.6, latency_us=12.0, servers=1),
        Stage("ssd-rec", "ssd", latency_us=40.0, servers=128,
              cap_kiops=1000.0),
    ], note="970 K op/s, ~300 us, zero host CPU (Figs 25/26)",
        tail_factor=1.4)


# -- Fig 4 / 19 / 20: echo latency models ---------------------------------------------

def echo_latency_us(size_b: int, responder: str) -> float:
    """TCP echo RTT by responder: 'host', 'dpu-linux', 'dpu-tldk'."""
    wire = 2.0 + size_b / 12.5e3            # 100 Gbps wire both ways
    if responder == "host":
        return wire + 11.0 + 24.0 + size_b / 4e3   # NIC->host PCIe + kernel TCP
    if responder == "dpu-linux":
        return wire + 3.0 + 68.0 + size_b / 2.4e3  # weak-core kernel stack
    if responder == "dpu-tldk":
        return wire + 3.0 + 9.5 + size_b / 8e3     # userspace stack on Arm
    raise ValueError(responder)


def faster_rmw_kops(threads: int, where: str) -> float:
    """Fig 5: FASTER RMW throughput on host vs DPU.

    Host (EPYC) scales past 8 threads; the DPU (8 Arm A72) is ~3x slower
    per thread and flat beyond 8 threads, reaching the paper's "up to 4.5x
    slower" at 8+ threads."""
    if where == "host":
        return 170.0 * min(threads, 48) ** 0.95
    return 170.0 / 3.0 * min(threads, 8) ** 0.82


def director_bandwidth_gbps(cores: int) -> float:
    """Fig 21: 6.4 Gbps on one Arm core, linear RSS scaling (8 cores max)."""
    return 6.4 * min(cores, 8)
