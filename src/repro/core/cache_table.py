"""DDS cache table (§6.1): cuckoo hashing with chained buckets.

The cache table maps application object keys (page id, KV key, ...) to the
user's cache items (file id / offset / size / version ...).  Requirements
(paper Table 2):

  * File service performs inserts/deletes at millions of op/s (bounded by the
    storage device).
  * Offload engine + traffic director perform lookups at up to tens of
    millions of op/s — lookups must be worst-case constant time and must not
    block behind writers.

Design, following the paper:

  * **Cuckoo hashing** with two hash functions — a key lives in one of two
    buckets, so a lookup probes at most two buckets (worst-case constant).
  * **Chained items within a bucket** — each bucket has ``slots`` in-line
    entries plus an overflow chain, which absorbs insert collisions without
    triggering cuckoo kicks on every conflict (reduces "the impact of
    collisions on insertions").
  * **Pre-reserved capacity** — the user declares the maximum number of cache
    items; the table never resizes at runtime.

Readers proceed without taking the writer lock: buckets are versioned with a
seqlock (even = stable); a reader retries if the version moved under it.
Writers (file service) serialize on a single mutex — there is exactly one
file-service writer thread in DDS, so this is not a scalability limit.
"""

from __future__ import annotations

import threading
from dataclasses import asdict, dataclass
from typing import Any, Iterator

_EMPTY = 0xFFFFFFFFFFFFFFFF
_MASK64 = 0xFFFFFFFFFFFFFFFF

# 64-bit mix (splitmix64 finalizer) — cheap, good avalanche.  Pure-int
# arithmetic: the table sits on BOTH hot paths (a lookup per directed
# request in the offload predicate, an insert per cache-on-write), where a
# numpy-scalar mix — ufunc dispatch + an errstate context manager per call —
# cost ~10x the hash itself.
_M1 = 0xBF58476D1CE4E5B9
_M2 = 0x94D049BB133111EB


def _mix(x: int, seed: int) -> int:
    # callers pass 64-bit non-negative ints; xor/shift stay in range, only
    # the multiplies need masking back to 64 bits
    x ^= seed
    x ^= x >> 30
    x = (x * _M1) & _MASK64
    x ^= x >> 27
    x = (x * _M2) & _MASK64
    x ^= x >> 31
    return x


@dataclass
class CacheTableStats:
    inserts: int = 0
    deletes: int = 0
    lookups: int = 0
    hits: int = 0
    kicks: int = 0        # cuckoo relocations
    chain_inserts: int = 0
    full_rejections: int = 0
    batched_lookups: int = 0   # lookup_many bursts served

    def as_dict(self) -> dict:
        """Plain-dict snapshot for app-level stats surfaces (e.g. the KV
        store's per-shard stats)."""
        return asdict(self)


class CacheTable:
    """Fixed-capacity cuckoo hash table with per-bucket chaining."""

    def __init__(self, max_items: int, slots_per_bucket: int = 4,
                 load_factor: float = 0.5):
        if max_items <= 0:
            raise ValueError("max_items must be positive")
        # Reserve memory up front to avoid runtime resizing (paper §6.1).
        want = int(max_items / max(load_factor, 1e-3))
        nbuckets = 1
        while nbuckets * slots_per_bucket < want:
            nbuckets <<= 1
        self.nbuckets = nbuckets
        self.slots = slots_per_bucket
        self.max_items = max_items
        self._mask = nbuckets - 1
        # In-line slot lists (keys as 64-bit int fingerprints of the full
        # key).  Plain lists, not numpy rows: slot probes are single-element
        # int compares, where numpy scalar indexing costs a boxing per probe.
        self._keys: list[list[int]] = [[_EMPTY] * slots_per_bucket
                                       for _ in range(nbuckets)]
        self._vals: list[list[Any]] = [[None] * slots_per_bucket for _ in range(nbuckets)]
        self._full_keys: list[list[Any]] = [[None] * slots_per_bucket for _ in range(nbuckets)]
        self._chains: list[dict[Any, Any]] = [dict() for _ in range(nbuckets)]
        self._versions = [0] * nbuckets  # seqlock (even = stable)
        self._count = 0
        self._wlock = threading.Lock()
        self.stats = CacheTableStats()

    # -- hashing ---------------------------------------------------------------
    def _hash_key(self, key: Any) -> int:
        if isinstance(key, int):
            return _mix(key & _MASK64, 0)
        return _mix(hash(key) & _MASK64, 0)

    def _buckets_for(self, hk: int) -> tuple[int, int]:
        # ``hk`` is already splitmix-finalized, so its low and high halves
        # are independently avalanche-mixed: deriving the two cuckoo
        # buckets from disjoint bit ranges costs ZERO extra mixes (the
        # old per-seed re-mix tripled the hashing cost of every
        # lookup/insert/delete on the predicate hot path).
        b1 = hk & self._mask
        b2 = (hk >> 32) & self._mask
        if b2 == b1:
            b2 = (b1 + 1) & self._mask
        return b1, b2

    # -- read path (lock-free via seqlock) --------------------------------------
    def lookup(self, key: Any) -> Any | None:
        self.stats.lookups += 1
        hk = self._hash_key(key)
        versions = self._versions
        for b in self._buckets_for(hk):
            for _ in range(64):  # seqlock retry budget
                v0 = versions[b]
                if v0 & 1:
                    continue  # writer active in this bucket
                found, val = self._probe(b, hk, key)
                if versions[b] == v0:
                    if found:
                        self.stats.hits += 1
                        return val
                    break
        return None

    def lookup_many(self, keys: list) -> list:
        """Burst lookup: one stats round for the whole batch.

        The director's offload predicate probes the table once per message
        of a network batch; the per-call stats updates (and per-call
        attribute traffic) of :meth:`lookup` are pure overhead there, so
        this walks the burst with everything hoisted and folds
        ``lookups``/``hits`` into the stats ONCE.  Returns one value (or
        ``None``) per key, in key order; the read path stays lock-free via
        the same per-bucket seqlock retry."""
        out: list = []
        hits = 0
        versions = self._versions
        hash_key = self._hash_key
        buckets_for = self._buckets_for
        probe = self._probe
        for key in keys:
            hk = hash_key(key)
            val = None
            for b in buckets_for(hk):
                hit = False
                for _ in range(64):  # seqlock retry budget
                    v0 = versions[b]
                    if v0 & 1:
                        continue  # writer active in this bucket
                    found, v = probe(b, hk, key)
                    if versions[b] == v0:
                        hit = found  # ONLY version-stable reads are trusted
                        break
                if hit:
                    val = v
                    hits += 1
                    break
            out.append(val)
        st = self.stats
        st.lookups += len(keys)
        st.hits += hits
        st.batched_lookups += 1
        return out

    def _probe(self, b: int, hk: int, key: Any) -> tuple[bool, Any]:
        row = self._keys[b]
        full = self._full_keys[b]
        for s, k in enumerate(row):
            if k == hk and full[s] == key:
                return True, self._vals[b][s]
        chain = self._chains[b]
        if key in chain:
            return True, chain[key]
        return False, None

    def __contains__(self, key: Any) -> bool:
        return self.lookup(key) is not None

    def __len__(self) -> int:
        return self._count

    # -- write path (single writer: the file service) ---------------------------
    def _bucket_begin(self, b: int) -> None:
        self._versions[b] += 1  # odd: writer active

    def _bucket_end(self, b: int) -> None:
        self._versions[b] += 1  # even: stable

    def insert(self, key: Any, value: Any) -> bool:
        """Insert or update.  Returns False iff the table is at capacity."""
        with self._wlock:
            hk = self._hash_key(key)
            b1, b2 = self._buckets_for(hk)
            # ONE pass over both buckets: find an in-place update target and
            # remember the first free slot for the (common) fresh-insert case.
            free_b = free_s = -1
            for b in (b1, b2):
                row = self._keys[b]
                full = self._full_keys[b]
                for s, k in enumerate(row):
                    if k == hk and full[s] == key:
                        self._bucket_begin(b)
                        self._vals[b][s] = value
                        self._bucket_end(b)
                        self.stats.inserts += 1
                        return True
                    if k == _EMPTY and free_b < 0:
                        free_b, free_s = b, s
                if key in self._chains[b]:
                    self._bucket_begin(b)
                    self._chains[b][key] = value
                    self._bucket_end(b)
                    self.stats.inserts += 1
                    return True
            if self._count >= self.max_items:
                self.stats.full_rejections += 1
                return False
            # Take the empty in-line slot spotted during the update scan.
            if free_b >= 0:
                self._place(free_b, free_s, hk, key, value)
                self._count += 1
                self.stats.inserts += 1
                return True
            # Cuckoo kicks with a bounded path; on failure, chain in-bucket.
            if self._kick_insert(b1, hk, key, value, budget=32):
                self._count += 1
                self.stats.inserts += 1
                return True
            self._bucket_begin(b1)
            self._chains[b1][key] = value
            self._bucket_end(b1)
            self.stats.chain_inserts += 1
            self._count += 1
            self.stats.inserts += 1
            return True

    def _free_slot(self, b: int) -> int | None:
        row = self._keys[b]
        for s in range(self.slots):
            if row[s] == _EMPTY:
                return s
        return None

    def _place(self, b: int, s: int, hk: int, key: Any, value: Any) -> None:
        self._bucket_begin(b)
        self._keys[b][s] = hk
        self._full_keys[b][s] = key
        self._vals[b][s] = value
        self._bucket_end(b)

    def _kick_insert(self, b: int, hk: int, key: Any, value: Any,
                     budget: int) -> bool:
        cur = (b, hk, key, value)
        for i in range(budget):
            b, hk, key, value = cur
            s = self._free_slot(b)
            if s is not None:
                self._place(b, s, hk, key, value)
                return True
            # Evict the slot this path landed on (round-robin by budget step).
            s = i % self.slots
            vk = self._keys[b][s]
            vfk, vv = self._full_keys[b][s], self._vals[b][s]
            self._place(b, s, hk, key, value)
            self.stats.kicks += 1
            vb1, vb2 = self._buckets_for(vk)
            nb = vb2 if vb1 == b else vb1
            cur = (nb, vk, vfk, vv)
        # Could not re-home the last victim: chain it in its bucket.
        b, hk, key, value = cur
        self._bucket_begin(b)
        self._chains[b][key] = value
        self._bucket_end(b)
        self.stats.chain_inserts += 1
        return True

    def delete(self, key: Any) -> bool:
        with self._wlock:
            hk = self._hash_key(key)
            b1, b2 = self._buckets_for(hk)
            for b in (b1, b2):
                row = self._keys[b]
                full = self._full_keys[b]
                for s in range(self.slots):
                    if row[s] == hk and full[s] == key:
                        self._bucket_begin(b)
                        row[s] = _EMPTY
                        full[s] = None
                        self._vals[b][s] = None
                        self._bucket_end(b)
                        self._count -= 1
                        self.stats.deletes += 1
                        return True
                if key in self._chains[b]:
                    self._bucket_begin(b)
                    del self._chains[b][key]
                    self._bucket_end(b)
                    self._count -= 1
                    self.stats.deletes += 1
                    return True
            return False

    def items(self) -> Iterator[tuple[Any, Any]]:
        """Stable snapshot of every (key, value) pair.

        The whole table is materialized UNDER the writer lock and an
        iterator over the snapshot returned.  The previous implementation
        was a generator that scanned lazily while holding the lock: items
        relocated by cuckoo kicks between ``next()`` calls could be yielded
        twice or skipped, and any insert from the consuming thread's
        call chain would deadlock on the non-reentrant writer lock.
        """
        with self._wlock:
            out: list[tuple[Any, Any]] = []
            for b in range(self.nbuckets):
                row = self._keys[b]
                full = self._full_keys[b]
                vals = self._vals[b]
                for s in range(self.slots):
                    if row[s] != _EMPTY:
                        out.append((full[s], vals[s]))
                out.extend(self._chains[b].items())
        return iter(out)
