"""DDS cache table (§6.1): cuckoo hashing with chained buckets.

The cache table maps application object keys (page id, KV key, ...) to the
user's cache items (file id / offset / size / version ...).  Requirements
(paper Table 2):

  * File service performs inserts/deletes at millions of op/s (bounded by the
    storage device).
  * Offload engine + traffic director perform lookups at up to tens of
    millions of op/s — lookups must be worst-case constant time and must not
    block behind writers.

Design, following the paper:

  * **Cuckoo hashing** with two hash functions — a key lives in one of two
    buckets, so a lookup probes at most two buckets (worst-case constant).
  * **Chained items within a bucket** — each bucket has ``slots`` in-line
    entries plus an overflow chain, which absorbs insert collisions without
    triggering cuckoo kicks on every conflict (reduces "the impact of
    collisions on insertions").
  * **Pre-reserved capacity** — the user declares the maximum number of cache
    items; the table never resizes at runtime.

Readers proceed without taking the writer lock: buckets are versioned with a
seqlock (even = stable); a reader retries if the version moved under it.
Writers (file service) serialize on a single mutex — there is exactly one
file-service writer thread in DDS, so this is not a scalability limit.

Backing-store layout (the vectorized data plane): fingerprints, versions
and chain occupancy live in flat contiguous numpy arrays —

  * ``_keys_np``   uint64, shape (nbuckets * slots,): slot fingerprints,
    ``_EMPTY`` marks a free slot; bucket ``b`` owns ``[b*slots, (b+1)*slots)``.
  * ``_fulls_np`` / ``_vals_np``  object, same shape: the full keys and the
    cached values (object refs; gathers are C loops, not interpreter loops).
  * ``_versions_np`` uint64, shape (nbuckets,): the seqlock word per bucket.
  * ``_chain_np``  int64, shape (nbuckets,): overflow-chain population, so a
    burst can prove "no chain to consult" array-wise.

Scalar probes still walk plain Python list mirrors (``_keys`` etc.) — a
single-element numpy index costs a boxing per probe, ~10x a list index —
so every writer mutation updates BOTH stores inside the same seqlock-odd
window.  The seqlock-over-arrays rule for vectorized readers: gather the
version column, gather whatever else you need, gather the version column
again — a burst element is trusted only if both snapshots are equal and
even; everything else retries on the scalar path.
"""

from __future__ import annotations

import threading
from dataclasses import asdict, dataclass
from typing import Any, Iterable, Iterator

import numpy as np

from repro.core import vector

_EMPTY = 0xFFFFFFFFFFFFFFFF
_MASK64 = 0xFFFFFFFFFFFFFFFF

# 64-bit mix (splitmix64 finalizer) — cheap, good avalanche.  Pure-int
# arithmetic: the table sits on BOTH hot paths (a lookup per directed
# request in the offload predicate, an insert per cache-on-write), where a
# numpy-scalar mix — ufunc dispatch + an errstate context manager per call —
# cost ~10x the hash itself.  ``vector.mix64`` is the bit-identical batch
# form used by ``lookup_many``.
_M1 = 0xBF58476D1CE4E5B9
_M2 = 0x94D049BB133111EB


def _mix(x: int, seed: int) -> int:
    # callers pass 64-bit non-negative ints; xor/shift stay in range, only
    # the multiplies need masking back to 64 bits
    x ^= seed
    x ^= x >> 30
    x = (x * _M1) & _MASK64
    x ^= x >> 27
    x = (x * _M2) & _MASK64
    x ^= x >> 31
    return x


# Bursts shorter than this stay on the scalar path: the fixed cost of the
# vectorized probe (a dozen ufunc dispatches) only amortizes past it.
# Burst size below which the scalar probe loop beats the vectorized one
# (fixed numpy dispatch cost vs ~2us/key scalar work; crossover measured
# at ~44-48 keys by benchmarks/micro/kernels_ab.py on CPython 3.11).
_VEC_MIN = 48


@dataclass
class CacheTableStats:
    inserts: int = 0
    deletes: int = 0
    lookups: int = 0
    hits: int = 0
    kicks: int = 0        # cuckoo relocations
    chain_inserts: int = 0
    full_rejections: int = 0
    batched_lookups: int = 0   # lookup_many bursts served
    locked_probes: int = 0     # seqlock retry budget exhausted -> locked read

    def as_dict(self) -> dict:
        """Plain-dict snapshot for app-level stats surfaces (e.g. the KV
        store's per-shard stats)."""
        return asdict(self)


class CacheTable:
    """Fixed-capacity cuckoo hash table with per-bucket chaining."""

    def __init__(self, max_items: int, slots_per_bucket: int = 4,
                 load_factor: float = 0.5):
        if max_items <= 0:
            raise ValueError("max_items must be positive")
        # Reserve memory up front to avoid runtime resizing (paper §6.1).
        want = int(max_items / max(load_factor, 1e-3))
        nbuckets = 1
        while nbuckets * slots_per_bucket < want:
            nbuckets <<= 1
        self.nbuckets = nbuckets
        self.slots = slots_per_bucket
        self.max_items = max_items
        self._mask = nbuckets - 1
        # In-line slot lists (keys as 64-bit int fingerprints of the full
        # key).  Plain lists, not numpy rows: slot probes are single-element
        # int compares, where numpy scalar indexing costs a boxing per probe.
        self._keys: list[list[int]] = [[_EMPTY] * slots_per_bucket
                                       for _ in range(nbuckets)]
        self._vals: list[list[Any]] = [[None] * slots_per_bucket for _ in range(nbuckets)]
        self._full_keys: list[list[Any]] = [[None] * slots_per_bucket for _ in range(nbuckets)]
        self._chains: list[dict[Any, Any]] = [dict() for _ in range(nbuckets)]
        self._versions = [0] * nbuckets  # seqlock (even = stable)
        # Flat contiguous mirrors for the vectorized burst path (layout in
        # the module docstring).  Writers keep both stores coherent inside
        # one seqlock-odd window.
        self._keys_np = np.full(nbuckets * slots_per_bucket, _EMPTY,
                                dtype=np.uint64)
        self._fulls_np = np.empty(nbuckets * slots_per_bucket, dtype=object)
        self._vals_np = np.empty(nbuckets * slots_per_bucket, dtype=object)
        self._versions_np = np.zeros(nbuckets, dtype=np.uint64)
        self._chain_np = np.zeros(nbuckets, dtype=np.int64)
        self._slot_iota = np.arange(slots_per_bucket, dtype=np.int64)
        self._count = 0
        self._wlock = threading.Lock()
        self.stats = CacheTableStats()
        # Mutation epoch: bumped on every bucket write window.  Lets callers
        # that probed a batch earlier in the SAME scheduling step (the
        # offload predicate) reuse their results iff nothing changed since,
        # instead of paying a second full probe per burst.
        self.epoch = 0

    # -- hashing ---------------------------------------------------------------
    def _hash_key(self, key: Any) -> int:
        if isinstance(key, int):
            return _mix(key & _MASK64, 0)
        return _mix(hash(key) & _MASK64, 0)

    def _buckets_for(self, hk: int) -> tuple[int, int]:
        # ``hk`` is already splitmix-finalized, so its low and high halves
        # are independently avalanche-mixed: deriving the two cuckoo
        # buckets from disjoint bit ranges costs ZERO extra mixes (the
        # old per-seed re-mix tripled the hashing cost of every
        # lookup/insert/delete on the predicate hot path).
        b1 = hk & self._mask
        b2 = (hk >> 32) & self._mask
        if b2 == b1:
            b2 = (b1 + 1) & self._mask
        return b1, b2

    # -- read path (lock-free via seqlock) --------------------------------------
    def _lookup_one(self, key: Any, hk: int) -> tuple[bool, Any]:
        """Authoritative single-key probe; shared by ``lookup`` and the
        ``lookup_many`` fallback.  Does NOT touch stats (callers fold).

        The value is bound ONLY under the version-stable check — an
        unstable probe can never leak a value from a bucket a writer was
        mid-mutation in.  If the seqlock retry budget runs dry (a writer
        spinning on this bucket), the probe falls back to a brief LOCKED
        read instead of reporting a false miss: present keys stay present
        under any writer schedule.
        """
        versions = self._versions
        for b in self._buckets_for(hk):
            for _ in range(64):  # seqlock retry budget
                v0 = versions[b]
                if v0 & 1:
                    continue  # writer active in this bucket
                found, val = self._probe(b, hk, key)
                if versions[b] == v0:
                    if found:
                        return True, val   # version-stable hit
                    break                  # version-stable miss here
            else:
                # Budget exhausted with the writer still live: take the
                # writer lock for one authoritative probe rather than
                # treating "couldn't read" as "absent".
                with self._wlock:
                    found, val = self._probe(b, hk, key)
                self.stats.locked_probes += 1
                if found:
                    return True, val
        return False, None

    def lookup(self, key: Any) -> Any | None:
        self.stats.lookups += 1
        found, val = self._lookup_one(key, self._hash_key(key))
        if found:
            self.stats.hits += 1
            return val
        return None

    def lookup_many(self, keys: list) -> list:
        """Burst lookup: ONE vectorized probe for the whole batch.

        The director's offload predicate probes the table once per message
        of a network batch.  The burst is resolved array-at-a-time — one
        splitmix mix, a two-bucket fingerprint gather and an equality
        reduce over the flat backing store — with the seqlock honored
        array-wise: the version column is gathered before and after the
        data gathers, and only elements whose buckets were even-and-equal
        in both snapshots are trusted.  Unstable elements, fingerprint
        collisions and chained buckets retry on the scalar path
        (:meth:`_lookup_one`), which also shields them from writer
        starvation via the locked-probe fallback.  Returns one value (or
        ``None``) per key, in key order; stats fold once per burst.
        """
        n = len(keys)
        st = self.stats
        st.lookups += n
        st.batched_lookups += 1
        if n < _VEC_MIN:
            hits = 0
            out: list = []
            hash_key = self._hash_key
            lookup_one = self._lookup_one
            for key in keys:
                found, val = lookup_one(key, hash_key(key))
                out.append(val if found else None)
                hits += found
            st.hits += hits
            return out

        hk = vector.hash_keys(keys)
        mask = np.uint64(self._mask)
        b1 = (hk & mask).astype(np.int64)
        b2 = ((hk >> np.uint64(32)) & mask).astype(np.int64)
        same = b1 == b2
        if same.any():
            b2[same] = (b1[same] + 1) & self._mask
        slots = self.slots
        vnp = self._versions_np
        knp = self._keys_np
        # Seqlock over arrays: version snapshot -> data gathers -> version
        # snapshot.  (CPython bytecode boundaries give the same atomicity
        # the scalar reader relies on.)
        v0_1 = vnp[b1]
        v0_2 = vnp[b2]
        rows1 = knp[(b1 * slots)[:, None] + self._slot_iota]
        rows2 = knp[(b2 * slots)[:, None] + self._slot_iota]
        eq1 = rows1 == hk[:, None]
        eq2 = rows2 == hk[:, None]
        hit1 = eq1.any(axis=1)
        hit2 = eq2.any(axis=1)
        chained = (self._chain_np[b1] > 0) | (self._chain_np[b2] > 0)
        # Candidate hits: gather full keys + values for fingerprint matches
        # (object gathers are C loops over refs, not interpreter loops).
        only1 = hit1 & ~hit2
        flat = np.where(only1, b1 * slots + eq1.argmax(axis=1),
                        b2 * slots + eq2.argmax(axis=1))
        cand = only1 | (hit2 & ~hit1)
        cidx = np.nonzero(cand)[0]
        if cidx.size:
            cfulls = self._fulls_np[flat[cidx]]
            cvals = self._vals_np[flat[cidx]]
        # Close the seqlock window AFTER every data gather.
        v1_1 = vnp[b1]
        v1_2 = vnp[b2]
        one = np.uint64(1)
        stable = ((v0_1 == v1_1) & (v0_2 == v1_2)
                  & ((v0_1 & one) == 0) & ((v0_2 & one) == 0))
        out_np = np.empty(n, dtype=object)
        hits = 0
        resolved_hit = np.zeros(n, dtype=bool)
        if cidx.size:
            ckeys = np.empty(cidx.size, dtype=object)
            ckeys[:] = [keys[i] for i in cidx]
            good = (cfulls == ckeys) & stable[cidx]
            gsel = cidx[good]
            out_np[gsel] = cvals[good]
            resolved_hit[gsel] = True
            hits += int(good.sum())
        # Resolved misses: stable, no fingerprint match, no chain to consult.
        # Everything else — unstable buckets, fingerprint collisions (full
        # key mismatched), double-bucket matches, chained buckets — retries
        # on the scalar path.
        resolved_miss = stable & ~hit1 & ~hit2 & ~chained
        fallback = np.nonzero(~(resolved_hit | resolved_miss))[0]
        if fallback.size:
            lookup_one = self._lookup_one
            for i in fallback:
                i = int(i)
                found, val = lookup_one(keys[i], int(hk[i]))
                if found:
                    out_np[i] = val
                    hits += 1
        st.hits += hits
        return out_np.tolist()

    def _probe(self, b: int, hk: int, key: Any) -> tuple[bool, Any]:
        row = self._keys[b]
        full = self._full_keys[b]
        for s, k in enumerate(row):
            if k == hk and full[s] == key:
                return True, self._vals[b][s]
        chain = self._chains[b]
        if key in chain:
            return True, chain[key]
        return False, None

    def __contains__(self, key: Any) -> bool:
        return self.lookup(key) is not None

    def __len__(self) -> int:
        return self._count

    # -- write path (single writer: the file service) ---------------------------
    def _bucket_begin(self, b: int) -> None:
        # Both version stores go odd BEFORE either data store is touched.
        self.epoch += 1
        self._versions_np[b] += 1
        self._versions[b] += 1  # odd: writer active

    def _bucket_end(self, b: int) -> None:
        self._versions[b] += 1  # even: stable
        self._versions_np[b] += 1

    def _set_slot(self, b: int, s: int, hk: int, key: Any, value: Any) -> None:
        """Mutate one in-line slot in BOTH backing stores (seqlock held odd)."""
        self._keys[b][s] = hk
        self._full_keys[b][s] = key
        self._vals[b][s] = value
        flat = b * self.slots + s
        self._keys_np[flat] = hk
        self._fulls_np[flat] = key
        self._vals_np[flat] = value

    def insert(self, key: Any, value: Any) -> bool:
        """Insert or update.  Returns False iff the table is at capacity."""
        with self._wlock:
            hk = self._hash_key(key)
            b1, b2 = self._buckets_for(hk)
            # ONE pass over both buckets: find an in-place update target and
            # remember the first free slot for the (common) fresh-insert case.
            free_b = free_s = -1
            for b in (b1, b2):
                row = self._keys[b]
                full = self._full_keys[b]
                for s, k in enumerate(row):
                    if k == hk and full[s] == key:
                        self._bucket_begin(b)
                        self._vals[b][s] = value
                        self._vals_np[b * self.slots + s] = value
                        self._bucket_end(b)
                        self.stats.inserts += 1
                        return True
                    if k == _EMPTY and free_b < 0:
                        free_b, free_s = b, s
                if key in self._chains[b]:
                    self._bucket_begin(b)
                    self._chains[b][key] = value
                    self._bucket_end(b)
                    self.stats.inserts += 1
                    return True
            if self._count >= self.max_items:
                self.stats.full_rejections += 1
                return False
            # Take the empty in-line slot spotted during the update scan.
            if free_b >= 0:
                self._place(free_b, free_s, hk, key, value)
                self._count += 1
                self.stats.inserts += 1
                return True
            # Cuckoo kicks with a bounded path; on failure, chain in-bucket.
            if self._kick_insert(b1, hk, key, value, budget=32):
                self._count += 1
                self.stats.inserts += 1
                return True
            self._chain_put(b1, key, value)
            self.stats.chain_inserts += 1
            self._count += 1
            self.stats.inserts += 1
            return True

    def _chain_put(self, b: int, key: Any, value: Any) -> None:
        self._bucket_begin(b)
        self._chains[b][key] = value
        self._chain_np[b] = len(self._chains[b])
        self._bucket_end(b)

    def _free_slot(self, b: int) -> int | None:
        row = self._keys[b]
        for s in range(self.slots):
            if row[s] == _EMPTY:
                return s
        return None

    def _place(self, b: int, s: int, hk: int, key: Any, value: Any) -> None:
        self._bucket_begin(b)
        self._set_slot(b, s, hk, key, value)
        self._bucket_end(b)

    def _kick_insert(self, b: int, hk: int, key: Any, value: Any,
                     budget: int) -> bool:
        cur = (b, hk, key, value)
        for i in range(budget):
            b, hk, key, value = cur
            s = self._free_slot(b)
            if s is not None:
                self._place(b, s, hk, key, value)
                return True
            # Evict the slot this path landed on (round-robin by budget step).
            s = i % self.slots
            vk = self._keys[b][s]
            vfk, vv = self._full_keys[b][s], self._vals[b][s]
            self._place(b, s, hk, key, value)
            self.stats.kicks += 1
            vb1, vb2 = self._buckets_for(vk)
            nb = vb2 if vb1 == b else vb1
            cur = (nb, vk, vfk, vv)
        # Could not re-home the last victim: chain it in its bucket.
        b, hk, key, value = cur
        self._chain_put(b, key, value)
        self.stats.chain_inserts += 1
        return True

    def delete(self, key: Any) -> bool:
        with self._wlock:
            hk = self._hash_key(key)
            b1, b2 = self._buckets_for(hk)
            for b in (b1, b2):
                row = self._keys[b]
                full = self._full_keys[b]
                for s in range(self.slots):
                    if row[s] == hk and full[s] == key:
                        self._bucket_begin(b)
                        self._set_slot(b, s, _EMPTY, None, None)
                        self._bucket_end(b)
                        self._count -= 1
                        self.stats.deletes += 1
                        return True
                if key in self._chains[b]:
                    self._bucket_begin(b)
                    del self._chains[b][key]
                    self._chain_np[b] = len(self._chains[b])
                    self._bucket_end(b)
                    self._count -= 1
                    self.stats.deletes += 1
                    return True
            return False

    def delete_many(self, keys: Iterable[Any]) -> int:
        """Drop a batch of keys (live-migration range invalidation).

        Each hit bumps its bucket's seqlock — and therefore the table
        ``epoch`` — so predicate probe memos taken before an ownership
        flip can never serve a migrated key from a stale mapping.
        Returns the number of keys actually removed."""
        n = 0
        for k in keys:
            if self.delete(k):
                n += 1
        return n

    def items(self) -> Iterator[tuple[Any, Any]]:
        """Stable snapshot of every (key, value) pair.

        The whole table is materialized UNDER the writer lock and an
        iterator over the snapshot returned.  The previous implementation
        was a generator that scanned lazily while holding the lock: items
        relocated by cuckoo kicks between ``next()`` calls could be yielded
        twice or skipped, and any insert from the consuming thread's
        call chain would deadlock on the non-reentrant writer lock.
        """
        with self._wlock:
            out: list[tuple[Any, Any]] = []
            for b in range(self.nbuckets):
                row = self._keys[b]
                full = self._full_keys[b]
                vals = self._vals[b]
                for s in range(self.slots):
                    if row[s] != _EMPTY:
                        out.append((full[s], vals[s]))
                out.extend(self._chains[b].items())
        return iter(out)
