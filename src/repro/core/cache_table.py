"""DDS cache table (§6.1): cuckoo hashing with chained buckets.

The cache table maps application object keys (page id, KV key, ...) to the
user's cache items (file id / offset / size / version ...).  Requirements
(paper Table 2):

  * File service performs inserts/deletes at millions of op/s (bounded by the
    storage device).
  * Offload engine + traffic director perform lookups at up to tens of
    millions of op/s — lookups must be worst-case constant time and must not
    block behind writers.

Design, following the paper:

  * **Cuckoo hashing** with two hash functions — a key lives in one of two
    buckets, so a lookup probes at most two buckets (worst-case constant).
  * **Chained items within a bucket** — each bucket has ``slots`` in-line
    entries plus an overflow chain, which absorbs insert collisions without
    triggering cuckoo kicks on every conflict (reduces "the impact of
    collisions on insertions").
  * **Pre-reserved capacity** — the user declares the maximum number of cache
    items; the table never resizes at runtime.

Readers proceed without taking the writer lock: buckets are versioned with a
seqlock (even = stable); a reader retries if the version moved under it.
Writers (file service) serialize on a single mutex — there is exactly one
file-service writer thread in DDS, so this is not a scalability limit.
"""

from __future__ import annotations

import threading
from dataclasses import dataclass
from typing import Any, Iterator

import numpy as np

_EMPTY = np.uint64(0xFFFFFFFFFFFFFFFF)

# 64-bit mix (splitmix64 finalizer) — cheap, good avalanche.
_M1 = np.uint64(0xBF58476D1CE4E5B9)
_M2 = np.uint64(0x94D049BB133111EB)


def _mix(x: np.uint64, seed: np.uint64) -> np.uint64:
    with np.errstate(over="ignore"):
        x = np.uint64(x) ^ seed
        x ^= x >> np.uint64(30)
        x *= _M1
        x ^= x >> np.uint64(27)
        x *= _M2
        x ^= x >> np.uint64(31)
    return x


@dataclass
class CacheTableStats:
    inserts: int = 0
    deletes: int = 0
    lookups: int = 0
    hits: int = 0
    kicks: int = 0        # cuckoo relocations
    chain_inserts: int = 0
    full_rejections: int = 0


class CacheTable:
    """Fixed-capacity cuckoo hash table with per-bucket chaining."""

    def __init__(self, max_items: int, slots_per_bucket: int = 4,
                 load_factor: float = 0.5):
        if max_items <= 0:
            raise ValueError("max_items must be positive")
        # Reserve memory up front to avoid runtime resizing (paper §6.1).
        want = int(max_items / max(load_factor, 1e-3))
        nbuckets = 1
        while nbuckets * slots_per_bucket < want:
            nbuckets <<= 1
        self.nbuckets = nbuckets
        self.slots = slots_per_bucket
        self.max_items = max_items
        self._mask = np.uint64(nbuckets - 1)
        self._seed1 = np.uint64(0x9E3779B97F4A7C15)
        self._seed2 = np.uint64(0xC2B2AE3D27D4EB4F)
        # In-line slot arrays (keys as uint64 fingerprints of the full key).
        self._keys = np.full((nbuckets, slots_per_bucket), _EMPTY, dtype=np.uint64)
        self._vals: list[list[Any]] = [[None] * slots_per_bucket for _ in range(nbuckets)]
        self._full_keys: list[list[Any]] = [[None] * slots_per_bucket for _ in range(nbuckets)]
        self._chains: list[dict[Any, Any]] = [dict() for _ in range(nbuckets)]
        self._versions = np.zeros(nbuckets, dtype=np.uint64)  # seqlock
        self._count = 0
        self._wlock = threading.Lock()
        self.stats = CacheTableStats()

    # -- hashing ---------------------------------------------------------------
    def _hash_key(self, key: Any) -> np.uint64:
        if isinstance(key, (int, np.integer)):
            h = np.uint64(int(key) & 0xFFFFFFFFFFFFFFFF)
        else:
            h = np.uint64(hash(key) & 0xFFFFFFFFFFFFFFFF)
        return _mix(h, np.uint64(0))

    def _buckets_for(self, hk: np.uint64) -> tuple[int, int]:
        b1 = int(_mix(hk, self._seed1) & self._mask)
        b2 = int(_mix(hk, self._seed2) & self._mask)
        if b2 == b1:
            b2 = (b1 + 1) & int(self._mask)
        return b1, b2

    # -- read path (lock-free via seqlock) --------------------------------------
    def lookup(self, key: Any) -> Any | None:
        self.stats.lookups += 1
        hk = self._hash_key(key)
        b1, b2 = self._buckets_for(hk)
        for b in (b1, b2):
            for _ in range(64):  # seqlock retry budget
                v0 = int(self._versions[b])
                if v0 & 1:
                    continue  # writer active in this bucket
                found, val = self._probe(b, hk, key)
                if int(self._versions[b]) == v0:
                    if found:
                        self.stats.hits += 1
                        return val
                    break
        return None

    def _probe(self, b: int, hk: np.uint64, key: Any) -> tuple[bool, Any]:
        row = self._keys[b]
        for s in range(self.slots):
            if row[s] == hk and self._full_keys[b][s] == key:
                return True, self._vals[b][s]
        chain = self._chains[b]
        if key in chain:
            return True, chain[key]
        return False, None

    def __contains__(self, key: Any) -> bool:
        return self.lookup(key) is not None

    def __len__(self) -> int:
        return self._count

    # -- write path (single writer: the file service) ---------------------------
    def _bucket_begin(self, b: int) -> None:
        self._versions[b] += np.uint64(1)  # odd: writer active

    def _bucket_end(self, b: int) -> None:
        self._versions[b] += np.uint64(1)  # even: stable

    def insert(self, key: Any, value: Any) -> bool:
        """Insert or update.  Returns False iff the table is at capacity."""
        with self._wlock:
            hk = self._hash_key(key)
            b1, b2 = self._buckets_for(hk)
            # Update in place if present.
            for b in (b1, b2):
                row = self._keys[b]
                for s in range(self.slots):
                    if row[s] == hk and self._full_keys[b][s] == key:
                        self._bucket_begin(b)
                        self._vals[b][s] = value
                        self._bucket_end(b)
                        self.stats.inserts += 1
                        return True
                if key in self._chains[b]:
                    self._bucket_begin(b)
                    self._chains[b][key] = value
                    self._bucket_end(b)
                    self.stats.inserts += 1
                    return True
            if self._count >= self.max_items:
                self.stats.full_rejections += 1
                return False
            # Try an empty in-line slot in either bucket.
            for b in (b1, b2):
                s = self._free_slot(b)
                if s is not None:
                    self._place(b, s, hk, key, value)
                    self._count += 1
                    self.stats.inserts += 1
                    return True
            # Cuckoo kicks with a bounded path; on failure, chain in-bucket.
            if self._kick_insert(b1, hk, key, value, budget=32):
                self._count += 1
                self.stats.inserts += 1
                return True
            self._bucket_begin(b1)
            self._chains[b1][key] = value
            self._bucket_end(b1)
            self.stats.chain_inserts += 1
            self._count += 1
            self.stats.inserts += 1
            return True

    def _free_slot(self, b: int) -> int | None:
        row = self._keys[b]
        for s in range(self.slots):
            if row[s] == _EMPTY:
                return s
        return None

    def _place(self, b: int, s: int, hk: np.uint64, key: Any, value: Any) -> None:
        self._bucket_begin(b)
        self._keys[b, s] = hk
        self._full_keys[b][s] = key
        self._vals[b][s] = value
        self._bucket_end(b)

    def _kick_insert(self, b: int, hk: np.uint64, key: Any, value: Any,
                     budget: int) -> bool:
        cur = (b, hk, key, value)
        for i in range(budget):
            b, hk, key, value = cur
            s = self._free_slot(b)
            if s is not None:
                self._place(b, s, hk, key, value)
                return True
            # Evict the slot this path landed on (round-robin by budget step).
            s = i % self.slots
            vk = self._keys[b, s]
            vfk, vv = self._full_keys[b][s], self._vals[b][s]
            self._place(b, s, hk, key, value)
            self.stats.kicks += 1
            vb1, vb2 = self._buckets_for(vk)
            nb = vb2 if vb1 == b else vb1
            cur = (nb, vk, vfk, vv)
        # Could not re-home the last victim: chain it in its bucket.
        b, hk, key, value = cur
        self._bucket_begin(b)
        self._chains[b][key] = value
        self._bucket_end(b)
        self.stats.chain_inserts += 1
        return True

    def delete(self, key: Any) -> bool:
        with self._wlock:
            hk = self._hash_key(key)
            b1, b2 = self._buckets_for(hk)
            for b in (b1, b2):
                row = self._keys[b]
                for s in range(self.slots):
                    if row[s] == hk and self._full_keys[b][s] == key:
                        self._bucket_begin(b)
                        self._keys[b, s] = _EMPTY
                        self._full_keys[b][s] = None
                        self._vals[b][s] = None
                        self._bucket_end(b)
                        self._count -= 1
                        self.stats.deletes += 1
                        return True
                if key in self._chains[b]:
                    self._bucket_begin(b)
                    del self._chains[b][key]
                    self._bucket_end(b)
                    self._count -= 1
                    self.stats.deletes += 1
                    return True
            return False

    def items(self) -> Iterator[tuple[Any, Any]]:
        with self._wlock:
            for b in range(self.nbuckets):
                for s in range(self.slots):
                    if self._keys[b, s] != _EMPTY:
                        yield self._full_keys[b][s], self._vals[b][s]
                yield from list(self._chains[b].items())
