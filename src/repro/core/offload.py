"""DDS offload engine (§6): customizable read offloading on the DPU.

Users customize offloading with the four functions of Table 1:

  ``OffPred(Msg, CacheTable) -> (HostReqs, DPUReqs)``  — who serves a request
  ``OffFunc(Req, CacheTable) -> ReadOp | None``        — request -> file read
  ``Cache(WriteOp)   -> [(Key, CacheItem)]``           — cache-on-write
  ``Invalidate(ReadOp) -> [Key]``                      — invalidate-on-read

Execution follows Fig 13 exactly: a context ring book-keeps outstanding
reads in arrival order; if the ring is full the request (and the rest of the
batch) is bounced to the host via the traffic director; completions are
processed from the head and stop at the first still-pending context so
responses leave in request order.

Zero-copy (Fig 12): the engine pre-allocates a pool of DMA-accessible huge
pages.  A read's destination buffer is carved from the pool WITH HEADROOM for
the application response header, and the response "packets" reference slices
of that same buffer (indirect packet buffers) — data is written once by the
storage device and never copied again on its way to the wire.  A
``zero_copy=False`` mode performs the straw-man's two copies so the benefit
is measurable (Fig 23).
"""

from __future__ import annotations

import struct
import threading
from collections import deque
from dataclasses import dataclass, field
from typing import Callable

import numpy as np

from repro.core.cache_table import CacheTable
from repro.core.file_service import SegmentFS
from repro.core.traffic import FiveTuple, Packet, TrafficDirector
from repro.core import wire

MTU = 1500
PKT_HEADROOM = 64  # L2-L4 placeholder space per packet buffer


@dataclass
class ReadOp:
    file_id: int
    offset: int
    size: int


@dataclass
class WriteOp:
    file_id: int
    offset: int
    data: bytes


@dataclass
class OffloadAPI:
    """The user-supplied customization (Table 1).  Nullable per the paper.

    ``response_header`` frames offloaded read responses for the application's
    wire protocol; ``host_handler`` lets the host application interpret
    non-default message types (integration hook, cf. §9's "hundreds of lines
    of code" adoption).  It returns one of:
      ('r', req_id, file_id, offset, nbytes)   -- host file read, then respond
      ('w', req_id, file_id, offset, data[, resp_body])
                                               -- host file write, then ack;
                                                  the optional 6th element is
                                                  the ack's response body
                                                  (e.g. a KV PUT returning
                                                  the record location, §9.2)
      ('resp', req_id, status, body)           -- immediate response
    """
    off_pred: Callable[[bytes, CacheTable | None], tuple[list[bytes], list[bytes]]]
    off_func: Callable[[bytes, CacheTable | None], ReadOp | None]
    cache: Callable[[WriteOp], list[tuple[object, object]]] | None = None
    invalidate: Callable[[ReadOp], list[object]] | None = None
    response_header: Callable[[bytes, "ReadOp", int], bytes] | None = None
    host_handler: Callable[[bytes], tuple] | None = None


class MemPool:
    """Pool of DMA-accessible huge pages with a first-fit free list.

    ``allocate`` returns ``(offset, memoryview)`` carved out of one large
    pinned region; the view is handed to the storage driver as the I/O
    destination and later referenced (not copied) by packet buffers.
    """

    def __init__(self, size: int = 1 << 24):
        self.size = size
        self.buf = np.zeros(size, dtype=np.uint8)
        self._free: list[tuple[int, int]] = [(0, size)]  # (off, len)
        self._lock = threading.Lock()
        self.allocs = 0
        self.failed = 0

    def allocate(self, n: int) -> tuple[int, memoryview] | None:
        n = (n + 63) & ~63  # cache-line align
        with self._lock:
            for i, (off, ln) in enumerate(self._free):
                if ln >= n:
                    if ln == n:
                        self._free.pop(i)
                    else:
                        self._free[i] = (off + n, ln - n)
                    self.allocs += 1
                    return off, memoryview(self.buf)[off : off + n]
            self.failed += 1
            return None

    def release(self, off: int, n: int) -> None:
        n = (n + 63) & ~63
        with self._lock:
            self._free.append((off, n))
            # Coalesce adjacent ranges (keep the list small).
            self._free.sort()
            merged: list[tuple[int, int]] = []
            for o, l in self._free:
                if merged and merged[-1][0] + merged[-1][1] == o:
                    merged[-1] = (merged[-1][0], merged[-1][1] + l)
                else:
                    merged.append((o, l))
            self._free = merged

    def in_use(self) -> int:
        with self._lock:
            return self.size - sum(l for _, l in self._free)


PENDING = 0
COMPLETE = 1
FAILED = 2


@dataclass
class _Context:
    """One slot of the context ring (§6.2)."""
    client: FiveTuple | None = None
    read_op: ReadOp | None = None
    status: int = COMPLETE   # empty slots look complete & consumed
    pool_off: int = 0
    pool_len: int = 0
    buf: memoryview | None = None
    app_hdr: bytes = b""
    consumed: bool = True


@dataclass
class OffloadStats:
    offloaded: int = 0
    bounced_to_host: int = 0   # context ring full -> host path (Fig 13 l.5-7)
    completed: int = 0
    failed: int = 0
    packets: int = 0
    data_copies: int = 0       # nonzero only with zero_copy=False
    bytes_served: int = 0


class OffloadEngine:
    """Executes offloaded reads with the context ring + zero-copy pool."""

    def __init__(self, fs: SegmentFS, director: TrafficDirector,
                 api: OffloadAPI, cache_table: CacheTable | None = None,
                 ring_size: int = 256, pool_size: int = 1 << 24,
                 zero_copy: bool = True,
                 app_header: Callable[[bytes, ReadOp, int], bytes] | None = None,
                 mtu: int = MTU):
        self.fs = fs
        self.director = director
        self.api = api
        self.cache_table = cache_table
        self.ring_size = ring_size
        self.pool = MemPool(pool_size)
        self.zero_copy = zero_copy
        self.app_header = app_header or (lambda req, op, err: b"")
        self.mtu = mtu
        self._ring = [_Context() for _ in range(ring_size)]
        self._head = 0
        self._tail = 0
        self.stats = OffloadStats()

    # -- Fig 13 main loop --------------------------------------------------------------
    def step(self, max_requests: int = 64) -> int:
        """Pull requests from the traffic director and execute them."""
        work = 0
        reqs: list[tuple[FiveTuple, bytes]] = []
        while self.director.offload_queue and len(reqs) < max_requests:
            reqs.append(self.director.offload_queue.popleft())
        i = 0
        while i < len(reqs):
            self.complete_pending()
            client, raw = reqs[i]
            if self._tail - self._head >= self.ring_size:
                # Ring fully occupied: send this and the REST to the host.
                for c2, r2 in reqs[i:]:
                    self._bounce_to_host(c2, r2)
                break
            read_op = self.api.off_func(raw, self.cache_table)
            if read_op is None:
                self._bounce_to_host(client, raw)
                i += 1
                continue
            alloc = self.pool.allocate(PKT_HEADROOM + read_op.size)
            if alloc is None:
                self._bounce_to_host(client, raw)
                i += 1
                continue
            off, view = alloc
            ctx = self._ring[self._tail % self.ring_size]
            ctx.client = client
            ctx.read_op = read_op
            ctx.status = PENDING
            ctx.pool_off, ctx.pool_len = off, PKT_HEADROOM + read_op.size
            ctx.buf = view
            ctx.app_hdr = self.app_header(raw, read_op, wire.E_OK)
            ctx.consumed = False
            self._tail += 1
            # Destination = pool memory; the device writes it exactly once.
            dest = view[PKT_HEADROOM : PKT_HEADROOM + read_op.size]
            if not self.zero_copy:
                scratch = bytearray(read_op.size)

                def done(err: int, ctx=ctx, scratch=scratch):
                    if err == wire.E_OK:
                        ctx.buf[PKT_HEADROOM : PKT_HEADROOM + ctx.read_op.size] = scratch
                        self.stats.data_copies += 1
                    ctx.status = COMPLETE if err == wire.E_OK else FAILED

                self.fs.submit_read(read_op.file_id, read_op.offset,
                                    read_op.size, memoryview(scratch), done)
            else:
                self.fs.submit_read(
                    read_op.file_id, read_op.offset, read_op.size, dest,
                    lambda err, ctx=ctx: self._mark(ctx, err))
            self.stats.offloaded += 1
            work += 1
            i += 1
        self.fs.device.poll()
        self.complete_pending()
        return work

    @staticmethod
    def _mark(ctx: _Context, err: int) -> None:
        ctx.status = COMPLETE if err == wire.E_OK else FAILED

    def _bounce_to_host(self, client: FiveTuple, raw: bytes) -> None:
        conn = self.director._conn(client)
        self.director._send_to_host(conn, client, raw)
        self.stats.bounced_to_host += 1

    # -- ordered completion (Fig 13 CompletePending) --------------------------------
    def complete_pending(self) -> int:
        done = 0
        while self._head != self._tail:
            ctx = self._ring[self._head % self.ring_size]
            if ctx.status == PENDING:
                break  # preserve response order
            if not ctx.consumed:
                pkts = self._create_pkts(ctx)
                self.director.dpu_response(ctx.client, pkts)
                self.pool.release(ctx.pool_off, ctx.pool_len)
                if ctx.status == COMPLETE:
                    self.stats.completed += 1
                    self.stats.bytes_served += ctx.read_op.size
                else:
                    self.stats.failed += 1
                ctx.consumed = True
                ctx.buf = None
            self._head += 1
            done += 1
        return done

    def _create_pkts(self, ctx: _Context) -> list[Packet]:
        """Indirect packet buffers: header bytes + *references* into the pool.

        Data > MTU is segmented into multiple packets whose payloads are
        slices of the read buffer — no copy (Fig 12 step 3).
        """
        hdr = ctx.app_hdr
        if ctx.status != COMPLETE:
            hdr = self.app_header(b"", ctx.read_op, wire.E_IO)
            pkt = Packet(ctx.client, 0, hdr)
            self.stats.packets += 1
            return [pkt]
        total = ctx.read_op.size
        data = ctx.buf[PKT_HEADROOM : PKT_HEADROOM + total]
        pkts: list[Packet] = []
        # First packet carries the app header; place it in the buffer headroom
        # immediately before the data so header+data are one contiguous slice.
        h = len(hdr)
        assert h <= PKT_HEADROOM
        ctx.buf[PKT_HEADROOM - h : PKT_HEADROOM] = hdr
        first_len = min(self.mtu, h + total)
        pkts.append(Packet(ctx.client, 0,
                           ctx.buf[PKT_HEADROOM - h : PKT_HEADROOM - h + first_len]))
        sent = first_len - h
        while sent < total:
            n = min(self.mtu, total - sent)
            pkts.append(Packet(ctx.client, 0, data[sent : sent + n]))
            sent += n
        self.stats.packets += len(pkts)
        return pkts

    # -- cache-table maintenance (wired into the file service, §6.1/Table 2) -------
    def on_host_write(self, op: WriteOp) -> None:
        if self.api.cache and self.cache_table is not None:
            for key, item in self.api.cache(op):
                self.cache_table.insert(key, item)

    def on_host_read(self, op: ReadOp) -> None:
        if self.api.invalidate and self.cache_table is not None:
            for key in self.api.invalidate(op):
                self.cache_table.delete(key)
