"""DDS offload engine (§6): customizable read offloading on the DPU.

Users customize offloading with the four functions of Table 1:

  ``OffPred(Msg, CacheTable) -> (HostReqs, DPUReqs)``  — who serves a request
  ``OffFunc(Req, CacheTable) -> ReadOp | None``        — request -> file read
  ``Cache(WriteOp)   -> [(Key, CacheItem)]``           — cache-on-write
  ``Invalidate(ReadOp) -> [Key]``                      — invalidate-on-read

Execution follows Fig 13 exactly: a context ring book-keeps outstanding
reads in arrival order; if the ring is full the request (and the rest of the
batch) is bounced to the host via the traffic director; completions are
processed from the head and stop at the first still-pending context so
responses leave in request order.

Zero-copy (Fig 12): the engine pre-allocates a pool of DMA-accessible huge
pages.  A read's destination buffer is carved from the pool WITH HEADROOM for
the application response header, and the response "packets" reference slices
of that same buffer (indirect packet buffers) — data is written once by the
storage device and never copied again on its way to the wire.  A
``zero_copy=False`` mode performs the straw-man's two copies so the benefit
is measurable (Fig 23).
"""

from __future__ import annotations

import struct
import threading
from collections import deque
from dataclasses import dataclass, field
from typing import Callable

import numpy as np

from repro.core.cache_table import CacheTable
from repro.core.file_service import SegmentFS
from repro.core.traffic import FiveTuple, Packet, TrafficDirector
from repro.core import wire

MTU = 1500
PKT_HEADROOM = 64  # L2-L4 placeholder space per packet buffer


@dataclass(slots=True)
class ReadOp:
    file_id: int
    offset: int
    size: int


@dataclass(slots=True)
class WriteOp:
    file_id: int
    offset: int
    data: bytes


@dataclass
class OffloadAPI:
    """The user-supplied customization (Table 1).  Nullable per the paper.

    ``response_header`` frames offloaded read responses for the application's
    wire protocol; ``host_handler`` lets the host application interpret
    non-default message types (integration hook, cf. §9's "hundreds of lines
    of code" adoption).  It returns one of:
      ('r', req_id, file_id, offset, nbytes)   -- host file read, then respond
      ('w', req_id, file_id, offset, data[, resp_body])
                                               -- host file write, then ack;
                                                  the optional 6th element is
                                                  the ack's response body
                                                  (e.g. a KV PUT returning
                                                  the record location, §9.2)
      ('resp', req_id, status, body)           -- immediate response
    """
    off_pred: Callable[[bytes, CacheTable | None], tuple[list[bytes], list[bytes]]]
    off_func: Callable[[bytes, CacheTable | None], ReadOp | None]
    cache: Callable[[WriteOp], list[tuple[object, object]]] | None = None
    invalidate: Callable[[ReadOp], list[object]] | None = None
    response_header: Callable[[bytes, "ReadOp", int], bytes] | None = None
    host_handler: Callable[[bytes], tuple] | None = None
    # Optional fused fast path: one call returning (ReadOp, ok_header) — or
    # None to fall back to the host — so the engine parses each request
    # header once instead of twice (OffFunc + response_header both unpack).
    prepare_read: Callable[[bytes, CacheTable | None],
                           tuple["ReadOp", bytes] | None] | None = None
    # Optional BURST form of ``prepare_read``: one call for the whole pull
    # returning one ``(ReadOp, ok_header) | None`` per request, so an app
    # can resolve every request of the burst with a single vectorized
    # cache-table probe (``lookup_many``) instead of a scalar lookup per
    # request.  Must be side-effect free: the engine may still bounce
    # individual prepared requests (ring full, read/write fence).
    prepare_read_many: Callable[[list, CacheTable | None], list] | None = None
    # Lifecycle classifier: the message TYPE BYTES that mean "read", used
    # by the server's LifecycleTracker to split host-path completion-tick
    # histograms into host-read vs write classes (a set probe per message,
    # not a call).  None => the server's default ({APP_READ}).
    read_types: frozenset | None = None
    # Request-id extractor for messages shed BEFORE any execution path
    # parses them (token-bucket admission at the director): msg -> req_id.
    # None => the server's default (u64 at byte offset 1, which both the
    # §8.1 app protocol and the KV protocol satisfy).
    req_id_of: Callable[[bytes], int] | None = None


SLAB_MIN_SHIFT = 6  # smallest size class: 64 B (one cache line)


class SlabPool:
    """Pool of DMA-accessible huge pages with a size-classed slab allocator.

    ``allocate`` returns ``(offset, memoryview)`` carved out of one large
    pinned region; the view is handed to the storage driver as the I/O
    destination and later referenced (not copied) by packet buffers.

    Requests are rounded up to power-of-two size classes (64 B minimum).
    Each class keeps a LIFO stack of freed offsets, so allocate and release
    are O(1): pop the class stack, else bump-allocate fresh space, else —
    only when both fail — fall back over the (constantly many, <= log2 size)
    larger classes.  A live-allocation map records each block's actual class,
    so a block borrowed from a larger class is returned to it intact and an
    allocate/release sequence can never corrupt a neighboring allocation.
    Replaces the old first-fit free list whose release path re-sorted and
    coalesced the whole list on EVERY call.
    """

    def __init__(self, size: int = 1 << 24):
        self.size = size
        self.buf = np.zeros(size, dtype=np.uint8)
        self._mv = memoryview(self.buf)
        self._nclasses = max((size - 1).bit_length() - SLAB_MIN_SHIFT + 1, 1)
        self._free: list[list[int]] = [[] for _ in range(self._nclasses)]
        self._live: dict[int, tuple[int, int]] = {}  # off -> (class, req n)
        self._bump = 0          # end of the slab-committed prefix
        self._lock = threading.Lock()
        self.allocs = 0
        self.failed = 0
        self._live_committed = 0  # class-rounded bytes of live blocks
        self._live_requested = 0  # caller-requested bytes of live blocks

    @staticmethod
    def class_for(n: int) -> int:
        """Index of the smallest size class holding ``n`` bytes."""
        return max((n - 1).bit_length() - SLAB_MIN_SHIFT, 0)

    @staticmethod
    def class_size(cls: int) -> int:
        return 1 << (SLAB_MIN_SHIFT + cls)

    def allocate(self, n: int) -> tuple[int, memoryview] | None:
        if n <= 0 or n > self.size:
            with self._lock:
                self.failed += 1
            return None
        cls = (n - 1).bit_length() - SLAB_MIN_SHIFT  # class_for(n), inlined
        if cls < 0:
            cls = 0
        cs = 1 << (SLAB_MIN_SHIFT + cls)
        with self._lock:
            free = self._free[cls]
            if free:
                off = free.pop()
            elif self._bump + cs <= self.size:
                off = self._bump
                self._bump += cs
            else:
                # Exhausted: borrow from a larger class (bounded scan over
                # at most log2(size) classes; blocks are NOT split, the map
                # below returns them to their true class on release).
                for c2 in range(cls + 1, self._nclasses):
                    if self._free[c2]:
                        off = self._free[c2].pop()
                        cls = c2
                        cs = 1 << (SLAB_MIN_SHIFT + c2)
                        break
                else:
                    if not self._live and cs <= self.size:
                        # Pool is COMPLETELY free but carved into smaller
                        # classes: reset the slab map (O(#classes)) so any
                        # class is satisfiable again.  Blocks are never
                        # split, so without this a small-read phase would
                        # permanently starve later large reads.
                        for fl in self._free:
                            fl.clear()
                        self._bump = 0
                        off = 0
                        self._bump = cs
                    else:
                        self.failed += 1
                        return None
            self._live[off] = (cls, n)
            self._live_committed += cs
            self._live_requested += n
            self.allocs += 1
            return off, self._mv[off : off + n]

    def allocate_many(self, count: int, n: int) -> list[tuple[int, memoryview]]:
        """Burst-allocate up to ``count`` blocks of ``n`` bytes: ONE lock round.

        Returns as many blocks as the freelist/bump region could satisfy
        without borrowing (possibly fewer than ``count``, possibly empty);
        callers fall back to per-item ``allocate`` — which may borrow from
        larger classes — for the remainder, so exhaustion behaviour is
        unchanged from the scalar path.
        """
        if count <= 0 or n <= 0 or n > self.size:
            return []
        cls = (n - 1).bit_length() - SLAB_MIN_SHIFT
        if cls < 0:
            cls = 0
        cs = 1 << (SLAB_MIN_SHIFT + cls)
        mv = self._mv
        entry = (cls, n)
        with self._lock:
            free = self._free[cls]
            take = min(count, len(free))
            if take:
                offs = free[len(free) - take:]
                del free[len(free) - take:]
            else:
                offs = []
            rem = count - take
            if rem:
                base = self._bump
                carve = min(rem, (self.size - base) // cs)
                if carve > 0:
                    offs.extend(range(base, base + carve * cs, cs))
                    self._bump = base + carve * cs
            live = self._live
            for off in offs:
                live[off] = entry
            got = len(offs)
            self._live_committed += cs * got
            self._live_requested += n * got
            self.allocs += got
        return [(off, mv[off : off + n]) for off in offs]

    def release(self, off: int, n: int) -> None:
        with self._lock:
            self._release_locked(off)

    def release_many(self, offs: list[int]) -> None:
        """Return a burst of blocks under ONE lock round (TX-batch reclaim)."""
        committed = requested = 0
        with self._lock:
            live = self._live
            free = self._free
            for off in offs:
                entry = live.pop(off, None)
                if entry is None:
                    raise ValueError(f"release of unallocated offset {off}")
                cls, req = entry
                free[cls].append(off)
                committed += 1 << (SLAB_MIN_SHIFT + cls)
                requested += req
            self._live_committed -= committed
            self._live_requested -= requested

    def _release_locked(self, off: int) -> None:
        entry = self._live.pop(off, None)
        if entry is None:
            raise ValueError(f"release of unallocated offset {off}")
        cls, req = entry
        self._free[cls].append(off)
        self._live_committed -= 1 << (SLAB_MIN_SHIFT + cls)
        self._live_requested -= req

    def in_use(self) -> int:
        with self._lock:
            return self._live_committed

    def occupancy(self) -> dict:
        """Fragmentation + per-class occupancy snapshot (observability)."""
        with self._lock:
            classes = {
                self.class_size(c): {"live": 0, "free": len(self._free[c])}
                for c in range(self._nclasses)
                if self._free[c]
            }
            for cls, _req in self._live.values():
                ent = classes.setdefault(self.class_size(cls),
                                         {"live": 0, "free": 0})
                ent["live"] += 1
            return {
                "classes": classes,
                "live_bytes": self._live_requested,
                "committed_bytes": self._live_committed,
                "internal_frag_bytes": (self._live_committed
                                        - self._live_requested),
                "bump_remaining": self.size - self._bump,
            }


# Backwards-compatible alias: the pool kept its public contract
# (``allocate -> (off, memoryview) | None``, ``release``, ``in_use``).
MemPool = SlabPool


PENDING = 0
COMPLETE = 1
FAILED = 2


@dataclass(slots=True)
class _Context:
    """One slot of the context ring (§6.2)."""
    client: FiveTuple | None = None
    read_op: ReadOp | None = None
    raw: bytes = b""         # the request message (error responses need it)
    status: int = COMPLETE   # empty slots look complete & consumed
    pool_off: int = 0
    pool_len: int = 0
    buf: memoryview | None = None
    app_hdr: bytes = b""
    consumed: bool = True
    open_tick: int = 0       # ingress tick (lifecycle stamp; plain int)

    def mark(self, err: int) -> None:
        """Device-completion callback (bound method: no per-op closure)."""
        self.status = COMPLETE if err == wire.E_OK else FAILED


@dataclass
class OffloadStats:
    offloaded: int = 0
    bounced_to_host: int = 0   # context ring full -> host path (Fig 13 l.5-7)
    completed: int = 0
    failed: int = 0
    packets: int = 0
    data_copies: int = 0       # nonzero only with zero_copy=False
    bytes_served: int = 0


class OffloadEngine:
    """Executes offloaded reads with the context ring + zero-copy pool."""

    def __init__(self, fs: SegmentFS, director: TrafficDirector,
                 api: OffloadAPI, cache_table: CacheTable | None = None,
                 ring_size: int = 256, pool_size: int = 1 << 24,
                 zero_copy: bool = True,
                 app_header: Callable[[bytes, ReadOp, int], bytes] | None = None,
                 mtu: int = MTU):
        self.fs = fs
        self.director = director
        self.api = api
        self.cache_table = cache_table
        self.ring_size = ring_size
        self.pool = SlabPool(pool_size)
        self.zero_copy = zero_copy
        self.app_header = app_header or (lambda req, op, err: b"")
        self.mtu = mtu
        self._ring = [_Context() for _ in range(ring_size)]
        self._head = 0
        self._tail = 0
        self.failed = False   # DPU failure injected: see ``fail()``
        self.stats = OffloadStats()
        # Request-lifecycle stamping, installed by the owning server.
        self.lifecycle = None
        # Optional read/write fence (ServerConfig.read_write_fence): a live
        # view of the file service's in-flight-write counts.  An offloaded
        # read of a file whose writes are still in the FILE-SERVICE
        # pipeline (held in a coalescing run, ring-queued, or at the
        # device) is bounced to the host, where the submission FIFO orders
        # it AFTER those writes.  The fence starts where the file service
        # accepts a write — a read demuxed in the same pump step as its
        # write (still on the host wire) is NOT fenced, exactly the window
        # the pre-overhaul FIFO device never ordered either; acked writes
        # are always visible regardless (acks follow device completion).
        self.busy_files: dict | None = None

    def fail(self) -> None:
        """Deterministic DPU failure: graceful degradation to the host path.

        Three things happen, none of which loses a request: (1) the
        director re-routes every future predicate-positive read straight
        to the host (``dpu_bypass`` — the PEP, admission and epoch fence
        stay in force, only the offload split is disabled); (2) requests
        already queued for the engine but not yet pulled bounce to the
        host now; (3) in-flight ring contexts complete normally — the
        device and pool are host-side resources the "DPU crash" does not
        take down, so their responses drain through ``complete_pending``.
        The server keeps serving at host-path throughput/latency
        (``DirectorStats.dpu_bypassed`` counts the degraded requests)."""
        if self.failed:
            return
        self.failed = True
        self.director.dpu_bypass = True
        queue = self.director.offload_queue
        while queue:
            for client, raw in queue.take(64):
                self._bounce_to_host(client, raw)

    def in_flight(self) -> bool:
        """True while context-ring slots await completion or consumption.

        A scheduler wakeup source: the owning server must stay runnable
        until every outstanding offloaded read has been completed AND its
        response packets pushed to the wire (``complete_pending``).
        """
        return self._head != self._tail

    # -- Fig 13 main loop --------------------------------------------------------------
    def step(self, max_requests: int = 64) -> int:
        """Pull requests from the traffic director and execute them.

        ``complete_pending`` runs once per batch (and again when the context
        ring fills up, to reclaim consumed slots before bouncing), not once
        per request — completions only materialize when the device polls.
        """
        work = 0
        queue = self.director.offload_queue
        if self.failed:
            # Degraded mode: anything that slipped into the queue after
            # ``fail()`` bounces to the host; in-flight contexts drain.
            n = 0
            while queue:
                for client, raw in queue.take(max_requests):
                    self._bounce_to_host(client, raw)
                    n += 1
            if self._head == self._tail:
                return n
            self.fs.device.poll()
            return n + self.complete_pending()
        if not queue:
            if self._head == self._tail:
                return 0  # nothing offloaded, nothing in flight
            self.fs.device.poll()
            return self.complete_pending()
        # Weighted-fair pull: the director's queue is demuxed per tenant,
        # so a flooding tenant's backlog yields this burst's slots to every
        # backlogged tenant in weight proportion (single-tenant: plain FIFO).
        reqs = queue.take(max_requests)
        # Hot loop: hoist per-request attribute lookups out of the loop and
        # fold per-request stats into ONE update after the batch.
        off_func = self.api.off_func
        prepare = self.api.prepare_read
        table = self.cache_table
        # Burst prepare: ONE call (and one vectorized cache-table probe)
        # resolves the whole pull; the loop below only consumes results.
        prepare_many = self.api.prepare_read_many
        prepped_list = None
        if prepare_many is not None and len(reqs) > 1:
            prepped_list = prepare_many([r for _, r in reqs], table)
        allocate = self.pool.allocate
        # Uniform-size burst alloc: when the whole pull wants one block size
        # (the storm shape), ONE pool lock round reserves every buffer; any
        # reserved-but-unused blocks (bounced requests) are released in one
        # round at the end.  Non-uniform pulls keep the per-item path.
        blocks: list[tuple[int, memoryview]] = []
        blk_n = 0
        if prepped_list is not None:
            sizes = {PKT_HEADROOM + p[0].size
                     for p in prepped_list if p is not None}
            if len(sizes) == 1:
                blk_n = sizes.pop()
                blocks = self.pool.allocate_many(
                    sum(p is not None for p in prepped_list), blk_n)
        app_header = self.app_header
        submit_read = self.fs.submit_read
        # Zero-copy submissions are DEFERRED and flushed as one
        # ``fs.submit_read_many`` burst — always before any device poll, so
        # queue order and completion order match the scalar submission loop.
        submit_read_many = self.fs.submit_read_many
        deferred: list = []
        ring, ring_size = self._ring, self.ring_size
        zero_copy = self.zero_copy
        lifecycle = self.lifecycle
        # One clock read covers the whole burst: the clock only ticks at
        # scheduling-step boundaries, never inside a step.
        now_tick = lifecycle.clock.now if lifecycle is not None else 0
        busy_files = self.busy_files
        tail = self._tail
        head = self._head
        for i, (client, raw) in enumerate(reqs):
            if tail - head >= ring_size:
                self._tail = tail
                if deferred:   # flush so in-flight reads can complete below
                    submit_read_many(deferred, priority=True)
                    deferred = []
                self.fs.device.poll()
                self.complete_pending()  # reclaim consumed contexts first
                head = self._head
                if tail - head >= ring_size:
                    # Ring fully occupied: send this and the REST to the host.
                    for c2, r2 in reqs[i:]:
                        self._bounce_to_host(c2, r2)
                    break
            if prepped_list is not None:
                prepped = prepped_list[i]
                if prepped is None:
                    self._bounce_to_host(client, raw)
                    continue
                read_op, ok_hdr = prepped
            elif prepare is not None:
                # fused path: ONE header parse yields the op and its header
                prepped = prepare(raw, table)
                if prepped is None:
                    self._bounce_to_host(client, raw)
                    continue
                read_op, ok_hdr = prepped
            else:
                read_op = off_func(raw, table)
                if read_op is None:
                    self._bounce_to_host(client, raw)
                    continue
                ok_hdr = None
            fid = read_op.file_id
            size = read_op.size
            if busy_files is not None and fid in busy_files:
                # Read/write fence: writes to this file are still in flight
                # on the host path — serve the read there too, so the file
                # service's submission FIFO orders it after them.
                self._bounce_to_host(client, raw)
                continue
            want = PKT_HEADROOM + size
            alloc = (blocks.pop() if blocks and want == blk_n
                     else allocate(want))
            if alloc is None:
                self._bounce_to_host(client, raw)
                continue
            off, view = alloc
            ctx = ring[tail % ring_size]
            ctx.client = client
            ctx.read_op = read_op
            ctx.raw = raw
            ctx.status = PENDING
            ctx.pool_off, ctx.pool_len = off, want
            ctx.buf = view
            ctx.app_hdr = (ok_hdr if ok_hdr is not None
                           else app_header(raw, read_op, wire.E_OK))
            ctx.consumed = False
            ctx.open_tick = now_tick
            tail += 1
            # Destination = pool memory; the device writes it exactly once.
            # Offloaded reads ride the device's PRIORITY queue: the
            # latency-critical path never waits behind host-path write runs
            # (the normal queue keeps a bounded interleave share).
            dest = view[PKT_HEADROOM:want]
            if not zero_copy:
                scratch = bytearray(size)

                def done(err: int, ctx=ctx, scratch=scratch):
                    if err == wire.E_OK:
                        ctx.buf[PKT_HEADROOM : PKT_HEADROOM + ctx.read_op.size] = scratch
                        self.stats.data_copies += 1
                    ctx.status = COMPLETE if err == wire.E_OK else FAILED

                self.fs.submit_read(read_op.file_id, read_op.offset,
                                    read_op.size, memoryview(scratch), done,
                                    priority=True)
            else:
                deferred.append((fid, read_op.offset, size, dest, ctx.mark))
            work += 1
        self._tail = tail
        if deferred:
            submit_read_many(deferred, priority=True)
        if blocks:   # reserved for requests that bounced instead
            self.pool.release_many([off for off, _ in blocks])
        self.stats.offloaded += work
        self.fs.device.poll()
        return work + self.complete_pending()

    def _bounce_to_host(self, client: FiveTuple, raw: bytes) -> None:
        # The bounced read re-enters the host path, where the host app's
        # in-flight meta stamps it — it finishes in the host_read class.
        conn = self.director._conn(client)
        self.director._send_to_host(conn, client, raw)
        self.stats.bounced_to_host += 1

    # -- ordered completion (Fig 13 CompletePending) --------------------------------
    def complete_pending(self) -> int:
        """Consume the completed prefix; responses leave in request order.

        Back-to-back completions for the SAME client are coalesced into one
        ``dpu_response`` burst (one sequence-stamp pass + one wire lock
        round per run of contexts instead of per response).
        """
        done = 0
        head, tail = self._head, self._tail
        if head == tail:
            return 0
        ring, ring_size = self._ring, self.ring_size
        stats = self.stats
        pool = self.pool
        lifecycle = self.lifecycle
        if lifecycle is not None:
            dpu_hist_add = lifecycle.hist["dpu_read"].add
            dpu_hist_bulk = lifecycle.hist["dpu_read"].add_many
            tenant_add = lifecycle.add_tenant
            now_tick = lifecycle.clock.now
        run_delta = run_n = 0  # run-length fold for untenanted completions
        completed = failed = bytes_served = pkt_count = 0
        burst_client = None
        burst: list[Packet] = []
        burst_n = 0
        dpu_response = self.director.dpu_response
        mtu = self.mtu
        burst_append = burst.append
        while head != tail:
            ctx = ring[head % ring_size]
            status = ctx.status
            if status == PENDING:
                break  # preserve response order
            if not ctx.consumed:
                client = ctx.client
                size = ctx.read_op.size
                if lifecycle is not None:
                    # Response-publish tick for this offloaded read.  Whole
                    # bursts share one publish tick and (usually) one open
                    # tick, so equal deltas are folded and counted once.
                    delta = now_tick - ctx.open_tick
                    t = client.tenant
                    if t:
                        dpu_hist_add(delta)
                        tenant_add(t, "dpu_read", delta)
                    elif delta == run_delta and run_n:
                        run_n += 1
                    else:
                        if run_n:
                            dpu_hist_bulk(run_delta, run_n)
                        run_delta, run_n = delta, 1
                if (status == COMPLETE
                        and (h := len(ctx.app_hdr)) + size <= mtu):
                    # Inlined ``_create_pkts`` common case — one indirect
                    # packet, header placed in the buffer headroom.
                    buf = ctx.buf
                    buf[PKT_HEADROOM - h:PKT_HEADROOM] = ctx.app_hdr
                    pkt = Packet(client, 0,
                                 buf[PKT_HEADROOM - h:PKT_HEADROOM + size])
                    pkt_count += 1
                    # Ownership rides on the (single) packet and is
                    # released at TX-consumption (Fig 12) — releasing here
                    # would let a later read overwrite a response the
                    # client has not drained yet.
                    pkt.pool_ref = (pool, ctx.pool_off, ctx.pool_len)
                    completed += 1
                    bytes_served += size
                    if client is burst_client:
                        burst_append(pkt)
                        burst_n += 1
                    else:
                        if burst:
                            dpu_response(burst_client, burst, burst_n)
                        burst_client, burst, burst_n = client, [pkt], 1
                        burst_append = burst.append
                else:
                    pkts = self._create_pkts(ctx)
                    if status == COMPLETE:
                        # Indirect packets reference pool memory: ownership
                        # rides on the last packet (Fig 12), as above.
                        pkts[-1].pool_ref = (pool, ctx.pool_off, ctx.pool_len)
                        completed += 1
                        bytes_served += size
                    else:
                        # Error responses carry only header bytes — the pool
                        # block is unreferenced and can be reclaimed now.
                        pool.release(ctx.pool_off, ctx.pool_len)
                        failed += 1
                    if client is burst_client:
                        burst.extend(pkts)
                        burst_n += 1
                    else:
                        if burst:
                            dpu_response(burst_client, burst, burst_n)
                        burst_client, burst, burst_n = client, pkts, 1
                        burst_append = burst.append
                ctx.consumed = True
                ctx.buf = None
                ctx.raw = b""
            head += 1
            done += 1
        self._head = head
        if run_n:
            dpu_hist_bulk(run_delta, run_n)
        if burst:
            dpu_response(burst_client, burst, burst_n)
        stats.completed += completed
        stats.failed += failed
        stats.bytes_served += bytes_served
        stats.packets += pkt_count
        return done

    def _create_pkts(self, ctx: _Context) -> list[Packet]:
        """Indirect packet buffers: header bytes + *references* into the pool.

        Data > MTU is segmented into multiple packets whose payloads are
        slices of the read buffer — no copy (Fig 12 step 3).
        """
        hdr = ctx.app_hdr
        if ctx.status != COMPLETE:
            # Frame the error from the ORIGINAL request so it carries the
            # real request id — a b"" fallback would answer req_id 0 and the
            # caller's wait() would never resolve.
            hdr = self.app_header(ctx.raw, ctx.read_op, wire.E_IO)
            pkt = Packet(ctx.client, 0, hdr)
            self.stats.packets += 1
            return [pkt]
        total = ctx.read_op.size
        # First packet carries the app header; place it in the buffer headroom
        # immediately before the data so header+data are one contiguous slice.
        h = len(hdr)
        assert h <= PKT_HEADROOM
        ctx.buf[PKT_HEADROOM - h : PKT_HEADROOM] = hdr
        if h + total <= self.mtu:  # common case: one indirect packet
            self.stats.packets += 1
            return [Packet(ctx.client, 0,
                           ctx.buf[PKT_HEADROOM - h : PKT_HEADROOM + total])]
        data = ctx.buf[PKT_HEADROOM : PKT_HEADROOM + total]
        pkts: list[Packet] = []
        first_len = min(self.mtu, h + total)
        pkts.append(Packet(ctx.client, 0,
                           ctx.buf[PKT_HEADROOM - h : PKT_HEADROOM - h + first_len]))
        sent = first_len - h
        while sent < total:
            n = min(self.mtu, total - sent)
            pkts.append(Packet(ctx.client, 0, data[sent : sent + n]))
            sent += n
        self.stats.packets += len(pkts)
        return pkts

    # -- cache-table maintenance (wired into the file service, §6.1/Table 2) -------
    def on_host_write(self, op: WriteOp) -> None:
        if self.api.cache and self.cache_table is not None:
            for key, item in self.api.cache(op):
                if item is None:
                    # Tombstone: the app logged a delete marker — drop the
                    # mapping instead of upserting it.
                    self.cache_table.delete(key)
                else:
                    self.cache_table.insert(key, item)

    def on_host_read(self, op: ReadOp) -> None:
        if self.api.invalidate and self.cache_table is not None:
            for key in self.api.invalidate(op):
                self.cache_table.delete(key)
