"""DDS traffic director (§5): bump-in-the-wire + PEP transport transparency.

No NIC exists inside a JAX container, so the transport is modeled with typed
packets on in-process wires — but the *semantics* the paper cares about are
implemented exactly:

  * **Application signature** (§5.1): a 5-tuple wildcard filter evaluated on
    packet headers.  Matching is "pushed down to the network interface": a
    non-matching packet is hardware-forwarded to the host with ZERO DPU-core
    latency added; only matching packets reach the director's cores.

  * **Offload predicate**: user code applied to packet payloads, producing a
    host list and a DPU list per network message (Table 1 ``OffPred``).

  * **PEP / TCP splitting** (§5.2): partial offloading breaks end-to-end
    sequence numbers (Fig 11) — if the DPU consumed bytes [132, 1064) of a
    flow, the host's TCP would see a gap and dup-ACK, forcing the client to
    resend everything that was offloaded.  The director therefore terminates
    the client connection at the DPU and opens a SECOND connection to the
    host with its own contiguous sequence space; host-bound requests are
    re-framed onto it.  ``TCPReceiver`` models the host stack so tests can
    show dup-ACKs with a naive splitter and none with the PEP.

  * **RSS** (§7): flows are mapped to director cores by a SYMMETRIC 5-tuple
    hash, so host responses in a split connection are handled by the same
    core that split it — no cross-core connection state.

Latency accounting is *modeled* (BF-2 measurements from §5.3: ~6 us to
forward a packet via an Arm core, ~10 us round trip for a matched packet
that fails the predicate); nothing sleeps.
"""

from __future__ import annotations

import struct
import threading
from collections import deque
from dataclasses import dataclass, field
from typing import Callable

# Modeled BF-2 constants (§5.3).
ARM_FORWARD_LATENCY_S = 6e-6
PREDICATE_FAIL_RTT_S = 10e-6
TLDK_PER_PKT_S = 2e-6     # userspace stack per-packet cost on an Arm core
LINUX_TCP_PER_PKT_S = 25e-6  # kernel stack on the DPU (Fig 19: ~3x worse)

FLAG_SYN = 1
FLAG_ACK = 2
FLAG_FIN = 4


@dataclass(frozen=True)
class FiveTuple:
    src_ip: str
    src_port: int
    dst_ip: str
    dst_port: int
    proto: str = "tcp"

    def reversed(self) -> "FiveTuple":
        return FiveTuple(self.dst_ip, self.dst_port, self.src_ip,
                         self.src_port, self.proto)


@dataclass
class Packet:
    flow: FiveTuple
    seq: int                 # first byte's sequence number
    payload: bytes | memoryview
    flags: int = 0
    ack: int = 0

    @property
    def nbytes(self) -> int:
        return len(self.payload)


@dataclass
class ApplicationSignature:
    """5-tuple wildcard filter; None = match-any (§5.1 example)."""
    src_ip: str | None = None
    src_port: int | None = None
    dst_ip: str | None = None
    dst_port: int | None = None
    proto: str | None = "tcp"

    def matches(self, ft: FiveTuple) -> bool:
        return ((self.src_ip is None or self.src_ip == ft.src_ip)
                and (self.src_port is None or self.src_port == ft.src_port)
                and (self.dst_ip is None or self.dst_ip == ft.dst_ip)
                and (self.dst_port is None or self.dst_port == ft.dst_port)
                and (self.proto is None or self.proto == ft.proto))


class Wire:
    """A unidirectional link: thread-safe packet queue."""

    def __init__(self, name: str):
        self.name = name
        self._q: deque[Packet] = deque()
        self._lock = threading.Lock()

    def push(self, pkt: Packet) -> None:
        with self._lock:
            self._q.append(pkt)

    def pop(self) -> Packet | None:
        with self._lock:
            return self._q.popleft() if self._q else None

    def __len__(self) -> int:
        with self._lock:
            return len(self._q)


class TCPReceiver:
    """Host TCP receive model: detects sequence gaps and duplicate-ACKs.

    This exists to demonstrate (and regression-test) Fig 11: with a naive
    bump-in-the-wire that silently consumes offloaded bytes, the host sees a
    gap and dup-ACKs, forcing client retransmission of offloaded data.
    """

    def __init__(self):
        self.expected_seq = 0
        self.dup_acks = 0
        self.delivered: list[bytes] = []
        self.acked: int = 0

    def receive(self, pkt: Packet) -> tuple[bool, int]:
        """Returns (accepted, ack_number)."""
        if pkt.flags & FLAG_SYN:
            self.expected_seq = pkt.seq + 1
            self.acked = self.expected_seq
            return True, self.acked
        if pkt.seq != self.expected_seq:
            self.dup_acks += 1          # fast-recovery trigger
            return False, self.acked    # duplicate ACK of the old edge
        self.expected_seq += pkt.nbytes
        self.acked = self.expected_seq
        self.delivered.append(bytes(pkt.payload))
        return True, self.acked


def rss_core(ft: FiveTuple, ncores: int) -> int:
    """Symmetric RSS hash: both directions of a flow land on one core (§7)."""
    a = (ft.src_ip, ft.src_port)
    b = (ft.dst_ip, ft.dst_port)
    lo, hi = (a, b) if a <= b else (b, a)
    h = hash((lo, hi, ft.proto)) & 0x7FFFFFFF
    return h % max(1, ncores)


@dataclass
class _PEPConnection:
    """State for one split client connection (client<->DPU, DPU<->host)."""
    client_flow: FiveTuple
    client_next_seq: int = 0     # next byte expected from the client
    client_resp_seq: int = 0     # next byte we send toward the client
    host_next_seq: int = 0       # next byte on the DPU->host connection
    core: int = 0


@dataclass
class DirectorStats:
    hw_forwarded: int = 0         # packets bypassing DPU cores (NIC match miss)
    inspected: int = 0
    to_host: int = 0              # messages re-framed to the host connection
    to_dpu: int = 0               # messages handed to the offload engine
    resp_from_host: int = 0
    resp_from_dpu: int = 0
    modeled_time_s: float = 0.0
    per_core_pkts: dict[int, int] = field(default_factory=dict)


class TrafficDirector:
    """The DDS bump-in-the-wire packet processor."""

    def __init__(self, signature: ApplicationSignature,
                 off_pred: Callable[[bytes, object], tuple[list[bytes], list[bytes]]],
                 cache_table: object | None = None,
                 ncores: int = 1,
                 host_port: int = 9999,
                 userspace_stack: bool = True):
        self.signature = signature
        self.off_pred = off_pred
        self.cache_table = cache_table
        self.ncores = ncores
        self.host_port = host_port
        self.per_pkt_cost = TLDK_PER_PKT_S if userspace_stack else LINUX_TCP_PER_PKT_S
        # Wires: ingress (from NIC), to-host, to-client, and the offload queue.
        self.ingress = Wire("nic-ingress")
        self.to_host = Wire("dpu->host")
        self.from_host = Wire("host->dpu")
        self.to_client = Wire("dpu->client")
        self.offload_queue: deque[tuple[FiveTuple, bytes]] = deque()
        self._conns: dict[FiveTuple, _PEPConnection] = {}
        self._host_flow_of: dict[FiveTuple, FiveTuple] = {}
        self.stats = DirectorStats()
        self._lock = threading.Lock()

    # -- connection management ------------------------------------------------------
    def _conn(self, ft: FiveTuple) -> _PEPConnection:
        c = self._conns.get(ft)
        if c is None:
            c = _PEPConnection(ft, core=rss_core(ft, self.ncores))
            self._conns[ft] = c
            # Second connection of the split: DPU -> host, own seq space.
            host_flow = FiveTuple("dpu-proxy", 40000 + len(self._conns),
                                  "host", self.host_port, ft.proto)
            self._host_flow_of[ft] = host_flow
        return c

    # -- ingress processing (one step = one packet) -----------------------------------
    def step(self) -> bool:
        pkt = self.ingress.pop()
        if pkt is None:
            return False
        # Stage 1: application signature, evaluated in NIC hardware (§5.3).
        if not self.signature.matches(pkt.flow):
            self.stats.hw_forwarded += 1
            self.to_host.push(pkt)   # line-rate forward; no Arm-core latency
            return True
        conn = self._conn(pkt.flow)
        self.stats.inspected += 1
        self.stats.per_core_pkts[conn.core] = (
            self.stats.per_core_pkts.get(conn.core, 0) + 1)
        self.stats.modeled_time_s += self.per_pkt_cost
        if pkt.flags & FLAG_SYN:
            conn.client_next_seq = pkt.seq + 1
            return True
        if pkt.seq != conn.client_next_seq:
            return True  # PEP handles client-side reliability; drop dup/ooo
        conn.client_next_seq += pkt.nbytes
        # Stage 2: the offload predicate inspects the payload.
        host_msgs, dpu_msgs = self.off_pred(bytes(pkt.payload), self.cache_table)
        for m in host_msgs:
            self._send_to_host(conn, pkt.flow, m)
        for m in dpu_msgs:
            self.stats.to_dpu += 1
            self.offload_queue.append((pkt.flow, m))
        if host_msgs and not dpu_msgs:
            # matched the signature but fully host-bound: paid the round trip
            self.stats.modeled_time_s += PREDICATE_FAIL_RTT_S - self.per_pkt_cost
        return True

    def _send_to_host(self, conn: _PEPConnection, client_flow: FiveTuple,
                      msg: bytes) -> None:
        """Re-frame a host-bound message onto the split DPU->host connection.

        The host connection's sequence numbers stay CONTIGUOUS even though
        the DPU consumed some client bytes — transport transparency.
        """
        host_flow = self._host_flow_of[client_flow]
        self.to_host.push(Packet(host_flow, conn.host_next_seq, msg))
        conn.host_next_seq += len(msg)
        self.stats.to_host += 1
        self.stats.modeled_time_s += ARM_FORWARD_LATENCY_S

    # -- response paths -----------------------------------------------------------------
    def host_response(self, host_flow: FiveTuple, msg: bytes) -> None:
        """A response from the host app on the second connection."""
        client_flow = next((cf for cf, hf in self._host_flow_of.items()
                            if hf == host_flow), None)
        if client_flow is None:
            # Hardware-forwarded flow (no split): respond on the client flow.
            client_flow = host_flow
        self._respond_to_client(client_flow, msg)
        self.stats.resp_from_host += 1

    def dpu_response(self, client_flow: FiveTuple, packets: list[Packet]) -> None:
        """Responses produced by the offload engine (already segmented)."""
        conn = self._conn(client_flow)
        for p in packets:
            p.flow = client_flow.reversed()
            p.seq = conn.client_resp_seq
            conn.client_resp_seq += p.nbytes
            self.to_client.push(p)
        self.stats.resp_from_dpu += 1

    def _respond_to_client(self, client_flow: FiveTuple, msg: bytes) -> None:
        conn = self._conn(client_flow)
        self.to_client.push(Packet(client_flow.reversed(),
                                   conn.client_resp_seq, msg))
        conn.client_resp_seq += len(msg)

    def drain_host_wire(self, deliver: Callable[[FiveTuple, bytes], None]) -> int:
        """Pump packets that crossed to the host into the host application."""
        n = 0
        while True:
            pkt = self.to_host.pop()
            if pkt is None:
                return n
            deliver(pkt.flow, bytes(pkt.payload))
            n += 1


class NaiveSplitter:
    """A broken bump-in-the-wire WITHOUT the PEP, for the Fig 11 test.

    Offloaded bytes are silently consumed; host-bound packets keep their
    ORIGINAL client sequence numbers, so the host receiver sees gaps.
    """

    def __init__(self, off_pred):
        self.off_pred = off_pred
        self.offloaded: list[bytes] = []

    def process(self, pkt: Packet, host: TCPReceiver) -> tuple[bool, int]:
        host_msgs, dpu_msgs = self.off_pred(bytes(pkt.payload), None)
        if dpu_msgs and not host_msgs:
            self.offloaded.append(bytes(pkt.payload))
            return True, host.acked  # consumed on the DPU; host never sees it
        return host.receive(pkt)     # gap => dup-ACK
