"""DDS traffic director (§5): bump-in-the-wire + PEP transport transparency.

No NIC exists inside a JAX container, so the transport is modeled with typed
packets on in-process wires — but the *semantics* the paper cares about are
implemented exactly:

  * **Application signature** (§5.1): a 5-tuple wildcard filter evaluated on
    packet headers.  Matching is "pushed down to the network interface": a
    non-matching packet is hardware-forwarded to the host with ZERO DPU-core
    latency added; only matching packets reach the director's cores.

  * **Offload predicate**: user code applied to packet payloads, producing a
    host list and a DPU list per network message (Table 1 ``OffPred``).

  * **PEP / TCP splitting** (§5.2): partial offloading breaks end-to-end
    sequence numbers (Fig 11) — if the DPU consumed bytes [132, 1064) of a
    flow, the host's TCP would see a gap and dup-ACK, forcing the client to
    resend everything that was offloaded.  The director therefore terminates
    the client connection at the DPU and opens a SECOND connection to the
    host with its own contiguous sequence space; host-bound requests are
    re-framed onto it.  ``TCPReceiver`` models the host stack so tests can
    show dup-ACKs with a naive splitter and none with the PEP.

  * **RSS** (§7): flows are mapped to director cores by a SYMMETRIC 5-tuple
    hash, so host responses in a split connection are handled by the same
    core that split it — no cross-core connection state.

Latency accounting is *modeled* (BF-2 measurements from §5.3: ~6 us to
forward a packet via an Arm core, ~10 us round trip for a matched packet
that fails the predicate); nothing sleeps.
"""

from __future__ import annotations

import bisect
import struct
import threading
from collections import deque
from dataclasses import dataclass, field
from itertools import repeat
from typing import Callable

from repro.core.vector import checksum64

# Modeled BF-2 constants (§5.3).
ARM_FORWARD_LATENCY_S = 6e-6
PREDICATE_FAIL_RTT_S = 10e-6
TLDK_PER_PKT_S = 2e-6     # userspace stack per-packet cost on an Arm core
LINUX_TCP_PER_PKT_S = 25e-6  # kernel stack on the DPU (Fig 19: ~3x worse)

FLAG_SYN = 1
FLAG_ACK = 2
FLAG_FIN = 4


@dataclass(frozen=True)
class FiveTuple:
    """Flow identity: the classic 5-tuple plus a first-class ``tenant`` id.

    The tenant rides the flow (it is part of identity and hashing): a
    client binds its tenant once at connection time, and every request,
    split host connection, and response inherits it — the wire format
    the QoS layer (weighted-fair demux, token-bucket admission, per-tenant
    histograms) keys on.  ``tenant == 0`` is the untenanted default.
    """

    src_ip: str
    src_port: int
    dst_ip: str
    dst_port: int
    proto: str = "tcp"
    tenant: int = 0

    def __post_init__(self):
        # Flows key every hot-path dict (connections, demux queues); caching
        # the hash beats re-tupling six fields on each lookup.
        object.__setattr__(self, "_hash", hash(
            (self.src_ip, self.src_port, self.dst_ip, self.dst_port,
             self.proto, self.tenant)))

    def __hash__(self) -> int:
        return self._hash

    def reversed(self) -> "FiveTuple":
        return FiveTuple(self.dst_ip, self.dst_port, self.src_ip,
                         self.src_port, self.proto, self.tenant)


def _flow_order(ft: FiveTuple) -> tuple:
    """Deterministic total order over flows (fair drains iterate sorted)."""
    return (ft.tenant, ft.src_ip, ft.src_port, ft.dst_ip, ft.dst_port,
            ft.proto)


@dataclass(slots=True)
class Packet:
    flow: FiveTuple
    seq: int                 # first byte's sequence number
    payload: bytes | memoryview
    flags: int = 0
    ack: int = 0
    # Indirect-packet buffer ownership (Fig 12): ``(pool, off, len)`` set on
    # the LAST packet referencing a pool allocation.  The wire consumer
    # releases it AFTER copying the payload out — like a NIC TX-completion —
    # so pool memory is never rewritten under an in-flight packet.
    pool_ref: tuple | None = None
    # Ring epoch the sender routed under (-1 = untagged: standalone clients
    # and control traffic skip epoch fencing entirely).  A tagged packet
    # older than the receiving director's current epoch is answered with a
    # terminal redirect instead of being served — post-failover, the keys it
    # addressed may live on a different shard.
    epoch: int = -1
    # Frame checksum (``vector.checksum64`` of the payload; -1 = unstamped).
    # Stamped by senders when wire checksums are armed; a receiver that
    # finds a mismatch DISCARDS the frame — a corrupt frame is a lost
    # frame, recovered by the client's timeout/resend layer, never parsed.
    csum: int = -1

    @property
    def nbytes(self) -> int:
        return len(self.payload)

    def consumed(self) -> None:
        """Release the backing pool block (no-op for direct packets)."""
        ref = self.pool_ref
        if ref is not None:
            self.pool_ref = None
            ref[0].release(ref[1], ref[2])


@dataclass
class ApplicationSignature:
    """5-tuple wildcard filter; None = match-any (§5.1 example)."""
    src_ip: str | None = None
    src_port: int | None = None
    dst_ip: str | None = None
    dst_port: int | None = None
    proto: str | None = "tcp"

    def matches(self, ft: FiveTuple) -> bool:
        return ((self.src_ip is None or self.src_ip == ft.src_ip)
                and (self.src_port is None or self.src_port == ft.src_port)
                and (self.dst_ip is None or self.dst_ip == ft.dst_ip)
                and (self.dst_port is None or self.dst_port == ft.dst_port)
                and (self.proto is None or self.proto == ft.proto))


class Wire:
    """A unidirectional link: thread-safe packet queue."""

    def __init__(self, name: str):
        self.name = name
        self._q: deque[Packet] = deque()
        self._lock = threading.Lock()

    def push(self, pkt: Packet) -> None:
        with self._lock:
            self._q.append(pkt)

    def push_many(self, pkts: list[Packet]) -> None:
        """Append a burst under a single lock round."""
        with self._lock:
            self._q.extend(pkts)

    def pop(self) -> Packet | None:
        with self._lock:
            return self._q.popleft() if self._q else None

    def pop_many(self, n: int) -> list[Packet]:
        """Pop up to ``n`` packets under ONE lock round (burst processing)."""
        if not self._q:   # racy-but-safe emptiness peek: skip the lock
            return []
        with self._lock:
            q = self._q
            if not q:
                return []
            k = min(n, len(q))
            return [q.popleft() for _ in range(k)]

    def __len__(self) -> int:
        with self._lock:
            return len(self._q)

    def __bool__(self) -> bool:
        # Lock-free emptiness peek (same contract as ``pop_many``'s): the
        # scheduler's busy-predicates probe wires on every idle check, where
        # a lock round per probe would double the cost of being idle.
        return bool(self._q)


class FlowDemuxWire:
    """A wire demultiplexed by destination flow: per-flow FIFO queues.

    The director's response wire carries every client's packets; a single
    shared queue forces each client to pop-and-requeue everyone else's
    traffic (O(clients x packets) per drain).  Demuxing gives each client an
    O(1) ``pop_flow``/``drain_flow`` on its own queue while per-flow FIFO
    order — the only order TCP guarantees — is preserved.
    """

    def __init__(self, name: str):
        self.name = name
        self._q: dict[FiveTuple, deque[Packet]] = {}
        self._lock = threading.Lock()
        self._len = 0
        # Tenant service weights for the fair drain (``pop_many``); None
        # means every tenant weighs ``1``.  Installed by the owning server
        # from its QoSProfile.
        self.weight_of: Callable[[int], int] | None = None
        self._next_tenant = 0   # fair-drain resume point (bounded starvation)

    def push(self, pkt: Packet) -> None:
        with self._lock:
            dq = self._q.get(pkt.flow)
            if dq is None:
                dq = self._q[pkt.flow] = deque()
            dq.append(pkt)
            self._len += 1

    def push_many(self, flow: FiveTuple, pkts: list[Packet]) -> None:
        """Append a burst for one flow under a single lock round."""
        with self._lock:
            dq = self._q.get(flow)
            if dq is None:
                dq = self._q[flow] = deque()
            dq.extend(pkts)
            self._len += len(pkts)

    def pop_flow(self, flow: FiveTuple) -> Packet | None:
        if not self._q.get(flow):   # racy-but-safe emptiness peek
            return None
        with self._lock:
            dq = self._q.get(flow)
            if not dq:
                return None
            self._len -= 1
            return dq.popleft()

    def drain_flow(self, flow: FiveTuple) -> list[Packet]:
        """Take EVERY queued packet for ``flow`` in one O(1) swap."""
        if not self._q.get(flow):   # racy-but-safe emptiness peek
            return []
        with self._lock:
            dq = self._q.get(flow)
            if not dq:
                return []
            out = list(dq)
            dq.clear()
            self._len -= len(out)
            return out

    def pop(self) -> Packet | None:
        """Pop from any non-empty flow (per-flow FIFO; cross-flow unordered)."""
        with self._lock:
            for dq in self._q.values():
                if dq:
                    self._len -= 1
                    return dq.popleft()
            return None

    def pop_many(self, n: int) -> list[Packet]:
        """Pop up to ``n`` packets, weighted-fairly ACROSS TENANTS.

        Per-flow FIFO (the only order TCP guarantees) is always preserved.
        With one backlogged flow this is exactly a FIFO burst pop; with
        several, service rotates tenant-by-tenant — each backlogged tenant
        takes up to ``weight_of(tenant)`` packets per round, its flows
        round-robined one packet at a time — so a flooding tenant's backlog
        cannot monopolize a drain slice.  The rotation resumes where the
        previous call stopped (``_next_tenant``), bounding starvation
        across calls even when ``n`` is smaller than the tenant count.
        """
        if self._len == 0 or n <= 0:
            return []
        with self._lock:
            live = [f for f, dq in self._q.items() if dq]
            if not live:
                return []
            if len(live) == 1:
                dq = self._q[live[0]]
                k = min(n, len(dq))
                out = [dq.popleft() for _ in range(k)]
                self._len -= k
                return out
            live.sort(key=_flow_order)
            # Group the (sorted) flows by tenant, preserving flow order.
            tenants: list[int] = []
            flows_of: dict[int, list[deque]] = {}
            for f in live:
                g = flows_of.get(f.tenant)
                if g is None:
                    g = flows_of[f.tenant] = []
                    tenants.append(f.tenant)
                g.append(self._q[f])
            # Rotate so service resumes after the last tenant served.
            i = bisect.bisect_left(tenants, self._next_tenant)
            tenants = tenants[i:] + tenants[:i]
            weight_of = self.weight_of
            out: list[Packet] = []
            budget = n
            while budget > 0 and tenants:
                alive: list[int] = []
                for ti, t in enumerate(tenants):
                    quantum = weight_of(t) if weight_of is not None else 1
                    if quantum > budget:
                        quantum = budget
                    group = flows_of[t]
                    took = 1
                    while quantum > 0 and took:
                        took = 0
                        for dq in group:
                            if not dq:
                                continue
                            out.append(dq.popleft())
                            took += 1
                            quantum -= 1
                            if quantum <= 0:
                                break
                    if any(group):
                        alive.append(t)
                    budget = n - len(out)
                    if budget <= 0:
                        nxt = tenants[ti + 1] if ti + 1 < len(tenants) \
                            else tenants[0]
                        self._next_tenant = nxt
                        break
                else:
                    tenants = alive
                    continue
                break
            self._len -= len(out)
            return out

    def flows(self) -> list[FiveTuple]:
        with self._lock:
            return [f for f, dq in self._q.items() if dq]

    def __len__(self) -> int:
        with self._lock:
            return self._len

    def __bool__(self) -> bool:
        return self._len > 0   # racy-but-safe peek (int read is atomic)


class TenantFairQueue:
    """The director's offload queue, demultiplexed per tenant.

    PR 5's priority demux put offloaded reads ahead of host work — but the
    offload queue itself was one FIFO, so a flooding tenant's GETs filled
    it and a well-behaved tenant's reads queued behind ALL of them.  This
    queue keys requests by ``flow.tenant`` and serves them weighted
    round-robin: each ``take`` round gives every backlogged tenant up to
    ``weight_of(tenant)`` requests, resuming across calls where the last
    take stopped, so no tenant is ever starved and the queue stays
    work-conserving (an idle tenant's share flows to the backlogged ones).

    Single-tenant behavior is EXACTLY the old FIFO (same pop order), so
    untenanted deployments keep byte-identical schedules.  Items are the
    director's ``(flow, msg)`` pairs.  Single-threaded by design: the
    queue is only touched from the owning server's pump (same discipline
    as the plain deque it replaces).
    """

    __slots__ = ("weight_of", "_q", "_next_tenant", "_len")

    def __init__(self, weight_of: Callable[[int], int] | None = None):
        self.weight_of = weight_of
        self._q: dict[int, deque] = {}
        self._next_tenant = 0
        self._len = 0

    def append(self, item: tuple[FiveTuple, bytes]) -> None:
        t = item[0].tenant
        dq = self._q.get(t)
        if dq is None:
            dq = self._q[t] = deque()
        dq.append(item)
        self._len += 1

    def extend_flow(self, flow: FiveTuple, msgs: list) -> None:
        """Enqueue one flow's whole message burst: one tenant lookup, and
        the (flow, msg) pairs are built by C-level ``zip`` instead of a
        Python tuple per message."""
        t = flow.tenant
        dq = self._q.get(t)
        if dq is None:
            dq = self._q[t] = deque()
        dq.extend(zip(repeat(flow), msgs))
        self._len += len(msgs)

    def take(self, budget: int) -> list[tuple[FiveTuple, bytes]]:
        """Take up to ``budget`` requests, weighted-fairly across tenants."""
        if self._len == 0 or budget <= 0:
            return []
        q = self._q
        active = [t for t in q if q[t]]
        if len(active) == 1:
            dq = q[active[0]]
            if len(dq) <= budget:
                out = list(dq)
                dq.clear()
            else:
                out = [dq.popleft() for _ in range(budget)]
            self._len -= len(out)
            return out
        active.sort()
        i = bisect.bisect_left(active, self._next_tenant)
        active = active[i:] + active[:i]
        weight_of = self.weight_of
        out: list = []
        while active and len(out) < budget:
            alive: list[int] = []
            exhausted = False
            for ti, t in enumerate(active):
                dq = q[t]
                quantum = weight_of(t) if weight_of is not None else 1
                k = min(quantum, budget - len(out), len(dq))
                for _ in range(k):
                    out.append(dq.popleft())
                if dq:
                    alive.append(t)
                if len(out) >= budget:
                    self._next_tenant = (active[ti + 1]
                                         if ti + 1 < len(active)
                                         else active[0])
                    exhausted = True
                    break
            if exhausted:
                break
            active = alive
        self._len -= len(out)
        return out

    def tenants(self) -> list[int]:
        """Backlogged tenant ids (observability/tests)."""
        return sorted(t for t, dq in self._q.items() if dq)

    def clear(self) -> None:
        self._q.clear()
        self._len = 0

    def __len__(self) -> int:
        return self._len

    def __bool__(self) -> bool:
        # Lock-free peek, same contract as Wire.__bool__ (busy-predicates).
        return self._len > 0


class TCPReceiver:
    """Host TCP receive model: detects sequence gaps and duplicate-ACKs.

    This exists to demonstrate (and regression-test) Fig 11: with a naive
    bump-in-the-wire that silently consumes offloaded bytes, the host sees a
    gap and dup-ACKs, forcing client retransmission of offloaded data.
    """

    def __init__(self):
        self.expected_seq = 0
        self.dup_acks = 0
        self.delivered: list[bytes] = []
        self.acked: int = 0

    def receive(self, pkt: Packet) -> tuple[bool, int]:
        """Returns (accepted, ack_number)."""
        if pkt.flags & FLAG_SYN:
            self.expected_seq = pkt.seq + 1
            self.acked = self.expected_seq
            return True, self.acked
        if pkt.seq != self.expected_seq:
            self.dup_acks += 1          # fast-recovery trigger
            return False, self.acked    # duplicate ACK of the old edge
        self.expected_seq += pkt.nbytes
        self.acked = self.expected_seq
        self.delivered.append(bytes(pkt.payload))
        return True, self.acked


def rss_core(ft: FiveTuple, ncores: int) -> int:
    """Symmetric RSS hash: both directions of a flow land on one core (§7)."""
    a = (ft.src_ip, ft.src_port)
    b = (ft.dst_ip, ft.dst_port)
    lo, hi = (a, b) if a <= b else (b, a)
    h = hash((lo, hi, ft.proto)) & 0x7FFFFFFF
    return h % max(1, ncores)


@dataclass
class _PEPConnection:
    """State for one split client connection (client<->DPU, DPU<->host)."""
    client_flow: FiveTuple
    client_next_seq: int = 0     # next byte expected from the client
    client_resp_seq: int = 0     # next byte we send toward the client
    host_next_seq: int = 0       # next byte on the DPU->host connection
    core: int = 0
    resp_flow: FiveTuple | None = None  # cached reversed flow (response dst)


@dataclass
class DirectorStats:
    hw_forwarded: int = 0         # packets bypassing DPU cores (NIC match miss)
    inspected: int = 0
    to_host: int = 0              # messages re-framed to the host connection
    to_dpu: int = 0               # messages handed to the offload engine
    resp_from_host: int = 0
    resp_from_dpu: int = 0
    admission_shed: int = 0       # requests dropped by token-bucket admission
    corrupt_dropped: int = 0      # checksum-failed frames discarded as losses
    seq_resyncs: int = 0          # PEP resyncs past a gap left by lost frames
    dpu_bypassed: int = 0         # messages host-routed because the DPU failed
    modeled_time_s: float = 0.0
    per_core_pkts: dict[int, int] = field(default_factory=dict)


class TrafficDirector:
    """The DDS bump-in-the-wire packet processor."""

    def __init__(self, signature: ApplicationSignature,
                 off_pred: Callable[[bytes, object], tuple[list[bytes], list[bytes]]],
                 cache_table: object | None = None,
                 ncores: int = 1,
                 host_port: int = 9999,
                 userspace_stack: bool = True):
        self.signature = signature
        self.off_pred = off_pred
        self.cache_table = cache_table
        self.ncores = ncores
        self.host_port = host_port
        self.per_pkt_cost = TLDK_PER_PKT_S if userspace_stack else LINUX_TCP_PER_PKT_S
        # Wires: ingress (from NIC), to-host, to-client, and the offload
        # queue.  ``to_host`` is flow-demuxed so its drain is tenant-fair
        # (same per-flow FIFO guarantee a TCP connection provides); the
        # offload queue is tenant-demuxed with weighted round-robin take.
        self.ingress = Wire("nic-ingress")
        self.to_host = FlowDemuxWire("dpu->host")
        self.from_host = Wire("host->dpu")
        self.to_client = FlowDemuxWire("dpu->client")
        self.offload_queue = TenantFairQueue()
        # Tenancy hooks, installed by the owning server when admission is
        # configured (QoSProfile): ``admit(tenant, n) -> granted`` and
        # ``on_shed(client_flow, msg)`` for each dropped request.  None
        # means admit-all (the untenanted default pays one attribute test).
        self.admit: Callable[[int, int], int] | None = None
        self.on_shed: Callable[[FiveTuple, bytes], None] | None = None
        # Ring-epoch fence, installed by the owning server when it joins a
        # replicated cluster: ``epoch_of() -> int`` is the current ring
        # epoch; a tagged packet with an older epoch is handed WHOLE to
        # ``on_stale_epoch(client_flow, payload, current)`` (the server
        # marks each request terminally redirected) and never served.  The
        # director stays policy-free: it only compares integers.
        self.epoch_of: Callable[[], int] | None = None
        self.on_stale_epoch: Callable[[FiveTuple, object, int], None] | None = None
        # Wire-checksum stamping for response frames (armed by the owning
        # server when ``ServerConfig.wire_checksums`` is set).  Ingress
        # verification needs no flag: a stamped frame (``csum != -1``) is
        # always verified, an unstamped one never is.
        self.stamp_checksums = False
        # DPU-failure bypass: when the offload engine dies
        # (``OffloadEngine.fail()``), every message the predicate would
        # have offloaded is re-routed to the host path instead, counted in
        # ``stats.dpu_bypassed``.  PEP, admission and the epoch fence stay
        # in force — only the DPU leg is gone.
        self.dpu_bypass = False
        self._conns: dict[FiveTuple, _PEPConnection] = {}
        self._host_flow_of: dict[FiveTuple, FiveTuple] = {}
        self._client_flow_of: dict[FiveTuple, FiveTuple] = {}  # reverse map
        self.stats = DirectorStats()
        self._lock = threading.Lock()

    # -- connection management ------------------------------------------------------
    def _conn(self, ft: FiveTuple) -> _PEPConnection:
        c = self._conns.get(ft)
        if c is None:
            c = _PEPConnection(ft, core=rss_core(ft, self.ncores),
                               resp_flow=ft.reversed())
            self._conns[ft] = c
            # Second connection of the split: DPU -> host, own seq space.
            # The client's tenant rides onto it, so host-path scheduling
            # and per-tenant stats stay attributable after the split.
            host_flow = FiveTuple("dpu-proxy", 40000 + len(self._conns),
                                  "host", self.host_port, ft.proto,
                                  tenant=ft.tenant)
            self._host_flow_of[ft] = host_flow
            self._client_flow_of[host_flow] = ft
        return c

    def busy(self) -> bool:
        """True while the director holds undelivered DPU-side work.

        This is one wakeup source of the cluster's work-signaled scheduler
        (see ``DDSCluster``): a server whose director has queued ingress
        packets, undrained offload requests, or host-bound packets must stay
        runnable.  All three probes are lock-free emptiness peeks — the
        predicate is evaluated on every idle re-arm check.  ``to_client`` is
        deliberately NOT included: undrained responses are the *client's*
        work, and pumping the server cannot make progress on them.
        """
        return bool(self.ingress) or bool(self.offload_queue) or bool(self.to_host)

    # -- ingress processing ---------------------------------------------------------
    def step(self) -> bool:
        """Process ONE ingress packet (kept for single-step tests)."""
        return self.step_n(1) > 0

    def step_n(self, budget: int = 64) -> int:
        """Process an ingress burst under one lock round (§6.1 batching).

        Per-packet accounting (inspected/hw-forwarded counts, modeled Arm
        time) is accumulated locally and folded into ``stats`` once per
        burst, so the bookkeeping cost is amortized across the batch.
        Returns the number of packets processed.
        """
        pkts = self.ingress.pop_many(budget)
        if not pkts:
            return 0
        st = self.stats
        off_q = self.offload_queue
        admit = self.admit
        inspected = hw_forwarded = to_dpu = adm_shed = 0
        modeled = 0.0
        for pkt in pkts:
            # Stage 0: wire-checksum verify.  A stamped frame that fails is
            # DISCARDED before any state is touched — corrupt frames behave
            # exactly like lost frames (the seq gap below resyncs past it
            # and the client's timeout layer resends the request).
            if pkt.csum != -1 and checksum64(pkt.payload) != pkt.csum:
                st.corrupt_dropped += 1
                continue
            # Stage 1: application signature, evaluated in NIC hardware (§5.3).
            if not self.signature.matches(pkt.flow):
                hw_forwarded += 1
                self.to_host.push(pkt)  # line-rate forward; no Arm latency
                continue
            conn = self._conn(pkt.flow)
            inspected += 1
            st.per_core_pkts[conn.core] = (
                st.per_core_pkts.get(conn.core, 0) + 1)
            modeled += self.per_pkt_cost
            if pkt.flags & FLAG_SYN:
                conn.client_next_seq = pkt.seq + 1
                continue
            if pkt.seq != conn.client_next_seq:
                if pkt.seq < conn.client_next_seq:
                    continue  # dup / stale retransmit: PEP suppresses it
                # Sequence GAP: frames were lost (or corrupt-discarded)
                # below the PEP.  The PEP models TCP's receive edge — the
                # lost request bytes are unrecoverable at this layer, so
                # resync to the new edge and let the client's timeout
                # resend the affected requests (under fresh seq numbers).
                st.seq_resyncs += 1
                conn.client_next_seq = pkt.seq
            conn.client_next_seq += pkt.nbytes
            if pkt.epoch >= 0 and self.epoch_of is not None:
                cur = self.epoch_of()
                if pkt.epoch < cur:
                    # Stale ring epoch: the sender routed before a failover
                    # repaired the ring.  Refuse the whole batch — serving
                    # it could apply writes to a demoted replica set.
                    if self.on_stale_epoch is not None:
                        self.on_stale_epoch(pkt.flow, pkt.payload, cur)
                    continue
            # Stage 2: the offload predicate inspects the payload (zero-copy:
            # the predicate sees the packet buffer itself, never a copy).
            host_msgs, dpu_msgs = self.off_pred(pkt.payload, self.cache_table)
            if dpu_msgs and self.dpu_bypass:
                # DPU path is down: everything the predicate offloaded is
                # served by the host instead (graceful degradation).
                st.dpu_bypassed += len(dpu_msgs)
                host_msgs = (host_msgs + dpu_msgs) if host_msgs else dpu_msgs
                dpu_msgs = []
            if admit is not None and (host_msgs or dpu_msgs):
                # Token-bucket admission, applied at the demux — BEFORE a
                # request can occupy a context-ring slot or device queue
                # entry.  Offloaded (latency-critical) requests draw tokens
                # first; everything over the grant is shed terminally via
                # ``on_shed`` (the server marks it E_SHED with a
                # retry-after hint for the client).
                n_off = len(host_msgs) + len(dpu_msgs)
                granted = admit(pkt.flow.tenant, n_off)
                if granted < n_off:
                    keep_dpu = min(granted, len(dpu_msgs))
                    keep_host = granted - keep_dpu
                    on_shed = self.on_shed
                    if on_shed is not None:
                        for m in dpu_msgs[keep_dpu:]:
                            on_shed(pkt.flow, m)
                        for m in host_msgs[keep_host:]:
                            on_shed(pkt.flow, m)
                    adm_shed += n_off - granted
                    dpu_msgs = dpu_msgs[:keep_dpu]
                    host_msgs = host_msgs[:keep_host]
            if host_msgs:
                self._send_to_host_many(conn, pkt.flow, host_msgs)
            if dpu_msgs:
                to_dpu += len(dpu_msgs)
                off_q.extend_flow(pkt.flow, dpu_msgs)
            elif host_msgs:
                # matched the signature but fully host-bound: paid the round trip
                modeled += PREDICATE_FAIL_RTT_S - self.per_pkt_cost
        st.hw_forwarded += hw_forwarded
        st.inspected += inspected
        st.to_dpu += to_dpu
        st.admission_shed += adm_shed
        st.modeled_time_s += modeled
        return len(pkts)

    def _send_to_host(self, conn: _PEPConnection, client_flow: FiveTuple,
                      msg: bytes) -> None:
        """Re-frame a host-bound message onto the split DPU->host connection.

        The host connection's sequence numbers stay CONTIGUOUS even though
        the DPU consumed some client bytes — transport transparency.
        """
        host_flow = self._host_flow_of[client_flow]
        self.to_host.push(Packet(host_flow, conn.host_next_seq, msg))
        conn.host_next_seq += len(msg)
        self.stats.to_host += 1
        self.stats.modeled_time_s += ARM_FORWARD_LATENCY_S

    def _send_to_host_many(self, conn: _PEPConnection, client_flow: FiveTuple,
                           msgs: list) -> None:
        """Burst form of ``_send_to_host``: each message still becomes its
        own packet on the split connection (same protocol, same per-message
        modeled Arm forwarding cost), but the wire is taken once."""
        host_flow = self._host_flow_of[client_flow]
        seq = conn.host_next_seq
        pkts = []
        for m in msgs:
            pkts.append(Packet(host_flow, seq, m))
            seq += len(m)
        conn.host_next_seq = seq
        self.to_host.push_many(host_flow, pkts)
        self.stats.to_host += len(msgs)
        self.stats.modeled_time_s += ARM_FORWARD_LATENCY_S * len(msgs)

    # -- response paths -----------------------------------------------------------------
    def host_response(self, host_flow: FiveTuple, msg: bytes) -> None:
        """A response from the host app on the second connection.

        The split connection is resolved with an O(1) reverse lookup; a flow
        with no split (hardware-forwarded) responds on the client flow.
        """
        client_flow = self._client_flow_of.get(host_flow, host_flow)
        self._respond_to_client(client_flow, msg)
        self.stats.resp_from_host += 1

    def host_response_many(self, host_flow: FiveTuple, msgs: list) -> None:
        """A burst of host responses for ONE split connection.

        Sequence numbers are stamped in one pass and the packets enqueued
        on the client's demuxed queue under a single lock round — the
        response-side mirror of ``dpu_response``'s burst handling."""
        client_flow = self._client_flow_of.get(host_flow, host_flow)
        conn = self._conn(client_flow)
        resp_flow = conn.resp_flow
        seq = conn.client_resp_seq
        stamp = self.stamp_checksums
        pkts = []
        for msg in msgs:
            pkts.append(Packet(resp_flow, seq, msg,
                               csum=checksum64(msg) if stamp else -1))
            seq += len(msg)
        conn.client_resp_seq = seq
        self.to_client.push_many(resp_flow, pkts)
        self.stats.resp_from_host += len(msgs)

    def dpu_response(self, client_flow: FiveTuple, packets: list[Packet],
                     responses: int = 1) -> None:
        """Responses produced by the offload engine (already segmented).

        A burst may carry the packets of several back-to-back responses for
        one flow (``responses`` keeps the per-response stat exact): the
        whole burst is stamped with contiguous sequence numbers and enqueued
        on the client's demuxed queue in one lock round.
        """
        conn = self._conn(client_flow)
        resp_flow = conn.resp_flow
        seq = conn.client_resp_seq
        stamp = self.stamp_checksums
        for p in packets:
            p.flow = resp_flow
            p.seq = seq
            seq += len(p.payload)
            if stamp:
                p.csum = checksum64(p.payload)
        conn.client_resp_seq = seq
        self.to_client.push_many(resp_flow, packets)
        self.stats.resp_from_dpu += responses

    def _respond_to_client(self, client_flow: FiveTuple, msg: bytes) -> None:
        conn = self._conn(client_flow)
        self.to_client.push(Packet(
            conn.resp_flow, conn.client_resp_seq, msg,
            csum=checksum64(msg) if self.stamp_checksums else -1))
        conn.client_resp_seq += len(msg)

    def drain_host_wire(self, deliver: Callable[[FiveTuple, bytes], None],
                        max_pkts: int | None = None) -> int:
        """Pump packets that crossed to the host into the host application.

        Payloads are handed over as-is (possibly ``memoryview`` slices of
        the client's packet buffer): whether to materialize is the host
        application's call — the write path rides views all the way into
        the request ring (zero-copy end to end).

        ``max_pkts`` bounds the drain slice: one hot flow's backlog cannot
        monopolize a whole pump step — the remainder stays queued (and
        ``busy()`` keeps the server runnable), so other flows' already-
        completed work gets its response-publish turn this step."""
        n = 0
        while True:
            budget = 64 if max_pkts is None else min(64, max_pkts - n)
            if budget <= 0:
                return n
            pkts = self.to_host.pop_many(budget)
            if not pkts:
                return n
            for pkt in pkts:
                deliver(pkt.flow, pkt.payload)
            n += len(pkts)


class NaiveSplitter:
    """A broken bump-in-the-wire WITHOUT the PEP, for the Fig 11 test.

    Offloaded bytes are silently consumed; host-bound packets keep their
    ORIGINAL client sequence numbers, so the host receiver sees gaps.
    """

    def __init__(self, off_pred):
        self.off_pred = off_pred
        self.offloaded: list[bytes] = []

    def process(self, pkt: Packet, host: TCPReceiver) -> tuple[bool, int]:
        host_msgs, dpu_msgs = self.off_pred(bytes(pkt.payload), None)
        if dpu_msgs and not host_msgs:
            self.offloaded.append(bytes(pkt.payload))
            return True, host.acked  # consumed on the DPU; host never sees it
        return host.receive(pkt)     # gap => dup-ACK
