"""DDS DPU file service (§4.3): segment file system + zero-copy execution.

Two layers live here:

``SegmentFS``
    The paper's minimal DPU file system: SSD space is divided into
    fixed-length segments (aligned to the disk block size); a bitmap tracks
    availability; files are allocated space by segments and grouped in flat
    directories; segment 0 persistently stores directory/file metadata and
    the *file mapping* (the vector of segments allocated to each file).
    ``translate`` converts a (file, offset, size) range into physical disk
    runs via the file mapping.

``FileServiceRunner``
    The DPU-side execution engine for host-issued file operations:

    * A dedicated DMA thread consumes request batches from each notification
      group's request ring (Fig 8b) into a DPU-side *request buffer* whose
      size is >= the host ring, so outstanding requests never overlap and the
      storage driver can consume request payloads IN PLACE — no request copy
      (§4.3 "Eliminating data copies").

    * Responses are pre-allocated in a DPU-side *response buffer* governed by
      three tails (§4.3 "Ordered execution"):
        TailA(llocated)  — end of pre-allocated response space,
        TailB(uffered)   — end of the completed-response prefix,
        TailC(ompleted)  — end of responses delivered to the host ring.
      The device writes read data straight into the pre-allocated response
      space (status starts E_PENDING) — no response copy.  TailB only
      advances over a contiguous completed prefix, preserving request order;
      a DMA write delivers [TailC, TailB) once it reaches the delivery batch
      size.

    The same ``submit`` entry point is used by the offload engine (§6.2) for
    DPU-local reads, with the destination pointing into ITS pre-allocated
    packet memory instead.

The runner is cooperatively scheduled (``step()``) so tests and benchmarks
are deterministic; ``start()`` wraps it in a thread for the live system.
"""

from __future__ import annotations

import json
import threading
from dataclasses import dataclass, field
from typing import Callable

import numpy as np

from repro.core import wire
from repro.core.ring import DMAEngine, ProgressiveRing, Region, ResponseRing, unframe_batch, frame
from repro.storage.blockdev import BlockDevice

META_SEGMENT = 0


class FSError(Exception):
    def __init__(self, errno: int, msg: str = ""):
        super().__init__(msg or f"fs error {errno}")
        self.errno = errno


@dataclass
class FileMeta:
    file_id: int
    name: str
    dir_id: int
    size: int = 0
    segments: list[int] = field(default_factory=list)  # the file mapping


@dataclass
class DirMeta:
    dir_id: int
    name: str
    files: list[int] = field(default_factory=list)


class SegmentFS:
    """Segment-granular file system over a :class:`BlockDevice`."""

    def __init__(self, device: BlockDevice, segment_size: int = 1 << 20):
        assert segment_size % device.block_size == 0
        self.device = device
        self.segment_size = segment_size
        self.num_segments = device.capacity // segment_size
        if self.num_segments < 2:
            raise ValueError("device too small for SegmentFS")
        self.bitmap = np.zeros(self.num_segments, dtype=bool)
        self.bitmap[META_SEGMENT] = True  # reserved for metadata
        self.files: dict[int, FileMeta] = {}
        self.dirs: dict[int, DirMeta] = {0: DirMeta(0, "/")}
        self._next_file_id = 1
        self._next_dir_id = 1
        self._lock = threading.Lock()

    # -- metadata persistence (segment 0) ----------------------------------------
    def sync_metadata(self) -> None:
        doc = {
            "files": {str(f.file_id): [f.name, f.dir_id, f.size, f.segments]
                      for f in self.files.values()},
            "dirs": {str(d.dir_id): [d.name, d.files] for d in self.dirs.values()},
            "next_file_id": self._next_file_id,
            "next_dir_id": self._next_dir_id,
            "bitmap": self.bitmap.tobytes().hex(),
        }
        blob = json.dumps(doc).encode()
        if len(blob) + 8 > self.segment_size:
            raise FSError(wire.E_NOSPC, "metadata exceeds metadata segment")
        hdr = len(blob).to_bytes(8, "little")
        self.device.raw_write(META_SEGMENT * self.segment_size, hdr + blob)

    @classmethod
    def mount(cls, device: BlockDevice, segment_size: int = 1 << 20) -> "SegmentFS":
        fs = cls(device, segment_size)
        raw = device.raw_read(META_SEGMENT * segment_size, 8)
        n = int.from_bytes(raw, "little")
        if n == 0:
            return fs  # fresh device
        blob = device.raw_read(META_SEGMENT * segment_size + 8, n)
        doc = json.loads(blob.decode())
        fs.bitmap = np.frombuffer(bytes.fromhex(doc["bitmap"]), dtype=bool).copy()
        fs.files = {int(k): FileMeta(int(k), v[0], v[1], v[2], list(v[3]))
                    for k, v in doc["files"].items()}
        fs.dirs = {int(k): DirMeta(int(k), v[0], list(v[1]))
                   for k, v in doc["dirs"].items()}
        fs._next_file_id = doc["next_file_id"]
        fs._next_dir_id = doc["next_dir_id"]
        return fs

    # -- control plane --------------------------------------------------------------
    def create_dir(self, name: str) -> int:
        with self._lock:
            did = self._next_dir_id
            self._next_dir_id += 1
            self.dirs[did] = DirMeta(did, name)
            return did

    def create_file(self, name: str, dir_id: int = 0) -> int:
        with self._lock:
            if dir_id not in self.dirs:
                raise FSError(wire.E_NOENT, f"no dir {dir_id}")
            fid = self._next_file_id
            self._next_file_id += 1
            self.files[fid] = FileMeta(fid, name, dir_id)
            self.dirs[dir_id].files.append(fid)
            return fid

    def delete_file(self, file_id: int) -> None:
        with self._lock:
            f = self.files.pop(file_id, None)
            if f is None:
                raise FSError(wire.E_NOENT)
            for s in f.segments:
                self.bitmap[s] = False
            self.dirs[f.dir_id].files.remove(file_id)

    def list_dir(self, dir_id: int) -> list[str]:
        d = self.dirs.get(dir_id)
        if d is None:
            raise FSError(wire.E_NOENT)
        return [self.files[f].name for f in d.files if f in self.files]

    def file_size(self, file_id: int) -> int:
        f = self.files.get(file_id)
        if f is None:
            raise FSError(wire.E_NOENT)
        return f.size

    # -- space management --------------------------------------------------------
    def _alloc_segment(self) -> int:
        free = np.flatnonzero(~self.bitmap)
        if len(free) == 0:
            raise FSError(wire.E_NOSPC, "device full")
        s = int(free[0])
        self.bitmap[s] = True
        return s

    def ensure_capacity(self, file_id: int, new_size: int) -> None:
        with self._lock:
            f = self.files.get(file_id)
            if f is None:
                raise FSError(wire.E_NOENT)
            need = -(-new_size // self.segment_size)  # ceil
            while len(f.segments) < need:
                f.segments.append(self._alloc_segment())
            if new_size > f.size:
                f.size = new_size

    def truncate(self, file_id: int, new_size: int) -> None:
        with self._lock:
            f = self.files.get(file_id)
            if f is None:
                raise FSError(wire.E_NOENT)
            keep = -(-new_size // self.segment_size)
            for s in f.segments[keep:]:
                self.bitmap[s] = False
            f.segments = f.segments[:keep]
            f.size = new_size

    # -- address translation (the file mapping) ------------------------------------
    def translate(self, file_id: int, offset: int, size: int) -> list[tuple[int, int]]:
        """(file, offset, size) -> [(device_byte_addr, nbytes), ...] runs."""
        f = self.files.get(file_id)
        if f is None:
            raise FSError(wire.E_NOENT)
        if offset + size > len(f.segments) * self.segment_size:
            raise FSError(wire.E_INVAL, "range beyond allocation")
        runs: list[tuple[int, int]] = []
        seg_sz = self.segment_size
        while size > 0:
            seg_idx = offset // seg_sz
            seg_off = offset % seg_sz
            n = min(size, seg_sz - seg_off)
            phys = f.segments[seg_idx] * seg_sz + seg_off
            if runs and runs[-1][0] + runs[-1][1] == phys:
                runs[-1] = (runs[-1][0], runs[-1][1] + n)  # coalesce
            else:
                runs.append((phys, n))
            offset += n
            size -= n
        return runs

    # -- data plane (async, zero-copy destinations) ---------------------------------
    def submit_read(self, file_id: int, offset: int, size: int,
                    dest: memoryview, on_complete: Callable[[int], None]) -> None:
        f = self.files.get(file_id)
        if f is None or offset + size > f.size:
            on_complete(wire.E_INVAL if f else wire.E_NOENT)
            return
        seg_sz = self.segment_size
        if size > 0 and offset // seg_sz == (offset + size - 1) // seg_sz:
            # Fast path: the range lives in ONE segment — a single device op,
            # no run list, no multi-completion state, no adapter closure
            # (device status codes coincide with wire error codes: 0 == E_OK,
            # nonzero values are failures either way).
            phys = f.segments[offset // seg_sz] * seg_sz + offset % seg_sz
            self.device.submit_read(phys, size, dest, on_complete)
            return
        runs = self.translate(file_id, offset, size)
        state = {"left": len(runs), "err": wire.E_OK}

        def done_one(status: int) -> None:
            if status != 0:
                state["err"] = wire.E_IO
            state["left"] -= 1
            if state["left"] == 0:
                on_complete(state["err"])

        pos = 0
        for phys, n in runs:
            self.device.submit_read(phys, n, dest[pos : pos + n], done_one)
            pos += n

    def submit_write(self, file_id: int, offset: int, data,
                     on_complete: Callable[[int], None]) -> None:
        try:
            self.ensure_capacity(file_id, offset + len(data))
            runs = self.translate(file_id, offset, len(data))
        except FSError as e:
            on_complete(e.errno)
            return
        state = {"left": len(runs), "err": wire.E_OK}

        def done_one(status: int) -> None:
            if status != 0:
                state["err"] = wire.E_IO
            state["left"] -= 1
            if state["left"] == 0:
                on_complete(state["err"])

        pos = 0
        mv = memoryview(data)
        for phys, n in runs:
            self.device.submit_write(phys, mv[pos : pos + n], done_one)
            pos += n


# ---------------------------------------------------------------------------
# The DPU-side runner for host-issued file operations.
# ---------------------------------------------------------------------------


@dataclass
class _PendingResp:
    """A pre-allocated response slot in the DPU response buffer."""
    group_id: int
    off: int           # start offset in the group's response buffer (virtual)
    size: int          # full response size (header + payload)
    request_id: int
    pad: bool = False  # wrap-padding slot: space only, never delivered


@dataclass
class _GroupState:
    group_id: int
    req_ring: ProgressiveRing
    resp_ring: ResponseRing
    # DPU request buffer: >= host ring size => outstanding requests never overlap.
    req_buf: Region = None  # type: ignore[assignment]
    req_buf_tail: int = 0
    # DPU response buffer with the three tails of §4.3.
    resp_buf: Region = None  # type: ignore[assignment]
    tail_a: int = 0  # allocated
    tail_b: int = 0  # buffered (completed prefix)
    tail_c: int = 0  # delivered to host
    pending: list[_PendingResp] = field(default_factory=list)
    ready: list[_PendingResp] = field(default_factory=list)  # completed, undelivered
    interrupt: Callable[[], None] | None = None  # "DPU driver interrupt"


@dataclass
class FileServiceStats:
    requests: int = 0
    reads: int = 0
    writes: int = 0
    control_ops: int = 0
    read_bytes: int = 0
    write_bytes: int = 0
    response_batches: int = 0
    responses_delivered: int = 0
    request_copies: int = 0   # nonzero only with zero_copy=False
    response_copies: int = 0
    shed_requests: int = 0    # dropped under un-drained-ring overload


class FileServiceRunner:
    """Executes host file requests on the DPU with zero copies (§4.3)."""

    def __init__(self, fs: SegmentFS, dma: DMAEngine | None = None,
                 resp_buf_size: int = 1 << 22,
                 delivery_batch: int = 1,
                 zero_copy: bool = True,
                 cache_hook: Callable[[wire.Request], None] | None = None,
                 invalidate_hook: Callable[[wire.Request], None] | None = None):
        self.fs = fs
        self.dma = dma or DMAEngine()
        self.resp_buf_size = resp_buf_size
        self.delivery_batch = delivery_batch
        self.zero_copy = zero_copy
        self.cache_hook = cache_hook
        self.invalidate_hook = invalidate_hook
        self.groups: dict[int, _GroupState] = {}
        self.stats = FileServiceStats()
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None
        self._lock = threading.Lock()

    # -- registration (host lib calls this when a notification group is made) -----
    def register_group(self, group_id: int, req_ring: ProgressiveRing,
                       resp_ring: ResponseRing,
                       interrupt: Callable[[], None] | None = None) -> None:
        g = _GroupState(group_id, req_ring, resp_ring)
        # Request buffer sized >= the host ring: no outstanding request overlaps.
        g.req_buf = Region(f"dpu:req{group_id}", max(req_ring.capacity, 1 << 12))
        g.resp_buf = Region(f"dpu:resp{group_id}", self.resp_buf_size)
        g.interrupt = interrupt
        with self._lock:
            self.groups[group_id] = g

    # -- cooperative scheduling -----------------------------------------------------
    def step(self) -> int:
        """One iteration: fetch -> submit -> complete -> deliver. Returns work."""
        work = 0
        with self._lock:
            groups = list(self.groups.values())
        for g in groups:
            work += self._fetch_and_submit(g)
        self.fs.device.poll()
        for g in groups:
            work += self._deliver(g)
        return work

    def run_until_idle(self, max_iters: int = 100_000) -> None:
        idle = 0
        for _ in range(max_iters):
            if self.step() == 0:
                self.fs.device.drain()
                if self.step() == 0:
                    idle += 1
                    if idle >= 2 and not self._any_pending():
                        return
            else:
                idle = 0
        raise TimeoutError("file service did not go idle")

    def _any_pending(self) -> bool:
        return any(g.pending or g.ready for g in self.groups.values())

    def busy(self) -> bool:
        """True while responses are pending or awaiting delivery."""
        return self._any_pending()

    def start(self) -> None:
        self._stop.clear()
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name="dds-file-service")
        self._thread.start()

    def stop(self) -> None:
        self._stop.set()
        if self._thread:
            self._thread.join(timeout=5)
            self._thread = None

    def _loop(self) -> None:
        while not self._stop.is_set():
            if self.step() == 0:
                self._stop.wait(50e-6)

    # -- request path -----------------------------------------------------------------
    def _fetch_and_submit(self, g: _GroupState) -> int:
        """Consume EVERY available batch this step (one loop, reused until
        the ring is drained), splitting each batch zero-copy."""
        work = 0
        while True:
            batch = g.req_ring.consume(self.dma)
            if batch is None:
                return work
            # Land the batch in the DPU request buffer (the DMA destination).
            # Size >= host ring guarantees in-flight requests never overlap.
            cap = len(g.req_buf.buf)
            pos = g.req_buf_tail % cap
            first = min(len(batch), cap - pos)
            g.req_buf.write(pos, batch[:first])
            if first < len(batch):
                g.req_buf.write(0, batch[first:])
            g.req_buf_tail += len(batch)
            for raw in unframe_batch(batch):
                self._submit_one(g, wire.decode_request(raw))
            work += 1

    def _submit_one(self, g: _GroupState, req: wire.Request) -> None:
        self.stats.requests += 1
        resp_size = wire.response_size_for(req)
        cap = len(g.resp_buf.buf)
        # Keep each response contiguous: pad TailA to the wrap boundary when
        # the slot would cross it (pad slots occupy space, deliver nothing).
        pos = g.tail_a % cap
        if pos + resp_size > cap:
            pad = cap - pos
            if g.tail_a + pad - g.tail_c > cap:
                self._complete_inline(g, req, wire.E_NOSPC, b"")
                return
            g.pending.append(_PendingResp(g.group_id, g.tail_a, pad,
                                          0, pad=True))
            g.tail_a += pad
        # Backpressure: the response buffer is a ring in virtual offsets.
        if g.tail_a + resp_size - g.tail_c > cap:
            self._complete_inline(g, req, wire.E_NOSPC, b"")
            return
        off = g.tail_a
        g.tail_a += resp_size  # pre-allocate response space (advance TailA)
        slot = _PendingResp(g.group_id, off, resp_size, req.request_id)
        g.pending.append(slot)
        self._write_resp_header(g, off, req.request_id, wire.E_PENDING,
                                resp_size - wire.RESP_HDR.size)
        if req.op == wire.OP_READ:
            self.stats.reads += 1
            self.stats.read_bytes += req.nbytes
            dest = self._resp_payload_view(g, off, req.nbytes)
            if not self.zero_copy:
                # Straw-man: read into a scratch buffer, copy to response later.
                scratch = bytearray(req.nbytes)

                def on_done(err: int, g=g, off=off, req=req, scratch=scratch):
                    if err == wire.E_OK:
                        view = self._resp_payload_view(g, off, req.nbytes)
                        view[:] = scratch  # the extra copy zero-copy removes
                        self.stats.response_copies += 1
                    self._finish(g, off, req, err)

                self.fs.submit_read(req.file_id, req.offset, req.nbytes,
                                    memoryview(scratch), on_done)
            else:
                self.fs.submit_read(
                    req.file_id, req.offset, req.nbytes, dest,
                    lambda err, g=g, off=off, req=req: self._finish(g, off, req, err))
            if self.invalidate_hook:
                self.invalidate_hook(req)  # invalidate-on-read (§6.1)
        elif req.op == wire.OP_WRITE:
            self.stats.writes += 1
            self.stats.write_bytes += len(req.payload)
            data = req.payload
            if not self.zero_copy:
                data = bytes(data)  # defensive copy the zero-copy path avoids
                self.stats.request_copies += 1
            self.fs.submit_write(
                req.file_id, req.offset, data,
                lambda err, g=g, off=off, req=req: self._finish(g, off, req, err))
            if self.cache_hook:
                self.cache_hook(req)  # cache-on-write (§6.1)
        else:
            self._control_op(g, off, req)

    def _control_op(self, g: _GroupState, off: int, req: wire.Request) -> None:
        self.stats.control_ops += 1
        err, payload = wire.E_OK, b""
        try:
            if req.op == wire.OP_CREATE_FILE:
                fid = self.fs.create_file(req.payload.decode(), req.file_id)
                payload = fid.to_bytes(4, "little")
            elif req.op == wire.OP_CREATE_DIR:
                did = self.fs.create_dir(req.payload.decode())
                payload = did.to_bytes(4, "little")
            elif req.op == wire.OP_DELETE_FILE:
                self.fs.delete_file(req.file_id)
            elif req.op == wire.OP_TRUNCATE:
                self.fs.truncate(req.file_id, req.offset)
            elif req.op == wire.OP_FSYNC:
                self.fs.sync_metadata()
            elif req.op == wire.OP_LIST_DIR:
                names = json.dumps(self.fs.list_dir(req.file_id)).encode()[:4096]
                payload = names.ljust(4096, b"\x00")
            else:
                err = wire.E_INVAL
        except FSError as e:
            err = e.errno
        expect = wire.response_size_for(req) - wire.RESP_HDR.size
        payload = payload.ljust(expect, b"\x00")
        view = self._resp_payload_view(g, off, expect)
        view[:] = payload
        self._finish(g, off, req, err)

    def _complete_inline(self, g: _GroupState, req: wire.Request, err: int,
                         payload: bytes, spin: int = 100_000) -> None:
        """Emergency completion bypassing pre-allocation (backpressure path).

        Bounded: if the host never drains its response ring, the request is
        SHED (load shedding, counted) rather than deadlocking the service
        thread — the host library surfaces the gap as a timeout."""
        resp = wire.Response(req.request_id, err, len(payload), payload).encode()
        for _ in range(spin):
            if g.resp_ring.produce(self.dma, frame(resp)):
                if g.interrupt:
                    g.interrupt()
                return
        self.stats.shed_requests += 1

    # -- response-buffer helpers -------------------------------------------------------
    def _resp_view(self, g: _GroupState, voff: int, n: int) -> memoryview:
        cap = len(g.resp_buf.buf)
        pos = voff % cap
        assert pos + n <= cap, "response crosses buffer wrap (sized to avoid)"
        return memoryview(g.resp_buf.buf)[pos : pos + n].cast("B")

    def _resp_payload_view(self, g: _GroupState, off: int, n: int) -> memoryview:
        return self._resp_view(g, off + wire.RESP_HDR.size, n)

    def _write_resp_header(self, g: _GroupState, off: int, rid: int, err: int,
                           nbytes: int) -> None:
        hdr = wire.RESP_HDR.pack(rid, err, nbytes)
        self._resp_view(g, off, wire.RESP_HDR.size)[:] = hdr

    def _read_resp_error(self, g: _GroupState, off: int) -> int:
        raw = bytes(self._resp_view(g, off, wire.RESP_HDR.size))
        return wire.RESP_HDR.unpack(raw)[1]

    def _finish(self, g: _GroupState, off: int, req: wire.Request, err: int) -> None:
        """I/O completion: flip the pre-allocated response's status in place."""
        n = wire.response_size_for(req) - wire.RESP_HDR.size
        self._write_resp_header(g, off, req.request_id, err, n)

    # -- delivery (TailB/TailC discipline) ------------------------------------------
    def _deliver(self, g: _GroupState) -> int:
        # Advance TailB over the contiguous completed prefix (ordered
        # execution); completed slots queue for delivery in order.
        while g.pending:
            slot = g.pending[0]
            if (not slot.pad
                    and self._read_resp_error(g, slot.off) == wire.E_PENDING):
                break
            g.pending.pop(0)
            g.tail_b = slot.off + slot.size
            if not slot.pad:
                g.ready.append(slot)
        if g.tail_b - g.tail_c < self.delivery_batch or not g.ready:
            return 0
        # One DMA write delivers as many ready responses as the host ring
        # accepts; TailC advances to the end of the delivered prefix.
        parts: list[bytes] = []
        space = g.resp_ring.free_space(self.dma)
        used = 0
        take = 0
        for slot in g.ready:
            body = bytes(self._resp_view(g, slot.off, slot.size))
            fr = frame(body)
            if used + len(fr) > space:
                break
            parts.append(fr)
            used += len(fr)
            take += 1
        if not parts:
            return 0  # host ring full; retry next step
        if not g.resp_ring.produce(self.dma, b"".join(parts)):
            return 0
        last = g.ready[take - 1]
        g.tail_c = last.off + last.size
        del g.ready[:take]
        self.stats.response_batches += 1
        self.stats.responses_delivered += take
        if g.interrupt:
            g.interrupt()
        return 1


def _split_responses(chunk: bytes) -> list[bytes]:
    """Split a contiguous [TailC, TailB) range into individual responses."""
    out = []
    off = 0
    n = len(chunk)
    while off < n:
        rid, err, nbytes = wire.RESP_HDR.unpack_from(chunk, off)
        total = wire.RESP_HDR.size + nbytes
        out.append(chunk[off : off + total])
        off += total
    return out
