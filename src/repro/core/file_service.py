"""DDS DPU file service (§4.3): segment file system + zero-copy execution.

Two layers live here:

``SegmentFS``
    The paper's minimal DPU file system: SSD space is divided into
    fixed-length segments (aligned to the disk block size); a bitmap tracks
    availability; files are allocated space by segments and grouped in flat
    directories; segment 0 persistently stores directory/file metadata and
    the *file mapping* (the vector of segments allocated to each file).
    ``translate`` converts a (file, offset, size) range into physical disk
    runs via the file mapping.

``FileServiceRunner``
    The DPU-side execution engine for host-issued file operations:

    * A dedicated DMA thread consumes request batches from each notification
      group's request ring (Fig 8b) into a DPU-side *request buffer* whose
      size is >= the host ring, so outstanding requests never overlap and the
      storage driver can consume request payloads IN PLACE — no request copy
      (§4.3 "Eliminating data copies").

    * Responses are pre-allocated in a DPU-side *response buffer* governed by
      three tails (§4.3 "Ordered execution"):
        TailA(llocated)  — end of pre-allocated response space,
        TailB(uffered)   — end of the completed-response prefix,
        TailC(ompleted)  — end of responses delivered to the host ring.
      The device writes read data straight into the pre-allocated response
      space (status starts E_PENDING) — no response copy.  TailB only
      advances over a contiguous completed prefix, preserving request order;
      a DMA write delivers [TailC, TailB) once it reaches the delivery batch
      size.

    The same ``submit`` entry point is used by the offload engine (§6.2) for
    DPU-local reads, with the destination pointing into ITS pre-allocated
    packet memory instead.

    * Execution is BATCHED end to end (the PR-3 host-path overhaul):
      ``consume_batch`` drains every available ring batch under one IncHead
      doorbell; a burst's requests are decoded inline (no per-request
      object); adjacent same-file writes coalesce into scatter-gather
      ``submit_writev`` runs; completions arrive through a flat
      cookie -> slots in-flight table reaped in bulk from the device's
      completion queue (no per-op closure); and delivery publishes a run of
      responses with one gathered DMA write + one doorbell
      (``publish_batch``).  See README "Host path & write model".

The runner is cooperatively scheduled (``step()``) so tests and benchmarks
are deterministic; ``start()`` wraps it in a thread for the live system.
"""

from __future__ import annotations

import json
import struct
import threading
from collections import deque
from dataclasses import dataclass, field
from typing import Callable

import numpy as np

from repro.core import wire
from repro.core.lifecycle import TickClock
from repro.core.ring import (FRAME_HDR, DMAEngine, ProgressiveRing, Region,
                             ResponseRing, frame, unframe_batch)
from repro.core.vector import checksum64
from repro.storage.blockdev import STATUS_PENDING, BlockDevice

META_SEGMENT = 0

# ---- redo journal (crash-consistent writes) ---------------------------------
# Record header: magic(u32) commit(u32) seq(u64) file_id(u32) offset(u64)
# nbytes(u32) new_size(u64) nsegs(u32) crc(u64), then nsegs * u32 segment
# ids (the file mapping AT SUBMIT TIME — replay needs no metadata sync),
# then the run's payload bytes, then an 8-byte zero terminator that
# clobbers any stale record header behind this one.  ``commit`` is written
# 0 with the record and flipped to 1 by a separate single-slot device
# write — the ordered metadata flip that makes the whole run atomic under
# power loss.  ``crc`` is :func:`repro.core.vector.checksum64` over the
# record body (segment ids + payload): replay refuses a committed record
# whose body no longer matches — a torn/bit-rotted journal replays as
# garbage otherwise, silently corrupting the file it meant to repair.
JOURNAL_MAGIC = 0x4A444453          # "SDDJ"
_JREC = struct.Struct("<IIQIQIQIQ")
_JCOMMIT_OFF = 4                    # byte offset of ``commit`` in the header
_JCOMMIT_ONE = (1).to_bytes(4, "little")
_JTERM = bytes(8)


class FSError(Exception):
    def __init__(self, errno: int, msg: str = ""):
        super().__init__(msg or f"fs error {errno}")
        self.errno = errno


@dataclass
class FileMeta:
    file_id: int
    name: str
    dir_id: int
    size: int = 0
    segments: list[int] = field(default_factory=list)  # the file mapping


@dataclass
class DirMeta:
    dir_id: int
    name: str
    files: list[int] = field(default_factory=list)


class SegmentFS:
    """Segment-granular file system over a :class:`BlockDevice`."""

    def __init__(self, device: BlockDevice, segment_size: int = 1 << 20,
                 journal_segments: int = 0):
        assert segment_size % device.block_size == 0
        self.device = device
        self.segment_size = segment_size
        self.num_segments = device.capacity // segment_size
        if self.num_segments < 2 + journal_segments:
            raise ValueError("device too small for SegmentFS")
        self.bitmap = np.zeros(self.num_segments, dtype=bool)
        self.bitmap[META_SEGMENT] = True  # reserved for metadata
        self.files: dict[int, FileMeta] = {}
        self.dirs: dict[int, DirMeta] = {0: DirMeta(0, "/")}
        self._next_file_id = 1
        self._next_dir_id = 1
        self._lock = threading.Lock()
        # Redo journal: ``journal_segments`` segments after META_SEGMENT
        # hold a circular log of committed write runs.  0 disables
        # journaling (writes land in place directly, the pre-PR7 behavior).
        self.journal_segments = journal_segments
        self._journal_start = (META_SEGMENT + 1) * segment_size
        self._journal_len = journal_segments * segment_size
        self._journal_head = 0        # next append offset within the region
        self._journal_tail = 0        # oldest byte still awaiting in-place
        self._journal_seq = 1
        # cookie -> (record_start, record_end): reclaimed when the run's
        # in-place writev completes (``journal_reaped``).
        self._journal_pending: dict[int, tuple[int, int]] = {}
        self.journal_replayed_records = 0
        self.journal_replayed_bytes = 0
        # Committed records whose body failed its checksum at recovery —
        # each one stopped the replay scan (everything after it is suspect).
        self.journal_crc_failures = 0
        for s in range(journal_segments):
            self.bitmap[META_SEGMENT + 1 + s] = True

    # -- metadata persistence (segment 0) ----------------------------------------
    def sync_metadata(self) -> None:
        doc = {
            "files": {str(f.file_id): [f.name, f.dir_id, f.size, f.segments]
                      for f in self.files.values()},
            "dirs": {str(d.dir_id): [d.name, d.files] for d in self.dirs.values()},
            "next_file_id": self._next_file_id,
            "next_dir_id": self._next_dir_id,
            "bitmap": self.bitmap.tobytes().hex(),
        }
        blob = json.dumps(doc).encode()
        if len(blob) + 8 > self.segment_size:
            raise FSError(wire.E_NOSPC, "metadata exceeds metadata segment")
        hdr = len(blob).to_bytes(8, "little")
        self.device.raw_write(META_SEGMENT * self.segment_size, hdr + blob)

    @classmethod
    def mount(cls, device: BlockDevice, segment_size: int = 1 << 20,
              journal_segments: int = 0) -> "SegmentFS":
        fs = cls(device, segment_size, journal_segments)
        raw = device.raw_read(META_SEGMENT * segment_size, 8)
        n = int.from_bytes(raw, "little")
        if n == 0:
            return fs  # fresh device
        blob = device.raw_read(META_SEGMENT * segment_size + 8, n)
        doc = json.loads(blob.decode())
        fs.bitmap = np.frombuffer(bytes.fromhex(doc["bitmap"]), dtype=bool).copy()
        for s in range(journal_segments):   # journal stays reserved regardless
            fs.bitmap[META_SEGMENT + 1 + s] = True
        fs.files = {int(k): FileMeta(int(k), v[0], v[1], v[2], list(v[3]))
                    for k, v in doc["files"].items()}
        fs.dirs = {int(k): DirMeta(int(k), v[0], list(v[1]))
                   for k, v in doc["dirs"].items()}
        fs._next_file_id = doc["next_file_id"]
        fs._next_dir_id = doc["next_dir_id"]
        return fs

    # -- control plane --------------------------------------------------------------
    def create_dir(self, name: str) -> int:
        with self._lock:
            did = self._next_dir_id
            self._next_dir_id += 1
            self.dirs[did] = DirMeta(did, name)
            return did

    def create_file(self, name: str, dir_id: int = 0) -> int:
        with self._lock:
            if dir_id not in self.dirs:
                raise FSError(wire.E_NOENT, f"no dir {dir_id}")
            fid = self._next_file_id
            self._next_file_id += 1
            self.files[fid] = FileMeta(fid, name, dir_id)
            self.dirs[dir_id].files.append(fid)
            return fid

    def delete_file(self, file_id: int) -> None:
        with self._lock:
            f = self.files.pop(file_id, None)
            if f is None:
                raise FSError(wire.E_NOENT)
            for s in f.segments:
                self.bitmap[s] = False
            self.dirs[f.dir_id].files.remove(file_id)

    def list_dir(self, dir_id: int) -> list[str]:
        d = self.dirs.get(dir_id)
        if d is None:
            raise FSError(wire.E_NOENT)
        return [self.files[f].name for f in d.files if f in self.files]

    def file_size(self, file_id: int) -> int:
        f = self.files.get(file_id)
        if f is None:
            raise FSError(wire.E_NOENT)
        return f.size

    # -- space management --------------------------------------------------------
    def _alloc_segment(self) -> int:
        free = np.flatnonzero(~self.bitmap)
        if len(free) == 0:
            raise FSError(wire.E_NOSPC, "device full")
        s = int(free[0])
        self.bitmap[s] = True
        return s

    def ensure_capacity(self, file_id: int, new_size: int) -> None:
        with self._lock:
            f = self.files.get(file_id)
            if f is None:
                raise FSError(wire.E_NOENT)
            need = -(-new_size // self.segment_size)  # ceil
            while len(f.segments) < need:
                f.segments.append(self._alloc_segment())
            if new_size > f.size:
                f.size = new_size

    def truncate(self, file_id: int, new_size: int) -> None:
        with self._lock:
            f = self.files.get(file_id)
            if f is None:
                raise FSError(wire.E_NOENT)
            keep = -(-new_size // self.segment_size)
            for s in f.segments[keep:]:
                self.bitmap[s] = False
            f.segments = f.segments[:keep]
            f.size = new_size

    # -- address translation (the file mapping) ------------------------------------
    def translate(self, file_id: int, offset: int, size: int) -> list[tuple[int, int]]:
        """(file, offset, size) -> [(device_byte_addr, nbytes), ...] runs."""
        f = self.files.get(file_id)
        if f is None:
            raise FSError(wire.E_NOENT)
        if offset + size > len(f.segments) * self.segment_size:
            raise FSError(wire.E_INVAL, "range beyond allocation")
        runs: list[tuple[int, int]] = []
        seg_sz = self.segment_size
        while size > 0:
            seg_idx = offset // seg_sz
            seg_off = offset % seg_sz
            n = min(size, seg_sz - seg_off)
            phys = f.segments[seg_idx] * seg_sz + seg_off
            if runs and runs[-1][0] + runs[-1][1] == phys:
                runs[-1] = (runs[-1][0], runs[-1][1] + n)  # coalesce
            else:
                runs.append((phys, n))
            offset += n
            size -= n
        return runs

    # -- data plane (async, zero-copy destinations) ---------------------------------
    def submit_read(self, file_id: int, offset: int, size: int,
                    dest: memoryview, on_complete: Callable[[int], None],
                    priority: bool = False) -> None:
        """``priority=True`` rides the device's priority submission queue —
        the offload engine's latency-critical path (§6.2) never queues
        behind host-path write runs."""
        f = self.files.get(file_id)
        if f is None or offset + size > f.size:
            on_complete(wire.E_INVAL if f else wire.E_NOENT)
            return
        seg_sz = self.segment_size
        if size > 0 and offset // seg_sz == (offset + size - 1) // seg_sz:
            # Fast path: the range lives in ONE segment — a single device op,
            # no run list, no multi-completion state, no adapter closure
            # (device status codes coincide with wire error codes: 0 == E_OK,
            # nonzero values are failures either way).
            phys = f.segments[offset // seg_sz] * seg_sz + offset % seg_sz
            self.device.submit_read(phys, size, dest, on_complete,
                                    priority=priority)
            return
        runs = self.translate(file_id, offset, size)
        state = {"left": len(runs), "err": wire.E_OK}

        def done_one(status: int) -> None:
            if status != 0:
                state["err"] = wire.E_IO
            state["left"] -= 1
            if state["left"] == 0:
                on_complete(state["err"])

        pos = 0
        for phys, n in runs:
            self.device.submit_read(phys, n, dest[pos : pos + n], done_one,
                                    priority=priority)
            pos += n

    def submit_read_many(self, reads: list, priority: bool = False) -> None:
        """Burst read submission: array-at-a-time address translation.

        ``reads`` items are ``(file_id, offset, size, dest, on_complete)``.
        The storm shape — many single-segment reads of a few files — is
        translated with one segment-map gather per file and handed to the
        device as ONE burst (one tick stamp / doorbell round).  Anything
        irregular (invalid range, zero size, multi-segment span) falls back
        to ``submit_read``; pending burst items are flushed first so the
        device queue order matches a scalar submission loop exactly —
        completion order, and therefore the modeled clock, are unchanged.
        """
        n = len(reads)
        if n < 4:
            for fid, off, size, dest, cb in reads:
                self.submit_read(fid, off, size, dest, cb, priority=priority)
            return
        seg_sz = self.segment_size
        offs = np.fromiter((r[1] for r in reads), dtype=np.int64, count=n)
        sizes = np.fromiter((r[2] for r in reads), dtype=np.int64, count=n)
        fid0 = reads[0][0]
        one_fid = all(r[0] == fid0 for r in reads)
        if one_fid:
            # Storm shape: every read targets ONE file (the shard's log) —
            # translate the whole burst with a single segment-map gather.
            f = self.files.get(fid0)
            if f is not None and f.segments:
                si = offs // seg_sz
                so = offs - si * seg_sz
                segarr = np.asarray(f.segments, dtype=np.int64)
                good = (sizes > 0) & (offs + sizes <= f.size) \
                    & (so + sizes <= seg_sz)
                si_safe = np.minimum(si, len(segarr) - 1)  # guard gather
                phys = segarr[si_safe] * seg_sz + so
                ok = good
            else:
                phys = np.zeros(n, dtype=np.int64)
                ok = np.zeros(n, dtype=bool)
            if ok.all():
                pl = phys.tolist()
                self.device.submit_read_many(
                    [(pl[i], r[2], r[3], r[4]) for i, r in enumerate(reads)],
                    priority=priority)
                return
        else:
            phys = np.zeros(n, dtype=np.int64)
            ok = np.zeros(n, dtype=bool)
            by_fid: dict[int, list[int]] = {}
            for i, r in enumerate(reads):
                by_fid.setdefault(r[0], []).append(i)
            for fid, idxs in by_fid.items():
                f = self.files.get(fid)
                if f is None or not f.segments:
                    continue
                ii = np.asarray(idxs, dtype=np.int64)
                o = offs[ii]
                s = sizes[ii]
                si = o // seg_sz
                so = o - si * seg_sz
                segarr = np.asarray(f.segments, dtype=np.int64)
                good = (s > 0) & (o + s <= f.size) & (so + s <= seg_sz)
                si_safe = np.minimum(si, len(segarr) - 1)  # guard gather
                phys[ii] = segarr[si_safe] * seg_sz + so
                ok[ii] = good
        dev = self.device
        pending: list[tuple[int, int, memoryview, Callable[[int], None]]] = []
        for i, (fid, off, size, dest, cb) in enumerate(reads):
            if ok[i]:
                pending.append((int(phys[i]), size, dest, cb))
            else:
                if pending:   # keep device queue order identical to scalar
                    dev.submit_read_many(pending, priority=priority)
                    pending = []
                self.submit_read(fid, off, size, dest, cb, priority=priority)
        if pending:
            dev.submit_read_many(pending, priority=priority)

    def submit_write(self, file_id: int, offset: int, data,
                     on_complete: Callable[[int], None]) -> None:
        try:
            self.ensure_capacity(file_id, offset + len(data))
            runs = self.translate(file_id, offset, len(data))
        except FSError as e:
            on_complete(e.errno)
            return
        state = {"left": len(runs), "err": wire.E_OK}

        def done_one(status: int) -> None:
            if status != 0:
                state["err"] = wire.E_IO
            state["left"] -= 1
            if state["left"] == 0:
                on_complete(state["err"])

        pos = 0
        mv = memoryview(data)
        for phys, n in runs:
            self.device.submit_write(phys, mv[pos : pos + n], done_one)
            pos += n

    # -- cookie-based data plane (closure-free burst execution) ----------------------
    #
    # The runner's burst pipeline uses these instead of the callback forms:
    # completion arrives through the device's completion queue
    # (``device.reap()``) tagged with ``cookie``.  The device completes ops
    # of one queue IN ORDER, so a multi-run operation rides its cookie on
    # the LAST run only — when it pops out of the completion queue every
    # earlier run has already executed.  Returns ``wire.E_OK`` when
    # submitted (a completion WILL arrive) or an errno when rejected
    # synchronously (no completion follows).

    def submit_read_c(self, file_id: int, offset: int, size: int,
                      dest: memoryview, cookie: int) -> int:
        f = self.files.get(file_id)
        if f is None:
            return wire.E_NOENT
        if offset + size > f.size:
            return wire.E_INVAL
        seg_sz = self.segment_size
        if size > 0 and offset // seg_sz == (offset + size - 1) // seg_sz:
            phys = f.segments[offset // seg_sz] * seg_sz + offset % seg_sz
            self.device.submit_read(phys, size, dest, cookie=cookie)
            return wire.E_OK
        if size == 0:
            self.device.push_completion(cookie)
            return wire.E_OK
        runs = self.translate(file_id, offset, size)
        pos = 0
        last = len(runs) - 1
        for i, (phys, n) in enumerate(runs):
            op = self.device.submit_read(phys, n, dest[pos : pos + n],
                                         cookie=cookie if i == last else None)
            if op.status != STATUS_PENDING and i != last:
                # A non-final run rejected synchronously would otherwise be
                # invisible (its cookie-less rejection notifies no one and
                # the final run would complete the op E_OK): fail the whole
                # op on the cookie and submit nothing further.
                self.device.push_completion(cookie, op.status)
                return wire.E_OK
            pos += n
        return wire.E_OK

    def submit_writev(self, file_id: int, offset: int, bufs: list,
                      cookie: int) -> int:
        """Gathered write: ``bufs`` land back to back at ``offset``.

        One capacity check + one translate for the WHOLE run, then one
        scatter-gather device submission per physical (segment-aligned)
        run — a burst of k coalesced request payloads costs O(runs) device
        ops instead of O(k).  Buffer views are never joined: each run's
        slice list streams straight into the device (zero-copy).
        """
        total = 0
        for b in bufs:
            total += len(b)
        try:
            self.ensure_capacity(file_id, offset + total)
            runs = self.translate(file_id, offset, total)
        except FSError as e:
            return e.errno
        if not runs:
            self.device.push_completion(cookie)
            return wire.E_OK
        if self.journal_segments:
            # Crash-consistent apply: journal the WHOLE run (record with
            # commit=0), flip the commit word with one ordered single-slot
            # write, THEN land the bytes in place.  The device completes
            # its normal queue strictly in order, so a crash at any point
            # leaves the file either fully pre-run (commit never landed —
            # recovery ignores the record and the in-place writev never
            # executed) or fully post-run (committed — recovery replays it
            # idempotently over whatever prefix landed in place).
            self._journal_append(file_id, offset, total, bufs, cookie)
        bi = 0       # current buffer index / position for the run walk
        bpos = 0
        last = len(runs) - 1
        for ri, (phys, n) in enumerate(runs):
            chunks = []
            need = n
            while need > 0:
                b = bufs[bi]
                avail = len(b) - bpos
                if bpos == 0 and avail <= need:
                    chunks.append(b)          # whole buffer: no slicing at all
                    need -= avail
                    bi += 1
                    continue
                mv = b if isinstance(b, memoryview) else memoryview(b)
                take = avail if avail <= need else need
                chunks.append(mv[bpos : bpos + take])
                need -= take
                if take == avail:
                    bi += 1
                    bpos = 0
                else:
                    bpos += take
            op = self.device.submit_writev(phys, chunks,
                                           cookie=cookie if ri == last else None)
            if op.status != STATUS_PENDING and ri != last:
                # Same shared-fate rule as submit_read_c: a rejected
                # non-final run fails the whole op on the cookie.
                self.device.push_completion(cookie, op.status)
                return wire.E_OK
        return wire.E_OK

    # -- redo journal ---------------------------------------------------------------
    def _journal_append(self, file_id: int, offset: int, total: int,
                        bufs: list, cookie: int) -> None:
        f = self.files[file_id]
        seg_blob = np.asarray(f.segments, dtype=np.uint32).tobytes()
        rec_len = _JREC.size + len(seg_blob) + total + len(_JTERM)
        if rec_len > self._journal_len:
            raise FSError(wire.E_NOSPC, "write run exceeds journal capacity")
        head, tail = self._journal_head, self._journal_tail
        wrapped = head + rec_len > self._journal_len
        pos = 0 if wrapped else head
        if self._journal_pending:
            # Unapplied region is [tail, head) (circularly).  The append
            # must not clobber it: if it would, force every outstanding
            # in-place write to media first — after a drain the whole
            # region is reclaimable.
            if tail > head:          # occupied wraps around the region end
                conflict = wrapped or head + rec_len > tail
            else:                    # occupied is the linear [tail, head)
                conflict = wrapped and rec_len > tail
            if conflict:
                self.device.drain()
                self._journal_pending.clear()
        if not self._journal_pending:
            self._journal_tail = pos
        # Body checksum: one vectorized pass over the logical record body
        # (mapping + payload), exactly what recovery reads back contiguously.
        crc = checksum64(seg_blob + b"".join(bufs))
        hdr = _JREC.pack(JOURNAL_MAGIC, 0, self._journal_seq, file_id,
                         offset, total, f.size, len(f.segments), crc)
        lba = self._journal_start + pos
        self.device.submit_writev(lba, [hdr + seg_blob, *bufs, _JTERM])
        self.device.submit_write(lba + _JCOMMIT_OFF, _JCOMMIT_ONE)
        self._journal_seq += 1
        self._journal_head = pos + rec_len
        self._journal_pending[cookie] = (pos, pos + rec_len)

    def journal_reaped(self, cookie: int) -> None:
        """The run under ``cookie`` finished its in-place writev: its
        journal record is reclaimable (the runner calls this from its bulk
        completion reap)."""
        pend = self._journal_pending
        if not pend or pend.pop(cookie, None) is None:
            return
        self._journal_tail = (next(iter(pend.values()))[0] if pend
                              else self._journal_head)

    def recover_journal(self) -> dict:
        """Replay committed journal records after a crash (idempotent).

        Scans from the region start: records of the latest pass sit there
        back to back with strictly increasing ``seq``; the scan stops at
        the first bad magic (the zero terminator), non-increasing seq
        (stale tail of an earlier wrap) or uncommitted record (its in-place
        writev — and everything after it — never executed, and the record
        itself may be torn).  Each committed record carries its own file
        mapping + size, so replay needs no trust in the possibly-stale
        metadata segment.  Returns ``{"records": n, "bytes": b}``.
        """
        out = {"records": 0, "bytes": 0}
        if not self.journal_segments:
            return out
        dev = self.device
        base = self._journal_start
        pos = 0
        prev_seq = 0
        while pos + _JREC.size <= self._journal_len:
            (magic, commit, seq, fid, off, nbytes, new_size, nsegs,
             crc) = _JREC.unpack(dev.raw_read(base + pos, _JREC.size))
            rec_len = _JREC.size + nsegs * 4 + nbytes + len(_JTERM)
            if (magic != JOURNAL_MAGIC or seq <= prev_seq or not commit
                    or pos + rec_len > self._journal_len):
                break
            seg_raw = dev.raw_read(base + pos + _JREC.size, nsegs * 4)
            payload = dev.raw_read(base + pos + _JREC.size + nsegs * 4, nbytes)
            if checksum64(seg_raw + payload) != crc:
                # Committed but corrupt: replaying it would write garbage
                # over good data, and every later record is suspect too —
                # stop the scan and surface the failure.
                self.journal_crc_failures += 1
                break
            segs = np.frombuffer(seg_raw, dtype=np.uint32).tolist()
            self._replay_record(fid, off, nbytes, new_size, segs, payload)
            out["records"] += 1
            out["bytes"] += nbytes
            prev_seq = seq
            pos += rec_len
        self._journal_head = pos
        self._journal_tail = pos
        self._journal_seq = prev_seq + 1
        self._journal_pending.clear()
        if out["records"]:
            self.sync_metadata()
        self.journal_replayed_records += out["records"]
        self.journal_replayed_bytes += out["bytes"]
        return out

    def _replay_record(self, fid: int, off: int, nbytes: int, new_size: int,
                       segs: list, payload: bytes) -> None:
        f = self.files.get(fid)
        if f is None:
            # Created after the last metadata sync: resurrect it from the
            # record (name is lost — only the id routes data-plane ops).
            f = FileMeta(fid, f"recovered-{fid}", 0)
            self.files[fid] = f
            self.dirs[0].files.append(fid)
            self._next_file_id = max(self._next_file_id, fid + 1)
        if len(segs) > len(f.segments):
            f.segments = list(segs)
        for s in f.segments:
            self.bitmap[s] = True
        if new_size > f.size:
            f.size = new_size
        seg_sz = self.segment_size
        pos = 0
        while pos < nbytes:    # address through the record's OWN mapping
            seg_off = (off + pos) % seg_sz
            n = min(nbytes - pos, seg_sz - seg_off)
            phys = segs[(off + pos) // seg_sz] * seg_sz + seg_off
            self.device.raw_write(phys, payload[pos : pos + n])
            pos += n


# ---------------------------------------------------------------------------
# The DPU-side runner for host-issued file operations.
# ---------------------------------------------------------------------------


@dataclass(slots=True)
class _PendingResp:
    """A pre-allocated response slot in the DPU response buffer.

    ``done`` is the in-memory mirror of the §4.3 E_PENDING protocol: a slot
    starts pending and flips when its response header (real error code) is
    written by ``_finish`` — the delivery scan checks the flag instead of
    DMA-reading the status word back out of the response buffer.
    """
    group_id: int
    off: int           # start offset in the group's response buffer (virtual)
    size: int          # full response size (header + payload)
    request_id: int
    pad: bool = False  # wrap-padding slot: space only, never delivered
    done: bool = False
    done_tick: int = 0    # tick the slot completed (age-based delivery)
    # Write bookkeeping: ``wfid >= 0`` marks a write slot — the in-flight-
    # write count for that file is decremented at completion, and (when a
    # cache hook is installed) the §6.1 cache-on-write fires THEN, not at
    # submission: a DPU cache entry must never point at bytes the device
    # has not written yet (the priority read queue would happily overtake
    # the write otherwise).
    wfid: int = -1
    woff: int = 0
    wdata: object = None  # zero-copy view of the write payload (cache hook)


@dataclass
class _GroupState:
    group_id: int
    req_ring: ProgressiveRing
    resp_ring: ResponseRing
    # DPU request buffer: >= host ring size => outstanding requests never overlap.
    req_buf: Region = None  # type: ignore[assignment]
    req_buf_tail: int = 0
    # DPU response buffer with the three tails of §4.3.
    resp_buf: Region = None  # type: ignore[assignment]
    tail_a: int = 0  # allocated
    tail_b: int = 0  # buffered (completed prefix)
    tail_c: int = 0  # delivered to host
    pending: deque = field(default_factory=deque)  # _PendingResp, alloc order
    ready: deque = field(default_factory=deque)    # completed, undelivered
    interrupt: Callable[[], None] | None = None  # "DPU driver interrupt"
    # Held coalesced write run (latency-adaptive batching): adjacent
    # same-file writes accumulate ACROSS ring batches and flush when a
    # read/control op needs the barrier, the run outgrows the cap, the run
    # is older than the tick budget, or the ring goes idle.
    wv_file: int = -1
    wv_off: int = 0
    wv_end: int = 0
    wv_bufs: list = field(default_factory=list)
    wv_slots: list = field(default_factory=list)
    wv_tick: int = 0   # tick the held run was started


@dataclass
class FileServiceStats:
    requests: int = 0
    reads: int = 0
    writes: int = 0
    control_ops: int = 0
    read_bytes: int = 0
    write_bytes: int = 0
    response_batches: int = 0
    responses_delivered: int = 0
    request_copies: int = 0   # nonzero only with zero_copy=False
    response_copies: int = 0
    shed_requests: int = 0    # dropped under un-drained-ring overload
    write_submits: int = 0    # gathered writev submissions issued
    coalesced_writes: int = 0  # write requests that rode an earlier submit
    completion_batches: int = 0  # non-empty device completion reaps


class FileServiceRunner:
    """Executes host file requests on the DPU with zero copies (§4.3)."""

    def __init__(self, fs: SegmentFS, dma: DMAEngine | None = None,
                 resp_buf_size: int = 1 << 22,
                 delivery_batch: int = 1,
                 zero_copy: bool = True,
                 cache_hook: Callable[[int, int, object], None] | None = None,
                 invalidate_hook: Callable[[int, int, int], None] | None = None,
                 clock: TickClock | None = None,
                 coalesce_ticks: int = 2,
                 deliver_ticks: int = 2,
                 coalesce_cap: int = 256,
                 shed_hook: Callable[[int], None] | None = None):
        self.fs = fs
        self.dma = dma or DMAEngine()
        self.resp_buf_size = resp_buf_size
        self.delivery_batch = delivery_batch
        self.zero_copy = zero_copy
        self.cache_hook = cache_hook
        self.invalidate_hook = invalidate_hook
        # Deterministic lifecycle clock: standalone runners own (and tick)
        # their own; a DDSStorageServer/DDSCluster installs the shared one
        # and ticks it once per pump step.
        self.clock = clock if clock is not None else TickClock()
        self._owns_clock = clock is None
        # Latency-adaptive write coalescing: a held run flushes when it is
        # ``coalesce_ticks`` old, when the ring goes idle, when a read or
        # control op needs the device-order barrier, or at ``coalesce_cap``
        # requests — batching never waits on an unbounded "full burst".
        self.coalesce_ticks = coalesce_ticks
        self.deliver_ticks = deliver_ticks
        self.coalesce_cap = coalesce_cap
        # In-flight write counts per file id (held + queued + at device):
        # the offload engine's read/write fence probes this, and it feeds
        # the cache-on-write-at-completion discipline.  Tracking is paid
        # only when someone needs it — a cache hook is installed or the
        # owning server enabled the read/write fence.
        self.write_inflight: dict[int, int] = {}
        self.track_writes = cache_hook is not None
        # Invoked with the request id of a SHED request (the bounded
        # E_NOSPC emergency path gave up) — the owning server surfaces a
        # terminal "shed" status to clients through the lifecycle tracker.
        self.shed_hook = shed_hook
        self.groups: dict[int, _GroupState] = {}
        self.stats = FileServiceStats()
        # Flat in-flight table: completion cookie -> (group, ((slot, req), ...)).
        # Replaces the per-op ``on_done`` lambda closures: the device's
        # completion queue is reaped in bulk and each cookie finishes its
        # whole run of response slots in one grouped pass.
        self._inflight: dict[int, tuple] = {}
        self._cookie = 1
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None
        self._lock = threading.Lock()
        # step() may be entered from the service thread AND from a
        # co-resident producer's ring-full on_retry (host_lib.submit_many):
        # serialize whole steps so the pipeline never runs two consumers.
        self._step_lock = threading.Lock()

    # -- clock adoption (cluster layer) --------------------------------------------
    def adopt_clock(self, clock: TickClock) -> None:
        """Rebind every stamp point to a scheduler-owned shared clock and
        stop self-ticking (the owner ticks once per scheduling step).  The
        rebinding knowledge lives HERE, next to the state it mutates — a
        future clock consumer inside the runner only needs updating in
        this one place."""
        self.clock = clock
        self._owns_clock = False
        for g in self.groups.values():
            g.req_ring.clock = clock

    # -- registration (host lib calls this when a notification group is made) -----
    def register_group(self, group_id: int, req_ring: ProgressiveRing,
                       resp_ring: ResponseRing,
                       interrupt: Callable[[], None] | None = None) -> None:
        g = _GroupState(group_id, req_ring, resp_ring)
        # Lifecycle instrumentation: the request ring records host-publish ->
        # DPU-consume residency ticks against the service's clock.
        req_ring.clock = self.clock
        # Request buffer sized >= the host ring: no outstanding request overlaps.
        g.req_buf = Region(f"dpu:req{group_id}", max(req_ring.capacity, 1 << 12))
        g.resp_buf = Region(f"dpu:resp{group_id}", self.resp_buf_size)
        g.interrupt = interrupt
        with self._lock:
            self.groups[group_id] = g

    # -- cooperative scheduling -----------------------------------------------------
    def step(self) -> int:
        """One iteration: fetch -> submit -> complete -> deliver. Returns work."""
        with self._step_lock:
            if self._owns_clock:
                self.clock.tick()   # standalone runner: step == tick
            work = 0
            with self._lock:
                groups = list(self.groups.values())
            for g in groups:
                work += self._fetch_and_submit(g)
            self.fs.device.poll()
            work += self._reap_completions()
            for g in groups:
                work += self._deliver(g)
            return work

    def run_until_idle(self, max_iters: int = 100_000) -> None:
        idle = 0
        for _ in range(max_iters):
            if self.step() == 0:
                self.fs.device.drain()
                if self.step() == 0:
                    idle += 1
                    if idle >= 2 and not self._any_pending():
                        return
            else:
                idle = 0
        raise TimeoutError("file service did not go idle")

    def _any_pending(self) -> bool:
        return any(g.pending or g.ready for g in self.groups.values())

    def busy(self) -> bool:
        """True while responses are pending or awaiting delivery.

        Scheduler wakeup source: probed on every idle re-arm check, so the
        common busy case (device ops in flight) short-circuits on the flat
        cookie table before paying the per-group pending/ready scan."""
        return bool(self._inflight) or self._any_pending()

    def start(self) -> None:
        self._stop.clear()
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name="dds-file-service")
        self._thread.start()

    def stop(self) -> None:
        self._stop.set()
        if self._thread:
            self._thread.join(timeout=5)
            self._thread = None

    def _loop(self) -> None:
        while not self._stop.is_set():
            if self.step() == 0:
                self._stop.wait(50e-6)

    # -- request path -----------------------------------------------------------------
    def _fetch_and_submit(self, g: _GroupState) -> int:
        """Consume EVERY available batch in one burst (single IncHead
        doorbell), splitting each batch zero-copy and submitting the whole
        decoded run through the coalescing write pipeline.

        A trailing run of adjacent writes is HELD across batches (and
        steps) so consecutive ring batches coalesce into one scatter-gather
        submission — but the hold is latency-bounded: the run flushes as
        soon as the ring goes idle this step, it reaches ``coalesce_ticks``
        of age, or it hits ``coalesce_cap`` requests.  Reads and control
        ops still flush it first (device-order barrier), so read-your-
        writes is preserved exactly as before."""
        batches = g.req_ring.consume_batch(self.dma)
        for batch in batches:
            # Land the batch in the DPU request buffer (the DMA destination).
            # Size >= host ring guarantees in-flight requests never overlap.
            cap = len(g.req_buf.buf)
            pos = g.req_buf_tail % cap
            n = len(batch)
            first = min(n, cap - pos)
            mv = memoryview(batch)
            g.req_buf.write(pos, mv[:first])
            if first < n:
                g.req_buf.write(0, mv[first:])
            g.req_buf_tail += n
            self._submit_burst(g, unframe_batch(batch))
        work = len(batches)
        if g.wv_slots and (
                not batches   # ring idle: nothing to batch against — flush now
                or self.clock.now - g.wv_tick >= self.coalesce_ticks):
            self._flush_held(g)
            work += 1
        return work

    def _submit_burst(self, g: _GroupState, raws: list) -> None:
        """Execute a burst of raw framed requests.

        Headers are unpacked inline (no per-request ``Request`` object on
        the data plane) and write payloads stay zero-copy views of the
        consumed batch.  Adjacent same-file writes (``offset == previous
        end``) coalesce into ONE :meth:`SegmentFS.submit_writev`
        scatter-gather submission — each request still gets its own
        pre-allocated response slot (acks stay per-request and ordered),
        but a run of k appends costs one capacity check, one translate and
        O(segment runs) device ops instead of k.  The trailing run is HELD
        on the group (``_fetch_and_submit`` flushes it on idle/age/cap) so
        adjacent writes from consecutive batches merge too.  A read or
        control op flushes the pending run first, so device submission
        order — and therefore read-your-writes within and across bursts —
        is preserved.  Cache-on-write (§6.1) fires at write COMPLETION (see
        ``_finish``), never here: a cache entry must not point at
        un-written bytes while offloaded reads can overtake writes via the
        device's priority queue.
        """
        stats = self.stats
        stats.requests += len(raws)
        zero_copy = self.zero_copy
        cache_hook = self.cache_hook
        invalidate_hook = self.invalidate_hook
        unpack = wire.REQ_HDR.unpack_from
        hdr_size = wire.REQ_HDR.size
        resp_hdr_size = wire.RESP_HDR.size
        wif = self.write_inflight
        track = self.track_writes
        for raw in raws:
            op, rid, fid, off, nbytes = unpack(raw, 0)
            if op == wire.OP_WRITE:
                slot = self._alloc_slot(g, rid, resp_hdr_size)
                if slot is None:
                    continue  # E_NOSPC backpressure, completed inline
                data = raw[hdr_size : hdr_size + nbytes]
                stats.writes += 1
                stats.write_bytes += nbytes
                if not zero_copy:
                    data = bytes(data)  # defensive copy zero-copy mode avoids
                    stats.request_copies += 1
                if track:
                    slot.wfid = fid
                    slot.woff = off
                    if cache_hook is not None:
                        slot.wdata = data  # cache-on-write, hooked at completion
                    wif[fid] = wif.get(fid, 0) + 1
                if g.wv_slots and fid == g.wv_file and off == g.wv_end:
                    g.wv_bufs.append(data)
                    g.wv_slots.append(slot)
                    g.wv_end += nbytes
                else:
                    if g.wv_slots:
                        self._flush_held(g)
                    g.wv_file, g.wv_off = fid, off
                    g.wv_end = off + nbytes
                    g.wv_bufs = [data]
                    g.wv_slots = [slot]
                    g.wv_tick = self.clock.now
                if len(g.wv_slots) >= self.coalesce_cap:
                    self._flush_held(g)
                continue
            # Reads/control ops must hit the device AFTER writes queued
            # before them in the burst: flush the pending run first.
            if g.wv_slots:
                self._flush_held(g)
            if op == wire.OP_READ:
                slot = self._alloc_slot(g, rid, resp_hdr_size + nbytes)
                if slot is None:
                    continue
                stats.reads += 1
                stats.read_bytes += nbytes
                if not zero_copy:
                    # Straw-man: read into scratch, copy to the response later.
                    scratch = bytearray(nbytes)

                    def on_done(err: int, g=g, slot=slot, nbytes=nbytes,
                                scratch=scratch):
                        if err == wire.E_OK:
                            view = self._resp_payload_view(g, slot.off, nbytes)
                            view[:] = scratch  # the copy zero-copy removes
                            self.stats.response_copies += 1
                        self._finish(g, slot, err)

                    self.fs.submit_read(fid, off, nbytes,
                                        memoryview(scratch), on_done)
                else:
                    dest = self._resp_payload_view(g, slot.off, nbytes)
                    ck = self._cookie
                    self._cookie = ck + 1
                    err = self.fs.submit_read_c(fid, off, nbytes, dest, ck)
                    if err != wire.E_OK:
                        self._finish(g, slot, err)
                    else:
                        self._inflight[ck] = (g, (slot,))
                if invalidate_hook:
                    invalidate_hook(fid, off, nbytes)  # invalidate-on-read
            else:
                req = wire.Request(op, rid, fid, off, nbytes,
                                   raw[hdr_size:])
                slot = self._alloc_slot(g, rid, wire.response_size_for(req))
                if slot is not None:
                    self._control_op(g, slot, req)
        # The trailing write run stays HELD on the group — the next batch
        # may extend it; ``_fetch_and_submit`` bounds the hold by idle/age.

    def _flush_held(self, g: _GroupState) -> None:
        """Submit the group's held coalesced write run (one cookie)."""
        bufs, slots = g.wv_bufs, g.wv_slots
        file_id, offset = g.wv_file, g.wv_off
        g.wv_bufs, g.wv_slots = [], []
        g.wv_file = -1
        self._flush_writev(g, file_id, offset, bufs, slots)

    def _alloc_slot(self, g: _GroupState, rid: int,
                    resp_size: int) -> _PendingResp | None:
        """Advance TailA over a pre-allocated response slot (§4.3).

        Returns None when the response-buffer ring is out of space — the
        request was answered inline with E_NOSPC (backpressure path)."""
        cap = len(g.resp_buf.buf)
        # Keep each response contiguous: pad TailA to the wrap boundary when
        # the slot would cross it (pad slots occupy space, deliver nothing).
        pos = g.tail_a % cap
        if pos + resp_size > cap:
            pad = cap - pos
            if g.tail_a + pad - g.tail_c > cap:
                self._complete_inline(g, rid, wire.E_NOSPC, b"")
                return None
            g.pending.append(_PendingResp(g.group_id, g.tail_a, pad,
                                          0, pad=True, done=True))
            g.tail_a += pad
        # Backpressure: the response buffer is a ring in virtual offsets.
        if g.tail_a + resp_size - g.tail_c > cap:
            self._complete_inline(g, rid, wire.E_NOSPC, b"")
            return None
        off = g.tail_a
        g.tail_a += resp_size  # pre-allocate response space (advance TailA)
        slot = _PendingResp(g.group_id, off, resp_size, rid)
        g.pending.append(slot)
        return slot

    def _flush_writev(self, g: _GroupState, file_id: int, offset: int,
                      bufs: list, slots: list) -> None:
        """Submit a coalesced write run under ONE completion cookie."""
        ck = self._cookie
        self._cookie = ck + 1
        err = self.fs.submit_writev(file_id, offset, bufs, ck)
        if err != wire.E_OK:
            # Rejected synchronously (no completion follows): the whole run
            # shares the verdict — coalesced appends have a shared fate.
            for slot in slots:
                self._finish(g, slot, err)
            return
        self._inflight[ck] = (g, tuple(slots))
        self.stats.write_submits += 1
        self.stats.coalesced_writes += len(slots) - 1

    def _reap_completions(self) -> int:
        """Batch-poll device completions into grouped ``_finish`` runs."""
        done = self.fs.device.reap()
        if not done:
            return 0
        inflight = self._inflight
        finish = self._finish
        journaled = self.fs.journal_segments
        for cookie, status in done:
            g, slots = inflight.pop(cookie)
            if journaled:
                self.fs.journal_reaped(cookie)   # run landed in place
            err = (wire.E_OK if status == 0 else
                   wire.E_INVAL if status == wire.E_INVAL else wire.E_IO)
            for slot in slots:
                finish(g, slot, err)
        self.stats.completion_batches += 1
        return len(done)

    def _control_op(self, g: _GroupState, slot: _PendingResp,
                    req: wire.Request) -> None:
        self.stats.control_ops += 1
        err, payload = wire.E_OK, b""
        try:
            if req.op == wire.OP_CREATE_FILE:
                fid = self.fs.create_file(bytes(req.payload).decode(),
                                          req.file_id)
                payload = fid.to_bytes(4, "little")
            elif req.op == wire.OP_CREATE_DIR:
                did = self.fs.create_dir(bytes(req.payload).decode())
                payload = did.to_bytes(4, "little")
            elif req.op == wire.OP_DELETE_FILE:
                self.fs.delete_file(req.file_id)
            elif req.op == wire.OP_TRUNCATE:
                self.fs.truncate(req.file_id, req.offset)
            elif req.op == wire.OP_FSYNC:
                self.fs.sync_metadata()
            elif req.op == wire.OP_LIST_DIR:
                names = json.dumps(self.fs.list_dir(req.file_id)).encode()[:4096]
                payload = names.ljust(4096, b"\x00")
            else:
                err = wire.E_INVAL
        except FSError as e:
            err = e.errno
        expect = slot.size - wire.RESP_HDR.size
        payload = payload.ljust(expect, b"\x00")
        view = self._resp_payload_view(g, slot.off, expect)
        view[:] = payload
        self._finish(g, slot, err)

    def _complete_inline(self, g: _GroupState, rid: int, err: int,
                         payload: bytes, spin: int = 100_000) -> None:
        """Emergency completion bypassing pre-allocation (backpressure path).

        Bounded: if the host never drains its response ring, the request is
        SHED (load shedding, counted) rather than deadlocking the service
        thread — the host library surfaces the gap as a timeout."""
        resp = wire.Response(rid, err, len(payload), payload).encode()
        for _ in range(spin):
            if g.resp_ring.produce(self.dma, frame(resp)):
                if g.interrupt:
                    g.interrupt()
                return
        self.stats.shed_requests += 1
        if self.shed_hook is not None:
            # Surface the terminal state: no response will ever arrive for
            # this request id — the server marks it shed in its lifecycle
            # tracker so clients stop waiting instead of timing out.
            self.shed_hook(rid)

    # -- response-buffer helpers -------------------------------------------------------
    def _resp_view(self, g: _GroupState, voff: int, n: int) -> memoryview:
        cap = len(g.resp_buf.buf)
        pos = voff % cap
        assert pos + n <= cap, "response crosses buffer wrap (sized to avoid)"
        return g.resp_buf._mv[pos : pos + n]

    def _resp_payload_view(self, g: _GroupState, off: int, n: int) -> memoryview:
        return self._resp_view(g, off + wire.RESP_HDR.size, n)

    def _write_resp_header(self, g: _GroupState, off: int, rid: int, err: int,
                           nbytes: int) -> None:
        hdr = wire.RESP_HDR.pack(rid, err, nbytes)
        self._resp_view(g, off, wire.RESP_HDR.size)[:] = hdr

    def _finish(self, g: _GroupState, slot: _PendingResp, err: int) -> None:
        """I/O completion: write the final response header and flip the
        slot's pending flag (the in-memory E_PENDING -> status transition
        of §4.3) so the delivery scan picks it up in order.

        Write slots additionally release their in-flight-write count and —
        only now, with the bytes durably on the device — fire the §6.1
        cache-on-write hook, so the DPU cache can never map a key to data
        a priority-queue read could observe before it exists."""
        self._write_resp_header(g, slot.off, slot.request_id, err,
                                slot.size - wire.RESP_HDR.size)
        slot.done = True
        slot.done_tick = self.clock.now
        fid = slot.wfid
        if fid >= 0:
            slot.wfid = -1
            wif = self.write_inflight
            c = wif.get(fid, 0) - 1
            if c > 0:
                wif[fid] = c
            else:
                wif.pop(fid, None)
            data = slot.wdata
            if data is not None:
                slot.wdata = None
                if err == wire.E_OK:
                    self.cache_hook(fid, slot.woff, data)

    # -- delivery (TailB/TailC discipline) ------------------------------------------
    def _deliver(self, g: _GroupState) -> int:
        # Advance TailB over the contiguous completed prefix (ordered
        # execution); completed slots queue for delivery in order.
        pending = g.pending
        while pending:
            slot = pending[0]
            if not slot.done:
                break
            pending.popleft()
            g.tail_b = slot.off + slot.size
            if not slot.pad:
                g.ready.append(slot)
        if not g.ready:
            return 0
        if (g.tail_b - g.tail_c < self.delivery_batch
                and self.clock.now - g.ready[0].done_tick < self.deliver_ticks):
            # Latency-adaptive delivery: batch responses for DMA efficiency
            # (``delivery_batch`` > 1), but never hold a completed response
            # past ``deliver_ticks`` — the age of the OLDEST ready slot
            # bounds the wait, so a trickle of responses still flushes.
            return 0
        # ONE gathered DMA write + ONE doorbell deliver as many ready
        # responses as the host ring accepts: frame headers interleave with
        # memoryviews of the response buffer, so response bytes move exactly
        # once (DPU response buffer -> host ring).  TailC advances to the
        # end of the delivered prefix.
        space = g.resp_ring.free_space(self.dma)
        hdr_n = FRAME_HDR.size
        used = 0
        take = 0
        last = None
        sizes: list = []
        for slot in g.ready:
            need = used + hdr_n + slot.size
            if need > space:
                break
            sizes.append(slot.size)
            used = need
            take += 1
            last = slot
        if not take:
            return 0  # host ring full; retry next step
        # Batch header-fill: every frame-length word of the burst lands in
        # ONE preallocated header arena with a single array store; the
        # parts list interleaves arena views with response-buffer views, so
        # the publish stays one gathered DMA write (and response bytes
        # still move exactly once).
        arena = bytearray(take * hdr_n)
        np.frombuffer(arena, dtype="<u4")[:] = sizes
        amv = memoryview(arena)
        parts: list = []
        i = 0
        for slot in g.ready:
            if i >= take:
                break
            parts.append(amv[i * hdr_n:(i + 1) * hdr_n])
            parts.append(self._resp_view(g, slot.off, slot.size))
            i += 1
        if not g.resp_ring.publish_batch(self.dma, parts, used):
            return 0
        g.tail_c = last.off + last.size
        for _ in range(take):
            g.ready.popleft()
        self.stats.response_batches += 1
        self.stats.responses_delivered += take
        if g.interrupt:
            g.interrupt()
        return 1
