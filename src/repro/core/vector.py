"""Array-at-a-time kernels for the data plane (the vectorization PR).

Every hot structure grew a burst API over PRs 2-6 (``lookup_many``,
``consume_batch``, ``write_gather``, ``pop_many``) but still executed a
pure-Python per-item inner loop underneath — the per-request software
stall that a real DPU pipeline eliminates.  This module holds the shared
numpy kernels those burst APIs now call:

  * :func:`mix64` / :func:`hash_keys` — vectorized splitmix64 finalizer,
    bit-identical to ``cache_table._mix`` so scalar and batched probes
    agree on fingerprints and bucket choices.
  * :func:`uniform_stride` — detect that a framed byte stream is one
    fixed-stride run and prove it equals the sequential decode (see the
    function docstring for the argument), unlocking columnar header
    decode with zero per-frame Python work.
  * :func:`pack_frames` — batch header-fill for the encode side: one
    preallocated output buffer, length words scattered array-at-a-time.
  * :func:`checksum64` / :func:`block_checksums` — the position-salted
    integrity checksum for the writev path (computed batch-at-a-time over
    coalesced runs, verified on read and on journal replay).

Kernels operate on contiguous numpy backing stores and are
jnp-compatible by construction (gather / compare / reduce / cumsum only,
no data-dependent Python control flow inside a burst).
"""

from __future__ import annotations

import struct

import numpy as np

MASK64 = 0xFFFFFFFFFFFFFFFF
_M1 = 0xBF58476D1CE4E5B9
_M2 = 0x94D049BB133111EB

# Position salt for checksums: odd constant (golden-ratio conjugate) so
# ``i * GOLD`` is a bijection mod 2^64 — swapping two words of a payload
# changes the checksum even though the fold is a commutative XOR.
GOLD = 0x9E3779B97F4A7C15
# Final length-fold seed: distinguishes e.g. b"\x00" * 8 from b"\x00" * 16.
LEN_SEED = 0xD6E8FEB86659FD93

_U64 = np.uint64


def mix64(x: np.ndarray, seed: int = 0) -> np.ndarray:
    """Vectorized splitmix64 finalizer over a uint64 array.

    Bit-identical to ``cache_table._mix`` (the scalar hot-path version):
    property tests assert equality element-wise.
    """
    with np.errstate(over="ignore"):
        x = x.astype(np.uint64, copy=True)
        if seed:
            x ^= _U64(seed)
        x ^= x >> _U64(30)
        x *= _U64(_M1)
        x ^= x >> _U64(27)
        x *= _U64(_M2)
        x ^= x >> _U64(31)
    return x


def scalar_mix(x: int, seed: int = 0) -> int:
    """Pure-int splitmix64 (reference / tail path; mirrors cache_table)."""
    x ^= seed
    x ^= x >> 30
    x = (x * _M1) & MASK64
    x ^= x >> 27
    x = (x * _M2) & MASK64
    x ^= x >> 31
    return x


def hash_keys(keys: list) -> np.ndarray:
    """Burst equivalent of ``CacheTable._hash_key``: one uint64 per key.

    The pre-mix value (``key & MASK64`` for ints, ``hash(key) & MASK64``
    otherwise) is gathered per item — Python ``hash`` has no array form —
    but the avalanche mix runs once over the whole burst.
    """
    n = len(keys)
    try:
        # Hash values fit int64; the uint64 reinterpret IS the & MASK64
        # (two's complement), skipping a Python big-int mask per key.
        raw = np.fromiter(
            (k if isinstance(k, int) else hash(k) for k in keys),
            dtype=np.int64, count=n).view(np.uint64)
    except OverflowError:   # int key outside int64 — rare, mask per item
        raw = np.fromiter(
            ((k if isinstance(k, int) else hash(k)) & MASK64 for k in keys),
            dtype=np.uint64, count=n)
    return mix64(raw)


# ---------------------------------------------------------------------------
# Wire framing
# ---------------------------------------------------------------------------

def uniform_stride(buf, hdr: int, len_off: int = 0,
                   min_frames: int = 2) -> tuple[int, int, int] | None:
    """Detect a fixed-stride framed prefix of ``buf``; ``None`` if irregular.

    A frame is ``hdr`` header bytes (little-endian u32 payload length at
    ``len_off``) followed by the payload.  Returns ``(count, stride,
    payload_len)`` covering the maximal whole-frame prefix, or ``None``
    when the stream is not provably uniform — or shorter than
    ``min_frames`` frames, below which the caller's scalar walk is
    cheaper than the proof (the hot call sites pass the crossover
    measured by ``benchmarks/micro/kernels_ab.py``, ~20 frames; the
    default 2 keeps the proof itself exercisable at any size).

    Equivalence argument (why this is safe for ARBITRARY input, not just
    benchmark traffic): the sequential decoder is a greedy walk — read the
    length word at the current offset, step ``hdr + len``.  We read the
    FIRST length word, hypothesize that every frame has that length, and
    then verify the hypothesis by comparing the length-word bytes at every
    stride multiple.  If they all match, the greedy walk would have read
    exactly these words and taken exactly these steps, so the columnar
    decode is byte-identical to the scalar one.  Any mismatch (including
    payload bytes that merely sit where a header would be in a
    DIFFERENTLY-framed stream) fails the comparison and falls back to the
    scalar walk.  Bytes past ``count * stride`` are the caller's remainder
    (a trailing partial frame, or frames of a different size).
    """
    total = len(buf)
    if total <= hdr:
        return None
    first = int.from_bytes(buf[len_off:len_off + 4], "little")
    stride = hdr + first
    n = total // stride
    if n < max(min_frames, 2):
        # Too few frames: scalar decode is cheaper than proving
        # uniformity, and the proof needs a second length word anyway.
        return None
    a = np.frombuffer(buf, dtype=np.uint8, count=n * stride)
    words = a.reshape(n, stride)[:, len_off:len_off + 4]
    if not (words == words[0]).all():
        return None
    return n, stride, first


def pack_frames(msgs: list, hdr: int = 4) -> bytearray:
    """Batch frame-pack: ``[u32 len][payload]`` per message, one buffer.

    The scalar path packs one 4-byte header object per message and joins
    2n fragments; here the length column is scattered into a single
    preallocated buffer array-at-a-time and only payload memcpys remain.
    Returns a ``bytearray`` (equal to the ``bytes`` the scalar join
    produces; callers hand it to buffer-protocol consumers).
    """
    n = len(msgs)
    if not n:
        return bytearray()
    lens = np.fromiter((len(m) for m in msgs), dtype=np.int64, count=n)
    ln0 = int(lens[0])
    if n >= 8 and ln0 and (lens == ln0).all():
        # Uniform frames: ONE payload join + one strided scatter replaces
        # the per-message memcpy loop entirely.
        stride = hdr + ln0
        out = bytearray(n * stride)
        a = np.frombuffer(out, dtype=np.uint8).reshape(n, stride)
        hb = ln0.to_bytes(4, "little") + b"\x00" * (hdr - 4)
        a[:, :hdr] = np.frombuffer(hb, dtype=np.uint8)
        a[:, hdr:] = np.frombuffer(b"".join(
            m if isinstance(m, (bytes, bytearray, memoryview)) else bytes(m)
            for m in msgs), dtype=np.uint8).reshape(n, ln0)
        return out
    starts = np.empty(n, dtype=np.int64)
    starts[0] = 0
    np.cumsum(lens[:-1] + hdr, out=starts[1:])
    out = bytearray(int(starts[-1] + hdr + lens[-1]))
    a = np.frombuffer(out, dtype=np.uint8)
    for b in range(4):
        a[starts + b] = (lens >> (8 * b)) & 0xFF
    mv = memoryview(out)
    for i, m in enumerate(msgs):
        s = int(starts[i]) + hdr
        mv[s:s + len(m)] = m if isinstance(m, (bytes, bytearray, memoryview)) \
            else bytes(m)
    return out


# ---------------------------------------------------------------------------
# Integrity checksums (batch CRC for the writev path)
# ---------------------------------------------------------------------------

def checksum64(data) -> int:
    """Position-salted 64-bit integrity checksum, one vectorized pass.

    Layout: the payload is read as little-endian u64 words; word ``i`` is
    salted with ``i * GOLD`` (bijective, so transpositions change the
    sum), avalanche-mixed, and XOR-folded.  A sub-8-byte tail is
    zero-padded into one final word, and the total length is folded in
    under ``LEN_SEED`` so runs of zeros of different lengths differ.
    Detects bit flips, transposed words, truncation and extension — the
    CRC32C role on the writev path, in one numpy pass per coalesced run.
    """
    mv = memoryview(data)
    if mv.ndim != 1 or mv.itemsize != 1:
        mv = mv.cast("B")
    n = len(mv)
    nwords = n >> 3
    acc = 0
    if nwords:
        words = np.frombuffer(mv, dtype="<u8", count=nwords)
        with np.errstate(over="ignore"):
            salted = words ^ (np.arange(nwords, dtype=np.uint64) * _U64(GOLD))
        acc = int(np.bitwise_xor.reduce(mix64(salted)))
    tail = n & 7
    if tail:
        last = int.from_bytes(bytes(mv[n - tail:]), "little")
        acc ^= scalar_mix(last ^ ((nwords * GOLD) & MASK64))
    return scalar_mix(acc ^ n, LEN_SEED)


def checksum64_scalar(data) -> int:
    """Pure-Python reference for :func:`checksum64` (property tests)."""
    mv = memoryview(data)
    if mv.ndim != 1 or mv.itemsize != 1:
        mv = mv.cast("B")
    n = len(mv)
    acc = 0
    for i in range(n >> 3):
        word = int.from_bytes(bytes(mv[i * 8:i * 8 + 8]), "little")
        acc ^= scalar_mix(word ^ ((i * GOLD) & MASK64))
    tail = n & 7
    if tail:
        last = int.from_bytes(bytes(mv[n - tail:]), "little")
        acc ^= scalar_mix(last ^ (((n >> 3) * GOLD) & MASK64))
    return scalar_mix(acc ^ n, LEN_SEED)


def block_checksums(mem: np.ndarray, lba: int, nblocks: int,
                    block: int) -> np.ndarray:
    """:func:`checksum64` of ``nblocks`` device blocks, batch-at-a-time.

    ``mem`` is the device's uint8 media array; blocks are ``block`` bytes
    (a multiple of 8), so the whole span folds as one (nblocks, words)
    matrix — per-column salts, one mix, one XOR-reduce per row.  Matches
    ``checksum64(mem[i*block:(i+1)*block].tobytes())`` element-wise.
    """
    span = mem[lba * block:(lba + nblocks) * block]
    wpb = block >> 3
    words = np.frombuffer(span, dtype="<u8").reshape(nblocks, wpb)
    with np.errstate(over="ignore"):
        salts = np.arange(wpb, dtype=np.uint64) * _U64(GOLD)
        rows = np.bitwise_xor.reduce(mix64(words ^ salts), axis=1)
    return mix64(rows ^ _U64(block), seed=LEN_SEED)


__all__ = [
    "MASK64", "GOLD", "LEN_SEED",
    "mix64", "scalar_mix", "hash_keys",
    "uniform_stride", "pack_frames",
    "checksum64", "checksum64_scalar", "block_checksums",
]
