"""Deterministic request-lifecycle clock + tick-latency accounting.

The paper's headline claim is *latency* (§8, Figs 14a/15a: offloaded reads
complete in 780 us vs 11 ms on the host path) — but wall-clock latency of a
cooperative simulator measures the Python interpreter, not the system.  This
module provides the deterministic alternative:

``TickClock``
    A logical clock advanced ONCE per scheduling step — ``DDSCluster.pump``
    (every shard of a cluster shares its cluster's clock) or a standalone
    ``DDSStorageServer.pump`` / ``FileServiceRunner.step``.  Nothing reads
    wall time, so two identical runs produce byte-identical latency
    distributions (regression-tested).

``TickHistogram``
    An exact integer histogram (dict of tick-delta -> count) with
    deterministic ``percentile``; no sampling, no binning error.

``LifecycleTracker``
    Per-server request stamping across the whole data plane:

      client issue      (clients stamp their own ``issue`` ticks)
      wire ingress +    the ingress tick rides EXISTING per-request state —
      offload decision  the context-ring slot (a plain int) for offloaded
                        reads, the host app's in-flight meta tuple for
                        host-bound requests — so no stamp allocates
      device submit/    ``BlockDevice`` stamps every op (completion-latency
      complete          histogram in its stats)
      response publish  deltas land in the per-class histogram: offloaded
                        GET (``dpu_read``), host-served read (``host_read``)
                        or ``write``
      response drain    clients record end-to-end issue->drain ticks,
                        classified read/write at issue time (the
                        offloaded-vs-host split for reads lives in the
                        server-side histograms, where it is exact)

    A request shed under overload (the file service's bounded E_NOSPC
    emergency path gave up) gets a terminal ``shed`` mark instead of
    silently vanishing — clients surface it from ``take_shed`` rather than
    spinning into a timeout heuristic.

Everything here is deliberately allocation-light (int ticks, plain dicts)
because the stamps ride the hot path; a component whose ``lifecycle`` is
``None`` pays a single attribute test.
"""

from __future__ import annotations

from . import wire


class TickClock:
    """Monotonic logical clock; one tick per scheduling step."""

    __slots__ = ("now",)

    def __init__(self) -> None:
        self.now = 0

    def tick(self) -> int:
        self.now += 1
        return self.now


class TickHistogram:
    """Exact integer-delta histogram with deterministic percentiles.

    Deliberately nothing but the counts dict: an ``add`` is two dict ops
    (the stamp rides every completion on the data plane); sample count,
    total and mean are derived on demand.
    """

    __slots__ = ("counts",)

    def __init__(self) -> None:
        self.counts: dict[int, int] = {}

    def add(self, delta: int) -> None:
        c = self.counts
        c[delta] = c.get(delta, 0) + 1

    def add_many(self, delta: int, k: int) -> None:
        """Fold ``k`` samples of one delta (run-length burst completions)."""
        c = self.counts
        c[delta] = c.get(delta, 0) + k

    def merge(self, other: "TickHistogram") -> None:
        c = self.counts
        for d, k in other.counts.items():
            c[d] = c.get(d, 0) + k

    @property
    def n(self) -> int:
        return sum(self.counts.values())

    @property
    def total(self) -> int:
        return sum(d * k for d, k in self.counts.items())

    def percentile(self, p: float) -> int:
        """Smallest delta covering ``p`` percent of samples (exact)."""
        n = self.n
        if not n:
            return 0
        need = -(-n * p // 100)  # ceil(n * p / 100), integer math
        cum = 0
        d = 0
        for d in sorted(self.counts):
            cum += self.counts[d]
            if cum >= need:
                return d
        return d

    def mean(self) -> float:
        n = self.n
        return self.total / n if n else 0.0

    def as_dict(self) -> dict[str, int]:
        """JSON-stable exact histogram (sorted keys, str-keyed)."""
        return {str(d): self.counts[d] for d in sorted(self.counts)}

    def summary(self) -> dict:
        n = self.n
        return {
            "count": n,
            "mean": round(self.total / n, 3) if n else 0.0,
            "p50": self.percentile(50),
            "p95": self.percentile(95),
            "p99": self.percentile(99),
            "max": max(self.counts) if self.counts else 0,
        }


# Terminal serving-path classes.
DPU_READ = "dpu_read"
HOST_READ = "host_read"
WRITE = "write"


class LifecycleTracker:
    """Per-server request stamping + per-class completion-tick histograms.

    The tracker itself keeps NO per-request state: ingress ticks ride
    existing per-request structures — the offload engine's context-ring
    slot for DPU reads (a plain int) and the host app's in-flight meta
    tuple for host-bound requests — so completion just computes a delta
    and bumps an exact histogram.  Only terminal SHED marks are stored
    here (there is no other structure left to carry them).
    """

    __slots__ = ("clock", "read_types", "_terminal", "hist", "sheds",
                 "redirects", "tenant_hist", "tenant_sheds")

    def __init__(self, clock: TickClock, read_types=None):
        self.clock = clock
        # Type bytes (msg[0]) that classify a message as a READ — a set
        # membership test instead of a per-message classifier call (the
        # stamp rides the host-path data plane).  The server passes the
        # §8.1 default; the KV app passes {KV_GET}.
        self.read_types = frozenset(read_types or ())
        # (flow, rid) -> (status code, hint bytes).  Terminal marks: no
        # response will ever arrive for these; the client synthesizes the
        # status.  E_SHED = dropped under overload/admission (hint = shed
        # hint); E_REDIRECT = stale ring epoch after a failover (hint =
        # redirect hint; retryable with the same request id).
        self._terminal: dict[tuple, tuple[int, bytes]] = {}
        self.hist: dict[str, TickHistogram] = {
            DPU_READ: TickHistogram(),
            HOST_READ: TickHistogram(),
            WRITE: TickHistogram(),
        }
        self.sheds = 0
        self.redirects = 0
        # Per-tenant split, recorded ONLY for nonzero tenants (tenant 0 is
        # the untenanted default and lives purely in the aggregate above),
        # so single-tenant deployments pay one int test per completion.
        self.tenant_hist: dict[int, dict[str, TickHistogram]] = {}
        self.tenant_sheds: dict[int, int] = {}

    # -- per-tenant completion stamps ---------------------------------------------
    def tenant_hist_for(self, tenant: int, cls: str) -> TickHistogram:
        per = self.tenant_hist.get(tenant)
        if per is None:
            per = self.tenant_hist[tenant] = {}
        h = per.get(cls)
        if h is None:
            h = per[cls] = TickHistogram()
        return h

    def add_tenant(self, tenant: int, cls: str, delta: int) -> None:
        self.tenant_hist_for(tenant, cls).add(delta)

    # -- terminal request status -------------------------------------------------
    def mark_shed(self, flow, rid: int, hint: bytes = b"") -> None:
        """The request was SHED (bounded E_NOSPC overload path gave up, or
        token-bucket admission refused it): no response will ever arrive.
        Clients poll ``take_shed`` instead of timing out.  ``hint`` is the
        retry-after body the client's E_SHED response will carry."""
        self._terminal[(flow, rid)] = (wire.E_SHED, hint)
        self.sheds += 1
        t = getattr(flow, "tenant", 0)
        if t:
            self.tenant_sheds[t] = self.tenant_sheds.get(t, 0) + 1

    def mark_redirect(self, flow, rid: int, hint: bytes = b"") -> None:
        """The request's routing is stale — it carried a pre-failover ring
        epoch, or its target shard died before answering.  ``hint`` is the
        redirect body (current ring epoch); the client retries the same
        request id against the repaired ring."""
        self._terminal[(flow, rid)] = (wire.E_REDIRECT, hint)
        self.redirects += 1

    def take_shed(self, flow, rid: int) -> bytes | None:
        """The shed hint for ``(flow, rid)``, or None if it was not shed.

        Distinguish with ``is not None`` — an empty hint is still a shed.
        Leaves non-shed terminal marks (redirects) in place for
        ``take_terminal`` consumers.
        """
        key = (flow, rid)
        entry = self._terminal.get(key)
        if entry is None or entry[0] != wire.E_SHED:
            return None
        del self._terminal[key]
        return entry[1]

    def take_terminal(self, flow, rid: int) -> tuple[int, bytes] | None:
        """Pop any terminal ``(status, hint)`` for ``(flow, rid)``."""
        return self._terminal.pop((flow, rid), None)

    def has_terminal(self) -> bool:
        """Whether ANY request is terminally marked (cheap probe)."""
        return bool(self._terminal)

    def summary(self) -> dict:
        out = {cls: h.summary() for cls, h in self.hist.items() if h.n}
        if self.sheds:
            out["sheds"] = self.sheds
        if self.redirects:
            out["redirects"] = self.redirects
        tenants = self._tenant_summary()
        if tenants:
            out["tenants"] = tenants
        return out

    def _tenant_summary(self) -> dict:
        out: dict[int, dict] = {}
        for t, per in sorted(self.tenant_hist.items()):
            ent = {cls: h.summary() for cls, h in per.items() if h.n}
            if ent:
                out[t] = ent
        for t, n in sorted(self.tenant_sheds.items()):
            out.setdefault(t, {})["sheds"] = n
        return out

    def histograms(self) -> dict:
        """Exact per-class histograms (determinism tests compare these)."""
        return {cls: h.as_dict() for cls, h in self.hist.items()}


class ClientLatency:
    """Client-side end-to-end (issue tick -> drain tick) per-class stats.

    Deltas are computed by the caller against its own clock reference (so
    clock adoption never needs to rebuild this object); this is just the
    per-class histogram bag."""

    __slots__ = ("hist",)

    def __init__(self) -> None:
        self.hist: dict[str, TickHistogram] = {}

    def hist_for(self, cls: str) -> TickHistogram:
        """The class histogram, created on first use (hoistable: callers on
        a hot drain loop bind ``hist_for(cls).add`` once per burst)."""
        h = self.hist.get(cls)
        if h is None:
            h = self.hist[cls] = TickHistogram()
        return h

    def record(self, cls: str, delta: int) -> None:
        self.hist_for(cls).add(delta)

    def summary(self) -> dict:
        return {cls: h.summary() for cls, h in sorted(self.hist.items())
                if h.n}

    def histograms(self) -> dict:
        return {cls: h.as_dict() for cls, h in sorted(self.hist.items())
                if h.n}
